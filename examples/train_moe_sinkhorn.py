"""End-to-end driver: train a ~100M-param MoE LM with the Sinkhorn-UOT
router for a few hundred steps on CPU, with checkpointing + fault-tolerant
trainer. The paper's technique (MAP-UOT fused iteration) runs INSIDE the
router of every MoE layer.

Run:  PYTHONPATH=src python examples/train_moe_sinkhorn.py [--steps 300]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--router", default="sinkhorn",
                    choices=["sinkhorn", "topk"])
    ap.add_argument("--ckpt", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    # ~100M-param olmoe-family config (same block structure, reduced dims)
    cfg = dataclasses.replace(
        get_arch("olmoe-1b-7b"),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=512, num_experts=8, top_k=2, vocab_size=2048,
        router=args.router, capacity_factor=2.0, loss_chunks=2,
        gla_chunk=32)
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: olmoe-family, {n / 1e6:.1f}M params, router={cfg.router}")

    pipe = SyntheticTokenPipeline(cfg, seq_len=128, global_batch=8)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                         ckpt_dir=args.ckpt, warmup=20, log_every=20)
    trainer = Trainer(model, pipe, OptConfig(lr=3e-4), tcfg)
    state = trainer.run(jax.random.PRNGKey(0))

    log = trainer.metrics_log
    print(f"\nstep  loss    aux     lr_scale  sec")
    for rec in log:
        print(f"{rec['step']:4d}  {rec['loss']:.4f}  {rec['aux']:.4f}  "
          f"{rec['lr_scale']:.3f}     {rec['sec']:.2f}")
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'did not decrease'}) "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()
