"""Cluster serving demo: one Poisson trace, 1 device vs an 8-device mesh.

Forces 8 XLA host devices (the flag must be set before jax imports), builds
the fourth serving tier (``repro.cluster.ClusterScheduler``) over a real
mesh, and replays the same trace through:

  1. the single-device ``UOTScheduler`` (tier 3);
  2. the 8-device ``ClusterScheduler`` (tier 4) — every device's lane pool
     advanced in ONE shard_map launch per chunk, requests placed
     least-loaded, one over-sized request escaping to the row-sharded gang,
     and one point-cloud request shipping O(M+N) coordinates instead of an
     M*N matrix.

Device time is simulated with the measured chunk service time (see
benchmarks/bench_cluster.py for why wall-clocking 8 forced host devices on
one CPU would serialize exactly what the mesh parallelizes); throughput,
p99, and per-device occupancy come from the schedulers' own telemetry.
Every cluster result is checked bit-identical to the 1-device run.

The demo ends with a **blackout drill**: the same trace replayed while one
of the 8 devices has its entire pool state NaN'd mid-replay
(``repro.serve.faults.DeviceBlackout``). The scheduler must quarantine the
device, requeue its in-flight requests onto healthy devices, and keep it
out of placement — zero requests lost, every coupling still bit-identical
to the healthy 8-device run (requeued solves replay from the intact host
payload).

The operational flags exercise PR 10's telemetry plane:

* ``--dashboard``      — periodically render the live text dashboard from
                         the exporter's JSON snapshot during the 8-device
                         replay and the blackout drill (windowed
                         throughput/latency, occupancy, firing alerts);
* ``--record PATH``    — write the blackout drill's flight-recorder
                         incident capture (the quarantine-triggered dump)
                         as replayable JSONL;
* ``--replay PATH``    — load a recorded capture, render its text
                         timeline, and exit (no mesh, no solves — the
                         black box is a post-mortem artifact).

Run:  PYTHONPATH=src python examples/cluster_serve_demo.py [--smoke]
          [--dashboard] [--record PATH | --replay PATH]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402

from repro.core import UOTConfig  # noqa: E402
from repro.geometry import PointCloudGeometry  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.serve import UOTScheduler, faults  # noqa: E402
from repro.cluster import ClusterScheduler, cluster_mesh  # noqa: E402


def make_trace(n, rate_hz, seed, cfg):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    shapes = [(48, 100), (56, 120), (64, 128), (40, 90)]
    trace = []
    for i, t in enumerate(arrivals):
        m, nn = shapes[rng.integers(len(shapes))]
        peak = float(rng.uniform(1.0, 8.0))
        C = rng.uniform(0, 1, (m, nn)).astype(np.float32) * peak
        a = rng.uniform(0.5, 1.5, m).astype(np.float32)
        b = rng.uniform(0.5, 1.5, nn).astype(np.float32)
        a, b = a / a.sum(), b / b.sum() * 1.2
        K = np.exp(-C / cfg.reg) * (a[:, None] * b[None, :])
        trace.append((float(t), K, a, b))
    return trace


def replay(build, trace, t_chunk, label, dashboard=False):
    from repro.obs import render_dashboard

    now = [0.0]
    sched = build(lambda: now[0])
    i, lat, out = 0, {}, {}
    rid_to_idx = {}
    steps = 0
    while i < len(trace) or sched.pending or sched.in_flight:
        if (not sched.pending and not sched.in_flight
                and trace[i][0] > now[0]):
            now[0] = trace[i][0]
        while i < len(trace) and trace[i][0] <= now[0]:
            rid_to_idx[sched.submit(*trace[i][1:])] = i
            i += 1
        for rid, P in sched.step().items():
            out[rid_to_idx[rid]] = P
            lat[rid_to_idx[rid]] = now[0] - trace[rid_to_idx[rid]][0]
        now[0] += t_chunk
        steps += 1
        if dashboard and sched.exporter.enabled and steps % 20 == 0:
            print(f"\n  -- dashboard @ step {steps} "
                  f"(t={now[0] * 1e3:.1f} ms sim) --")
            print(render_dashboard(sched.exporter.snapshot()))
    if dashboard and sched.exporter.enabled:
        print(f"\n  -- dashboard (final, t={now[0] * 1e3:.1f} ms sim) --")
        print(render_dashboard(sched.exporter.snapshot()))
    lats = [lat[k] for k in range(len(trace))]
    print(f"  {label}: throughput {len(trace) / now[0]:7.1f} req/s   "
          f"p50 {np.percentile(lats, 50) * 1e3:6.1f} ms   "
          f"p99 {np.percentile(lats, 99) * 1e3:6.1f} ms")
    return out, sched


def main():
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dashboard", action="store_true",
                    help="render the live exporter dashboard during replays")
    ap.add_argument("--record", metavar="PATH",
                    help="write the blackout drill's flight capture (JSONL)")
    ap.add_argument("--replay", metavar="PATH",
                    help="render a recorded flight capture and exit")
    args = ap.parse_args()

    if args.replay:
        from repro.obs import FlightRecorder
        dump = FlightRecorder.load_jsonl(args.replay)
        print(FlightRecorder.render(dump))
        return

    import jax
    assert jax.device_count() == 8, jax.device_count()
    smoke = args.smoke
    if smoke:
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=24, tol=1e-3)
        lanes, chunk = 2, 4
        n, rate = 48, 4000.0
    else:
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=120, tol=1e-4)
        lanes, chunk = 4, 6
        n, rate = 160, 4000.0      # offered load saturating 8 devices
    trace = make_trace(n, rate, seed=0, cfg=cfg)

    # measured chunk service time: what one scheduling round costs a device
    st = ops.make_lane_state(lanes, 64, 128, cfg)
    for j in range(lanes):
        st = ops.lane_admit(st, np.int32(j), *trace[j][1:])
    import time
    ops.solve_fused_stepped(st, chunk, cfg, impl="jnp")  # warm
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(
            ops.solve_fused_stepped(st, chunk, cfg, impl="jnp").P)
    t_chunk = (time.perf_counter() - t0) / 5
    print(f"chunk service time (lanes={lanes}, chunk={chunk}): "
          f"{t_chunk * 1e3:.2f} ms\n")

    print(f"replaying {n} Poisson requests at {rate:.0f} req/s offered:")
    out1, _ = replay(
        lambda clock: UOTScheduler(cfg, lanes_per_pool=lanes,
                                   chunk_iters=chunk, impl="jnp",
                                   clock=clock),
        trace, t_chunk, "1 device  (UOTScheduler)  ")
    mesh = cluster_mesh(8)
    from repro.obs import SLO, CounterDelta, default_slos
    # operational objectives for the cluster replays: the starter serve
    # set on cluster.* metrics, plus the chaos signature (a quarantine
    # inside the window is an incident — objective 0.5 on a counter
    # delta fires on the first event)
    demo_slos = tuple(default_slos("cluster", window=60.0)) + (
        SLO("cluster_quarantine", objective=0.5, window=60.0,
            series=CounterDelta("cluster.devices_quarantined"),
            patience=1),)
    out8, cs = replay(
        lambda clock: ClusterScheduler(cfg, mesh=mesh,
                                       lanes_per_device=lanes,
                                       chunk_iters=chunk, impl="jnp",
                                       clock=clock, slos=demo_slos),
        trace, t_chunk, "8 devices (ClusterScheduler)",
        dashboard=args.dashboard)

    assert all(np.array_equal(out1[k], out8[k]) for k in range(n))
    print("\nevery request bit-identical across 1-device and 8-device runs")

    st8 = cs.stats()
    print("\nper-device telemetry (8-device run):")
    print("  device  placed  completed  occupancy")
    for d, v in st8["devices"].items():
        print(f"  {d:>6}  {v['placed']:>6}  {v['completed']:>9}  "
              f"{v['occupancy_mean']:>9.2f}")
    print(f"  router decisions: {st8['router']}")

    # --- the escape hatch + coordinate payloads, same submit API ---------
    big = ClusterScheduler(cfg, mesh=mesh, lanes_per_device=lanes,
                           impl="jnp",
                           lane_budget=lambda Mb, Nb: Mb * Nb <= 128 * 256)
    rng = np.random.default_rng(1)
    Kb = trace[0][1]
    C = rng.uniform(0, 1, (400, 512)).astype(np.float32)
    ab = rng.uniform(0.5, 1.5, 400).astype(np.float32)
    bb = rng.uniform(0.5, 1.5, 512).astype(np.float32)
    ab, bb = ab / ab.sum(), bb / bb.sum() * 1.2
    Kbig = np.exp(-C / cfg.reg) * (ab[:, None] * bb[None, :])
    x = rng.normal(size=(48, 3)).astype(np.float32)
    y = rng.normal(size=(100, 3)).astype(np.float32) + 0.3
    ap = rng.uniform(0.5, 1.5, 48).astype(np.float32)
    bp = rng.uniform(0.5, 1.5, 100).astype(np.float32)
    ap, bp = ap / ap.sum(), bp / bp.sum() * 1.2
    r_lane = big.submit(Kb, trace[0][2], trace[0][3])
    r_gang = big.submit(Kbig, ab, bb)       # over budget -> row-sharded gang
    r_pts = big.submit_points(x, y, ap, bp, scale=2.0)
    big.run()
    g = PointCloudGeometry.from_points(x, y, scale=2.0)
    by_rid = {t.rid: t for t in big.request_log}
    print(f"\none submit API, three routes:")
    print(f"  lane request  -> device {by_rid[r_lane].device}, "
          f"route={by_rid[r_lane].route!r}")
    print(f"  400x512 req   -> route={by_rid[r_gang].route!r} "
          f"(row-sharded gang across all 8 devices)")
    print(f"  points req    -> device {by_rid[r_pts].device}, "
          f"route={by_rid[r_pts].route!r}, payload "
          f"{g.payload_nbytes() / 1024:.1f} KB vs "
          f"{48 * 100 * 4 / 1024:.1f} KB dense")

    # --- blackout drill: lose 1 of 8 devices mid-replay ------------------
    # saturating variant of the same problems (all offered at t=0) so the
    # struck device is busy: the quarantine signature is EVERY active lane
    # on a device going unhealthy at once
    print("\nblackout drill: device 2's pool state NaN'd at step 3 ...")
    wave = [(0.0,) + t[1:] for t in trace]
    drill = faults.DeviceBlackout(device=2, at_step=3)
    out_bo, cs_bo = replay(
        lambda clock: ClusterScheduler(cfg, mesh=mesh,
                                       lanes_per_device=lanes,
                                       chunk_iters=chunk, impl="jnp",
                                       fault_injector=drill, clock=clock,
                                       slos=demo_slos),
        wave, t_chunk, "8 devices, 1 blacked out   ",
        dashboard=args.dashboard)
    st_bo = cs_bo.stats()
    assert drill.fired and st_bo["device_health"][2] == "quarantined"
    assert sorted(out_bo) == list(range(n)), "requests lost in blackout"
    assert all(np.array_equal(out8[k], out_bo[k]) for k in range(n))
    placed_late = [t for t in cs_bo.request_log
                   if t.route == "lane" and t.retries > 0]
    assert all(t.device != 2 for t in placed_late)
    print(f"  device 2 quarantined ({st_bo['device_health']}),"
          f" {st_bo['requeued']} in-flight requests requeued to healthy"
          f" devices,\n  zero requests lost, all {n} couplings"
          f" bit-identical to the healthy 8-device run")

    # --- the black box caught it: quarantine + alert dumps retained ------
    assert cs_bo.flight.triggered("quarantine"), \
        [d.trigger for d in cs_bo.flight.dumps]
    assert cs_bo.obs.slo.fired("cluster_quarantine")
    capture = next(d for d in cs_bo.flight.dumps
                   if d.trigger == "quarantine")
    print(f"\nflight recorder: {len(cs_bo.flight.dumps)} incident captures "
          f"({', '.join(d.trigger for d in cs_bo.flight.dumps)})")
    if args.record:
        lines = cs_bo.flight.write_jsonl(args.record, dump=capture)
        print(f"  wrote {lines} JSONL lines to {args.record} "
              f"(replay with --replay {args.record})")
    else:
        from repro.obs import FlightRecorder
        print(FlightRecorder.render(capture, max_rounds=8))


if __name__ == "__main__":
    main()
