"""Batched serving demo: LLM decode batching + UOT request batching.

Part 1: prefill + continuous greedy decode with KV cache (ServeEngine).
Part 2: shape-bucketed batch solving of queued UOT problems (UOTBatchEngine)
        — many requests, one fused kernel launch per shape bucket.
Part 3: continuous-batching scheduler (UOTScheduler) — lanes advance in
        chunks, converged problems are evicted (and returned) immediately,
        queued requests are admitted earliest-deadline-first into freed
        lanes, and per-request telemetry comes back with the answers.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import UOTConfig
from repro.models.model import build_model
from repro.serve import UOTScheduler
from repro.serve.engine import ServeEngine, UOTBatchEngine


def main():
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=2048, loss_chunks=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServeEngine(model, params, batch_size=4, cache_len=128)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(4)]
    outs = engine.generate(prompts, max_new_tokens=24)
    for i, o in enumerate(outs):
        print(f"request {i}: prompt[:6]={prompts[i][:6].tolist()} "
              f"-> generated {o[:12].tolist()}...")

    tps = engine.throughput_probe(steps=16, prompt_len=16)
    print(f"\ndecode throughput (batch=4, CPU): {tps:.1f} tokens/s")

    # ---- UOT request batching -------------------------------------------
    uot = UOTBatchEngine(UOTConfig(reg=0.05, reg_m=1.0, num_iters=50),
                         max_batch=16)
    rids = []
    for k, (m, n) in enumerate([(100, 120), (64, 128), (90, 120), (250, 300)]):
        C = rng.uniform(0, 1, (m, n)).astype(np.float32)
        a = rng.uniform(0.5, 1.5, m).astype(np.float32)
        b = rng.uniform(0.5, 1.5, n).astype(np.float32)
        K = np.exp(-C / 0.05) * (a[:, None] / a.sum() * b[None, :] / b.sum())
        rids.append(uot.submit(K, a / a.sum(), b / b.sum()))
    print(f"\nqueued {uot.pending} UOT requests of mixed shapes")
    couplings = uot.flush()
    for rid in rids:
        P = np.asarray(couplings[rid])
        print(f"request {rid}: coupling {P.shape}, mass={P.sum():.4f}")

    # ---- UOT continuous-batching scheduler ------------------------------
    # tol turns on per-lane convergence eviction; peaky costs converge
    # slower, so the workload retires at different iteration counts.
    import time

    sched = UOTScheduler(
        UOTConfig(reg=0.05, reg_m=1.0, num_iters=200, tol=1e-4),
        lanes_per_pool=4, chunk_iters=5)
    print("\ncontinuous scheduler: deadline-aware admission, per-lane "
          "convergence eviction")
    now = time.monotonic()  # deadlines are absolute times on sched's clock
    for k, ((m, n), peak, rel_deadline) in enumerate(
            [((100, 120), 1.0, None), ((90, 120), 4.0, 0.05),
             ((64, 128), 8.0, 0.5), ((100, 100), 2.0, 0.1)]):
        C = rng.uniform(0, 1, (m, n)).astype(np.float32) * peak
        a = rng.uniform(0.5, 1.5, m).astype(np.float32)
        a /= a.sum()
        b = rng.uniform(0.5, 1.5, n).astype(np.float32)
        b /= b.sum()
        K = np.exp(-C / 0.05) * (a[:, None] * b[None, :])
        deadline = None if rel_deadline is None else now + rel_deadline
        sched.submit(K, a, b, deadline=deadline, priority=k % 2)
    results = sched.run()
    for t in sched.request_log:
        print(f"request {t.rid}: lane={t.lane} iters={t.iters} "
              f"converged={t.converged} wait={t.wait * 1e3:.1f}ms "
              f"mass={np.asarray(results[t.rid]).sum():.4f}")
    s = sched.stats()
    print(f"scheduler stats: {s['completed']} done in {s['steps']} chunks, "
          f"mean occupancy {s['occupancy_mean']:.2f}, "
          f"iters mean/max {s['iters_mean']:.0f}/{s['iters_max']}")


if __name__ == "__main__":
    main()
