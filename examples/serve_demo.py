"""Batched serving demo: prefill + continuous greedy decode with KV cache.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=2048, loss_chunks=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServeEngine(model, params, batch_size=4, cache_len=128)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(4)]
    outs = engine.generate(prompts, max_new_tokens=24)
    for i, o in enumerate(outs):
        print(f"request {i}: prompt[:6]={prompts[i][:6].tolist()} "
              f"-> generated {o[:12].tolist()}...")

    tps = engine.throughput_probe(steps=16, prompt_len=16)
    print(f"\ndecode throughput (batch=4, CPU): {tps:.1f} tokens/s")


if __name__ == "__main__":
    main()
