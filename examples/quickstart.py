"""Quickstart: solve an unbalanced optimal transport problem with MAP-UOT.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (UOTConfig, gibbs_kernel, marginal_error,
                        sinkhorn_uot_baseline, sinkhorn_uot_fused)
from repro.core.applications import pairwise_sq_dists
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    # two point clouds with unequal masses -> a genuinely unbalanced problem
    X = rng.normal(size=(512, 2)).astype(np.float32)
    Y = rng.normal(size=(384, 2)).astype(np.float32) + 1.0
    a = jnp.full((512,), 1.0 / 512)
    b = jnp.full((384,), 1.3 / 384)

    C = pairwise_sq_dists(jnp.asarray(X), jnp.asarray(Y))
    C = C / C.max()
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=200)
    A0 = gibbs_kernel(C, cfg.reg) * (a[:, None] * b[None, :])

    # 1) POT-style 4-pass baseline
    P_base, _ = sinkhorn_uot_baseline(A0, a, b, cfg)
    # 2) MAP-UOT fused (paper Algorithm 1) — identical iterates, 3x less HBM
    P_fused, stats = sinkhorn_uot_fused(A0, a, b, cfg)
    # 3) the Pallas TPU kernel (interpret mode on CPU)
    P_kernel, _ = ops.solve_fused(A0, a, b, cfg)

    print("max |baseline - fused|:", float(jnp.abs(P_base - P_fused).max()))
    print("max |fused - kernel|  :", float(jnp.abs(P_fused - P_kernel).max()))
    print("coupling mass:", float(P_fused.sum()),
          " (marginal masses: 1.0 / 1.3)")
    print("transport cost <C,P>:", float((C * P_fused).sum()))
    print("balanced-sense marginal error:",
          float(marginal_error(P_fused, a, b)))


if __name__ == "__main__":
    main()
