"""Color transfer via UOT (the paper's Section 5.5 application) — on the
point-cloud geometry path.

Builds two synthetic 'images' (mixtures-of-Gaussians color clouds) and
solves UOT between their palettes. The RGB clouds themselves are the cost
source (``repro.geometry.PointCloudGeometry``): the solver receives
``(M + N) * 3`` coordinates instead of an ``M * N`` cost matrix, the
squared-Euclidean Gibbs tiles are evaluated on-device (on-chip in VMEM on
the TPU kernel path), and cost normalization uses the static unit-cube
bound ``||x - y||^2 <= 3`` — a bound you can know without ever forming C.
The dense path is timed alongside for comparison; the UOT solve dominates
either way, matching the paper's Fig. 2/17 observation.

Run:  PYTHONPATH=src python examples/color_transfer.py
"""
import time

import numpy as np
import jax

from repro.core import UOTConfig
from repro.core.applications import color_transfer, color_transfer_geometry


def synth_palette(rng, centers, n):
    mix = rng.integers(0, len(centers), size=n)
    c = np.asarray(centers)[mix]
    return np.clip(c + rng.normal(0, 0.08, size=(n, 3)), 0, 1).astype(np.float32)


def main():
    rng = np.random.default_rng(0)
    n = 1024
    sunset = [(0.9, 0.5, 0.2), (0.8, 0.2, 0.3), (0.3, 0.2, 0.5)]
    forest = [(0.1, 0.5, 0.2), (0.3, 0.6, 0.3), (0.1, 0.2, 0.1)]
    src = synth_palette(rng, sunset, n)
    dst = synth_palette(rng, forest, n)

    cfg = UOTConfig(reg=0.05, reg_m=10.0, num_iters=200)

    # geometry path: coordinates in, no dense C anywhere on the kernel path
    t0 = time.perf_counter()
    mapped, P = jax.block_until_ready(
        color_transfer_geometry(src, dst, cfg))
    t_total = time.perf_counter() - t0  # includes compile
    t0 = time.perf_counter()
    mapped, P = jax.block_until_ready(
        color_transfer_geometry(src, dst, cfg))
    t_geom = time.perf_counter() - t0

    # dense path (explicit C materialized + data-dependent normalization),
    # for reference
    f_dense = jax.jit(lambda s, d: color_transfer(s, d, cfg, fused=True))
    jax.block_until_ready(f_dense(src, dst))
    t0 = time.perf_counter()
    mapped_d, _ = jax.block_until_ready(f_dense(src, dst))
    t_dense = time.perf_counter() - t0

    print(f"palette size: {n} x {n}, iterations: {cfg.num_iters}")
    print(f"geometry path  first call (with compile): {t_total * 1e3:.1f} ms; "
          f"steady-state: {t_geom * 1e3:.1f} ms  "
          f"(request payload: {(2 * n * (3 + 1) * 4) / 1e3:.1f} KB of "
          f"coordinates + norms vs {(n * n * 4) / 1e6:.1f} MB of cost "
          f"matrix)")
    print(f"dense path     steady-state: {t_dense * 1e3:.1f} ms "
          f"(different init/normalization — a timing reference, not a "
          f"parity check; see color_transfer_geometry's docstring)")
    print("source mean color :", src.mean(0).round(3))
    print("target mean color :", dst.mean(0).round(3))
    print("mapped mean color :", np.asarray(mapped).mean(0).round(3),
          "(should move toward target)")
    drift = np.linalg.norm(np.asarray(mapped).mean(0) - dst.mean(0))
    print("mean-color distance to target:", round(float(drift), 4))


if __name__ == "__main__":
    main()
