"""Color transfer via UOT (the paper's Section 5.5 application).

Builds two synthetic 'images' (mixtures-of-Gaussians color clouds), solves
UOT between their palettes with the MAP-UOT fused solver, and applies the
barycentric map. Prints per-stage timing: the UOT solve dominates, matching
the paper's Fig. 2/17 observation.

Run:  PYTHONPATH=src python examples/color_transfer.py
"""
import time

import numpy as np
import jax

from repro.core import UOTConfig
from repro.core.applications import color_transfer


def synth_palette(rng, centers, n):
    mix = rng.integers(0, len(centers), size=n)
    c = np.asarray(centers)[mix]
    return np.clip(c + rng.normal(0, 0.08, size=(n, 3)), 0, 1).astype(np.float32)


def main():
    rng = np.random.default_rng(0)
    n = 1024
    sunset = [(0.9, 0.5, 0.2), (0.8, 0.2, 0.3), (0.3, 0.2, 0.5)]
    forest = [(0.1, 0.5, 0.2), (0.3, 0.6, 0.3), (0.1, 0.2, 0.1)]
    src = synth_palette(rng, sunset, n)
    dst = synth_palette(rng, forest, n)

    cfg = UOTConfig(reg=0.05, reg_m=10.0, num_iters=200)
    f = jax.jit(lambda s, d: color_transfer(s, d, cfg, fused=True))

    t0 = time.perf_counter()
    mapped, P = jax.block_until_ready(f(src, dst))
    t_total = time.perf_counter() - t0  # includes compile
    t0 = time.perf_counter()
    mapped, P = jax.block_until_ready(f(src, dst))
    t_run = time.perf_counter() - t0

    print(f"palette size: {n} x {n}, iterations: {cfg.num_iters}")
    print(f"first call (with compile): {t_total * 1e3:.1f} ms; "
          f"steady-state: {t_run * 1e3:.1f} ms")
    print("source mean color :", src.mean(0).round(3))
    print("target mean color :", dst.mean(0).round(3))
    print("mapped mean color :", np.asarray(mapped).mean(0).round(3),
          "(should move toward target)")
    drift = np.linalg.norm(np.asarray(mapped).mean(0) - dst.mean(0))
    print("mean-color distance to target:", round(float(drift), 4))


if __name__ == "__main__":
    main()
