"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# Records of every emit() since the last reset_records(); run.py drains this
# into per-suite BENCH_<suite>.json files so the perf trajectory accumulates.
RECORDS: list[dict] = []


def make_problem(M, N, reg=0.05, seed=0, dtype=jnp.float32, peak=1.0):
    """Random UOT problem (Gibbs kernel, unbalanced b). ``peak`` scales the
    cost relative to reg — peaky costs converge much slower, so mixing
    peaks gives workloads heterogeneous iteration counts."""
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(M, N)).astype(np.float32) * peak
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.2
    K = np.exp(-C / reg) * (a[:, None] * b[None, :])
    return (jnp.asarray(K, dtype), jnp.asarray(a), jnp.asarray(b))


def time_fn(fn, *args, warmup=1, iters=3):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})


def reset_records() -> list[dict]:
    """Return the accumulated records and start a fresh list."""
    global RECORDS
    out, RECORDS = RECORDS, []
    return out
