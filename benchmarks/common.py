"""Shared benchmark utilities.

Timing discipline: every ``us_per_call`` this module helps produce is a
**steady-state** number — ``time_fn`` warms up (trace + compile happen on
the warmup calls) before the timed reps, and ``time_fn_full`` additionally
reports the first (cold, trace+compile-inclusive) call separately so the
two regimes are never conflated in one figure. Suites that time a single
call by hand must warm that call up first for the same reason.

``bench_meta()`` stamps each ``BENCH_<suite>.json`` with enough provenance
to compare runs honestly (schema version, git sha, jax versions, machine
fingerprint); ``check_payload()`` is the perf-regression gate ``run.py
--check`` applies against committed snapshots — it skips cross-machine
comparisons outright rather than flagging noise as regression.
"""
from __future__ import annotations

import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

# Bumped whenever the BENCH_<suite>.json payload shape changes:
# 1 = bare {suite, backend, platform, records}
# 2 = + meta block (git sha, versions, machine fingerprint), records may
#     carry first_us (cold trace+compile call) next to us_per_call
BENCH_SCHEMA_VERSION = 2

# Records of every emit() since the last reset_records(); run.py drains this
# into per-suite BENCH_<suite>.json files so the perf trajectory accumulates.
RECORDS: list[dict] = []


def make_problem(M, N, reg=0.05, seed=0, dtype=jnp.float32, peak=1.0):
    """Random UOT problem (Gibbs kernel, unbalanced b). ``peak`` scales the
    cost relative to reg — peaky costs converge much slower, so mixing
    peaks gives workloads heterogeneous iteration counts."""
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(M, N)).astype(np.float32) * peak
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.2
    K = np.exp(-C / reg) * (a[:, None] * b[None, :])
    return (jnp.asarray(K, dtype), jnp.asarray(a), jnp.asarray(b))


def time_fn_full(fn, *args, warmup=1, iters=3):
    """``(first_s, median_s)``: the cold first call (trace + compile +
    execute) timed separately from the steady-state median of ``iters``
    post-warmup reps. ``warmup`` counts calls *after* the first — with the
    default 1, the timed reps start on call 3."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first = time.perf_counter() - t0
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return first, float(np.median(ts))


def time_fn(fn, *args, warmup=1, iters=3):
    """Median steady-state wall time (s) of fn(*args) with
    block_until_ready; the cold call is burned as warmup. Use
    ``time_fn_full`` when the trace+compile cost itself is the datum."""
    _, med = time_fn_full(fn, *args, warmup=warmup, iters=iters)
    return med


def emit(name: str, us_per_call: float, derived: str, *,
         first_us: float | None = None):
    """Record one benchmark line. ``us_per_call`` must be steady-state;
    pass the cold trace+compile call as ``first_us`` so it lands in the
    JSON without polluting the comparable number."""
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"name": name, "us_per_call": round(us_per_call, 1),
           "derived": derived}
    if first_us is not None:
        rec["first_us"] = round(first_us, 1)
    RECORDS.append(rec)


def reset_records() -> list[dict]:
    """Return the accumulated records and start a fresh list."""
    global RECORDS
    out, RECORDS = RECORDS, []
    return out


def _git_sha() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except OSError:
        return None


def bench_meta() -> dict:
    """Provenance block for ``BENCH_*.json`` / ``OBS_*.json`` payloads:
    schema version, git sha, jax/jaxlib versions, backend, device kind,
    and the hostname-free machine fingerprint ``check_payload`` keys
    comparability on."""
    import jaxlib
    from repro.obs.measure import machine_fingerprint
    fp = machine_fingerprint()
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": fp["backend"],
        "device_kind": fp["device_kind"],
        "fingerprint": fp,
    }


def check_payload(fresh: dict, baseline: dict, *, threshold: float = 1.3,
                  min_us: float = 50.0) -> dict:
    """Perf-regression verdict for one suite: fresh vs committed baseline.

    Returns ``{"status": "ok"|"fail"|"skip", "reason", "failures",
    "compared"}``. Skips (never fails) when either payload predates the
    meta schema or the machine fingerprints differ — a number measured on
    another machine is not a baseline, it is a different experiment.
    Records are matched by name; records below ``min_us`` steady-state are
    ignored (sub-50us host timings are noise-dominated), as are
    non-positive sentinels. A record regresses when
    ``fresh > threshold * baseline`` on ``us_per_call``.
    """
    fm, bm = fresh.get("meta"), baseline.get("meta")
    if not fm or not bm:
        return {"status": "skip", "reason": "missing meta (pre-v2 schema)",
                "failures": [], "compared": 0}
    f_id = (fm.get("fingerprint") or {}).get("id")
    b_id = (bm.get("fingerprint") or {}).get("id")
    if f_id is None or b_id is None or f_id != b_id:
        return {"status": "skip",
                "reason": f"machine fingerprint mismatch "
                          f"({f_id} vs baseline {b_id})",
                "failures": [], "compared": 0}
    base_by_name = {r["name"]: r for r in baseline.get("records", [])}
    failures, compared = [], 0
    for rec in fresh.get("records", []):
        base = base_by_name.get(rec["name"])
        if base is None:
            continue
        f_us, b_us = rec.get("us_per_call", 0), base.get("us_per_call", 0)
        if f_us <= 0 or b_us <= 0 or b_us < min_us:
            continue
        compared += 1
        if f_us > threshold * b_us:
            failures.append({"name": rec["name"], "baseline_us": b_us,
                             "fresh_us": f_us,
                             "ratio": round(f_us / b_us, 3)})
    return {"status": "fail" if failures else "ok",
            "reason": "", "failures": failures, "compared": compared}
