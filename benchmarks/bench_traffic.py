"""Paper Fig. 11 analog: memory traffic (the cache-miss proxy on TPU).

cost_analysis 'bytes accessed' of one compiled iteration, baseline vs
MAP-UOT vs u/v-fused — the architectural quantity the paper's cache-miss
reductions come from. Also checks the analytic model (6MN/2MN/1MN elements).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import UOTConfig
from repro.core.problem import rescale_factors
from repro.core.sinkhorn_fused import fused_iteration
from repro.core.sinkhorn_uv import uv_fused_iteration
from benchmarks.common import make_problem, emit

SIZES = [(1024, 1024), (4096, 4096), (10240, 10240)]


def _bytes(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):  # newer jax: one dict per computation
        c = c[0] if c else {}
    return float(c.get("bytes accessed", 0.0))


def run():
    fi = 0.95
    for M, N in SIZES:
        K, a, b = make_problem(M, N)
        colsum = K.sum(0)

        def baseline_iter(A, a, b):
            A = A * rescale_factors(b, A.sum(0), fi)[None, :]
            A = A * rescale_factors(a, A.sum(1), fi)[:, None]
            return A

        def fused_iter(A, colsum, a, b):
            return fused_iteration(A, colsum, a, b, fi)[:2]

        def uv_iter(K, v, a, b):
            return uv_fused_iteration(K, v, a, b, fi)

        v = jnp.ones((N,), jnp.float32)
        b_base = _bytes(baseline_iter, K, a, b)
        b_fused = _bytes(fused_iter, K, colsum, a, b)
        b_uv = _bytes(uv_iter, K, v, a, b)
        ideal_base = 6 * M * N * 4
        ideal_fused = 2 * M * N * 4
        emit(f"traffic_baseline_{M}x{N}", b_base / 1e3,
             f"bytes={b_base:.3g}_model={ideal_base:.3g}")
        emit(f"traffic_mapuot_{M}x{N}", b_fused / 1e3,
             f"bytes={b_fused:.3g}_model={ideal_fused:.3g}_"
             f"reduction={b_base / b_fused:.2f}x")
        emit(f"traffic_uvfused_{M}x{N}", b_uv / 1e3,
             f"bytes={b_uv:.3g}_reduction={b_base / b_uv:.2f}x")
