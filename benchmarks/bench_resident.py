"""Resident vs streamed solving: whole-solve fusion against per-iteration
launches, wall clock + modeled HBM bytes.

For a stack of B same-shape problems solved to a tolerance, compares:

  * ``resident``          — ops.solve_fused_resident: ONE launch runs every
                            iteration with the tile resident (Pallas
                            lane-grid kernel on TPU; the jnp mirror — same
                            iteration fusion in one XLA executable — on
                            CPU, which is what CI measures).
  * ``streamed_periter``  — the per-iteration streamed loop: one
                            independent ``solve_fused_batched(num_iters=1)``
                            launch per iteration, coupling written to and
                            re-read from memory every iteration and the
                            column-sum accumulator re-derived per launch
                            (the launch-per-iteration pattern the resident
                            tier replaces).
  * ``streamed_stepped``  — per-iteration ``solve_fused_stepped`` launches
                            with carried LaneState + a host convergence
                            pull per iteration (the scheduler cadence at
                            chunk_iters=1: state carried, still one memory
                            round trip per iteration).
  * ``streamed_oneshot``  — ``solve_fused_batched`` single call (PR-1 path:
                            one jit, per-iteration storage-dtype round
                            trips inside).

All paths run the same tol-enabled convergence machinery (``tol`` is set
below any reachable drift, so every path executes exactly ``ITERS`` masked
iterations — iteration counts are asserted to match, and resident vs
streamed iterates are asserted to agree to dtype tolerance, so the timing
compares equal work). Modeled coupling traffic per solve, with s = storage
itemsize: resident = 2*B*M*N*s (one read + one write total); stepped =
2*B*M*N*s per iteration; periter restart additionally re-reads the matrix
for the per-launch column-sum pass (3*B*M*N*s per iteration).

The ISSUE-3 acceptance bar: ``resident`` >= 1.3x faster than
``streamed_periter`` at B=32, 256x256, 50 iters on CPU.

``BENCH_RESIDENT_SMOKE=1`` shrinks the cases to a seconds-long CI run.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core import UOTConfig
from repro.kernels import ops
from benchmarks.common import time_fn, time_fn_full, emit

# tol below any reachable factor drift: the convergence machinery runs
# (masked iterations, drift checks) but never fires, so every path does
# exactly ITERS iterations — equal work, assertable counts
TOL = 1e-9


def make_stack(B, M, N, reg=0.05, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(B, M, N)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=(B, M)).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=(B, N)).astype(np.float32)
    a = a / a.sum(axis=1, keepdims=True)
    b = b / b.sum(axis=1, keepdims=True) * 1.2
    K = np.exp(-C / reg) * (a[:, :, None] * b[:, None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def _mb(nbytes):
    return nbytes / 1e6


def bench_case(B, M, N, iters, storage_dtype):
    sdt = jnp.dtype(storage_dtype)
    tag = f"B{B}_{M}x{N}_i{iters}_{sdt.name}"
    K, a, b = make_stack(B, M, N)
    K = K.astype(sdt)
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=iters, tol=TOL)
    cfg1 = UOTConfig(reg=0.05, reg_m=1.0, num_iters=1, tol=TOL)

    def resident():
        return ops.solve_fused_resident(K, a, b, cfg,
                                        storage_dtype=storage_dtype)

    def periter():
        A = K
        for _ in range(iters):
            A, _ = ops.solve_fused_batched(A, a, b, cfg1, impl="jnp",
                                           storage_dtype=storage_dtype)
        return A

    state0 = ops.make_lane_state(B, M, N, cfg, storage_dtype=storage_dtype)
    state0 = ops.lane_admit(state0, jnp.arange(B), K, a, b)

    def stepped():
        st = state0
        for _ in range(iters):
            st = ops.solve_fused_stepped(st, 1, cfg, impl="jnp")
            if np.asarray(ops.lane_done(st, cfg.num_iters)).all():
                break
        return st

    def oneshot():
        return ops.solve_fused_batched(K, a, b, cfg, impl="jnp",
                                       storage_dtype=storage_dtype)

    # resident is the one-launch whole-solve path, so its trace+compile
    # cost is the number amortized over a pool's lifetime — report it
    # (first_us) next to the steady-state execute it must never pollute.
    # Timed first so the cold call really is cold; parity below reuses
    # the now-warm executables.
    f_res, t_res = time_fn_full(resident)
    t_per = time_fn(periter)
    t_step = time_fn(stepped)
    t_one = time_fn(oneshot)

    # -- parity: identical iteration counts, agreeing iterates (fp32
    # tight; bf16 to one-final-rounding tolerance, since resident by
    # design drops the per-iteration rounding)
    P_res, _, it_res, _ = resident()
    st = stepped()
    assert (np.asarray(it_res) == iters).all(), np.asarray(it_res)
    assert (np.asarray(st.iters) == iters).all(), np.asarray(st.iters)
    P_stream = np.asarray(st.P, np.float32)[:, :M, :N]
    atol = 2e-6 if sdt.itemsize == 4 else 2e-2
    scale = np.abs(P_stream).max()
    max_rel = np.abs(np.asarray(P_res, np.float32) - P_stream).max() / scale
    assert max_rel <= atol, (max_rel, atol)

    coupling = B * M * N * sdt.itemsize
    emit(f"resident_{tag}", t_res * 1e6,
         f"modeled_mb={_mb(2 * coupling):.1f},iters_match=True,"
         f"max_rel_err={max_rel:.1e}", first_us=f_res * 1e6)
    emit(f"streamed_periter_{tag}", t_per * 1e6,
         f"modeled_mb={_mb(3 * coupling * iters):.1f},"
         f"speedup_resident={t_per / t_res:.2f}x")
    emit(f"streamed_stepped_{tag}", t_step * 1e6,
         f"modeled_mb={_mb(2 * coupling * iters):.1f},"
         f"speedup_resident={t_step / t_res:.2f}x")
    emit(f"streamed_oneshot_{tag}", t_one * 1e6,
         f"speedup_resident={t_one / t_res:.2f}x")
    return t_per / t_res


def run():
    smoke = bool(os.environ.get("BENCH_RESIDENT_SMOKE"))
    if smoke:
        cases = [(4, 64, 128, 10)]
        dtypes = [jnp.float32]
    else:
        # (B, M, N, iters): the acceptance case, the 256x384 serving
        # bucket (PR 1-2's workload, the tier's design point)
        cases = [(32, 256, 256, 50), (16, 256, 384, 50)]
        dtypes = [jnp.float32, jnp.bfloat16]
    for B, M, N, iters in cases:
        for sdt in dtypes:
            ratio = bench_case(B, M, N, iters, sdt)
            if (B, M, N, iters) == (32, 256, 256, 50):
                emit(f"resident_acceptance_{jnp.dtype(sdt).name}",
                     ratio, "bar>=1.3x_vs_streamed_periter")
