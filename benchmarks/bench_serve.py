"""Serving-tier comparison: continuous scheduler vs flush-barrier engine.

Replays one Poisson arrival trace of ragged UOT problems (heterogeneous
convergence speeds via cost peakiness) through both tier-2 and tier-3
serving (see ``repro.serve``) as a discrete-event simulation whose service
times are *measured wall clock*:

  * ``flush``     — ``UOTBatchEngine``: at each event, flush everything that
                    has arrived; requests arriving mid-flush wait for the
                    whole flush (the barrier), then ride the next one.
  * ``scheduler`` — ``UOTScheduler``: requests are admitted into lanes at
                    chunk boundaries and evicted on convergence, so nobody
                    waits for a slow lane-mate or a full batch.

Both run the same cfg (tol-based early exit enabled for both — the flush
path also stops when ALL lanes converge, so the scheduler's edge is
specifically per-request eviction + mid-solve admission). Reports p50/p99
request latency (arrival -> result), throughput, and the deadline-miss
rate against a per-request latency SLO — the scheduler's from its own
``RequestTelemetry`` counters, the flush barrier's derived from the
simulated latencies; the ISSUE-2 acceptance bar is scheduler p99 < flush
p99 at equal (same-trace) throughput.

``BENCH_SERVE_SMOKE=1`` shrinks the trace to a seconds-long CI smoke run.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import UOTConfig
from repro.serve import UOTBatchEngine, UOTScheduler
from benchmarks.common import emit, make_problem


def make_trace(n, rate_hz, seed, shapes, peak_range, reg):
    """Poisson arrivals of ragged problems (``common.make_problem`` with
    per-request cost peakiness). Returns a list of (arrival_time, K, a, b)
    numpy triples sorted by arrival."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for i, t in enumerate(arrivals):
        m, nn = shapes[rng.integers(len(shapes))]
        K, a, b = make_problem(m, nn, reg=reg, seed=seed * 100_003 + i,
                               peak=float(rng.uniform(*peak_range)))
        out.append((float(t), np.asarray(K), np.asarray(a), np.asarray(b)))
    return out


def _percentiles(latencies):
    lat = np.array(latencies)
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _warm_flush_specializations(trace, cfg, max_batch):
    """Compile every (bucket, canonical batch) the replay can hit — flushes
    happen at data-dependent queue depths, so all pow2 chunk sizes must be
    warm or compile time pollutes the measured service times. Warms through
    the engine itself so the jit static args match the replay exactly."""
    from repro.kernels import ops

    buckets = {ops.bucket_shape(K.shape[0], K.shape[1])
               for _, K, _, _ in trace}
    eng = UOTBatchEngine(cfg, max_batch=max_batch, impl="jnp")
    for Mb, Nb in buckets:
        B = 1
        while B <= max_batch:
            for _ in range(B):
                eng.submit(np.zeros((Mb, Nb), np.float32),
                           np.zeros(Mb, np.float32),
                           np.zeros(Nb, np.float32))
            eng.flush()
            B *= 2


def sim_flush(trace, cfg, *, max_batch, warmup=True):
    """Flush-barrier serving of the trace; returns (latencies, makespan)."""
    import time

    if warmup:
        _warm_flush_specializations(trace, cfg, max_batch)

    eng = UOTBatchEngine(cfg, max_batch=max_batch, impl="jnp")
    t, i, lat = 0.0, 0, {}
    while i < len(trace):
        if trace[i][0] > t:          # idle: jump to the next arrival
            t = trace[i][0]
        batch = []
        while i < len(trace) and trace[i][0] <= t:
            eng.submit(*trace[i][1:])
            batch.append(i)
            i += 1
        t0 = time.perf_counter()
        out = eng.flush()
        t += time.perf_counter() - t0
        for k in batch:
            lat[k] = t - trace[k][0]
    return [lat[k] for k in range(len(trace))], t


def sim_scheduler(trace, cfg, *, lanes_per_pool, chunk_iters, warmup=True,
                  deadline_budget=None, obs=None, slos=None):
    """Continuous-batching serving of the trace; returns
    (latencies, makespan, scheduler) — the scheduler for its telemetry.
    With ``deadline_budget`` set, every request gets the deadline
    ``arrival + budget`` (simulated clock), so the scheduler's own
    deadline-miss telemetry is exercised and reported. ``obs`` passes
    through to the scheduler (``False`` disables tracing/traffic —
    ``bench_obs`` measures the difference); ``slos`` declares SLO
    objectives for the operational plane (windows run on the simulated
    clock, so burn rates are in simulated seconds)."""
    import time

    def build(clock):
        return UOTScheduler(cfg, lanes_per_pool=lanes_per_pool,
                            chunk_iters=chunk_iters, impl="jnp",
                            clock=clock, obs=obs, slos=slos)

    if warmup:
        sched = build(lambda: 0.0)
        for _, K, a, b in trace:
            sched.submit(K, a, b)
        sched.run()

    now = [0.0]
    sched = build(lambda: now[0])
    i, lat = 0, {}
    rid_to_idx = {}
    while i < len(trace) or sched.pending or sched.in_flight:
        if (not sched.pending and not sched.in_flight
                and trace[i][0] > now[0]):
            now[0] = trace[i][0]     # idle: jump to the next arrival
        while i < len(trace) and trace[i][0] <= now[0]:
            deadline = (None if deadline_budget is None
                        else trace[i][0] + deadline_budget)
            rid_to_idx[sched.submit(*trace[i][1:], deadline=deadline)] = i
            i += 1
        t0 = time.perf_counter()
        done = sched.step()
        now[0] += time.perf_counter() - t0
        for rid in done:
            lat[rid_to_idx[rid]] = now[0] - trace[rid_to_idx[rid]][0]
    return [lat[k] for k in range(len(trace))], now[0], sched


def run():
    smoke = bool(os.environ.get("BENCH_SERVE_SMOKE"))
    if smoke:
        n, rate = 8, 200.0
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=1e-3)
        shapes = [(24, 100), (40, 120)]
        lanes, chunk, max_batch = 4, 4, 16
    else:
        # Loaded regime (occupancy ~0.8): under light traffic the flush
        # barrier is fine — the scheduler's architectural edge (no barrier,
        # per-lane eviction) is a *tail latency under load* story.
        n, rate = 80, 200.0
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=400, tol=1e-4)
        shapes = [(200, 300), (224, 320), (256, 384), (240, 360)]
        lanes, chunk, max_batch = 12, 6, 32
    peak_range = (1.0, 8.0) if smoke else (2.0, 20.0)
    # per-request latency SLO: misses are completions past arrival+budget
    deadline_budget = 0.2 if smoke else 0.3
    trace = make_trace(n, rate, seed=0, shapes=shapes,
                       peak_range=peak_range, reg=cfg.reg)

    flush_lat, flush_T = sim_flush(trace, cfg, max_batch=max_batch)
    sched_lat, sched_T, sched = sim_scheduler(
        trace, cfg, lanes_per_pool=lanes, chunk_iters=chunk,
        deadline_budget=deadline_budget)

    f50, f99 = _percentiles(flush_lat)
    s50, s99 = _percentiles(sched_lat)
    tag = "smoke" if smoke else f"n{n}_rate{rate:.0f}"
    emit(f"serve_flush_p50_{tag}", f50 * 1e6,
         f"throughput={n / flush_T:.1f}rps")
    emit(f"serve_flush_p99_{tag}", f99 * 1e6, f"makespan={flush_T:.3f}s")
    emit(f"serve_sched_p50_{tag}", s50 * 1e6,
         f"throughput={n / sched_T:.1f}rps")
    emit(f"serve_sched_p99_{tag}", s99 * 1e6,
         f"p99_speedup={f99 / s99:.2f}x_vs_flush")
    st = sched.stats()
    emit(f"serve_sched_iters_{tag}", st["iters_mean"],
         f"max={st['iters_max']},converged={st['converged_frac']:.2f},"
         f"occupancy={st['occupancy_mean']:.2f}")
    # deadline-miss rate alongside p99: the scheduler's from its own
    # telemetry (RequestTelemetry.missed), the flush barrier's from the
    # simulated latencies against the same SLO
    flush_miss = float(np.mean([l > deadline_budget for l in flush_lat]))
    emit(f"serve_flush_missrate_{tag}", flush_miss * 100,
         f"slo={deadline_budget * 1e3:.0f}ms")
    emit(f"serve_sched_missrate_{tag}", st["miss_rate"] * 100,
         f"slo={deadline_budget * 1e3:.0f}ms,"
         f"misses={st['deadline_misses']}/{st['completed']}")
    # zero span loss: every submitted rid carries exactly one terminal
    # 'complete' event in the scheduler's trace
    audit = sched.obs.tracer.check_complete()
    assert audit["total"] == n and not audit["missing"] \
        and not audit["multiple"], audit
    emit(f"serve_sched_spans_{tag}", len(sched.obs.tracer.events),
         f"rids={audit['total']},span_loss=0")
