"""Overload harness: a 3x-capacity Poisson burst through the cluster
scheduler, drop-policy baseline vs predictive admission + degrade ladder.

A discrete-event simulation on a SIMULATED clock: service time is modeled
as ``chunk_iters * seconds_per_iter`` of simulated time per scheduler
round (the pinned rate the predictive scheduler is configured with), so
"3x capacity" is exact by construction — arrivals carry 3x the iteration
work the lane pools can drain per simulated second — and the comparison
is architectural, not a wall-clock race:

  * ``drop``   — the PR-6 baseline: ``shed_policy='drop'``, no service
                 model. Expired requests are refused at admission;
                 everything else is served at full quality no matter how
                 hopeless its deadline has become.
  * ``ladder`` — ``predictive=True`` + ``shed_policy='degrade'``: SLO
                 feasibility judged at submit AND at admission (against
                 the remaining budget), brownout-controlled degrade
                 ladder ending in the exact sliced 1-D tier for point
                 requests.

Hard asserts (the ISSUE-8 acceptance bar — failures fail the suite):

  1. zero lost requests in BOTH runs: every submitted rid resolves to a
     coupling or a typed disposition;
  2. zero SLO misses among full-quality completions in the ladder run —
     a request served at ``degrade_level == 0`` passed the feasibility
     gate at both judgment points, so a miss would mean the service
     model lied by more than ``feasibility_margin``;
  3. every degraded result labeled (``degrade_level`` >= 1 and a
     non-None ``est_error``);
  4. ladder goodput >= 1.5x drop-policy goodput, where goodput counts
     in-SLO full completions at weight 1 and in-SLO degraded
     completions at weight 0.5, per simulated second.

A second, harsher spike (12x full-quality capacity — ~3x even the
level-1 truncated tier's capacity) then replays through the ladder
alone: sustained pressure must walk the brownout past level 1 into the
sliced 1-D tier (asserted: level-2 completions > 0, still zero lost,
still every degrade labeled). At 3x the controller correctly stops at
level 1 — it never sheds more accuracy than the backlog demands — so
the deeper rungs only show under deeper overload.

``BENCH_OVERLOAD_SMOKE=1`` shrinks the burst for CI (run there on 8
forced host devices — the scheduler shape matches bench_cluster's).
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UOTConfig, sinkhorn_uot_log
from repro.serve import BrownoutController, InfeasibleDeadline, RequestFailure
from repro.cluster import ClusterScheduler
from benchmarks.common import emit, make_problem

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)
SPI = 1e-3           # pinned seconds per lane iteration (simulated)
CHUNK = 4
LANES_PER_DEVICE = 4
MARGIN = 2.0         # feasibility margin; SLO budget = 2x margin x service
POINT_SCALE = 10.0   # tempers the squared-Euclid cost into the reg regime


def make_point_problem(M, N, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(M, d)).astype(np.float32)
    y = rng.normal(size=(N, d)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, N).astype(np.float32)
    return x, y, a / a.sum(), b / b.sum() * 1.2


def measure_chunked_iters(samples=6):
    """Mean chunk-rounded iteration count of the workload distribution —
    the capacity unit the 3x rate and the SLO budget are derived from."""
    counts = []
    for s in range(samples):
        K, a, b = make_problem(12, 14, reg=CFG.reg, seed=1000 + s,
                               peak=1.0 + 2.0 * (s / max(1, samples - 1)))
        C = -CFG.reg * np.log(np.maximum(np.asarray(K, np.float64), 1e-30))
        _, _, stats = sinkhorn_uot_log(jnp.asarray(C), jnp.asarray(a),
                                       jnp.asarray(b), CFG)
        counts.append(math.ceil(int(stats["iters"]) / CHUNK) * CHUNK)
    return float(np.mean(counts))


def make_trace(n, rate_hz, seed, point_frac=0.3):
    """Poisson arrivals of mixed dense / point-cloud requests (one shape
    bucket, bounded cost peakiness so the service model stays honest)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = []
    for i, t in enumerate(arrivals):
        if rng.random() < point_frac:
            out.append((float(t), "points",
                        make_point_problem(12, 10, 3, seed * 7919 + i)))
        else:
            K, a, b = make_problem(12, 14, reg=CFG.reg, seed=seed * 104_729 + i,
                                   peak=float(rng.uniform(1.0, 3.0)))
            out.append((float(t), "dense",
                        (np.asarray(K), np.asarray(a), np.asarray(b))))
    return out


def _submit(sched, kind, payload, deadline):
    if kind == "dense":
        K, a, b = payload
        return sched.submit(K, a, b, deadline=deadline)
    x, y, a, b = payload
    return sched.submit_points(x, y, a, b, scale=POINT_SCALE,
                               deadline=deadline)


def replay(build, trace, warm, budget):
    """Drive one scheduler through warmup + the burst on the simulated
    clock; returns (sched, completions, refused_rids, burst_rid_lo,
    burst makespan)."""
    now = [0.0]
    sched = build(lambda: now[0])
    for kind, payload in warm:       # calibrate predictor + compile pools
        _submit(sched, kind, payload, None)
    while sched.pending or sched.in_flight:
        sched.step()
        now[0] += CHUNK * SPI
    t0, rid_lo = now[0], sched._next_rid
    completions, refused = {}, []
    i = 0
    while i < len(trace) or sched.pending or sched.in_flight:
        if (not sched.pending and not sched.in_flight and i < len(trace)
                and t0 + trace[i][0] > now[0]):
            now[0] = t0 + trace[i][0]
        while i < len(trace) and t0 + trace[i][0] <= now[0]:
            arrival, kind, payload = trace[i]
            try:
                _submit(sched, kind, payload, t0 + arrival + budget)
            except InfeasibleDeadline as err:
                refused.append(err.rid)
            i += 1
        completions.update(sched.step())
        now[0] += CHUNK * SPI
    return sched, completions, refused, rid_lo, now[0] - t0


def account(sched, completions, refused, rid_lo, makespan):
    """Resolve + classify every burst rid; returns the goodput summary.
    Raises AssertionError on lost requests or unlabeled degrades."""
    recs = [r for r in sched.request_log if r.rid >= rid_lo]
    lost = []
    for rid in range(rid_lo, sched._next_rid):
        if rid in completions:
            continue
        out = sched.poll(rid)
        if not isinstance(out, RequestFailure):
            lost.append(rid)
    assert not lost, f"{len(lost)} requests vanished unresolved: {lost[:5]}"
    served = [r for r in recs
              if r.status in ("ok", "timed_out", "retried_ok")
              and r.shed != "dropped"]
    degraded = [r for r in served if r.degrade_level >= 1]
    unlabeled = [r.rid for r in degraded if r.est_error is None]
    assert not unlabeled, f"degraded without error label: {unlabeled[:5]}"
    full_ok = [r for r in served if r.degrade_level == 0 and not r.missed]
    full_miss = [r for r in served if r.degrade_level == 0 and r.missed]
    deg_ok = [r for r in degraded if not r.missed]
    return {
        "goodput": (len(full_ok) + 0.5 * len(deg_ok)) / makespan,
        "served": len(served),
        "full_ok": len(full_ok),
        "full_miss": len(full_miss),
        "deg_ok": len(deg_ok),
        "degraded": len(degraded),
        "refused": len(refused),
        "dropped": len([r for r in recs if r.shed == "dropped"]),
    }


def run():
    smoke = bool(os.environ.get("BENCH_OVERLOAD_SMOKE"))
    devices = len(jax.devices())
    total_lanes = devices * LANES_PER_DEVICE
    i_eff = measure_chunked_iters()
    # 3x capacity: lane pools drain total_lanes/SPI iters per simulated
    # second; arrivals carry 3x that. SLO budget = 2x the margined
    # full-quality service time, so full solves are submit-feasible.
    rate = 3.0 * total_lanes / (SPI * i_eff)
    budget = 2.0 * MARGIN * i_eff * SPI
    # the burst must SUSTAIN 3x overload: short bursts let a wide lane
    # fleet absorb the backlog within the SLO budget, which tests the
    # queue, not the overload model — so size the trace in lane-rounds
    n = max(48, 10 * total_lanes) if smoke else max(160, 20 * total_lanes)
    trace = make_trace(n, rate, seed=0)
    warm = ([("dense", (np.asarray(K), np.asarray(a), np.asarray(b)))
             for K, a, b in (make_problem(12, 14, reg=CFG.reg, seed=s,
                                          peak=1.0 + (s % 3))
                             for s in range(total_lanes))]
            + [("points", make_point_problem(12, 10, 3, 500 + s))
               for s in range(2)])

    common = dict(num_devices=devices, lanes_per_device=LANES_PER_DEVICE,
                  chunk_iters=CHUNK, m_bucket=32, n_bucket=32, impl="jnp",
                  max_queue=10 * n, max_results=2 * n + len(warm))

    def build_drop(clock):
        return ClusterScheduler(CFG, shed_policy="drop", clock=clock,
                                **common)

    def build_ladder(clock, slos=None):
        return ClusterScheduler(
            CFG, shed_policy="degrade", predictive=True,
            seconds_per_iter=SPI, feasibility_margin=MARGIN,
            brownout=BrownoutController(high=1.0, low=0.25, patience=2),
            clock=clock, slos=slos, **common)

    sched_d, comp_d, ref_d, lo_d, span_d = replay(build_drop, trace, warm,
                                                  budget)
    drop = account(sched_d, comp_d, ref_d, lo_d, span_d)
    sched_l, comp_l, ref_l, lo_l, span_l = replay(build_ladder, trace,
                                                  warm, budget)
    ladder = account(sched_l, comp_l, ref_l, lo_l, span_l)

    tag = "smoke" if smoke else f"n{n}"
    emit(f"overload_capacity_{tag}", i_eff,
         f"devices={devices},lanes={total_lanes},rate={rate:.0f}rps,"
         f"slo={budget * 1e3:.0f}ms")
    emit(f"overload_drop_goodput_{tag}", drop["goodput"],
         f"full_ok={drop['full_ok']},miss={drop['full_miss']},"
         f"dropped={drop['dropped']},served={drop['served']}")
    st = sched_l.stats()
    emit(f"overload_ladder_goodput_{tag}", ladder["goodput"],
         f"full_ok={ladder['full_ok']},deg_ok={ladder['deg_ok']},"
         f"refused={ladder['refused']},"
         f"levels={st['degrade_levels']},"
         f"infeasible={st['admission_infeasible']}")

    # hard acceptance asserts (1 and 3 already enforced inside account)
    assert ladder["full_miss"] == 0, (
        f"{ladder['full_miss']} feasibility-admitted full-quality "
        f"completions missed their SLO — the service model lied")
    ratio = ladder["goodput"] / max(drop["goodput"], 1e-12)
    assert ratio >= 1.5, (
        f"ladder goodput only {ratio:.2f}x the drop baseline "
        f"({ladder['goodput']:.1f} vs {drop['goodput']:.1f})")
    emit(f"overload_goodput_ratio_{tag}", ratio * 100,
         f"ladder_vs_drop={ratio:.2f}x,floor=1.5x,lost=0")

    # deepening overload: a 12x spike must escalate past truncation into
    # the sliced tier (at 3x the controller rightly stops at level 1).
    # The operational plane watches the same signature: a degrade-fraction
    # SLO over sim-clock windows must fire during the spike and leave a
    # flight-recorder incident capture behind.
    from repro import obs as obslib
    spike_slos = (obslib.SLO(
        "cluster_degrade_fraction", objective=0.25, window=60.0,
        series=obslib.CounterRatio("cluster.shed_degraded",
                                   "cluster.submitted"),
        patience=1, min_count=4),)
    spike_trace = make_trace(max(n // 2, 4 * total_lanes), 4.0 * rate,
                             seed=1)
    sched_s, comp_s, ref_s, lo_s, span_s = replay(
        lambda clock: build_ladder(clock, slos=spike_slos),
        spike_trace, warm, budget)
    spike = account(sched_s, comp_s, ref_s, lo_s, span_s)
    st_s = sched_s.stats()
    assert st_s["degrade_levels"][2] > 0, (
        f"12x spike never reached the sliced tier: {st_s['degrade_levels']}")
    assert spike["full_miss"] == 0, (
        f"{spike['full_miss']} full-quality SLO misses under the spike")
    assert sched_s.obs.slo.fired("cluster_degrade_fraction"), \
        sched_s.obs.slo.states()
    assert sched_s.flight.triggered("alert:cluster_degrade_fraction"), \
        [d.trigger for d in sched_s.flight.dumps]
    spike_dump = next(d for d in sched_s.flight.dumps
                      if d.trigger == "alert:cluster_degrade_fraction")
    assert spike_dump.rounds, "spike alert dump captured no rounds"
    emit(f"overload_spike_goodput_{tag}", spike["goodput"],
         f"deg_ok={spike['deg_ok']},levels={st_s['degrade_levels']},"
         f"brownout_peak>=2,lost=0")
    emit(f"overload_spike_alerts_{tag}",
         sum(a.state == "firing" for a in sched_s.obs.slo.alerts),
         f"slo=cluster_degrade_fraction,"
         f"dump_rounds={len(spike_dump.rounds)}")
