"""Implicit vs dense cost geometries at serving shapes.

Compares, for a B-stack of point-cloud UOT problems at the bucketed
serving shape (256x384-class, PR 1-3's workload):

  * ``dense_e2e``     — the historical serving pipeline: materialize the
                        squared-Euclidean cost + Gibbs kernel on the HOST
                        (numpy, the POT-style preprocessing), ship the
                        ``B*M*N`` stack to the device, solve.
  * ``implicit_e2e``  — ship ``B*(M+N)*(d+1)`` coordinate floats, hand
                        ``solve_fused_batched`` a ``PointCloudGeometry``;
                        cost tiles are evaluated on-device (on-chip in
                        VMEM on the TPU kernel path), the cost matrix
                        never exists in HBM.

Both run ``impl='auto'`` so the serving shape lands on the resident tier
— which is also where the implicit win compounds: the implicit VMEM
budget is coupling-only (``resident_fits(implicit=True)``), so shapes the
dense path must stream (1024x2048 fp32) run resident under a geometry,
measured below as ``residentfit_*``.

Hard in-bench asserts (the ISSUE-4 acceptance):
  * parity — the implicit path's couplings equal the dense-mirror path's
    bit-for-bit in fp32;
  * memory model — the implicit solve's operand set contains NOTHING
    M*N-sized (largest operand is O((M+N)*d) coordinates; asserted
    against the actual arrays handed to the jit), while the dense path's
    smallest possible cost operand is ``B*M*N*4`` bytes;
  * dispatch — ``impl='auto'`` routes 1024x2048 fp32 to the resident tier
    under the implicit geometry and to the streamed tier dense.

Wall-clock honesty (measured, CPU, fp32, tol-converged ~12-iteration
serving solves): the ISSUE-4 expectation was >=1.3x e2e "from halved
read traffic", but on a CPU-only backend the host->device "transfer" is
a memcpy and the read-traffic savings the geometry buys (the kernel
path's on-chip tiles) are exactly the part CPU cannot express — the
measured e2e delta is the host-materialization slice (~4-7 ms of numpy
cost+exp per 16-problem flush, whether via the gemm trick or POT-style
scipy cdist) against a ~25 ms solve, i.e. ~1.0-1.3x and within this
host's scheduler noise. It is emitted as ``geometry_acceptance_fp32``
with that caveat; the claims that survive ANY backend are asserted
structurally instead (bitwise parity, 38x request-payload cut, zero
M*N-sized solve operands), the resident-fit expansion is measured at
~1.2-2x below, and the bandwidth win proper is a TPU-hardware follow-on
(ROADMAP). The grid-geometry records measure the separable-cost path of
``sinkhorn_uot_uv``: per-axis factor contractions vs dense-K matvecs
(~13-15x at 48x48 grids), which also never form M*N.

``BENCH_GEOMETRY_SMOKE=1`` shrinks the cases to a seconds-long CI run.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UOTConfig
from repro.core.sinkhorn_uv import sinkhorn_uot_uv
from repro.geometry import DenseGeometry, GridGeometry, PointCloudGeometry
from repro.kernels import ops
from benchmarks.common import time_fn, emit


def best_of(fn, reps=9, warmup=2):
    """Best-of-N wall time: the right statistic for an e2e comparison on
    a shared/noisy CPU host, where the median still soaks up scheduler
    interference an order of magnitude above the effect being measured."""
    import time
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def make_clouds(B, M, N, d=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.uniform(0, 1, (B, M, d)).astype(np.float32)
    ys = rng.uniform(0, 1, (B, N, d)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, (B, M)).astype(np.float32)
    a /= a.sum(1, keepdims=True)
    b = rng.uniform(0.5, 1.5, (B, N)).astype(np.float32)
    b = b / b.sum(1, keepdims=True) * 1.2
    return xs, ys, a, b


def _mb(nbytes):
    return nbytes / 1e6


def bench_serving_case(B, M, N, d, tol):
    tag = f"B{B}_{M}x{N}_d{d}"
    xs, ys, a, b = make_clouds(B, M, N, d)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=100, tol=tol)
    scale = float(d)  # unit-cube bound ||x - y||^2 <= d
    geom = PointCloudGeometry.from_points(xs, ys, scale=scale)

    def dense_e2e():
        # host materialization (numpy), then ship the B*M*N stack
        Ks = np.empty((B, M, N), np.float32)
        for k in range(B):
            xn = (xs[k] ** 2).sum(1)[:, None]
            yn = (ys[k] ** 2).sum(1)[None, :]
            Ks[k] = np.exp(-((xn + yn - 2.0 * xs[k] @ ys[k].T) / scale)
                           / cfg.reg)
        return ops.solve_fused_batched(jnp.asarray(Ks), aj, bj, cfg,
                                       impl="auto")[0]

    def implicit_e2e():
        # ship coordinates; reuse the geometry's precomputed norms (what a
        # serving stack caches per request at submit)
        gg = PointCloudGeometry(x=jnp.asarray(xs), y=jnp.asarray(ys),
                                xn=geom.xn, yn=geom.yn, scale=scale)
        return ops.solve_fused_batched(None, aj, bj, cfg, impl="auto",
                                       geometry=gg)[0]

    # ---- memory model: the implicit solve's operands are O((M+N)*d);
    # nothing M*N-sized exists before the coupling itself. The dense
    # path's cost operand alone is B*M*N*4 bytes.
    coord_bytes = sum(int(np.prod(s.shape)) * 4
                      for s in (geom.x, geom.y, geom.xn, geom.yn))
    dense_cost_bytes = B * M * N * 4
    assert coord_bytes == B * (M + N) * (d + 1) * 4
    largest_operand = max(int(np.prod(s.shape))
                          for s in (geom.x, geom.y, geom.xn, geom.yn))
    assert largest_operand < M * N, (largest_operand, M * N)

    # ---- parity: implicit == dense-mirror, bit for bit (fp32). (The
    # host-numpy baseline above reproduces the mirror's arithmetic only
    # to float tolerance — gemm vs unrolled dot — so the bitwise assert
    # runs against DenseGeometry(geometry.cost()); the e2e baseline is
    # additionally checked at float tolerance.)
    P_impl = implicit_e2e()
    P_mirror = ops.solve_fused_batched(
        None, aj, bj, cfg, impl="auto",
        geometry=DenseGeometry(geom.cost()))[0]
    assert (np.asarray(P_impl) == np.asarray(P_mirror)).all(), \
        "implicit path diverged from the dense-mirror path"
    P_dense = dense_e2e()
    scale_p = np.abs(np.asarray(P_dense)).max()
    max_rel = (np.abs(np.asarray(P_dense) - np.asarray(P_impl)).max()
               / scale_p)
    assert max_rel < 1e-4, max_rel

    t_dense = best_of(dense_e2e)
    t_impl = best_of(implicit_e2e)
    emit(f"geometry_dense_e2e_{tag}", t_dense * 1e6,
         f"ship_mb={_mb(dense_cost_bytes):.2f},host_materialize=True")
    emit(f"geometry_implicit_e2e_{tag}", t_impl * 1e6,
         f"ship_mb={_mb(coord_bytes):.3f},transfer_cut="
         f"{dense_cost_bytes / coord_bytes:.0f}x,"
         f"speedup={t_dense / t_impl:.2f}x,bitwise_parity=True")
    return t_dense / t_impl


def bench_resident_fit_expansion(smoke):
    """The implicit VMEM budget is coupling-only: 1024x2048 fp32 streams
    dense (16 B/elt > budget) but runs resident implicit (12 B/elt)."""
    M, N = (256, 512) if smoke else (1024, 2048)
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=10)
    rng = np.random.default_rng(1)
    g = PointCloudGeometry.from_points(
        rng.uniform(0, 1, (M, 3)).astype(np.float32),
        rng.uniform(0, 1, (N, 3)).astype(np.float32), scale=3.0)
    a = jnp.asarray((rng.uniform(0.5, 1.5, M) / M).astype(np.float32))
    b = jnp.asarray((rng.uniform(0.5, 1.5, N) / N).astype(np.float32))
    if not smoke:
        # the acceptance dispatch assert: same shape, same budget — the
        # implicit geometry is what moves it across the resident boundary
        assert not ops.resident_fits(M, N, cfg)
        assert ops.resident_fits(M, N, cfg, implicit=True)
        ops.reset_dispatch_stats()
        ops.solve_fused(None, a, b, cfg, impl="auto", geometry=g)
        assert ops.dispatch_stats() == {"resident": 1, "streamed": 0}
        ops.reset_dispatch_stats()
        ops.solve_fused(None, a, b, cfg, impl="auto",
                        geometry=DenseGeometry(g.cost()))
        assert ops.dispatch_stats() == {"resident": 0, "streamed": 1}

    gd = DenseGeometry(g.cost())
    t_impl = time_fn(lambda: ops.solve_fused(None, a, b, cfg, impl="auto",
                                             geometry=g)[0])
    t_dense = time_fn(lambda: ops.solve_fused(None, a, b, cfg,
                                              impl="auto",
                                              geometry=gd)[0])
    emit(f"residentfit_implicit_{M}x{N}", t_impl * 1e6,
         f"tier=resident,per_solve_coupling_mb={_mb(2 * M * N * 4):.1f}")
    emit(f"residentfit_dense_{M}x{N}", t_dense * 1e6,
         f"tier={'resident' if smoke else 'streamed'},"
         f"speedup_implicit={t_dense / t_impl:.2f}x")


def bench_grid(smoke):
    """Separable grid cost: per-axis contractions vs dense-K matvecs in
    the u/v solver — the geometry never forms M*N at all."""
    n = 16 if smoke else 48
    rng = np.random.default_rng(2)
    Cx = rng.uniform(0, 1, (n, n)).astype(np.float32)
    Cy = rng.uniform(0, 1, (n, n)).astype(np.float32)
    g = GridGeometry((jnp.asarray(Cx), jnp.asarray(Cy)))
    M, N = g.shape
    a = jnp.asarray((rng.uniform(0.5, 1.5, M) / M).astype(np.float32))
    b = jnp.asarray((rng.uniform(0.5, 1.5, N) / N * 1.1)
                    .astype(np.float32))
    cfg = UOTConfig(reg=0.2, reg_m=1.0, num_iters=20)
    K = g.kernel(cfg.reg)

    P_d, _, _ = sinkhorn_uot_uv(K, a, b, cfg)
    P_g, _, _ = sinkhorn_uot_uv(g, a, b, cfg)
    rel = (np.abs(np.asarray(P_d) - np.asarray(P_g)).max()
           / np.abs(np.asarray(P_d)).max())
    assert rel < 1e-4, rel

    t_dense = time_fn(lambda: sinkhorn_uot_uv(K, a, b, cfg)[0])
    t_grid = time_fn(lambda: sinkhorn_uot_uv(g, a, b, cfg)[0])
    flop_dense = 2 * M * N                 # per matvec pair, elements
    flop_grid = n * n * (n + n)            # two per-axis contractions
    emit(f"grid_uv_dense_{M}x{N}", t_dense * 1e6,
         f"kernel_mb={_mb(M * N * 4):.1f},matvec_elts={flop_dense}")
    emit(f"grid_uv_factored_{M}x{N}", t_grid * 1e6,
         f"kernel_mb={_mb((n * n * 2) * 4):.3f},matvec_elts={flop_grid},"
         f"speedup={t_dense / t_grid:.1f}x,never_forms_MN=True")


def run():
    smoke = bool(os.environ.get("BENCH_GEOMETRY_SMOKE"))
    if smoke:
        ratio = bench_serving_case(4, 64, 128, 3, tol=1e-4)
    else:
        ratio = bench_serving_case(16, 256, 384, 3, tol=1e-4)
        bench_serving_case(16, 256, 384, 8, tol=1e-4)
        emit("geometry_acceptance_fp32", ratio,
             "bar>=1.3x_e2e;cpu_delta_is_host_materialization_only_"
             "see_docstring;structural_asserts=bitwise_parity+"
             "no_MN_operands+resident_fit_expansion")
    bench_resident_fit_expansion(smoke)
    bench_grid(smoke)
