"""Paper Fig. 16 analog: multi-node scaling (Tianhe-1 -> TPU pod).

Runs the shard_map row-sharded solver on forced host devices (subprocess,
2/4/8 ranks) checking correctness + measuring per-iteration collective
volume, then projects the paper's 20480^2 strong-scaling curve onto a v5e
pod: T(p) = compute(2MN/p bytes @819GB/s) + allreduce(2N bytes @50GB/s
ring) per iteration.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import emit

HBM_BW = 819e9
ICI_BW = 50e9
ROOT = pathlib.Path(__file__).resolve().parent.parent

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(p)d"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import UOTConfig, sinkhorn_uot_fused
from repro.core.distributed import rowsharded_fused_solver, shard_inputs
import time

M = N = 2048
rng = np.random.default_rng(0)
K = jnp.asarray(np.exp(-rng.uniform(0, 1, (M, N)) / 0.05), jnp.float32)
a = jnp.asarray(rng.uniform(0.5, 1.5, M).astype(np.float32))
b = jnp.asarray(rng.uniform(0.5, 1.5, N).astype(np.float32))
cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=20)
mesh = jax.make_mesh((%(p)d,), ("rows",))
solver = rowsharded_fused_solver(mesh, "rows", cfg)
sA, sa, sb = shard_inputs(mesh, "rows", K, a, b)
ref, _ = sinkhorn_uot_fused(K, a, b, cfg)
A, _ = solver(sA, sa, sb)
ok = bool(jnp.allclose(A, ref, rtol=3e-5, atol=1e-8))
jax.block_until_ready(solver(sA, sa, sb))
t0 = time.perf_counter(); jax.block_until_ready(solver(sA, sa, sb))
dt = time.perf_counter() - t0
hlo = jax.jit(solver).lower(sA, sa, sb).compile().as_text()
n_ar = hlo.count(" all-reduce(") + hlo.count(" all-reduce-start(")
print(json.dumps({"ok": ok, "sec": dt, "allreduce_ops": n_ar}))
"""


def run():
    for p in (2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        out = subprocess.run([sys.executable, "-c", _CHILD % {"p": p}],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        line = out.stdout.strip().splitlines()[-1] if out.stdout else "{}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            rec = {"ok": False, "sec": -1, "allreduce_ops": -1,
                   "err": out.stderr[-200:]}
        emit(f"dist_rowsharded_p{p}_2048", rec.get("sec", -1) / 20 * 1e6,
             f"correct={rec.get('ok')}_allreduce_ops={rec.get('allreduce_ops')}")

    # projected strong scaling, paper's M=N=20480 (v5e constants)
    M = N = 20480
    t1 = None
    for p in (1, 8, 64, 256, 512, 768):
        t_comp = 2 * M * N * 4 / p / HBM_BW
        t_coll = 0.0 if p == 1 else 2 * N * 4 / ICI_BW
        t = t_comp + t_coll
        t1 = t1 or t
        emit(f"dist_projected_p{p}_20480", t * 1e6,
             f"v5e_speedup={t1 / t:.1f}x_(paper_199x@512:_COFFEE_147x,_POT_89x)")
