"""Observability overhead: the scheduler DES with obs on vs off.

Replays the same Poisson trace (the ``bench_serve`` recipe: measured
wall-clock service times advance a simulated clock) through two
``UOTScheduler`` configurations:

  * **off** — ``obs=False``: the metrics registry stays live (``stats()``
    counters are not optional), but the span tracer and the HBM-traffic
    accountant are their null twins;
  * **on**  — the default bundle: every lifecycle event traced, every
    dispatch decision charged — PLUS the full operational telemetry
    plane (PR 10): per-round window ticks over the whole registry, SLO
    burn-rate evaluation against declared objectives, and the black-box
    flight recorder closing a round capture every step. The <= 5% bar
    covers all of it.

Because the DES folds each ``step()``'s measured host time into the
simulated clock, the *simulated* throughput and p99 absorb the obs
layer's real host cost — which is exactly the quantity the acceptance
bar bounds. Each mode runs ``REPEATS`` times after a shared compile
warmup and keeps its best (min makespan / min p99) replay, so scheduler
jitter does not masquerade as obs overhead.

The *on* mode is the full default bundle — which since the measured-
performance layer includes the wall-clock profiler hooks (``PhaseTimer``
round phases + the ``ops.launch_profiler`` kernel timer with its
per-launch device sync), so the <= 5% bar covers profiling too, not just
tracing and byte accounting.

Hard-asserts (the obs-overhead CI job): on-vs-off overhead <= 5% on both
throughput (makespan) and p99 latency. ``BENCH_OBS_SMOKE=1`` shrinks the
trace for CI — at smoke scale the p99 of a 16-request trace is a
max-statistic over ~ms latencies (one noisy chunk anywhere swamps a 5%
bar without any obs involvement), so the smoke run repeats more and
holds p99 to a jitter-tolerant bar while keeping the full 5% bar on
throughput; the strict p99 bar belongs to the full-size run. The smoke
p99 bar is 1.5x since the operational plane landed: a registry-wide
window tick every ``op_interval`` rounds folds ~10us/round of host time
into the simulated clock, which is invisible against full-size ~50ms
latencies but a real ~0.15x on a 16-request smoke p99 of ~6ms (and the
max-statistic's jitter stacks another ~0.1x on busy runners) — the
plane's absolute cost is bounded by the throughput gate, which stays
at 5%.

Alert hygiene rides along: the on-mode replay is a clean, fault-free
DES, so the declared SLOs must fire ZERO alerts — a false positive here
is an alerting bug, and it fails the bench.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import UOTConfig
from benchmarks.common import emit
from benchmarks.bench_serve import make_trace, sim_scheduler, _percentiles

REPEATS = 3
SMOKE_REPEATS = 7
OVERHEAD_BAR = 1.05
SMOKE_P99_BAR = 1.5


def _best_replay(trace, cfg, *, lanes, chunk, obs, repeats=REPEATS,
                 slos=None):
    """Best-of-``repeats`` (min makespan, min p99) replays of the trace."""
    best_T, best_p99 = float("inf"), float("inf")
    sched = None
    for _ in range(repeats):
        lat, T, sched = sim_scheduler(trace, cfg, lanes_per_pool=lanes,
                                      chunk_iters=chunk, warmup=False,
                                      obs=obs, slos=slos)
        _, p99 = _percentiles(lat)
        best_T = min(best_T, T)
        best_p99 = min(best_p99, p99)
    return best_T, best_p99, sched


def run():
    smoke = bool(os.environ.get("BENCH_OBS_SMOKE"))
    if smoke:
        n, rate = 16, 200.0
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=1e-3)
        shapes = [(24, 100), (40, 120)]
        lanes, chunk = 4, 4
    else:
        n, rate = 80, 200.0
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=200, tol=1e-4)
        shapes = [(200, 300), (224, 320), (256, 384)]
        lanes, chunk = 12, 6
    trace = make_trace(n, rate, seed=3, shapes=shapes,
                       peak_range=(1.0, 8.0), reg=cfg.reg)

    # one shared compile warmup (obs state doesn't brand jit signatures,
    # so one warm pass covers both modes)
    sim_scheduler(trace, cfg, lanes_per_pool=lanes, chunk_iters=chunk,
                  warmup=True, obs=False)

    # the on mode declares real SLO objectives so the operational plane
    # does full per-round work: window tick over every registry metric,
    # burn-rate evaluation for each SLO, flight-recorder round capture
    from repro.obs import default_slos
    slos = default_slos("serve", window=30.0)
    repeats = SMOKE_REPEATS if smoke else REPEATS
    T_off, p99_off, s_off = _best_replay(trace, cfg, lanes=lanes,
                                         chunk=chunk, obs=False,
                                         repeats=repeats)
    T_on, p99_on, s_on = _best_replay(trace, cfg, lanes=lanes,
                                      chunk=chunk, obs=None,
                                      repeats=repeats, slos=slos)

    # the off mode must actually be off, and the on mode actually on
    assert not s_off.obs.tracer.enabled and not s_off.obs.traffic.enabled
    assert s_on.obs.tracer.enabled and s_on.obs.traffic.enabled
    assert len(s_on.obs.tracer.events) > 0
    assert s_on.obs.traffic.totals()["bytes"] > 0
    # ... including the measured-performance instruments: kernel cells
    # recorded and round phases timed when on, null twins when off —
    # this is what puts the profiler's per-launch sync under the bar
    assert s_on.obs.profile.enabled and len(s_on.obs.profile.cells()) > 0
    assert s_on.obs.registry.histogram(
        "profile.phase.serve.chunk").snapshot()["count"] > 0
    assert not s_off.obs.profile.enabled and not s_off.obs.phases.enabled
    # ... and the operational telemetry plane: windows ticked every
    # round, SLOs evaluated, flight rounds recorded when on; null twins
    # when off — so the <= 5% bar covers PR 10's whole plane
    assert s_on.obs.windows.enabled and s_on.obs.windows.samples > 1
    assert s_on.obs.slo.enabled and s_on.obs.slo.states()
    assert s_on.flight.enabled and len(s_on.flight.rounds()) > 0
    assert not s_off.obs.windows.enabled and not s_off.obs.slo.enabled \
        and not s_off.flight.enabled
    # alert hygiene: a clean fault-free DES must fire zero alerts
    clean_alerts = [a for a in s_on.obs.slo.alerts if a.state == "firing"]
    assert not clean_alerts, \
        f"false-positive alerts on a clean replay: {clean_alerts}"
    # the exporter renders the whole bundle as valid Prometheus text
    from repro.obs import parse_prometheus_text
    families = parse_prometheus_text(s_on.exporter.prometheus())
    assert any(k.startswith("serve_") for k in families), sorted(families)[:5]
    # the registry stays live either way: stats() totals must agree
    assert s_off.stats()["completed"] == s_on.stats()["completed"] == n

    tput_ratio = T_on / T_off          # >1 = obs made the replay slower
    p99_ratio = p99_on / p99_off
    p99_bar = SMOKE_P99_BAR if smoke else OVERHEAD_BAR
    tag = "smoke" if smoke else f"n{n}"
    emit(f"obs_off_p99_{tag}", p99_off * 1e6,
         f"throughput={n / T_off:.1f}rps,makespan={T_off:.3f}s")
    emit(f"obs_on_p99_{tag}", p99_on * 1e6,
         f"throughput={n / T_on:.1f}rps,"
         f"events={len(s_on.obs.tracer.events)},"
         f"charges={s_on.obs.traffic.totals()['charges']}")
    emit(f"obs_plane_{tag}", s_on.obs.windows.samples,
         f"slos={len(s_on.obs.slo.states())},alerts=0,"
         f"flight_rounds={len(s_on.flight.rounds())},"
         f"prom_families={len(families)}")
    emit(f"obs_overhead_{tag}", (tput_ratio - 1.0) * 100,
         f"tput_ratio={tput_ratio:.4f},p99_ratio={p99_ratio:.4f},"
         f"bar={OVERHEAD_BAR:.2f}")
    assert tput_ratio <= OVERHEAD_BAR, \
        (f"obs-on makespan {T_on:.4f}s is {tput_ratio:.3f}x obs-off "
         f"{T_off:.4f}s (bar: {OVERHEAD_BAR}x)")
    assert p99_ratio <= p99_bar, \
        (f"obs-on p99 {p99_on * 1e3:.2f}ms is {p99_ratio:.3f}x obs-off "
         f"{p99_off * 1e3:.2f}ms (bar: {p99_bar}x)")
