"""Chaos harness: the fault-containment ladder under injected faults.

Replays one trace of UOT problems through the 8-device
``ClusterScheduler`` twice, on the measured-service simulated clock (the
bench_cluster recipe — one scheduling round costs one measured chunk
time):

  * **baseline** — the clean requests only, fault-free, 8 healthy devices;
  * **chaos**    — the full trace with ~5% NaN payloads (poison the lane
    in flight), ~3% overflow-regime marginals (refused at admission by the
    ``uv_safe`` bound), and one device of 8 blacked out mid-replay
    (``DeviceBlackout`` NaNs its whole pool state; the scheduler must
    quarantine it, requeue its in-flight requests, and never place on it
    again).

The fault plan is materialized up front with the seeded injectors from
``repro.serve.faults`` (same (seed, rid) streams the schedulers' hook
uses), so the baseline can replay exactly the chaos run's clean subset.

Hard asserts (the ISSUE-6 acceptance bar + the PR-7 observability bar):
  * **zero requests lost** — every submitted rid resolves to exactly one
    coupling or typed ``RequestFailure``; refused rids resolve too;
  * **zero span loss** — the chaos run's trace exports to JSONL, reloads
    exactly, and every submitted rid carries exactly one terminal
    ``complete`` event (``SpanTracer.check_complete``);
  * **traffic totals match the dispatch-table formulas** — every
    aggregate the chaos scheduler's ``TrafficAccountant`` charged
    re-derives mechanically from its formula key
    (``bytes == count * formula(**key)``), and the per-route rollup sums
    the records;
  * **bit-identical healthy results** — every clean request's coupling
    equals the fault-free baseline's, including requests bounced off the
    blacked-out device (requeue replays them from the intact host
    payload);
  * **the blacked-out device is quarantined** and receives no placements
    after the blackout;
  * **goodput >= 0.9x fault-free** — clean couplings delivered per
    simulated second. Both runs deliver the same clean set, so the ratio
    isolates the *time* cost of containment: requeues, poisoned-lane
    occupancy until detection, and the capacity of the lost device. The
    trace runs at ~0.6 utilization — the headroom regime a
    fault-tolerant deployment actually provisions (at 100% saturation,
    losing 1 of 8 devices costs 12.5% throughput before containment even
    starts, and no scheduler can win it back).

``BENCH_CHAOS_SMOKE=1`` shrinks the trace to a seconds-long CI run (and
uses the real 8-device mesh when the job forces 8 host devices).
"""
from __future__ import annotations

import os
import pathlib
import tempfile

import jax
import numpy as np

from repro import obs as obslib
from repro.core import InvalidProblemError, UOTConfig
from repro.cluster import ClusterScheduler, cluster_mesh
from repro.serve import RequestFailure, faults
from benchmarks.common import emit, make_problem
from benchmarks.bench_cluster import measure_chunk_time
from repro.kernels import ops

N_DEV = 8
BLACKOUT_DEV = 2
NAN_RATE, OVERFLOW_RATE = 0.05, 0.03


def make_trace(n, n_wave, mean_gap, shapes, peak_range, cfg, seed=0):
    """A wave of ``n_wave`` requests at t=0 (so the blackout at step 2
    strikes a busy device) followed by Poisson arrivals with ``mean_gap``
    inter-arrival time. Returns [(t, K, a, b)] sorted by arrival."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap, size=n)
    arrivals = [0.0] * n_wave + list(np.cumsum(gaps[n_wave:]))
    out = []
    for i, t in enumerate(arrivals):
        m, nn = shapes[rng.integers(len(shapes))]
        K, a, b = make_problem(m, nn, reg=cfg.reg, seed=seed * 7919 + i,
                               peak=float(rng.uniform(*peak_range)))
        out.append((float(t), np.asarray(K), np.asarray(a), np.asarray(b)))
    return out


def plan_faults(trace, seed):
    """Apply the payload injectors up front: returns (chaos_trace, tags)
    where tags[i] is None for clean requests. Uses the same (seed, rid)
    streams the schedulers' fault_injector hook would, with rid = trace
    index (requests are submitted in trace order)."""
    inj = faults.Compose([faults.NaNPayload(NAN_RATE, seed=seed),
                          faults.OverflowConfig(OVERFLOW_RATE,
                                                seed=seed + 1)])
    chaos, tags = [], []
    for i, (t, K, a, b) in enumerate(trace):
        K, a, b, tag = inj.on_submit(i, K, a, b)
        chaos.append((t, np.asarray(K), np.asarray(a), np.asarray(b)))
        tags.append(tag)
    return chaos, tags


def verify_traffic(records):
    """Re-derive every traffic aggregate from its formula key — the
    mechanical check that what the accountant charged matches
    ``kernels/ops.py``'s dispatch-table formulas cell by cell."""
    for r in records:
        if r["kind"] == "chunk":
            per = obslib.chunk_bytes(r["lanes"], r["M"], r["N"],
                                     r["itemsize"], r["iters"],
                                     tier=r["tier"])
            flops = obslib.modeled_flops(r["M"], r["N"], r["iters"],
                                         lanes=r["lanes"])
            coll = 0
        elif r["kind"] == "solve":
            per = r["lanes"] * obslib.solve_bytes(
                r["M"], r["N"], r["itemsize"], r["iters"], tier=r["tier"],
                source=r["source"], d=r["d"])
            flops = obslib.modeled_flops(r["M"], r["N"], r["iters"],
                                         lanes=r["lanes"])
            coll = (obslib.gang_collective_bytes(r["N"], r["iters"])
                    if r["route"] == "gang" else 0)
        else:                                  # admission's G payment
            per = obslib.cost_source_bytes(r["M"], r["N"], r["itemsize"],
                                           source=r["source"], d=r["d"])
            flops = coll = 0
        assert r["bytes"] == r["count"] * per, r
        assert r["flops"] == r["count"] * flops, r
        assert r["coll_bytes"] == r["count"] * coll, r


def replay(trace, cfg, t_chunk, *, lanes, chunk, m_bucket, mesh,
           injector=None, slos=None):
    """Drive the cluster step loop on the simulated clock. Returns
    (results by trace index, rid by trace index, makespan, scheduler);
    refused submissions land in the rid map too (their typed failure is
    pollable by that rid). ``slos`` attaches the operational telemetry
    plane (windows/burn rates in *simulated* seconds)."""
    now = [0.0]
    cs = ClusterScheduler(cfg, mesh=mesh, num_devices=N_DEV,
                          lanes_per_device=lanes, chunk_iters=chunk,
                          m_bucket=m_bucket, impl="jnp",
                          max_results=len(trace) + 8,
                          fault_injector=injector, clock=lambda: now[0],
                          slos=slos)
    i, rid_of, rid_to_idx, out = 0, {}, {}, {}
    while i < len(trace) or cs.pending or cs.in_flight:
        if (not cs.pending and not cs.in_flight
                and i < len(trace) and trace[i][0] > now[0]):
            now[0] = trace[i][0]     # idle: jump to the next arrival
        while i < len(trace) and trace[i][0] <= now[0]:
            try:
                rid_of[i] = cs.submit(*trace[i][1:])
            except InvalidProblemError as err:
                rid_of[i] = err.rid
            rid_to_idx[rid_of[i]] = i
            i += 1
        for rid, P in cs.step().items():
            out[rid_to_idx[rid]] = P
        now[0] += t_chunk
    return out, rid_of, now[0], cs


def run():
    smoke = bool(os.environ.get("BENCH_CHAOS_SMOKE"))
    if smoke:
        n, lanes, chunk = 48, 2, 4
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=24, tol=1e-3)
        shapes = [(24, 100), (32, 120)]
        peak_range = (1.0, 6.0)
    else:
        n, lanes, chunk = 160, 2, 6
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=120, tol=1e-4)
        shapes = [(48, 100), (56, 120), (64, 128)]
        peak_range = (2.0, 10.0)
    m_bucket = 64
    n_lanes = N_DEV * lanes

    trace = make_trace(n, n_wave=n_lanes, mean_gap=1.0, shapes=shapes,
                       peak_range=peak_range, cfg=cfg)
    bucket = ops.bucket_shape(*max(s for s in shapes), m_bucket, 128)
    t_chunk = measure_chunk_time(bucket, lanes, chunk, cfg,
                                 [t[1:] for t in trace])
    # ~0.6 utilization: inter-arrival = est. chunks/request * t_chunk
    # / (n_lanes * util) -- rebuild the tail with the measured quantum
    est_chunks = 5.0
    mean_gap = est_chunks * t_chunk / (n_lanes * 0.6)
    trace = make_trace(n, n_wave=n_lanes, mean_gap=mean_gap, shapes=shapes,
                       peak_range=peak_range, cfg=cfg)
    chaos_trace, tags = plan_faults(trace, seed=7)
    clean = [i for i in range(n) if tags[i] is None]
    n_nan = sum(t == "nan_payload" for t in tags)
    n_over = sum(t == "overflow_cfg" for t in tags)
    assert n_nan > 0 and n_over > 0, "fault plan realized no faults"

    mesh = cluster_mesh(N_DEV) if jax.device_count() >= N_DEV else None
    kw = dict(lanes=lanes, chunk=chunk, m_bucket=m_bucket, mesh=mesh)

    # chaos-signature SLOs (sim-clock windows): a quarantine or a typed
    # request failure inside the window is an incident — objective 0.5
    # on a counter delta means "fires on the first event"
    slos = (obslib.SLO("cluster_quarantine", objective=0.5, window=60.0,
                       series=obslib.CounterDelta(
                           "cluster.devices_quarantined"), patience=1),
            obslib.SLO("cluster_failures", objective=0.5, window=60.0,
                       series=obslib.CounterDelta("cluster.failed"),
                       patience=1))

    base_out, _, base_T, base_cs = replay(
        [trace[i] for i in clean], cfg, t_chunk, slos=slos, **kw)
    assert len(base_out) == len(clean)

    blackout = faults.DeviceBlackout(BLACKOUT_DEV, at_step=2)
    chaos_out, rid_of, chaos_T, cs = replay(
        chaos_trace, cfg, t_chunk, injector=blackout, slos=slos, **kw)
    st = cs.stats()

    # --- zero requests lost: every index resolves exactly once ---------
    failures, lost = {}, []
    for i in range(n):
        if i in chaos_out:
            continue
        f = cs.poll(rid_of[i])
        if isinstance(f, RequestFailure):
            failures[i] = f
        else:
            lost.append(i)
    assert not lost, f"requests lost without disposition: {lost}"

    # --- typed outcomes match the fault plan ---------------------------
    for i, f in failures.items():
        assert tags[i] is not None, \
            f"clean request {i} ended as {f.status}"
        want = "rejected" if tags[i] == "overflow_cfg" else "failed"
        assert f.status == want, (i, tags[i], f.status)

    # --- blast radius: clean couplings bit-identical to fault-free -----
    base_idx = {idx: k for k, idx in enumerate(clean)}
    for i in clean:
        assert i in chaos_out, f"clean request {i} has no coupling"
        assert np.array_equal(chaos_out[i], base_out[base_idx[i]]), \
            f"clean request {i} diverged under chaos"

    # --- the blacked-out device is out of rotation ---------------------
    assert st["device_health"][BLACKOUT_DEV] == "quarantined", \
        st["device_health"]
    late = [t for t in cs.request_log
            if t.route == "lane" and t.retries > 0]
    assert all(t.device != BLACKOUT_DEV for t in late)
    tag = "smoke" if smoke else f"n{n}"

    # --- alert correctness: the blackout trips the quarantine SLO with a
    # flight-recorder incident capture attached; the fault-free baseline
    # replay (same SLO set, same clock discipline) fires nothing --------
    assert cs.obs.slo.fired("cluster_quarantine"), cs.obs.slo.states()
    assert cs.flight.triggered("alert:cluster_quarantine"), \
        [d.trigger for d in cs.flight.dumps]
    assert cs.flight.triggered("quarantine"), \
        [d.trigger for d in cs.flight.dumps]
    alert_dump = next(d for d in cs.flight.dumps
                      if d.trigger == "alert:cluster_quarantine")
    assert alert_dump.rounds, "alert dump captured no scheduler rounds"
    base_alerts = [a for a in base_cs.obs.slo.alerts if a.state == "firing"]
    assert not base_alerts, \
        f"fault-free baseline fired alerts: {base_alerts}"
    assert not base_cs.flight.triggered("alert:"), \
        [d.trigger for d in base_cs.flight.dumps]
    flight_path = pathlib.Path(tempfile.gettempdir()) / "FLIGHT_chaos.jsonl"
    cs.flight.write_jsonl(flight_path, dump=alert_dump)
    reloaded_fl = obslib.FlightRecorder.load_jsonl(flight_path)
    assert len(reloaded_fl.rounds) == len(alert_dump.rounds)
    emit(f"chaos_alerts_{tag}",
         sum(a.state == "firing" for a in cs.obs.slo.alerts),
         f"slo=cluster_quarantine,dumps={len(cs.flight.dumps)},"
         f"baseline_alerts=0,flight={flight_path.name}")

    # --- zero span loss: JSONL round-trip + one terminal span per rid --
    trace_path = pathlib.Path(tempfile.gettempdir()) / "OBS_chaos.jsonl"
    n_events = cs.obs.tracer.write_jsonl(trace_path)
    reloaded = obslib.SpanTracer.from_events(
        obslib.SpanTracer.load_jsonl(trace_path))
    assert reloaded.events == cs.obs.tracer.events, "JSONL round-trip drift"
    audit = reloaded.check_complete(submitted=rid_of.values())
    assert not audit["missing"] and not audit["multiple"], audit
    emit(f"chaos_spans_{tag}", n_events,
         f"rids={audit['total']},span_loss=0,jsonl={trace_path.name}")

    # --- traffic: every charge re-derives from its formula key ---------
    records = cs.obs.traffic.records()
    assert records, "chaos run charged no traffic"
    verify_traffic(records)
    per_route = cs.obs.traffic.per_route()
    assert "lane" in per_route and per_route["lane"]["bytes"] > 0
    emit(f"chaos_traffic_{tag}", cs.obs.traffic.bytes_per_solve(),
         f"routes={sorted(per_route)},"
         f"GB={cs.obs.traffic.totals()['bytes'] / 1e9:.3f},"
         f"ai={cs.obs.traffic.roofline()['arithmetic_intensity']:.2f}")

    # --- goodput: clean couplings / sim second, vs fault-free ----------
    goodput_base = len(clean) / base_T
    goodput_chaos = len(clean) / chaos_T
    ratio = goodput_chaos / goodput_base
    emit(f"chaos_chunk_service_{tag}", t_chunk * 1e6,
         f"bucket={bucket},lanes={lanes},chunk={chunk}")
    emit(f"chaos_fault_mix_{tag}", (n - len(clean)) / n * 100,
         f"nan={n_nan},overflow={n_over},blackout=dev{BLACKOUT_DEV},"
         f"requeued={st['requeued']},failed={st['failed']},"
         f"rejected={st['rejected']}")
    emit(f"chaos_goodput_base_{tag}", goodput_base,
         f"clean={len(clean)}/{n},makespan={base_T:.3f}s_sim")
    emit(f"chaos_goodput_{tag}", goodput_chaos,
         f"ratio={ratio:.3f}x_vs_fault_free,"
         f"makespan={chaos_T:.3f}s_sim,mesh={mesh is not None}")
    assert ratio >= 0.9, \
        (f"chaos goodput {goodput_chaos:.2f}/s is {ratio:.2f}x the "
         f"fault-free {goodput_base:.2f}/s (bar: 0.9x)")
