"""Paper Fig. 9/10 analog: UOT solver wall time, fused vs 4-pass baseline.

This container is a single CPU core, so wall-clock here measures the XLA:CPU
execution of both schedules (the paper's single-threaded Figure 9 setting);
the TPU projection lives in bench_kernel (roofline-model based).
"""
from __future__ import annotations

import jax

from repro.core import (UOTConfig, sinkhorn_uot_baseline, sinkhorn_uot_fused,
                        sinkhorn_uot_uv_fused)
from benchmarks.common import make_problem, time_fn_full, emit

SIZES = [(1024, 1024), (2048, 2048), (4096, 4096), (1024, 8192)]
ITERS = 20


def run():
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=ITERS)
    for M, N in SIZES:
        K, a, b = make_problem(M, N)
        base = jax.jit(lambda K, a, b: sinkhorn_uot_baseline(K, a, b, cfg)[0])
        fused = jax.jit(lambda K, a, b: sinkhorn_uot_fused(K, a, b, cfg)[0])
        uv = jax.jit(lambda K, a, b: sinkhorn_uot_uv_fused(K, a, b, cfg)[0])
        # first_us carries the cold trace+compile call; us_per_call stays
        # steady-state so cross-run comparisons never mix the two regimes
        f_base, t_base = time_fn_full(base, K, a, b)
        f_fused, t_fused = time_fn_full(fused, K, a, b)
        f_uv, t_uv = time_fn_full(uv, K, a, b)
        emit(f"uot_baseline_{M}x{N}", t_base / ITERS * 1e6,
             f"iters={ITERS}", first_us=f_base * 1e6)
        emit(f"uot_mapuot_{M}x{N}", t_fused / ITERS * 1e6,
             f"speedup={t_base / t_fused:.2f}x_vs_POT",
             first_us=f_fused * 1e6)
        emit(f"uot_uvfused_{M}x{N}", t_uv / ITERS * 1e6,
             f"speedup={t_base / t_uv:.2f}x_vs_POT(beyond-paper)",
             first_us=f_uv * 1e6)
