"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV. Mapping to the paper:
  bench_uot          -> Fig 9/10 (CPU single/multi-thread performance)
  bench_traffic      -> Fig 11  (cache misses -> HBM traffic)
  bench_kernel       -> Fig 8/13/14 (GPU tiling/perf/throughput -> TPU roofline)
  bench_memory       -> Fig 15  (peak memory consumption)
  bench_distributed  -> Fig 16  (Tianhe-1 scaling -> pod scaling)
  bench_application  -> Fig 17  (color-transfer application)
  bench_moe_router   -> beyond-paper (Sinkhorn-UOT MoE routing)
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_uot, bench_traffic, bench_kernel,
                            bench_memory, bench_distributed,
                            bench_application, bench_moe_router)
    mods = [bench_uot, bench_traffic, bench_kernel, bench_memory,
            bench_distributed, bench_application, bench_moe_router]
    print("name,us_per_call,derived")
    failed = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{mod.__name__},-1,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
