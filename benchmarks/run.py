"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV and writes one ``BENCH_<suite>.json``
per suite (into --out-dir, default cwd) so the perf trajectory accumulates
across PRs. Each suite also gets an ``OBS_<suite>.json`` — the
process-global observability dump (``repro.obs.global_dump``: registry
counters/gauges/histograms + the HBM-traffic accountant's per-route byte
totals and roofline summary), reset between suites so each file describes
one suite's work. Both payloads carry a ``meta`` provenance block
(``common.bench_meta``: schema version, git sha, jax versions, machine
fingerprint); ``--check`` re-reads the committed ``BENCH_<suite>.json``
from ``--baseline-dir`` before writing and fails the run when any record
regresses past ``--threshold`` (default 1.3x) on the same machine —
cross-machine comparisons are skipped, not judged. Mapping to the paper:
  bench_uot          -> Fig 9/10 (CPU single/multi-thread performance)
  bench_traffic      -> Fig 11  (cache misses -> HBM traffic)
  bench_kernel       -> Fig 8/13/14 (GPU tiling/perf/throughput -> TPU roofline)
  bench_memory       -> Fig 15  (peak memory consumption)
  bench_distributed  -> Fig 16  (Tianhe-1 scaling -> pod scaling)
  bench_application  -> Fig 17  (color-transfer application)
  bench_moe_router   -> beyond-paper (Sinkhorn-UOT MoE routing)
  bench_batch        -> beyond-paper (batched serving: fused stack vs loop)
  bench_serve        -> beyond-paper (continuous scheduler vs flush barrier
                        on a Poisson arrival trace; BENCH_SERVE_SMOKE=1
                        shrinks it to a CI smoke run)
  bench_resident     -> beyond-paper (VMEM-resident whole-solve fusion vs
                        per-iteration streamed launches;
                        BENCH_RESIDENT_SMOKE=1 for the CI smoke run)
  bench_geometry     -> beyond-paper (implicit cost geometries: coordinate
                        payloads + on-chip cost tiles vs host-materialized
                        dense C; BENCH_GEOMETRY_SMOKE=1 for the CI smoke
                        run)
  bench_cluster      -> beyond-paper (multi-device serving: 8 sharded lane
                        pool devices vs the 1-device scheduler, measured
                        -service DES; BENCH_CLUSTER_SMOKE=1 for the CI
                        smoke run on 8 forced host devices)
  bench_chaos        -> beyond-paper (fault-containment chaos harness: NaN
                        payloads + overflow configs + a device blackout
                        through the 8-device scheduler; hard-asserts zero
                        lost requests, zero span loss in the exported
                        JSONL trace, traffic totals that match the
                        dispatch-table formulas, bit-identical healthy
                        results, and goodput >= 0.9x fault-free;
                        BENCH_CHAOS_SMOKE=1 for the CI smoke run)
  bench_obs          -> beyond-paper (observability overhead: the
                        bench_serve scheduler DES with the obs bundle
                        enabled vs disabled; hard-asserts <= 5% overhead
                        on throughput and p99; BENCH_OBS_SMOKE=1 for the
                        CI smoke run)
  bench_overload     -> beyond-paper (overload robustness: 3x-capacity
                        Poisson burst, predictive admission + degrade
                        ladder vs the drop-policy baseline on a simulated
                        clock; hard-asserts zero lost requests, zero SLO
                        misses among full-quality completions, labeled
                        degrades, goodput >= 1.5x the baseline, and a 12x
                        spike escalating into the sliced 1-D tier;
                        BENCH_OVERLOAD_SMOKE=1 for the CI smoke run)
"""
import argparse
import json
import pathlib
import platform
import sys
import traceback

import jax


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default=".",
                        help="directory for BENCH_<suite>.json files")
    parser.add_argument("--suite", action="append", default=None,
                        help="run only these suites (repeatable), e.g. "
                             "--suite bench_batch")
    parser.add_argument("--check", action="store_true",
                        help="after each suite, compare its fresh records "
                             "against the committed baseline in "
                             "--baseline-dir (common.check_payload); exit "
                             "1 on any regression")
    parser.add_argument("--baseline-dir", default=".",
                        help="directory holding baseline BENCH_<suite>.json "
                             "files for --check (default: cwd)")
    parser.add_argument("--threshold", type=float, default=1.3,
                        help="per-record slowdown ratio that counts as a "
                             "regression for --check (default 1.3)")
    args = parser.parse_args(argv)

    from repro import obs as obslib
    from benchmarks import (common, bench_uot, bench_traffic, bench_kernel,
                            bench_memory, bench_distributed,
                            bench_application, bench_moe_router, bench_batch,
                            bench_serve, bench_resident, bench_geometry,
                            bench_cluster, bench_chaos, bench_obs,
                            bench_overload)
    mods = [bench_uot, bench_traffic, bench_kernel, bench_memory,
            bench_distributed, bench_application, bench_moe_router,
            bench_batch, bench_serve, bench_resident, bench_geometry,
            bench_cluster, bench_chaos, bench_obs, bench_overload]
    if args.suite:
        known = {m.__name__.split(".")[-1] for m in mods}
        unknown = set(args.suite) - known
        if unknown:
            parser.error(f"unknown suite(s) {sorted(unknown)}; "
                         f"known: {sorted(known)}")
        mods = [m for m in mods if m.__name__.split(".")[-1] in args.suite]

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    baseline_dir = pathlib.Path(args.baseline_dir)
    meta = common.bench_meta()
    print("name,us_per_call,derived")
    failed = 0
    regressed = 0
    for mod in mods:
        suite = mod.__name__.split(".")[-1]
        json_path = out_dir / f"BENCH_{suite}.json"
        obs_path = out_dir / f"OBS_{suite}.json"
        # read the baseline BEFORE writing the fresh payload — --check
        # with out-dir == baseline-dir must not clobber-then-compare
        baseline = None
        if args.check:
            bpath = baseline_dir / f"BENCH_{suite}.json"
            if bpath.exists():
                baseline = json.loads(bpath.read_text())
        common.reset_records()
        # zero the process-global registry + traffic accountant so the
        # suite's OBS dump describes this suite's work only
        obslib.reset_global()
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{mod.__name__},-1,FAILED", file=sys.stderr)
            traceback.print_exc()
            # don't let a stale JSON from an earlier run masquerade as
            # this run's result
            json_path.unlink(missing_ok=True)
            obs_path.unlink(missing_ok=True)
            continue
        payload = {
            "suite": suite,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "meta": meta,
            "records": common.reset_records(),
        }
        json_path.write_text(json.dumps(payload, indent=2) + "\n")
        obs_path.write_text(
            json.dumps({"suite": suite, "meta": meta,
                        **obslib.global_dump()}, indent=2)
            + "\n")
        if args.check:
            if baseline is None:
                print(f"check {suite}: SKIP (no baseline in "
                      f"{baseline_dir})", file=sys.stderr)
                continue
            verdict = common.check_payload(payload, baseline,
                                           threshold=args.threshold)
            if verdict["status"] == "skip":
                print(f"check {suite}: SKIP ({verdict['reason']})",
                      file=sys.stderr)
            elif verdict["status"] == "fail":
                regressed += 1
                for f in verdict["failures"]:
                    print(f"check {suite}: REGRESSION {f['name']} "
                          f"{f['baseline_us']} -> {f['fresh_us']} us "
                          f"({f['ratio']}x > {args.threshold}x)",
                          file=sys.stderr)
            else:
                print(f"check {suite}: OK ({verdict['compared']} records "
                      f"within {args.threshold}x)", file=sys.stderr)
    if failed or regressed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
