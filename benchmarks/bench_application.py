"""Paper Fig. 17 analog: end-to-end color-transfer application."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import UOTConfig
from repro.core.applications import color_transfer
from benchmarks.common import time_fn, emit

SIZES = [512, 1024, 2048]


def run():
    rng = np.random.default_rng(0)
    for n in SIZES:
        src = rng.uniform(0, 1, size=(n, 3)).astype(np.float32)
        dst = np.clip(rng.normal(0.6, 0.2, size=(n, 3)), 0, 1).astype(np.float32)
        cfg = UOTConfig(reg=0.05, reg_m=10.0, num_iters=100)
        f_fused = jax.jit(lambda s, d: color_transfer(s, d, cfg, fused=True)[0])
        f_base = jax.jit(lambda s, d: color_transfer(s, d, cfg, fused=False)[0])
        tb = time_fn(f_base, src, dst)
        tf = time_fn(f_fused, src, dst)
        emit(f"app_colortransfer_baseline_{n}", tb * 1e6, "end_to_end")
        emit(f"app_colortransfer_mapuot_{n}", tf * 1e6,
             f"speedup={tb / tf:.2f}x")
