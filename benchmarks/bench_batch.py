"""Batched serving path: one fused launch for B problems vs the alternatives.

Compares, for a stack of B same-shape problems:
  * ``batched_fused``   — ops.solve_fused_batched, ONE (batch, row_blocks)
                          grid kernel launch per iteration for the stack.
  * ``loop_fused``      — Python loop of per-problem ops.solve_fused
                          (B dispatches + B paddings per solve).
  * ``vmap_baseline``   — jax.vmap of the 4-pass jnp baseline (XLA batching,
                          no explicit single-pass schedule).
  * ``batched_bf16``    — batched_fused with bf16 storage / fp32 accumulation
                          (half the HBM bytes per iteration).

The ISSUE-1 acceptance bar: batched_fused >= 1.5x loop_fused at B=32,
256x256 on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UOTConfig, sinkhorn_uot_baseline
from repro.kernels import ops
from benchmarks.common import time_fn, emit

CASES = [(32, 256, 256), (8, 512, 512)]
ITERS = 20


def make_stack(B, M, N, reg=0.05, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(B, M, N)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=(B, M)).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=(B, N)).astype(np.float32)
    a = a / a.sum(axis=1, keepdims=True)
    b = b / b.sum(axis=1, keepdims=True) * 1.2
    K = np.exp(-C / reg) * (a[:, :, None] * b[:, None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def run():
    cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=ITERS)
    for B, M, N in CASES:
        K, a, b = make_stack(B, M, N)

        def batched(K, a, b):
            return ops.solve_fused_batched(K, a, b, cfg)[0]

        def loop(K, a, b):
            return [ops.solve_fused(K[i], a[i], b[i], cfg)[0]
                    for i in range(B)]

        vmap_base = jax.jit(jax.vmap(
            lambda K_, a_, b_: sinkhorn_uot_baseline(K_, a_, b_, cfg)[0]))

        def batched_bf16(K, a, b):
            return ops.solve_fused_batched(
                K, a, b, cfg, storage_dtype=jnp.bfloat16)[0]

        t_batched = time_fn(batched, K, a, b)
        t_loop = time_fn(loop, K, a, b)
        t_vmap = time_fn(vmap_base, K, a, b)
        t_bf16 = time_fn(batched_bf16, K, a, b)

        tag = f"B{B}_{M}x{N}"
        emit(f"batch_loop_fused_{tag}", t_loop / ITERS * 1e6,
             f"iters={ITERS}")
        emit(f"batch_fused_{tag}", t_batched / ITERS * 1e6,
             f"speedup={t_loop / t_batched:.2f}x_vs_loop")
        emit(f"batch_vmap_baseline_{tag}", t_vmap / ITERS * 1e6,
             f"speedup={t_vmap / t_batched:.2f}x_slower_than_batched")
        emit(f"batch_fused_bf16_{tag}", t_bf16 / ITERS * 1e6,
             f"speedup={t_loop / t_bf16:.2f}x_vs_loop")
