"""Beyond-paper table: Sinkhorn-UOT MoE router — balance quality + cost.

The framework-integration benchmark: expert-load coefficient of variation
(CV) and token drop rate under capacity 1.0, top-k vs MAP-UOT sinkhorn
routing, plus router wall-time overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import moe_init, moe_apply
from benchmarks.common import time_fn, emit


def run():
    key = jax.random.PRNGKey(0)
    d, E, k = 256, 32, 4
    p = moe_init(key, d, 512, E)
    # skewed inputs -> hot experts under plain top-k
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 512, d)) + 1.5

    for router in ("topk", "sinkhorn"):
        fn = jax.jit(lambda p, x: moe_apply(
            p, x, top_k=k, capacity_factor=1.0, router=router, dbg=True))
        _, aux, dbg = fn(p, x)
        ids = np.asarray(dbg["ids"]).ravel()
        counts = np.bincount(ids, minlength=E)
        cv = counts.std() / counts.mean()
        drop = 1.0 - float(np.asarray(dbg["keep"]).mean())
        t = time_fn(lambda p, x: fn(p, x)[0], p, x)
        emit(f"moe_router_{router}", t * 1e6,
             f"load_cv={cv:.3f}_droprate={drop:.3f}_aux={float(aux):.3f}")
