"""Paper Fig. 8/13/14 analog: the Pallas kernel on the TPU roofline.

No TPU in this container, so kernel quality is assessed structurally:
traffic per iteration from the analytic model validated against
cost_analysis of the interpret-mode jnp semantics, projected onto v5e
(819 GB/s HBM): projected_time = bytes / BW. Block-shape sweep reports the
VMEM working set per grid step (the quantity that must stay under ~16 MB
double-buffered) — the TPU analog of the paper's Tx/Ny table.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ops
from benchmarks.common import emit

HBM_BW = 819e9
SIZES = [(4096, 4096), (10240, 10240), (20480, 20480)]


def run():
    for M, N in SIZES:
        el = M * N
        for name, passes_r, passes_w, dtype_b in [
            ("pot_baseline", 4, 2, 4),
            ("mapuot_fused", 1, 1, 4),
            ("mapuot_fused_bf16", 1, 1, 2),
            ("uv_fused", 1, 0, 4),
            ("uv_fused_bf16", 1, 0, 2),
        ]:
            traffic = (passes_r + passes_w) * el * dtype_b
            t = traffic / HBM_BW
            base = 6 * el * 4 / HBM_BW
            emit(f"kernel_{name}_{M}x{N}", t * 1e6,
                 f"v5e_projected_speedup={base / t:.2f}x_"
                 f"traffic={traffic / 1e9:.2f}GB")

    # block_m sweep (paper Fig. 8 analog): VMEM working set per grid step
    M, N = 10240, 10240
    for bm in (8, 32, 128, 256, 512):
        vmem = 2 * bm * N * 4 + 2 * N * 4  # in+out tile (dbl-buf) + vectors
        note = "fits" if vmem < 64 * 2**20 else "OVERFLOWS"
        emit(f"kernel_blocksweep_bm{bm}_{M}x{N}", vmem / 1024,
             f"vmem_KiB_per_step_{note}_auto={ops.pick_block_m(M, N)}")
