"""Paper Fig. 15 analog: peak memory of one solver iteration.

memory_analysis() of the compiled single-iteration programs: MAP-UOT's
in-place schedule vs the baseline's four-pass chain (XLA temp bytes) and
the u/v form (no matrix writes at all -> temp ~O(M+N)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import rescale_factors
from repro.core.sinkhorn_fused import fused_iteration
from repro.core.sinkhorn_uv import uv_fused_iteration
from benchmarks.common import make_problem, emit

SIZES = [(2048, 2048), (4096, 4096)]


def _mem(fn, *args):
    c = jax.jit(fn, donate_argnums=(0,)).lower(*args).compile()
    m = c.memory_analysis()
    if m is None:
        return -1.0
    return float(m.temp_size_in_bytes + m.argument_size_in_bytes)


def run():
    fi = 0.95
    for M, N in SIZES:
        K, a, b = make_problem(M, N)
        colsum = K.sum(0)
        v = jnp.ones((N,), jnp.float32)

        def baseline_iter(A, a, b):
            A = A * rescale_factors(b, A.sum(0), fi)[None, :]
            A = A * rescale_factors(a, A.sum(1), fi)[:, None]
            return A

        def fused_iter(A, colsum, a, b):
            return fused_iteration(A, colsum, a, b, fi)[:2]

        def uv_iter(K, v, a, b):
            return uv_fused_iteration(K, v, a, b, fi)

        mb = _mem(baseline_iter, K, a, b)
        mf = _mem(fused_iter, K, colsum, a, b)
        mu = _mem(uv_iter, K, v, a, b)
        matrix = M * N * 4
        emit(f"mem_baseline_{M}x{N}", mb / 1e3,
             f"bytes={mb:.3g}_matrices={mb / matrix:.2f}")
        emit(f"mem_mapuot_{M}x{N}", mf / 1e3,
             f"bytes={mf:.3g}_saving={(1 - mf / mb) * 100:.1f}%")
        emit(f"mem_uvfused_{M}x{N}", mu / 1e3,
             f"bytes={mu:.3g}_saving={(1 - mu / mb) * 100:.1f}%")
