"""Cluster serving throughput: 8 sharded lane-pool devices vs one.

Replays one saturating trace of ragged UOT problems (heterogeneous
convergence speeds) through the single-device ``UOTScheduler`` and the
8-device ``ClusterScheduler`` at the SAME per-device lane count, and
reports throughput, p99 latency, and per-device occupancy.

Device time is *simulated* (measured-service discrete-event, the
bench_serve recipe): the chunk service time of one L-lane pool advance is
measured warm, then both schedulers' step loops run on that clock — one
scheduling round costs one chunk time. That is the honest model for the
cluster: a round's D per-device chunk advances are ONE collective-free
``shard_map`` launch, concurrent across real devices, so a round costs one
chunk time whatever D is; CPU CI's forced host devices share one physical
CPU, and wall-clocking them would serialize exactly the work the mesh
parallelizes. Real wall clock of both replay loops is also emitted
(unasserted) so the host-side scheduling overhead stays visible.

Hard asserts (the ISSUE-5 acceptance bar, smoke-scaled in CI):
  * cluster throughput >= 4x the 1-device scheduler on a trace that
    saturates 8 devices at fixed per-device lane count;
  * every request's cluster coupling is bit-identical to its
    single-device coupling (placement cannot change math).

``BENCH_CLUSTER_SMOKE=1`` shrinks the trace to a seconds-long CI run (and
uses the real 8-device mesh when the job forces 8 host devices).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import UOTConfig
from repro.kernels import ops
from repro.serve import UOTScheduler
from repro.cluster import ClusterScheduler, cluster_mesh
from benchmarks.common import emit, make_problem, time_fn

N_DEV = 8


def make_trace(n, shapes, peak_range, cfg, seed=0):
    """n requests, all offered at t=0 — the saturating regime the cluster
    tier exists for (a queue the single device drains 8x slower)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        m, nn = shapes[rng.integers(len(shapes))]
        out.append(make_problem(m, nn, reg=cfg.reg, seed=seed * 7919 + i,
                                peak=float(rng.uniform(*peak_range))))
    return [(np.asarray(K), np.asarray(a), np.asarray(b))
            for K, a, b in out]


def measure_chunk_time(bucket, lanes, chunk, cfg, trace):
    """Warm wall time of one L-lane pool chunk advance at the bucket shape
    — the service quantum both schedulers' simulated clocks tick by."""
    st = ops.make_lane_state(lanes, bucket[0], bucket[1], cfg)
    for i in range(min(lanes, len(trace))):
        K, a, b = trace[i]
        st = ops.lane_admit(st, np.int32(i), K, a, b)
    return time_fn(
        lambda: ops.solve_fused_stepped(st, chunk, cfg, impl="jnp"),
        warmup=2, iters=5)


def replay(build, trace, t_chunk):
    """Drive a scheduler's step loop on the simulated device clock.
    Returns (results by trace index, latencies, sim makespan, wall time,
    scheduler)."""
    now = [0.0]
    sched = build(lambda: now[0])
    rid_to_idx = {sched.submit(*req): i for i, req in enumerate(trace)}
    lat, out = {}, {}
    wall0 = time.perf_counter()
    while sched.pending or sched.in_flight:
        done = sched.step()
        now[0] += t_chunk
        for rid, P in done.items():
            out[rid_to_idx[rid]] = P
            lat[rid_to_idx[rid]] = now[0]
    wall = time.perf_counter() - wall0
    return out, [lat[i] for i in range(len(trace))], now[0], wall, sched


def run():
    smoke = bool(os.environ.get("BENCH_CLUSTER_SMOKE"))
    if smoke:
        n, lanes, chunk = 48, 2, 4
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=24, tol=1e-3)
        shapes = [(24, 100), (32, 120)]
        peak_range = (1.0, 6.0)
    else:
        n, lanes, chunk = 256, 4, 6
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=200, tol=1e-4)
        shapes = [(48, 100), (56, 120), (64, 128), (40, 90)]
        peak_range = (2.0, 12.0)
    m_bucket = 64
    trace = make_trace(n, shapes, peak_range, cfg)
    bucket = ops.bucket_shape(*max(s for s in shapes), m_bucket, 128)
    t_chunk = measure_chunk_time(bucket, lanes, chunk, cfg, trace)

    single_out, single_lat, single_T, single_wall, _ = replay(
        lambda clock: UOTScheduler(cfg, lanes_per_pool=lanes,
                                   chunk_iters=chunk, m_bucket=m_bucket,
                                   impl="jnp", clock=clock),
        trace, t_chunk)

    # real mesh when the process has 8 devices (the CI cluster job forces
    # them); otherwise the per-device-loop mode — same math, same model
    mesh = cluster_mesh(N_DEV) if jax.device_count() >= N_DEV else None
    cluster_out, cluster_lat, cluster_T, cluster_wall, cs = replay(
        lambda clock: ClusterScheduler(
            cfg, mesh=mesh, num_devices=N_DEV, lanes_per_device=lanes,
            chunk_iters=chunk, m_bucket=m_bucket, impl="jnp", clock=clock),
        trace, t_chunk)

    # placement cannot change math: bit-identical per request
    for i in range(n):
        assert np.array_equal(single_out[i], cluster_out[i]), \
            f"request {i}: cluster result != single-device result"

    thr1 = n / single_T
    thrD = n / cluster_T
    speedup = thrD / thr1
    st = cs.stats()
    occ = [v["occupancy_mean"] for v in st["devices"].values()]
    tag = "smoke" if smoke else f"n{n}"
    emit(f"cluster_chunk_service_{tag}", t_chunk * 1e6,
         f"bucket={bucket},lanes={lanes},chunk={chunk}")
    emit(f"cluster_1dev_throughput_{tag}", thr1,
         f"p99={np.percentile(single_lat, 99) * 1e3:.0f}ms_sim,"
         f"wall={single_wall:.2f}s")
    emit(f"cluster_{N_DEV}dev_throughput_{tag}", thrD,
         f"p99={np.percentile(cluster_lat, 99) * 1e3:.0f}ms_sim,"
         f"wall={cluster_wall:.2f}s,mesh={mesh is not None}")
    emit(f"cluster_speedup_{tag}", speedup * 100,
         f"{speedup:.2f}x_vs_1dev,occ_mean={np.mean(occ):.2f},"
         f"occ_spread={max(occ) - min(occ):.2f}")
    assert speedup >= 4.0, \
        (f"cluster throughput {thrD:.1f}/s is only {speedup:.2f}x the "
         f"1-device scheduler's {thr1:.1f}/s (bar: 4x at saturation)")
