"""Subprocess body for distributed-solver tests (8 forced host devices).

Run as:  XLA flags are set HERE, before jax import — pytest invokes this in
a fresh interpreter so the main test process keeps its single device.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import UOTConfig, sinkhorn_uot_fused  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    rowsharded_fused_solver, sharded2d_fused_solver,
    rowsharded_overlapped_solver, shard_inputs)
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402


def make_problem(M=128, N=96, reg=0.1, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(M, 2)).astype(np.float32)
    Y = rng.normal(size=(N, 2)).astype(np.float32) + 0.5
    C = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    C = C / C.max()
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.3
    K = np.exp(-C / reg) * (a[:, None] * b[None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def main():
    assert jax.device_count() == 8, jax.device_count()
    K, a, b = make_problem()
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60)
    ref, _ = sinkhorn_uot_fused(K, a, b, cfg)
    ref = np.asarray(ref)

    # --- 1-D row-sharded (the paper's MPI design) over all 8 devices ------
    mesh = jax.make_mesh((8,), ("rows",))
    solver = rowsharded_fused_solver(mesh, "rows", cfg)
    sA, sa, sb = shard_inputs(mesh, "rows", K, a, b)
    A1, colsum = solver(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A1), ref, rtol=3e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(colsum), ref.sum(0), rtol=3e-4)
    print("rowsharded: OK")

    # --- 2-D sharded (beyond paper) over a 4x2 mesh -----------------------
    mesh2 = jax.make_mesh((4, 2), ("r", "c"))
    solver2 = sharded2d_fused_solver(mesh2, "r", "c", cfg)
    sA = jax.device_put(K, NamedSharding(mesh2, P("r", "c")))
    sa = jax.device_put(a, NamedSharding(mesh2, P("r")))
    sb = jax.device_put(b, NamedSharding(mesh2, P("c")))
    A2, _ = solver2(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A2), ref, rtol=3e-5, atol=1e-8)
    print("sharded2d: OK")

    # --- overlapped ring-reduce variant ------------------------------------
    solver3 = rowsharded_overlapped_solver(mesh, "rows", cfg, num_chunks=4)
    sA, sa, sb = shard_inputs(mesh, "rows", K, a, b)
    A3, _ = solver3(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A3), ref, rtol=3e-5, atol=1e-8)
    print("overlapped: OK")

    # --- collective volume sanity: HLO contains exactly the expected ops ---
    lowered = jax.jit(solver.__wrapped__ if hasattr(solver, "__wrapped__")
                      else solver).lower(sA, sa, sb)
    hlo = lowered.compile().as_text()
    assert "all-reduce" in hlo, "expected an all-reduce (MPI_Allreduce analog)"
    print("hlo: OK")


if __name__ == "__main__":
    main()
    print("DISTRIBUTED_CHECK_PASSED")
