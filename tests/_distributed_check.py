"""Subprocess body for distributed-solver tests (8 forced host devices).

Run as:  XLA flags are set HERE, before jax import — pytest invokes this in
a fresh interpreter so the main test process keeps its single device.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import UOTConfig, sinkhorn_uot_fused  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    rowsharded_fused_solver, sharded2d_fused_solver,
    rowsharded_overlapped_solver, shard_inputs)
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402


def make_problem(M=128, N=96, reg=0.1, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(M, 2)).astype(np.float32)
    Y = rng.normal(size=(N, 2)).astype(np.float32) + 0.5
    C = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    C = C / C.max()
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.3
    K = np.exp(-C / reg) * (a[:, None] * b[None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def main():
    assert jax.device_count() == 8, jax.device_count()
    K, a, b = make_problem()
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60)
    ref, _ = sinkhorn_uot_fused(K, a, b, cfg)
    ref = np.asarray(ref)

    # --- 1-D row-sharded (the paper's MPI design) over all 8 devices ------
    mesh = jax.make_mesh((8,), ("rows",))
    solver = rowsharded_fused_solver(mesh, "rows", cfg)
    sA, sa, sb = shard_inputs(mesh, "rows", K, a, b)
    A1, colsum = solver(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A1), ref, rtol=3e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(colsum), ref.sum(0), rtol=3e-4)
    print("rowsharded: OK")

    # --- 2-D sharded (beyond paper) over a 4x2 mesh -----------------------
    mesh2 = jax.make_mesh((4, 2), ("r", "c"))
    solver2 = sharded2d_fused_solver(mesh2, "r", "c", cfg)
    sA = jax.device_put(K, NamedSharding(mesh2, P("r", "c")))
    sa = jax.device_put(a, NamedSharding(mesh2, P("r")))
    sb = jax.device_put(b, NamedSharding(mesh2, P("c")))
    A2, _ = solver2(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A2), ref, rtol=3e-5, atol=1e-8)
    print("sharded2d: OK")

    # --- overlapped ring-reduce variant ------------------------------------
    solver3 = rowsharded_overlapped_solver(mesh, "rows", cfg, num_chunks=4)
    sA, sa, sb = shard_inputs(mesh, "rows", K, a, b)
    A3, _ = solver3(sA, sa, sb)
    np.testing.assert_allclose(np.asarray(A3), ref, rtol=3e-5, atol=1e-8)
    print("overlapped: OK")

    # --- collective volume sanity: HLO contains exactly the expected ops ---
    lowered = jax.jit(solver.__wrapped__ if hasattr(solver, "__wrapped__")
                      else solver).lower(sA, sa, sb)
    hlo = lowered.compile().as_text()
    assert "all-reduce" in hlo, "expected an all-reduce (MPI_Allreduce analog)"
    print("hlo: OK")

    # --- bf16 storage / fp32 reduction on every distributed variant --------
    # The advertised mixed-precision mode, now asserted: blocks stored
    # bf16, psums fp32. The error bar is the documented streamed-bf16
    # pointwise bar (tests/test_bf16_accumulation.py: error saturates well
    # under 5e-2 relative to the coupling scale).
    from repro.core.distributed import gang_solve
    bf16 = jnp.bfloat16
    scale = float(np.abs(ref).max())
    bar = 5e-2 * scale
    builders = [
        ("rowsharded", lambda: rowsharded_fused_solver(
            mesh, "rows", cfg, storage_dtype=bf16), mesh, "1d"),
        ("sharded2d", lambda: sharded2d_fused_solver(
            mesh2, "r", "c", cfg, storage_dtype=bf16), mesh2, "2d"),
        ("overlapped", lambda: rowsharded_overlapped_solver(
            mesh, "rows", cfg, num_chunks=4, storage_dtype=bf16),
         mesh, "1d"),
    ]
    for name, build, m, kind in builders:
        solver16 = build()
        if kind == "1d":
            sA16, sa16, sb16 = shard_inputs(m, "rows", K, a, b)
        else:
            sA16 = jax.device_put(K, NamedSharding(m, P("r", "c")))
            sa16 = jax.device_put(a, NamedSharding(m, P("r")))
            sb16 = jax.device_put(b, NamedSharding(m, P("c")))
        A16, cs16 = solver16(sA16, sa16, sb16)
        assert A16.dtype == bf16, (name, A16.dtype)
        assert cs16.dtype == jnp.float32, (name, cs16.dtype)
        err = float(np.abs(np.asarray(A16, np.float32) - ref).max())
        assert err <= bar, (name, err, bar)
        print(f"bf16 {name}: OK (max abs err {err:.2e} <= {bar:.2e})")

    # --- gang_solve serving adapter: padding + cache + bf16 ----------------
    # M=100 does not divide 8: the adapter zero-pads rows (exact no-ops),
    # shards, and trims — so any request shape can ride the gang.
    K100, a100 = np.asarray(K)[:100], np.asarray(a)[:100]
    Pg, csg = gang_solve(mesh, "rows", K100, a100, np.asarray(b), cfg)
    refg, _ = sinkhorn_uot_fused(jnp.asarray(K100), jnp.asarray(a100), b,
                                 cfg)
    np.testing.assert_allclose(Pg, np.asarray(refg), rtol=3e-5, atol=1e-8)
    Pg16, _ = gang_solve(mesh, "rows", K100, a100, np.asarray(b), cfg,
                         storage_dtype=bf16)
    err = float(np.abs(Pg16.astype(np.float32)
                       - np.asarray(refg)).max())
    assert err <= 5e-2 * float(np.abs(np.asarray(refg)).max())
    print("gang_solve: OK (padded rows, fp32 + bf16)")

    # overlapped gang: M=100 pads to 8*4=32-row multiples (128), so every
    # local chunk loop covers its whole block — the tail rows a mesh-only
    # pad would leave unrescaled (regression: silently wrong colsums)
    Pgo, _ = gang_solve(mesh, "rows", K100, a100, np.asarray(b), cfg,
                        overlapped=True, num_chunks=4)
    np.testing.assert_allclose(Pgo, np.asarray(refg), rtol=3e-5, atol=1e-8)
    print("gang_solve overlapped: OK (chunk-divisible row padding)")


if __name__ == "__main__":
    main()
    print("DISTRIBUTED_CHECK_PASSED")
