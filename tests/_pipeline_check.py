"""Subprocess: GPipe pipeline == sequential on 4 forced host devices."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.parallel.pipeline import pipeline_apply  # noqa: E402


def main():
    P_STAGES, N_MICRO, MB, D = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    # one linear+relu layer per stage
    w = jnp.asarray(rng.normal(0, 0.5, (P_STAGES, D, D)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(N_MICRO, MB, D)), jnp.float32)

    def stage_fn(params, h):
        return jax.nn.relu(h @ params)

    mesh = jax.make_mesh((4,), ("pipe",))
    y_pipe = pipeline_apply(mesh, "pipe", stage_fn, w, x)

    # sequential reference
    y_ref = x
    for s in range(P_STAGES):
        y_ref = jax.nn.relu(y_ref @ w[s])

    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-6)
    print("PIPELINE_CHECK_PASSED")


if __name__ == "__main__":
    main()
