"""Cross-validate against an INDEPENDENT literal NumPy transcription of
POT's sinkhorn_knopp_unbalanced (no shared code with repro.core)."""
import numpy as np
import jax.numpy as jnp

from repro.core import UOTConfig, sinkhorn_uot_uv
from repro.kernels import ops


def pot_sinkhorn_unbalanced_numpy(C, a, b, reg, reg_m, iters):
    """Literal transcription of POT's algorithm (Chizat et al. scaling)."""
    K = np.exp(-C / reg)
    fi = reg_m / (reg_m + reg)
    u = np.ones_like(a)
    v = np.ones_like(b)
    for _ in range(iters):
        Kv = K @ v
        u = (a / Kv) ** fi
        Ktu = K.T @ u
        v = (b / Ktu) ** fi
    return u[:, None] * K * v[None, :]


def test_uv_solver_matches_independent_pot_transcription():
    rng = np.random.default_rng(0)
    M, N = 60, 45
    C = rng.uniform(0, 1, (M, N)).astype(np.float64)
    a = rng.uniform(0.5, 1.5, M); a /= a.sum()
    b = rng.uniform(0.5, 1.5, N); b /= b.sum() / 1.2
    reg, reg_m, iters = 0.1, 1.0, 200

    P_ref = pot_sinkhorn_unbalanced_numpy(C, a, b, reg, reg_m, iters)

    K = jnp.asarray(np.exp(-C / reg), jnp.float32)
    cfg = UOTConfig(reg=reg, reg_m=reg_m, num_iters=iters)
    P_uv, _, _ = sinkhorn_uot_uv(K, jnp.asarray(a, jnp.float32),
                                 jnp.asarray(b, jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(P_uv), P_ref, rtol=2e-3, atol=1e-7)

    # and the Pallas kernel path end-to-end against the same oracle
    P_kern, _ = ops.solve_uv(K, jnp.asarray(a, jnp.float32),
                             jnp.asarray(b, jnp.float32), cfg,
                             block_m=16, interpret=True)
    np.testing.assert_allclose(np.asarray(P_kern), P_ref, rtol=2e-3,
                               atol=1e-7)
