"""Attention variants + GLA core: detailed unit/property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    attention_init, attention_apply, attention_decode,
    attention_prefill_windowed, attention_decode_windowed)
from repro.models.gla import chunked_gla, serial_gla

KW = dict(num_heads=4, num_kv_heads=2, head_dim=16)


def setup(T=48, B=2, D=64, seed=0):
    p = attention_init(jax.random.PRNGKey(seed), D, 4, 2, 16)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, D))
    return p, x


class TestFlashAttention:
    @pytest.mark.parametrize("qc,kc", [(8, 8), (16, 48), (48, 16), (12, 24)])
    def test_chunk_shapes(self, qc, kc):
        p, x = setup()
        o1, _ = attention_apply(p, x, **KW, impl="naive")
        o2, _ = attention_apply(p, x, **KW, impl="flash", q_chunk=qc,
                                kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), win=st.sampled_from([0, 8, 17, 40]))
    def test_flash_equals_naive_any_window(self, seed, win):
        p, x = setup(seed=seed % 100)
        o1, _ = attention_apply(p, x, **KW, impl="naive", window=win)
        o2, _ = attention_apply(p, x, **KW, impl="flash", q_chunk=16,
                                kv_chunk=16, window=win, unroll=bool(seed % 2))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=3e-5, atol=3e-6)


class TestWindowedRingCache:
    def test_ring_decode_matches_full_recompute(self):
        """Windowed ring-buffer decode == full windowed attention, across a
        cache wrap-around boundary."""
        W = 16
        p, x = setup(T=40)
        B, T, D = x.shape
        # prefill 24 tokens, then decode 16 more (wraps the W=16 ring twice)
        out_p, cache = attention_prefill_windowed(p, x[:, :24], window=W, **KW)
        outs = []
        for t in range(24, T):
            o, cache = attention_decode_windowed(p, x[:, t:t + 1], cache,
                                                 jnp.int32(t), window=W, **KW)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)

        ref_full, _ = attention_apply(p, x, **KW, window=W)
        ref = ref_full[:, 24:]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=3e-4, atol=5e-5)

    def test_plain_decode_matches_full(self):
        p, x = setup(T=32)
        B, T, D = x.shape
        S_cache = 64
        cache = {"k": jnp.zeros((B, S_cache, 2, 16)),
                 "v": jnp.zeros((B, S_cache, 2, 16))}
        outs = []
        for t in range(T):
            o, cache = attention_decode(p, x[:, t:t + 1], cache, jnp.int32(t),
                                        **KW)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        ref, _ = attention_apply(p, x, **KW)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=3e-4, atol=5e-5)


class TestGLAProperties:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([2, 4, 8, 5]),
           use_norm=st.booleans())
    def test_chunked_equals_serial(self, seed, chunk, use_norm):
        rng = np.random.default_rng(seed)
        B, T, H, dk, dv = 2, 16, 2, 4, 8
        q = jnp.asarray(rng.normal(size=(B, T, H, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, T, H, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, T, H, dv)), jnp.float32)
        lg = jnp.asarray(np.log(rng.uniform(0.5, 1.0, (B, T, H))), jnp.float32)
        li = jnp.asarray(np.log(rng.uniform(0.05, 1.0, (B, T, H))), jnp.float32)
        y1, S1, n1 = chunked_gla(q, k, v, lg, li, chunk=chunk,
                                 use_norm=use_norm)
        y2, S2, n2 = serial_gla(q, k, v, lg, li, use_norm=use_norm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(S1), np.asarray(S2),
                                   rtol=1e-4, atol=1e-5)

    def test_state_carry_composes(self):
        """GLA over [0:T] == GLA over [0:T/2] then [T/2:T] with carried
        state (the prefill-continuation invariant)."""
        rng = np.random.default_rng(3)
        B, T, H, dk, dv = 1, 16, 2, 4, 4
        mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
        q, k, v = mk(B, T, H, dk), mk(B, T, H, dk), mk(B, T, H, dv)
        lg = jnp.asarray(np.log(rng.uniform(0.7, 1.0, (B, T, H))), jnp.float32)
        li = jnp.zeros((B, T, H), jnp.float32)
        y_full, S_full, _ = chunked_gla(q, k, v, lg, li, chunk=4,
                                        use_norm=False)
        h = T // 2
        y1, S1, n1 = chunked_gla(q[:, :h], k[:, :h], v[:, :h], lg[:, :h],
                                 li[:, :h], chunk=4, use_norm=False)
        y2, S2, _ = chunked_gla(q[:, h:], k[:, h:], v[:, h:], lg[:, h:],
                                li[:, h:], chunk=4, use_norm=False,
                                S0=S1, n0=n1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                                   rtol=1e-4, atol=1e-5)
