"""Elastic scale-down restart: checkpoint on an 8-device mesh, restore and
continue on a 4-device mesh (different sharding layout)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_elastic_scale_down_restart(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    script = str(ROOT / "tests" / "_elastic_check.py")
    p1 = subprocess.run([sys.executable, script, "save", str(tmp_path)],
                        capture_output=True, text=True, env=env, timeout=900)
    assert p1.returncode == 0, p1.stderr
    assert "SAVED" in p1.stdout
    p2 = subprocess.run([sys.executable, script, "restore", str(tmp_path)],
                        capture_output=True, text=True, env=env, timeout=900)
    assert p2.returncode == 0, p2.stderr
    assert "RESTORED_AND_TRAINED" in p2.stdout
