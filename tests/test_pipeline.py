"""Pipeline parallelism (GPipe over a mesh axis) — subprocess test."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_pipeline_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PIPELINE_CHECK_PASSED" in proc.stdout
