"""Continuous-batching UOT serving: steppable solver + scheduler.

The load-bearing property: a request's answer must not depend on HOW it was
served — arrival order, admission interleaving, lane assignment, chunk
boundaries, or what else shared the pool. Per-lane math is independent and
convergence freezing happens per-iteration inside the chunk, so the
scheduler's output is required to EQUAL the standalone solve (exactly for a
fixed lane pool / same impl; to kernel-vs-jnp tolerance otherwise).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig, sinkhorn_uot_fused
from repro.kernels import ops
from repro.serve import QueueFullError, UOTScheduler

IMPLS = ["jnp", "kernel"]


from benchmarks.common import make_problem as _common_problem


def make_problem(m, n, seed, peak=1.0, reg=0.1):
    """Random UOT problem (shared recipe from benchmarks.common);
    ``peak`` scales the cost (peaky cost = slow convergence), giving
    workloads heterogeneous iteration counts."""
    return _common_problem(m, n, reg=reg, seed=seed, peak=peak)


def ragged_workload(seed, n_requests=8):
    """Seeded ragged problem list spanning several shape buckets and a
    ~10x spread of convergence speeds."""
    r = np.random.default_rng(seed)
    shapes = [(8, 100), (20, 128), (32, 64), (16, 90), (24, 120)]
    out = []
    for i in range(n_requests):
        m, n = shapes[r.integers(len(shapes))]
        out.append(make_problem(m, n, seed * 1000 + i,
                                peak=float(r.uniform(1.0, 8.0))))
    return out


class TestSteppedSolver:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_matches_batched_fixed_iters(self, impl):
        """A lane stepped in chunks equals the one-shot batched solve."""
        K, a, b = make_problem(40, 100, 1)
        st = ops.make_lane_state(4, 64, 128, self.CFG)
        st = ops.lane_admit(st, jnp.int32(2), K, a, b)
        for _ in range(4):
            st = ops.solve_fused_stepped(st, 5, self.CFG, interpret=True,
                                         impl=impl)
        assert bool(ops.lane_done(st, self.CFG.num_iters)[2])
        P_ref, cs_ref = ops.solve_fused_batched(
            K[None], a[None], b[None], self.CFG, interpret=True, impl=impl)
        np.testing.assert_allclose(st.P[2, :40, :100], P_ref[0],
                                   rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(st.colsum[2, :100], cs_ref[0], rtol=1e-6)

    def test_chunk_boundaries_do_not_change_results(self):
        """Convergence freezing is per-iteration inside the chunk, so the
        final iterate is independent of the chunk size."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-4)
        K, a, b = make_problem(32, 128, 3, peak=4.0)
        finals = []
        for chunk in (1, 4, 7):
            st = ops.lane_admit(ops.make_lane_state(2, 32, 128, cfg),
                                jnp.int32(0), K, a, b)
            for _ in range(60):
                st = ops.solve_fused_stepped(st, chunk, cfg, impl="jnp")
                if bool(ops.lane_done(st, cfg.num_iters)[0]):
                    break
            finals.append((np.asarray(st.P[0]), int(st.iters[0])))
        for P, iters in finals[1:]:
            np.testing.assert_array_equal(P, finals[0][0])
            assert iters == finals[0][1]

    @pytest.mark.parametrize("impl", IMPLS)
    def test_tol_matches_single_problem_solver(self, impl):
        """Per-lane stationarity eviction reproduces the core solver's tol
        semantics: same iteration count, same iterate, per lane."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=200, tol=1e-4)
        probs = [make_problem(32, 128, s, peak=p)
                 for s, p in [(1, 1.0), (2, 4.0), (3, 8.0)]]
        st = ops.make_lane_state(3, 32, 128, cfg)
        for i, (K, a, b) in enumerate(probs):
            st = ops.lane_admit(st, jnp.int32(i), K, a, b)
        for _ in range(80):
            st = ops.solve_fused_stepped(st, 5, cfg, interpret=True,
                                         impl=impl)
            if bool(np.asarray(ops.lane_done(st, cfg.num_iters)).all()):
                break
        iters = np.asarray(st.iters)
        assert len(set(iters.tolist())) > 1, \
            f"workload should converge heterogeneously, got {iters}"
        for i, (K, a, b) in enumerate(probs):
            A_core, stats = sinkhorn_uot_fused(K, a, b, cfg)
            assert int(stats["iters"]) == int(iters[i])
            np.testing.assert_allclose(st.P[i], A_core, rtol=1e-5,
                                       atol=1e-8)

    def test_evict_frees_lane_and_zeroes_problem(self):
        cfg = self.CFG
        K, a, b = make_problem(20, 100, 5)
        st = ops.lane_admit(ops.make_lane_state(2, 32, 128, cfg),
                            jnp.int32(1), K, a, b)
        st = ops.lane_evict(st, jnp.int32(1))
        assert not bool(st.active[1])
        np.testing.assert_array_equal(np.asarray(st.P[1]), 0.0)
        # an evicted lane is a no-op for the stepped math
        st2 = ops.solve_fused_stepped(st, 3, cfg, impl="jnp")
        np.testing.assert_array_equal(np.asarray(st2.P), np.asarray(st.P))


class TestSchedulerProperty:
    """Scheduler output == standalone solve, whatever the serving history."""

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_arrival_order_invariance(self, impl, seed):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)
        probs = ragged_workload(seed)
        rng = np.random.default_rng(seed + 99)

        def serve(order, stages):
            """Serve ``probs[order]``, submitting in ``stages`` slices with
            scheduler steps in between (admission interleaving)."""
            sched = UOTScheduler(cfg, lanes_per_pool=2, chunk_iters=3,
                                 m_bucket=32, interpret=True, impl=impl)
            rid_to_prob = {}
            out = {}
            lo = 0
            for hi in stages + [len(order)]:
                for k in order[lo:hi]:
                    rid = sched.submit(*probs[k],
                                       priority=int(rng.integers(3)))
                    rid_to_prob[rid] = k
                lo = hi
                out.update(sched.step())
            out.update(sched.run())
            assert sched.pending == 0 and sched.in_flight == 0
            return {rid_to_prob[rid]: P for rid, P in out.items()}

        base = serve(list(range(len(probs))), [])
        assert set(base) == set(range(len(probs)))

        # every request equals its standalone tol solve
        for k, (K, a, b) in enumerate(probs):
            A_core, _ = sinkhorn_uot_fused(K, a, b, cfg)
            rtol = 1e-5 if impl == "jnp" else 3e-5
            np.testing.assert_allclose(base[k], A_core, rtol=rtol,
                                       atol=1e-8)

        # permuted arrival + staged admission: identical results per request
        order = list(rng.permutation(len(probs)))
        staged = serve(order, stages=[3, 5])
        for k in base:
            np.testing.assert_allclose(staged[k], base[k], rtol=1e-7,
                                       atol=1e-10)

    def test_fixed_iteration_mode_equals_solve_fused(self):
        """tol=None: every request runs exactly num_iters in its lane and
        equals the per-request Pallas solve."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=15)
        probs = ragged_workload(7, n_requests=5)
        sched = UOTScheduler(cfg, lanes_per_pool=2, chunk_iters=4,
                             m_bucket=32, impl="jnp")
        rids = [sched.submit(*p) for p in probs]
        out = sched.run()
        for rid, (K, a, b) in zip(rids, probs):
            P_ref, _ = ops.solve_fused(K, a, b, cfg, interpret=True)
            np.testing.assert_allclose(out[rid], P_ref, rtol=1e-5,
                                       atol=1e-8)
        for t in sched.request_log:
            assert t.iters == cfg.num_iters and not t.converged


class TestScheduling:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=6)

    def _sched(self, **kw):
        t = kw.pop("t")
        return UOTScheduler(self.CFG, lanes_per_pool=1, chunk_iters=3,
                            m_bucket=32, impl="jnp",
                            clock=lambda: t[0], **kw)

    def test_edf_admission_order(self):
        """With one lane, earliest deadline is admitted (and so completes)
        first regardless of submission order."""
        t = [0.0]
        sched = self._sched(t=t)
        K, a, b = make_problem(16, 100, 0)
        r_late = sched.submit(K, a, b, deadline=30.0)
        r_first = sched.submit(K, a, b, deadline=1.0)
        r_mid = sched.submit(K, a, b, deadline=2.0)
        r_none = sched.submit(K, a, b)            # no deadline -> last
        sched.run()
        assert [tt.rid for tt in sched.request_log] == \
            [r_first, r_mid, r_late, r_none]

    def test_priority_breaks_deadline_ties(self):
        t = [0.0]
        sched = self._sched(t=t)
        K, a, b = make_problem(16, 100, 1)
        r0 = sched.submit(K, a, b, deadline=5.0, priority=0)
        r1 = sched.submit(K, a, b, deadline=5.0, priority=9)
        r2 = sched.submit(K, a, b, deadline=5.0, priority=4)
        sched.run()
        assert [tt.rid for tt in sched.request_log] == [r1, r2, r0]

    def test_fifo_breaks_full_ties(self):
        t = [0.0]
        sched = self._sched(t=t)
        K, a, b = make_problem(16, 100, 2)
        rids = [sched.submit(K, a, b) for _ in range(3)]
        sched.run()
        assert [tt.rid for tt in sched.request_log] == rids

    def test_backpressure_rejects_then_recovers(self):
        t = [0.0]
        sched = self._sched(t=t, max_queue=2)
        K, a, b = make_problem(16, 100, 3)
        sched.submit(K, a, b)
        sched.submit(K, a, b)
        with pytest.raises(QueueFullError):
            sched.submit(K, a, b)
        sched.step()                     # admits one -> queue has room again
        rid = sched.submit(K, a, b)
        out = sched.run()
        assert rid in out and len(out) == 3

    def test_poll_take_semantics_and_bounded_logs(self):
        t = [0.0]
        sched = self._sched(t=t, max_log=3, max_results=3)
        K, a, b = make_problem(16, 100, 6)
        rids = [sched.submit(K, a, b) for _ in range(5)]
        while sched.pending or sched.in_flight:
            sched.step()
        # poll hands each result out exactly once
        assert sched.poll(rids[-1]) is not None
        assert sched.poll(rids[-1]) is None
        # telemetry and pickup store are capped at max_log
        assert len(sched.request_log) <= 3
        assert len(sched.occupancy_log) <= 3
        assert len(sched._results) <= 3

    def test_idle_pool_released_after_ttl(self):
        t = [0.0]
        sched = self._sched(t=t, pool_idle_ttl=2)
        K, a, b = make_problem(16, 100, 7)
        rid = sched.submit(K, a, b)
        out = sched.run()
        assert rid in out and len(sched._pools) == 1
        for _ in range(3):          # idle rounds past the TTL
            sched.step()
        assert sched._pools == {}
        # pool is recreated transparently for new traffic
        rid2 = sched.submit(K, a, b)
        out2 = sched.run()
        np.testing.assert_array_equal(np.asarray(out2[rid2]),
                                      np.asarray(out[rid]))

    def test_telemetry(self):
        t = [0.0]
        sched = self._sched(t=t)

        def stepping_clock():
            t[0] += 0.25
            return t[0]
        sched.clock = stepping_clock
        K, a, b = make_problem(16, 100, 4)
        sched.submit(K, a, b)
        sched.submit(K, a, b)
        sched.run()
        s = sched.stats()
        assert s["completed"] == 2
        assert s["iters_max"] == self.CFG.num_iters
        assert s["occupancy_mean"] > 0
        # second request waited for the single lane
        waits = sorted(tt.wait for tt in sched.request_log)
        assert waits[1] > waits[0]
        assert all(tt.latency >= tt.wait for tt in sched.request_log)
        assert len(sched.occupancy_log) == s["steps"]

    def test_deadline_miss_accounting(self):
        """Completions after their deadline are counted — per request
        (RequestTelemetry.missed), as a running total in the occupancy
        log, and as miss_rate in stats()."""
        t = [0.0]
        sched = self._sched(t=t)

        def stepping_clock():
            t[0] += 0.25
            return t[0]
        sched.clock = stepping_clock
        K, a, b = make_problem(16, 100, 4)
        # one lane: the impossible-deadline request and a lax one queue up,
        # a no-deadline request is excluded from the rate denominator
        sched.submit(K, a, b, deadline=0.01)        # must be missed
        sched.submit(K, a, b, deadline=1e9)         # comfortably met
        sched.submit(K, a, b)                       # no deadline
        sched.run()
        s = sched.stats()
        assert s["completed"] == 3
        assert s["deadline_misses"] == 1
        assert s["miss_rate"] == pytest.approx(0.5)  # 1 of 2 deadlined
        by_rid = {tt.rid: tt for tt in sched.request_log}
        assert by_rid[0].missed and by_rid[0].deadline == 0.01
        assert not by_rid[1].missed
        assert not by_rid[2].missed and by_rid[2].deadline is None
        assert sched.occupancy_log[-1]["deadline_misses"] == 1
        # running counters survive log trimming
        sched.request_log.clear()
        assert sched.stats()["deadline_misses"] == 1


class TestDeadlineShedding:
    """Deadline-aware shedding: requests whose deadline has already passed
    at admission time stop wasting lanes — dropped outright
    (shed_policy='drop') or solved on a reduced iteration budget
    ('degrade'), with the shed accounting in stats() / RequestTelemetry."""

    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=24)

    def _sched(self, t, **kw):
        return UOTScheduler(self.CFG, lanes_per_pool=2, chunk_iters=4,
                            m_bucket=32, impl="jnp",
                            clock=lambda: t[0], **kw)

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError, match="shed_policy"):
            UOTScheduler(self.CFG, shed_policy="maybe")

    def test_drop_policy_refuses_expired_requests_a_lane(self):
        t = [10.0]
        sched = self._sched(t, shed_policy="drop")
        K, a, b = make_problem(16, 100, 5)
        r_dead = sched.submit(K, a, b, deadline=9.0)    # already passed
        r_live = sched.submit(K, a, b, deadline=1e9)
        out = sched.run()
        assert r_live in out and r_dead not in out
        # a dropped request still RESOLVES: typed 'rejected' disposition
        # (exactly once), never a silent None
        failure = sched.poll(r_dead)
        assert failure is not None and failure.status == "rejected"
        assert sched.poll(r_dead) is None    # take semantics
        s = sched.stats()
        assert s["shed_dropped"] == 1 and s["shed_degraded"] == 0
        # served-work aggregates exclude the drop; the log records it
        assert s["completed"] == 1
        rec = {tt.rid: tt for tt in sched.request_log}[r_dead]
        assert rec.shed == "dropped" and rec.lane == -1 and rec.iters == 0

    def test_degrade_policy_caps_iterations_at_one_chunk(self):
        t = [10.0]
        sched = self._sched(t, shed_policy="degrade")
        K, a, b = make_problem(16, 100, 6)
        r_deg = sched.submit(K, a, b, deadline=9.0)
        r_full = sched.submit(K, a, b, deadline=1e9)
        out = sched.run()
        assert r_deg in out and r_full in out       # degraded still answers
        by_rid = {tt.rid: tt for tt in sched.request_log}
        assert by_rid[r_deg].shed == "degraded"
        assert by_rid[r_deg].iters == sched.degrade_iters == 4
        assert by_rid[r_full].shed is None
        assert by_rid[r_full].iters == self.CFG.num_iters
        s = sched.stats()
        assert s["shed_degraded"] == 1 and s["shed_dropped"] == 0
        # the degraded answer is the genuine 4-iteration iterate
        cfg4 = UOTConfig(reg=0.1, reg_m=1.0, num_iters=4)
        P_ref, _ = sinkhorn_uot_fused(jnp.asarray(K), jnp.asarray(a),
                                      jnp.asarray(b), cfg4)
        np.testing.assert_allclose(out[r_deg], np.asarray(P_ref),
                                   rtol=1e-5, atol=1e-9)

    def test_default_policy_serves_expired_requests_in_full(self):
        t = [10.0]
        sched = self._sched(t)                      # shed_policy='none'
        K, a, b = make_problem(16, 100, 7)
        rid = sched.submit(K, a, b, deadline=9.0)
        out = sched.run()
        assert rid in out
        s = sched.stats()
        assert s["shed_dropped"] == s["shed_degraded"] == 0
        assert s["deadline_misses"] == 1            # still counted missed

    def test_future_deadlines_are_never_shed(self):
        t = [0.0]
        sched = self._sched(t, shed_policy="drop")
        K, a, b = make_problem(16, 100, 8)
        rid = sched.submit(K, a, b, deadline=1e9)
        out = sched.run()
        assert rid in out and sched.stats()["shed_dropped"] == 0
