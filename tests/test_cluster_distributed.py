"""Cluster serving runtime on a real 8-device mesh — run in a subprocess
with 8 forced host devices (XLA device count is locked at first jax init,
so the flag must be set in a fresh interpreter; see
tests/_cluster_check.py for what is asserted)."""
import os
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_cluster_runtime_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_cluster_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "CLUSTER_CHECK_PASSED" in proc.stdout
