"""Hypothesis property tests for the cluster router: permuting the
device assignment (and the arrival order, and the chunk size) cannot
change any request's result — placement is routing, never math."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import UOTConfig
from repro.cluster import ClusterScheduler
from test_cluster import ragged_workload

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       perm=st.permutations(list(range(4))),
       order=st.permutations(list(range(4))),
       chunk=st.integers(1, 5))
def test_permuted_device_assignment_same_results(seed, perm, order, chunk):
    probs = ragged_workload(seed % 1000, n_requests=4)

    class PermutedScheduler(ClusterScheduler):
        def _pick_device(self, pool):
            d = super()._pick_device(pool)
            # with one lane per device and <= D requests in flight the
            # permuted target always has a free lane
            return None if d is None else perm[d]

    base = ClusterScheduler(CFG, num_devices=4, lanes_per_device=1,
                            chunk_iters=chunk, m_bucket=32, impl="jnp")
    permuted = PermutedScheduler(CFG, num_devices=4, lanes_per_device=1,
                                 chunk_iters=chunk, m_bucket=32, impl="jnp")
    rid_b = [base.submit(*probs[k]) for k in range(4)]
    rid_p = [permuted.submit(*probs[k]) for k in order]
    out_b, out_p = base.run(), permuted.run()
    for k, rb in enumerate(rid_b):
        rp = rid_p[order.index(k)]
        np.testing.assert_array_equal(out_b[rb], out_p[rp])
