"""Fault containment: admission validation, lane health, quarantine-and-
retry, device quarantine, and the chaos injectors.

The load-bearing claims, each tested against a fault-free oracle run:

1. blast radius — a poisoned request never changes any OTHER request's
   answer: healthy couplings are bit-identical to the fault-free run;
2. resolution — every submitted rid resolves via ``poll`` to exactly one
   coupling or typed ``RequestFailure``, never silently vanishes;
3. detection — non-finite lane state is flagged by the in-flight detector
   (both advance impls) and frozen, not propagated.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (InvalidProblemError, UOTConfig, escalate_log_solve,
                        escalation_config, uv_safe, validate_problem)
from repro.cluster import ClusterScheduler
from repro.kernels import ops
from repro.serve import (QueueFullError, RequestFailure, UOTScheduler,
                         faults, submit_with_retry)

IMPLS = ["jnp", "kernel"]

from benchmarks.common import make_problem as _common_problem


def make_problem(m, n, seed, peak=1.0, reg=0.1):
    return _common_problem(m, n, reg=reg, seed=seed, peak=peak)


CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-5)


def _sched(**kw):
    kw.setdefault("lanes_per_pool", 4)
    kw.setdefault("chunk_iters", 6)
    kw.setdefault("m_bucket", 32)
    kw.setdefault("impl", "jnp")
    return UOTScheduler(CFG, **kw)


def _cluster(**kw):
    kw.setdefault("num_devices", 2)
    kw.setdefault("lanes_per_device", 4)
    kw.setdefault("chunk_iters", 6)
    kw.setdefault("m_bucket", 32)
    kw.setdefault("impl", "jnp")
    return ClusterScheduler(CFG, **kw)


class TestAdmissionValidation:
    def test_reasons(self):
        a = np.ones(8, np.float32)
        b = np.ones(12, np.float32)
        cases = [
            (dict(a=np.ones((8, 1), np.float32)), "shape"),
            (dict(a=np.ones(8, np.int32)), "dtype"),
            (dict(a=np.r_[a[:-1], np.nan].astype(np.float32)),
             "non_finite"),
            (dict(a=np.r_[a[:-1], -1.0].astype(np.float32)), "negative"),
            (dict(a=np.zeros(8, np.float32)), "empty"),
            (dict(b=np.ones(5, np.float32)), "shape"),
        ]
        for override, reason in cases:
            kw = dict(a=a, b=b)
            kw.update(override)
            with pytest.raises(InvalidProblemError) as ei:
                validate_problem(CFG, kw["a"], kw["b"], shape=(8, 12),
                                 rid=7)
            assert ei.value.reason == reason
            assert ei.value.rid == 7
        validate_problem(CFG, a, b, shape=(8, 12))   # clean passes

    def test_uv_safe_bound(self):
        a = np.ones(8, np.float32)
        b = np.ones(8, np.float32)
        assert uv_safe(CFG, a, b)
        # balanced problems have no amplification mode at ANY mass ratio
        bal = dataclasses.replace(CFG, reg_m=float("inf"))
        assert uv_safe(bal, a * 1e30, b)
        # unbalanced + huge mass imbalance -> overflow regime
        hot = UOTConfig(reg=0.001, reg_m=10.0, num_iters=10)
        assert not uv_safe(hot, a * 1e30, b)
        with pytest.raises(InvalidProblemError) as ei:
            validate_problem(hot, a * 1e30, b)
        assert ei.value.reason == "uv_overflow"

    def test_escalation_config_and_solve(self):
        ecfg = escalation_config(CFG, factor=3)
        assert ecfg.num_iters == 3 * CFG.num_iters
        K, a, b = make_problem(8, 12, 0)
        P, stats, ok = escalate_log_solve(K, a, b, CFG)
        assert ok and np.all(np.isfinite(P)) and P.shape == (8, 12)
        Kn = np.asarray(K).copy()
        Kn[2, 3] = np.nan          # poison must stay poisonous
        _, _, ok_bad = escalate_log_solve(Kn, a, b, CFG)
        assert not ok_bad


class TestLaneHealthDetector:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_poisoned_lane_frozen_others_bit_identical(self, impl):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=24, tol=1e-6)
        probs = [make_problem(16, 48, s) for s in range(4)]
        clean = ops.make_lane_state(4, 32, 64, cfg)
        dirty = ops.make_lane_state(4, 32, 64, cfg)
        for i, (K, a, b) in enumerate(probs):
            clean = ops.lane_admit(clean, jnp.int32(i), K, a, b)
            if i == 2:
                Kn = np.asarray(K).copy()
                Kn[3, 7] = np.nan
                K = jnp.asarray(Kn)
            dirty = ops.lane_admit(dirty, jnp.int32(i), K, a, b)
        for _ in range(4):
            clean = ops.solve_fused_stepped(clean, 6, cfg, interpret=True,
                                            impl=impl)
            dirty = ops.solve_fused_stepped(dirty, 6, cfg, interpret=True,
                                            impl=impl)
        healthy = np.asarray(dirty.healthy)
        assert healthy.tolist() == [True, True, False, True]
        # frozen at detection (inside the first chunk), done
        assert int(dirty.iters[2]) <= 6
        assert bool(ops.lane_done(dirty, cfg.num_iters)[2])
        assert np.asarray(clean.healthy).all()
        for i in (0, 1, 3):
            assert np.array_equal(np.asarray(clean.P[i]),
                                  np.asarray(dirty.P[i])), i

    def test_eviction_resets_health(self):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=12)
        K, a, b = make_problem(16, 48, 0)
        Kn = np.asarray(K).copy()
        Kn[0, 0] = np.inf
        st = ops.make_lane_state(2, 32, 64, cfg)
        st = ops.lane_admit(st, jnp.int32(0), jnp.asarray(Kn), a, b)
        st = ops.solve_fused_stepped(st, 4, cfg, impl="jnp")
        assert not bool(st.healthy[0])
        st = ops.lane_evict(st, jnp.int32(0))
        assert bool(st.healthy[0])
        # the scrubbed lane serves a fresh problem cleanly
        st = ops.lane_admit(st, jnp.int32(0), K, a, b)
        st = ops.solve_fused_stepped(st, cfg.num_iters, cfg, impl="jnp")
        assert bool(st.healthy[0])
        assert np.all(np.isfinite(np.asarray(st.P[0])))


class TestSchedulerContainment:
    def _oracle(self, probs):
        s = _sched()
        rids = [s.submit(*p) for p in probs]
        return rids, s.run()

    def test_rejection_resolves_and_takes_once(self):
        s = _sched()
        K, a, b = make_problem(8, 40, 1)
        bad_a = np.asarray(a).copy()
        bad_a[0] = np.nan
        with pytest.raises(InvalidProblemError) as ei:
            s.submit(K, bad_a, b)
        rid = ei.value.rid
        rec = {t.rid: t for t in s.request_log}[rid]
        assert rec.status == "rejected" and rec.lane == -1
        failure = s.poll(rid)
        assert isinstance(failure, RequestFailure)
        assert failure.status == "rejected"
        assert s.poll(rid) is None
        assert s.stats()["rejected"] == 1

    def test_nan_payload_fails_neighbors_unharmed(self):
        probs = [make_problem(16, 48, s) for s in range(5)]
        rids0, res0 = self._oracle(probs)
        s = _sched()
        K, a, b = probs[2]
        Kn = np.asarray(K).copy()
        Kn[1, 2] = np.nan
        rids = []
        for i, p in enumerate(probs):
            rids.append(s.submit(Kn, a, b) if i == 2 else s.submit(*p))
        res = s.run()
        bad = rids[2]
        assert bad not in res
        failure = s.poll(bad)
        assert isinstance(failure, RequestFailure)
        assert failure.status == "failed" and failure.retries == 1
        for i, r in enumerate(rids):
            if i != 2:
                assert np.array_equal(res[r], res0[rids0[i]]), i
        st = s.stats()
        assert st["failed"] == 1 and st["unhealthy_evictions"] == 1

    def test_lane_fault_escalates_retried_ok(self):
        probs = [make_problem(16, 48, s) for s in range(5)]
        rids0, res0 = self._oracle(probs)
        s = _sched()
        rids = [s.submit(*p) for p in probs]
        s.step()
        assert s.inject_lane_fault(rids[1])
        res = s.run()
        assert set(res) == set(rids)
        rec = {t.rid: t for t in s.request_log}[rids[1]]
        assert rec.status == "retried_ok" and rec.retries == 1
        assert np.all(np.isfinite(res[rids[1]]))
        for i, r in enumerate(rids):
            if i != 1:
                assert np.array_equal(res[r], res0[rids0[i]]), i
        assert s.stats()["retried_ok"] == 1

    def test_timed_out_status_on_cap(self):
        s = UOTScheduler(UOTConfig(reg=0.1, reg_m=1.0, num_iters=6,
                                   tol=1e-12),
                         lanes_per_pool=2, chunk_iters=6, m_bucket=32,
                         impl="jnp")
        K, a, b = make_problem(16, 48, 0, peak=8.0)
        rid = s.submit(K, a, b)
        res = s.run()
        assert rid in res                      # capped coupling delivered
        rec = {t.rid: t for t in s.request_log}[rid]
        assert rec.status == "timed_out" and not rec.converged
        assert s.stats()["timed_out"] == 1

    def test_bounded_results_leave_lost_tombstones(self):
        probs = [make_problem(16, 48, s) for s in range(6)]
        s = _sched(max_results=2)
        rids = [s.submit(*p) for p in probs]
        s.run()
        lost, kept = [], []
        for r in rids:
            out = s.poll(r)
            assert out is not None             # resolution invariant
            (lost if isinstance(out, RequestFailure) else kept).append(r)
        assert len(kept) == 2 and len(lost) == 4
        assert all(s.poll(r) is None for r in rids)   # take-once
        assert s.stats()["lost_results"] == 4

    def test_shed_drop_resolves_as_rejected(self):
        t = [10.0]
        s = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                         m_bucket=32, impl="jnp", shed_policy="drop",
                         clock=lambda: t[0])
        K, a, b = make_problem(16, 48, 0)
        dead = s.submit(K, a, b, deadline=9.0)
        s.run()
        failure = s.poll(dead)
        assert isinstance(failure, RequestFailure)
        assert failure.status == "rejected"


class TestSubmitWithRetry:
    def test_gives_up_after_attempts(self):
        s = _sched(max_queue=1)
        K, a, b = make_problem(8, 40, 0)
        s.submit(K, a, b)
        sleeps = []
        with pytest.raises(QueueFullError):
            submit_with_retry(s, K, a, b, attempts=4, base_delay=0.1,
                              max_delay=0.3, sleep=sleeps.append)
        assert len(sleeps) == 3                # no sleep after final try
        # capped exponential envelope with jitter in [0.5, 1.0)
        for i, d in enumerate(sleeps):
            hi = min(0.3, 0.1 * 2 ** i)
            assert 0.5 * hi <= d < hi

    def test_deterministic_jitter(self):
        s1 = _sched(max_queue=1)
        s2 = _sched(max_queue=1)
        K, a, b = make_problem(8, 40, 0)
        s1.submit(K, a, b)
        s2.submit(K, a, b)
        d1, d2 = [], []
        with pytest.raises(QueueFullError):
            submit_with_retry(s1, K, a, b, attempts=3, seed=5,
                              sleep=d1.append)
        with pytest.raises(QueueFullError):
            submit_with_retry(s2, K, a, b, attempts=3, seed=5,
                              sleep=d2.append)
        assert d1 == d2

    def test_succeeds_when_queue_drains(self):
        calls = {"n": 0}

        def flaky(*args, **kw):
            calls["n"] += 1
            if calls["n"] < 3:
                raise QueueFullError("full")
            return 42

        out = submit_with_retry(None, "x", attempts=5, sleep=lambda d: None,
                                submit=flaky)
        assert out == 42 and calls["n"] == 3

    def test_invalid_problem_not_retried(self):
        s = _sched()
        K, a, b = make_problem(8, 40, 0)
        bad_a = np.asarray(a).copy()
        bad_a[0] = -1.0
        sleeps = []
        with pytest.raises(InvalidProblemError):
            submit_with_retry(s, K, bad_a, b, attempts=5,
                              sleep=sleeps.append)
        assert sleeps == []                    # refused stays refused


class TestClusterContainment:
    def _oracle(self, probs):
        s = _sched()
        rids = [s.submit(*p) for p in probs]
        return rids, s.run()

    def test_blackout_quarantines_and_loses_nothing(self):
        probs = [make_problem(16, 48, s) for s in range(8)]
        rids0, res0 = self._oracle(probs)
        cs = _cluster(num_devices=4, lanes_per_device=2)
        rids = [cs.submit(*p) for p in probs]
        cs.step()
        cs.inject_device_fault(1)
        res = cs.run()
        assert set(res) == set(rids)
        st = cs.stats()
        assert st["device_health"][1] == "quarantined"
        assert st["requeued"] >= 1 and st["failed"] == 0
        # EVERY answer (including requeued victims) is the lane answer
        for i, r in enumerate(rids):
            assert np.array_equal(res[r], res0[rids0[i]]), i
        # quarantined device receives no further placements
        recs = [t for t in cs.request_log if t.route == "lane"]
        bounced = [t for t in recs if t.retries > 0]
        assert bounced and all(t.device != 1 for t in bounced)

    def test_lane_fault_requeues_bit_identical(self):
        probs = [make_problem(16, 48, s) for s in range(6)]
        rids0, res0 = self._oracle(probs)
        cs = _cluster()
        rids = [cs.submit(*p) for p in probs]
        cs.step()
        assert cs.inject_lane_fault(rids[3])
        res = cs.run()
        assert set(res) == set(rids)
        st = cs.stats()
        assert st["requeued"] == 1 and st["device_health"] == ["ok", "ok"]
        rec = {t.rid: t for t in cs.request_log}[rids[3]]
        assert rec.status == "ok" and rec.retries == 1
        for i, r in enumerate(rids):
            assert np.array_equal(res[r], res0[rids0[i]]), i

    def test_double_fault_escalates(self):
        cs = _cluster()
        K, a, b = make_problem(16, 48, 0)
        rid = cs.submit(K, a, b)
        cs.step()
        assert cs.inject_lane_fault(rid)
        cs.step()                               # detector flags
        cs.step()                               # requeue + readmit
        assert cs.inject_lane_fault(rid)        # strike the second lane
        res = cs.run()
        rec = {t.rid: t for t in cs.request_log}[rid]
        assert rec.status == "retried_ok" and rec.retries == 2
        assert rid in res and np.all(np.isfinite(res[rid]))

    def test_nan_payload_fails_after_bounce(self):
        cs = _cluster()
        K, a, b = make_problem(16, 48, 0)
        Kn = np.asarray(K).copy()
        Kn[0, 1] = np.nan
        bad = cs.submit(Kn, a, b)
        good = cs.submit(K, a, b)
        res = cs.run()
        assert good in res and bad not in res
        failure = cs.poll(bad)
        assert isinstance(failure, RequestFailure)
        assert failure.status == "failed" and failure.retries == 2
        assert cs.stats()["status_counts"]["failed"] == 1

    def test_all_quarantined_falls_back_to_gang(self):
        probs = [make_problem(16, 48, s) for s in range(4)]
        cs = _cluster(lanes_per_device=2)
        rids = [cs.submit(*p) for p in probs]
        cs.step()
        cs.inject_device_fault(0)
        cs.inject_device_fault(1)
        res = cs.run()
        assert set(res) == set(rids)
        st = cs.stats()
        assert st["device_health"] == ["quarantined", "quarantined"]
        assert st["gang_completed"] >= 1

    def test_gang_timeout_latches_degrade(self):
        t = {"now": 0.0}

        def clk():
            t["now"] += 10.0
            return t["now"]

        cs = ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                              m_bucket=32, impl="jnp", gang_timeout=5.0,
                              clock=clk,
                              lane_budget=lambda Mb, Nb: False)
        K, a, b = make_problem(16, 48, 0)
        g1 = cs.submit(K, a, b)
        g2 = cs.submit(*make_problem(16, 48, 1))
        cs.run()
        st = cs.stats()
        recs = {x.rid: x for x in cs.request_log}
        assert st["gang_timeouts"] >= 1
        assert recs[g1].status == "timed_out"
        assert recs[g2].iters <= cs.degrade_iters

    def test_cluster_rejection(self):
        cs = _cluster()
        K, a, b = make_problem(16, 48, 0)
        bad_b = np.asarray(b).copy()
        bad_b[0] = np.inf
        with pytest.raises(InvalidProblemError) as ei:
            cs.submit(K, a, bad_b)
        failure = cs.poll(ei.value.rid)
        assert isinstance(failure, RequestFailure)
        assert failure.status == "rejected"


class TestInjectors:
    def test_seeded_and_arrival_order_invariant(self):
        inj1 = faults.NaNPayload(0.5, seed=3)
        inj2 = faults.NaNPayload(0.5, seed=3)
        K, a, b = make_problem(8, 40, 0)
        # same (seed, rid) -> same decision, regardless of call order
        outs1 = [inj1.on_submit(r, np.asarray(K), a, b)[3]
                 for r in (0, 1, 2, 3)]
        outs2 = [inj2.on_submit(r, np.asarray(K), a, b)[3]
                 for r in (3, 1, 0, 2)]
        assert outs1 == [outs2[2], outs2[1], outs2[3], outs2[0]]

    def test_compose_first_tag_wins_and_merges(self):
        nan = faults.NaNPayload(1.0, seed=0)
        stuck = faults.StuckLane(1.0, seed=0)
        comp = faults.Compose([nan, stuck])
        K, a, b = make_problem(8, 40, 0)
        _, _, _, tag = comp.on_submit(0, np.asarray(K), a, b)
        assert tag == "nan_payload"
        assert comp.injected == {0: "nan_payload"}

    def test_stuck_lane_hits_cap(self):
        inj = faults.StuckLane(1.0, seed=0, power=8.0)
        s = _sched(fault_injector=inj)
        K, a, b = make_problem(16, 48, 0)
        rid = s.submit(K, a, b)
        res = s.run()
        rec = {t.rid: t for t in s.request_log}[rid]
        assert rid in res and rec.status == "timed_out"

    def test_overflow_injector_rejected(self):
        hot = UOTConfig(reg=0.001, reg_m=10.0, num_iters=10)
        s = UOTScheduler(hot, m_bucket=32, impl="jnp",
                         fault_injector=faults.OverflowConfig(1.0, seed=0))
        K, a, b = make_problem(8, 40, 0)
        with pytest.raises(InvalidProblemError) as ei:
            s.submit(K, a, b)
        assert ei.value.reason == "uv_overflow"

    def test_device_blackout_noop_on_single_device(self):
        inj = faults.DeviceBlackout(device=0, at_step=0)
        s = _sched(fault_injector=inj)
        K, a, b = make_problem(16, 48, 0)
        rid = s.submit(K, a, b)
        res = s.run()
        assert rid in res and not inj.fired    # no hook -> no-op


def _chaos_trial(seed, make_sched, n_requests=12):
    """One seeded chaos trial: composed injectors + shuffled arrivals.
    Returns (resolutions, injected tags, healthy-coupling dict)."""
    rng = np.random.default_rng(seed)
    probs = [make_problem(16, 48, 100 + i) for i in range(n_requests)]
    order = rng.permutation(n_requests)
    inj = faults.Compose([
        faults.NaNPayload(0.15, seed=seed),
        faults.StuckLane(0.1, seed=seed + 1),
        faults.LaneFault(0.05, seed=seed + 2),
    ])
    s = make_sched(inj)
    rids = {}
    for i in order:
        rids[i] = s.submit(*probs[int(i)])
    res = s.run()
    resolved = {}
    for i, r in rids.items():
        out = res.get(r)
        if out is None:
            out = s.poll(r)
        resolved[int(i)] = out
    return resolved, inj.injected, rids


class TestChaosProperty:
    """The resolution + blast-radius property under seeded random fault
    schedules and arrival orders (the hypothesis variant lives in
    test_faults_property.py; these seeded trials always run)."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_uot_scheduler(self, seed):
        probs = [make_problem(16, 48, 100 + i) for i in range(12)]
        base = _sched(max_results=64)
        base_rids = [base.submit(*p) for p in probs]
        base_res = base.run()

        resolved, injected, rids = _chaos_trial(
            seed, lambda inj: _sched(fault_injector=inj, max_results=64))
        for i, out in resolved.items():
            assert out is not None, f"request {i} never resolved"
            if rids[i] not in injected:
                assert isinstance(out, np.ndarray), (i, out)
                assert np.array_equal(out, base_res[base_rids[i]]), i

    @pytest.mark.parametrize("seed", [0, 1])
    def test_cluster_scheduler(self, seed):
        probs = [make_problem(16, 48, 100 + i) for i in range(12)]
        base = _sched(max_results=64)
        base_rids = [base.submit(*p) for p in probs]
        base_res = base.run()

        resolved, injected, rids = _chaos_trial(
            seed,
            lambda inj: _cluster(fault_injector=inj, max_results=64))
        for i, out in resolved.items():
            assert out is not None, f"request {i} never resolved"
            if rids[i] not in injected:
                assert isinstance(out, np.ndarray), (i, out)
                assert np.array_equal(out, base_res[base_rids[i]]), i
