"""Multi-device cluster serving: sharded lane pools + request router.

The load-bearing property, one tier up from tests/test_scheduler.py: a
request's answer must not depend on WHERE it was served — which device
shard, which lane, how many devices, which placement policy, sync or async
step loop, own-bucket pool or a shared wider one. Per-lane math is
placement-invariant, so the cluster scheduler's output is required to
EQUAL the single-device ``UOTScheduler``'s bit for bit. (The shard_map
mesh path needs real multi-device XLA — tests/_cluster_check.py covers it
on 8 forced host devices; here the per-device-loop mode, which
tests/_cluster_check.py asserts is bit-identical to the mesh path.)
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig, sinkhorn_uot_fused
from repro.kernels import ops
from repro.serve import QueueFullError, UOTScheduler
from repro.cluster import (ClusterScheduler, cluster_admit, cluster_done,
                           cluster_evict, cluster_stepped,
                           make_cluster_lane_state)

from benchmarks.common import make_problem as _common_problem


def make_problem(m, n, seed, peak=1.0, reg=0.1):
    return _common_problem(m, n, reg=reg, seed=seed, peak=peak)


def ragged_workload(seed, n_requests=8):
    r = np.random.default_rng(seed)
    shapes = [(8, 100), (20, 128), (32, 64), (16, 90), (24, 120)]
    out = []
    for i in range(n_requests):
        m, n = shapes[r.integers(len(shapes))]
        out.append(make_problem(m, n, seed * 1000 + i,
                                peak=float(r.uniform(1.0, 8.0))))
    return out


class TestClusterLanes:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)

    def _admit_single(self, cs, d, l, K, a, b):
        return cluster_admit(cs, jnp.int32(d), jnp.int32(l),
                             jnp.asarray(K), jnp.asarray(a), jnp.asarray(b))

    def test_matches_single_device_pool_bitwise(self):
        """A cluster slot's trajectory == the same problem in a plain
        single-device lane pool, bit for bit."""
        K, a, b = make_problem(30, 100, 1, peak=4.0)
        st = ops.lane_admit(ops.make_lane_state(2, 32, 128, self.CFG),
                            jnp.int32(1), K, a, b)
        cs = self._admit_single(
            make_cluster_lane_state(3, 2, 32, 128, self.CFG), 2, 1, K, a, b)
        for _ in range(10):
            st = ops.solve_fused_stepped(st, 4, self.CFG, impl="jnp")
            cs = cluster_stepped(cs, 4, self.CFG, impl="jnp")
        np.testing.assert_array_equal(np.asarray(cs.lanes.P[2, 1]),
                                      np.asarray(st.P[1]))
        assert int(cs.lanes.iters[2, 1]) == int(st.iters[1])
        assert bool(cluster_done(cs, self.CFG.num_iters)[2, 1]) == \
            bool(ops.lane_done(st, self.CFG.num_iters)[1])

    def test_placement_invariance_across_slots(self):
        """Same problem admitted to any (device, lane) slot -> same bits,
        whatever else shares the stack."""
        K, a, b = make_problem(24, 120, 2, peak=2.0)
        K2, a2, b2 = make_problem(30, 90, 3, peak=8.0)
        results = []
        for (d, l), (d2, l2) in [((0, 0), (1, 1)), ((2, 1), (0, 0)),
                                 ((1, 0), (2, 0))]:
            cs = make_cluster_lane_state(3, 2, 32, 128, self.CFG)
            cs = self._admit_single(cs, d, l, K, a, b)
            cs = self._admit_single(cs, d2, l2, K2, a2, b2)
            for _ in range(12):
                cs = cluster_stepped(cs, 4, self.CFG, impl="jnp")
            results.append((np.asarray(cs.lanes.P[d, l]),
                            int(cs.lanes.iters[d, l])))
        for P, iters in results[1:]:
            np.testing.assert_array_equal(P, results[0][0])
            assert iters == results[0][1]

    def test_evicted_slot_is_noop_and_reusable(self):
        K, a, b = make_problem(20, 100, 4)
        cs = self._admit_single(
            make_cluster_lane_state(2, 2, 32, 128, self.CFG), 1, 0, K, a, b)
        cs = cluster_evict(cs, jnp.int32(1), jnp.int32(0))
        assert not bool(cs.lanes.active.any())
        assert int(cs.lanes.m_valid[1, 0]) == 0
        np.testing.assert_array_equal(np.asarray(cs.lanes.P), 0.0)
        cs2 = cluster_stepped(cs, 3, self.CFG, impl="jnp")
        np.testing.assert_array_equal(np.asarray(cs2.lanes.P),
                                      np.asarray(cs.lanes.P))

    def test_cross_bucket_admit_into_wider_pool_bitwise(self):
        """Cross-bucket lane sharing groundwork: a problem admitted with
        valid-extent masking into a WIDER pool (both dims) produces the
        bit-identical iterate on its valid region — appended zeros are
        exact identities of every reduction."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-4)
        K, a, b = make_problem(24, 60, 5, peak=4.0)
        # own-bucket pool: (32, 64)-shaped lanes
        own = ops.lane_admit(ops.make_lane_state(2, 32, 64, cfg),
                             jnp.int32(0), K, a, b)
        # wider shared pool: (64, 128)-shaped lanes, valid counts recorded
        wide = ops.lane_admit(ops.make_lane_state(2, 64, 128, cfg),
                              jnp.int32(1), K, a, b,
                              m_valid=jnp.int32(24), n_valid=jnp.int32(60))
        assert int(wide.m_valid[1]) == 24 and int(wide.n_valid[1]) == 60
        for _ in range(20):
            own = ops.solve_fused_stepped(own, 4, cfg, impl="jnp")
            wide = ops.solve_fused_stepped(wide, 4, cfg, impl="jnp")
        assert int(own.iters[0]) == int(wide.iters[1])
        np.testing.assert_array_equal(np.asarray(own.P[0, :24, :60]),
                                      np.asarray(wide.P[1, :24, :60]))
        np.testing.assert_array_equal(np.asarray(wide.P[1, 24:, :]), 0.0)
        np.testing.assert_array_equal(np.asarray(wide.P[1, :, 60:]), 0.0)

    def test_admit_masks_payload_junk_beyond_valid_counts(self):
        """lane_admit enforces the mask: payload garbage beyond the valid
        extents cannot leak into the pool."""
        cfg = self.CFG
        K, a, b = make_problem(16, 64, 6)
        junk = np.full((32, 128), 7.0, np.float32)
        junk[:16, :64] = np.asarray(K)
        aj = np.full(32, 3.0, np.float32)
        aj[:16] = np.asarray(a)
        bj = np.full(128, 3.0, np.float32)
        bj[:64] = np.asarray(b)
        st = ops.lane_admit(ops.make_lane_state(1, 32, 128, cfg),
                            jnp.int32(0), jnp.asarray(junk),
                            jnp.asarray(aj), jnp.asarray(bj),
                            m_valid=jnp.int32(16), n_valid=jnp.int32(64))
        clean = ops.lane_admit(ops.make_lane_state(1, 32, 128, cfg),
                               jnp.int32(0), K, a, b)
        np.testing.assert_array_equal(np.asarray(st.P), np.asarray(clean.P))
        np.testing.assert_array_equal(np.asarray(st.colsum),
                                      np.asarray(clean.colsum))


class TestClusterSchedulerProperty:
    """Cluster output == single-device UOTScheduler output, bit for bit."""

    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)

    def _reference(self, probs):
        ref = UOTScheduler(self.CFG, lanes_per_pool=2, chunk_iters=3,
                           m_bucket=32, impl="jnp")
        rids = [ref.submit(*p) for p in probs]
        out = ref.run()
        return [out[r] for r in rids]

    @pytest.mark.parametrize("kwargs", [
        dict(num_devices=1),
        dict(num_devices=3),
        dict(num_devices=4, placement="bucket_affinity"),
        dict(num_devices=3, step_mode="async"),
    ])
    def test_bit_identical_to_single_device_scheduler(self, kwargs):
        probs = ragged_workload(11)
        ref = self._reference(probs)
        cs = ClusterScheduler(self.CFG, lanes_per_device=2, chunk_iters=3,
                              m_bucket=32, impl="jnp", **kwargs)
        rids = [cs.submit(*p) for p in probs]
        out = cs.run()
        assert cs.pending == 0 and cs.in_flight == 0
        for rid, expect in zip(rids, ref):
            np.testing.assert_array_equal(out[rid], expect)

    def test_async_equals_sync_including_iteration_counts(self):
        """The double-buffered loop makes the same decisions on the same
        data as the sync loop: bit-identical couplings AND identical
        per-request iteration counts."""
        probs = ragged_workload(13, n_requests=10)
        outs, iters = [], []
        for mode in ("sync", "async"):
            cs = ClusterScheduler(self.CFG, num_devices=2,
                                  lanes_per_device=2, chunk_iters=3,
                                  m_bucket=32, impl="jnp", step_mode=mode,
                                  clock=lambda: 0.0)
            rids = [cs.submit(*p) for p in probs]
            out = cs.run()
            outs.append([out[r] for r in rids])
            by_rid = {t.rid: t.iters for t in cs.request_log}
            iters.append([by_rid[r] for r in rids])
        for a, b in zip(outs[0], outs[1]):
            np.testing.assert_array_equal(a, b)
        assert iters[0] == iters[1]

    def test_points_requests_match_dense_submission(self):
        """Coordinate payloads through the cluster == dense submission of
        the same geometry's kernel (single-device contract, inherited)."""
        from repro.geometry import PointCloudGeometry
        cfg = self.CFG
        rng = np.random.default_rng(3)
        x = rng.normal(size=(24, 3)).astype(np.float32)
        y = rng.normal(size=(100, 3)).astype(np.float32) + 0.3
        a = rng.uniform(0.5, 1.5, 24).astype(np.float32)
        b = rng.uniform(0.5, 1.5, 100).astype(np.float32)
        a, b = a / a.sum(), b / b.sum() * 1.2
        g = PointCloudGeometry.from_points(x, y, scale=2.0)
        dense = ClusterScheduler(cfg, num_devices=2, lanes_per_device=2,
                                 m_bucket=32, impl="jnp")
        rd = dense.submit(np.asarray(g.kernel(cfg.reg)), a, b)
        pts = ClusterScheduler(cfg, num_devices=2, lanes_per_device=2,
                               m_bucket=32, impl="jnp")
        rp = pts.submit_points(x, y, a, b, scale=2.0)
        np.testing.assert_array_equal(dense.run()[rd], pts.run()[rp])


class TestClusterScheduling:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=6)

    def test_router_least_loaded_spreads_devices(self):
        cs = ClusterScheduler(self.CFG, num_devices=4, lanes_per_device=1,
                              m_bucket=32, impl="jnp")
        K, a, b = make_problem(16, 100, 0)
        for _ in range(4):
            cs.submit(K, a, b)
        cs.step()
        st = cs.stats()
        assert st["router"]["least_loaded"] == 4
        assert all(v["placed"] == 1 for v in st["devices"].values())

    def test_bucket_affinity_packs_then_spills(self):
        cs = ClusterScheduler(self.CFG, num_devices=3, lanes_per_device=2,
                              m_bucket=32, impl="jnp",
                              placement="bucket_affinity")
        K, a, b = make_problem(16, 100, 1)
        for _ in range(3):
            cs.submit(K, a, b)
        cs.step()
        st = cs.stats()
        # first placement spills (no hot device), next two pack device 0
        # then spill to a fresh device once it is full
        assert st["router"]["affinity_hits"] == 1
        assert st["router"]["affinity_spills"] == 2
        assert st["devices"][0]["placed"] == 2

    def test_device_active_cap_limits_placement(self):
        cs = ClusterScheduler(self.CFG, num_devices=2, lanes_per_device=4,
                              m_bucket=32, impl="jnp", device_active_cap=1)
        K, a, b = make_problem(16, 100, 2)
        rids = [cs.submit(K, a, b) for _ in range(4)]
        cs.step()
        st = cs.stats()
        assert all(v["active"] <= 1 for v in st["devices"].values())
        assert st["router"]["placement_stalls"] >= 1
        out = cs.run()
        assert all(r in out for r in rids)     # capped, not starved

    def test_cluster_backpressure(self):
        cs = ClusterScheduler(self.CFG, num_devices=2, lanes_per_device=1,
                              m_bucket=32, impl="jnp", max_queue=2)
        K, a, b = make_problem(16, 100, 3)
        cs.submit(K, a, b)
        cs.submit(K, a, b)
        with pytest.raises(QueueFullError):
            cs.submit(K, a, b)
        cs.step()
        rid = cs.submit(K, a, b)
        out = cs.run()
        assert rid in out and len(out) == 3

    def test_gang_escape_hatch_no_mesh(self):
        """Over-budget shapes are served (per-request tier without a mesh),
        not rejected, and recorded with the gang route."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=12)
        cs = ClusterScheduler(cfg, num_devices=2, lanes_per_device=2,
                              impl="jnp", interpret=True,
                              lane_budget=lambda Mb, Nb: Mb * Nb <= 64 * 128)
        K, a, b = make_problem(16, 100, 4)
        Kb, ab, bb = make_problem(150, 200, 5)
        r_lane = cs.submit(K, a, b)
        r_gang = cs.submit(Kb, ab, bb)
        out = cs.run()
        assert r_lane in out and r_gang in out
        ref, _ = sinkhorn_uot_fused(jnp.asarray(Kb), jnp.asarray(ab),
                                    jnp.asarray(bb), cfg)
        np.testing.assert_allclose(out[r_gang], np.asarray(ref),
                                   rtol=1e-5, atol=1e-8)
        st = cs.stats()
        assert st["gang_completed"] == 1
        assert st["router"]["gang_routed"] == 1
        by_rid = {t.rid: t for t in cs.request_log}
        assert by_rid[r_gang].route == "gang"
        assert by_rid[r_gang].device == -1
        assert by_rid[r_lane].route == "lane"
        assert by_rid[r_lane].device >= 0

    def test_shared_pool_bit_identical_and_counted(self):
        """share_pools: a one-off narrow bucket rides an existing wider
        pool (masked lanes) instead of allocating a new pool stack, with
        bit-identical results."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=1e-3)
        wide = make_problem(24, 120, 6, peak=4.0)    # bucket (32, 128)
        narrow = make_problem(20, 60, 7, peak=2.0)   # bucket (32, 64)
        own = ClusterScheduler(cfg, num_devices=2, lanes_per_device=2,
                               m_bucket=32, n_bucket=64, impl="jnp")
        r0 = own.submit(*narrow)
        expect = own.run()[r0]
        shared = ClusterScheduler(cfg, num_devices=2, lanes_per_device=2,
                                  m_bucket=32, n_bucket=64, impl="jnp",
                                  share_pools=True,
                                  placement="bucket_affinity")
        r_wide = shared.submit(*wide)
        shared.step()                     # wide pool now exists
        r_narrow = shared.submit(*narrow)
        out = shared.run()
        np.testing.assert_array_equal(out[r_narrow], expect)
        assert shared.stats()["router"]["shared_pool"] == 1
        assert len(shared._pools) == 1    # no second pool stack allocated

    def test_share_pools_requires_bucket_affinity(self):
        with pytest.raises(ValueError, match="bucket_affinity"):
            ClusterScheduler(self.CFG, num_devices=2, share_pools=True)

    def test_shed_policies_cluster(self):
        t = [10.0]
        cs = ClusterScheduler(self.CFG, num_devices=2, lanes_per_device=2,
                              m_bucket=32, impl="jnp", shed_policy="drop",
                              clock=lambda: t[0])
        K, a, b = make_problem(16, 100, 8)
        r_dead = cs.submit(K, a, b, deadline=9.0)
        r_live = cs.submit(K, a, b, deadline=1e9)
        out = cs.run()
        assert r_live in out and r_dead not in out
        st = cs.stats()
        assert st["shed_dropped"] == 1 and st["completed"] == 1
        rec = {tt.rid: tt for tt in cs.request_log}[r_dead]
        assert rec.route == "dropped" and rec.device == -1

    def test_poll_take_semantics_and_device_telemetry(self):
        t = [0.0]
        cs = ClusterScheduler(self.CFG, num_devices=2, lanes_per_device=1,
                              m_bucket=32, impl="jnp", clock=lambda: t[0])
        K, a, b = make_problem(16, 100, 9)
        rids = [cs.submit(K, a, b) for _ in range(4)]
        while cs.pending or cs.in_flight:
            cs.step()
        assert cs.poll(rids[0]) is not None
        assert cs.poll(rids[0]) is None
        st = cs.stats()
        assert st["completed"] == 4
        assert sum(v["completed"] for v in st["devices"].values()) == 4
        assert sum(v["placed"] for v in st["devices"].values()) == 4
        assert st["occupancy_mean"] > 0
        assert len(cs.occupancy_log) == st["steps"]
        assert cs.occupancy_log[-1]["device_active"] == [0, 0]


class TestDispatchCounters:
    """The dispatch_stats() footgun fix: per-context counters."""

    def test_nested_contexts_do_not_clobber(self):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=4)
        K, a, b = make_problem(16, 100, 0)
        ops.reset_dispatch_stats()
        before = ops.dispatch_stats()
        with ops.dispatch_counters() as outer:
            ops.solve_fused(K, a, b, cfg, interpret=True, impl="auto")
            with ops.dispatch_counters() as inner:
                ops.solve_fused(K, a, b, cfg, interpret=True, impl="auto")
                # innermost scope is what dispatch_stats() reports
                assert ops.dispatch_stats() == inner
            assert sum(inner.values()) == 1
        assert sum(outer.values()) == 2       # outer aggregates inner
        after = ops.dispatch_stats()
        # the global base also counted both, and was not reset by the
        # scopes closing
        assert (sum(after.values()) - sum(before.values())) == 2

    def test_two_schedulers_track_their_own_decisions(self):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=4)
        K, a, b = make_problem(16, 100, 1)
        s1 = ClusterScheduler(cfg, num_devices=1, lanes_per_device=1,
                              m_bucket=32, impl="auto", interpret=True)
        s2 = ClusterScheduler(cfg, num_devices=1, lanes_per_device=1,
                              m_bucket=32, impl="auto", interpret=True)
        r1 = s1.submit(K, a, b)
        r2 = s2.submit(K, a, b)
        # interleave the two schedulers' steps: each counts only its own
        # pool advances
        while s1.pending or s1.in_flight or s2.pending or s2.in_flight:
            if s1.pending or s1.in_flight:
                s1.step()
            if s2.pending or s2.in_flight:
                s2.step()
        assert s1.poll(r1) is not None and s2.poll(r2) is not None
        # num_iters=4 == chunk_iters: each scheduler advanced its pool
        # exactly once, and — the footgun fix — counted only its OWN
        # advance despite the interleaving (the shared global would say 2)
        d1, d2 = s1.stats()["dispatch"], s2.stats()["dispatch"]
        assert sum(d1.values()) == 1
        assert sum(d2.values()) == 1
