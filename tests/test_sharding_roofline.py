"""Sharding rule table + roofline HLO parser unit tests (no big meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.launch import roofline
from repro.models.model import build_model
from repro.parallel import sharding as shd


class FakeMesh:
    """Duck-typed mesh: .axis_names + .devices.shape + .shape mapping."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)
        self.shape = dict(zip(names, shape))


MESH = FakeMesh((16, 16), ("data", "model"))


def _specs_for(arch):
    cfg = get_arch(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return cfg, shapes, shd.param_specs(cfg, shapes, MESH)


class TestParamSpecs:
    def test_dense_tp_pattern(self):
        cfg, shapes, specs = _specs_for("granite-3-2b")
        lyr = specs["layers"]
        # col-parallel qkv / row-parallel o (megatron pair); stacked L dim free
        assert lyr["attn"]["w_q"] == P(None, "data", "model")
        assert lyr["attn"]["w_o"] == P(None, "model", "data")
        assert lyr["mlp"]["w_up"] == P(None, "data", "model")
        assert lyr["mlp"]["w_down"] == P(None, "model", "data")
        # vocab-parallel head; d-sharded embedding
        assert specs["head"]["w_out"] == P("data", "model")
        assert specs["embed"]["table"] == P("data", "model")
        # norms replicated
        assert specs["final_norm"]["scale"] == P(None)

    def test_moe_expert_parallel(self):
        cfg, shapes, specs = _specs_for("olmoe-1b-7b")
        moe = specs["layers"]["moe"]
        # experts over model (EP), d_model FSDP; router replicated
        assert moe["w_gate"] == P(None, "model", "data", None)
        assert moe["w_router"] == P(None, None, None)

    def test_indivisible_heads_shard_flat_dim(self):
        """smollm: 15 heads but H*hd = 960 IS divisible by 16 -> TP shards
        the flat projection dim (the per-head reshape resharding is XLA's
        job); tiny tensors (<2^20 elems) skip FSDP."""
        cfg, shapes, specs = _specs_for("smollm-360m")
        wq = specs["layers"]["attn"]["w_q"]
        assert wq[-1] == "model"
        assert "data" not in wq  # 960*960 < 2^20: no FSDP
        # d_ff = 2560 divisible -> TP applies on mlp
        assert specs["layers"]["mlp"]["w_up"][-1] == "model"

    def test_hybrid_and_ssm_specs_exist(self):
        for arch in ("zamba2-7b", "xlstm-350m"):
            cfg, shapes, specs = _specs_for(arch)
            flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            assert all(isinstance(s, P) for s in flat)

    def test_specs_valid_against_shapes(self):
        """Every sharded dim must divide evenly (the rule's invariant)."""
        mesh_axes = {"data": 16, "model": 16}
        for arch in ("granite-34b", "phi4-mini-3.8b", "moonshot-v1-16b-a3b",
                     "musicgen-medium", "llava-next-34b"):
            cfg, shapes, specs = _specs_for(arch)

            def check(s, spec):
                for dim, p in zip(s.shape, spec):
                    if p is None:
                        continue
                    axes = p if isinstance(p, tuple) else (p,)
                    k = 1
                    for ax in axes:
                        k *= mesh_axes[ax]
                    assert dim % k == 0, (arch, s.shape, spec)

            jax.tree.map(check, shapes, specs,
                         is_leaf=lambda x: isinstance(x, P))


class TestBatchCacheSpecs:
    def test_batch_sharded_over_dp(self):
        cfg = get_arch("granite-3-2b")
        shapes = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        specs = shd.batch_specs(cfg, shapes, MESH)
        assert specs["tokens"] == P(("data",), None)

    def test_batch_of_one_not_sharded(self):
        cfg = get_arch("zamba2-7b")
        shapes = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
        specs = shd.batch_specs(cfg, shapes, MESH)
        assert specs["tokens"] == P(None, None)

    def test_kv_cache_mqa_shards_sequence(self):
        """granite-34b kv=1: heads can't shard -> sequence dim over model."""
        cfg = get_arch("granite-34b")
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda: model.init_cache(128, 32768))
        specs = shd.cache_specs(cfg, shapes, MESH)
        assert specs["k"] == P(None, ("data",), "model", None, None)

    def test_kv_cache_gqa_shards_heads(self):
        cfg = get_arch("olmoe-1b-7b")  # kv=16
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda: model.init_cache(128, 32768))
        specs = shd.cache_specs(cfg, shapes, MESH)
        assert specs["k"] == P(None, ("data",), None, "model", None)


class TestRooflineParser:
    HLO = """
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[256,512]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %cp = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute-start(%w)
  %cpd = f32[8,8]{1,0} collective-permute-done(%cp)
  %a2a = f32[4,4]{1,0} all-to-all(%v), dimensions={1}
"""

    def test_collective_bytes(self):
        out = roofline.collective_bytes(self.HLO)
        assert out["counts"] == {"all-reduce": 1, "all-gather": 1,
                                 "reduce-scatter": 1,
                                 "collective-permute": 1, "all-to-all": 1}
        b = out["bytes_by_kind"]
        assert b["all-reduce"] == 2 * 16 * 1024 * 4      # 2x ring
        assert b["all-gather"] == 256 * 512 * 2
        assert b["reduce-scatter"] == 64 * 4
        assert b["all-to-all"] == 4 * 4 * 4
        assert b["collective-permute"] == 2 * 8 * 8 * 4  # start tuple

    def test_terms_and_bottleneck(self):
        t = roofline.RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2,
                                   coll_bytes=50e9 * 0.5)
        assert abs(t.t_comp - 1.0) < 1e-9
        assert abs(t.t_mem - 2.0) < 1e-9
        assert t.bottleneck == "memory"
        assert t.t_bound == t.t_mem

    def test_model_flops(self):
        cfg = get_arch("granite-3-2b")
        shape = type("S", (), {"kind": "train", "global_batch": 256,
                               "seq_len": 4096})()
        mf = roofline.model_flops(cfg, shape)
        assert abs(mf - 6 * cfg.param_count() * 256 * 4096) / mf < 1e-9
