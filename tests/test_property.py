"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import UOTConfig, sinkhorn_uot_baseline, sinkhorn_uot_fused
from repro.kernels import ops, ref
from repro.kernels.uot_fused import fused_iteration


dims = st.integers(min_value=1, max_value=7)


def _problem(M, N, seed, mass_ratio):
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(M, N)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * mass_ratio
    K = np.exp(-C / 0.1) * (a[:, None] * b[None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


@settings(max_examples=25, deadline=None)
@given(m8=dims, n8=dims, seed=st.integers(0, 2**31 - 1),
       mass_ratio=st.floats(0.3, 3.0),
       reg_m=st.floats(0.1, 50.0),
       iters=st.integers(1, 30))
def test_fused_equals_baseline_any_problem(m8, n8, seed, mass_ratio, reg_m,
                                           iters):
    """Schedule-only claim: MAP-UOT == 4-pass baseline for ALL inputs."""
    M, N = 8 * m8, 16 * n8
    K, a, b = _problem(M, N, seed, mass_ratio)
    cfg = UOTConfig(reg=0.1, reg_m=reg_m, num_iters=iters)
    A1, _ = sinkhorn_uot_baseline(K, a, b, cfg)
    A2, _ = sinkhorn_uot_fused(K, a, b, cfg)
    np.testing.assert_allclose(np.asarray(A1), np.asarray(A2),
                               rtol=5e-5, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       fi=st.floats(0.1, 1.0),
       bm_log=st.integers(3, 6))
def test_kernel_matches_oracle_any_input(seed, fi, bm_log):
    """Pallas fused kernel == oracle for random shapes/factors/exponents."""
    rng = np.random.default_rng(seed)
    bm = 2 ** bm_log
    M = bm * int(rng.integers(1, 5))
    N = 128 * int(rng.integers(1, 5))
    A = jnp.asarray(rng.uniform(0.01, 2.0, size=(M, N)), jnp.float32)
    fcol = jnp.asarray(rng.uniform(0.1, 2.0, size=N), jnp.float32)
    a = jnp.asarray(rng.uniform(0.1, 2.0, size=M), jnp.float32)
    out, cs = fused_iteration(A, fcol, a, fi=float(fi), block_m=bm,
                              interpret=True)
    out_r, cs_r = ref.fused_iteration_ref(A, fcol, a, fi=float(fi))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=3e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(cs_r), rtol=3e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       M=st.integers(3, 90), N=st.integers(3, 90))
def test_padding_invariance(seed, M, N):
    """ops.solve_fused pads to (bm, 128); result must be pad-independent."""
    K, a, b = _problem(M, N, seed, 1.2)
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=10)
    A_core, _ = sinkhorn_uot_fused(K, a, b, cfg)
    A_kern, _ = ops.solve_fused(K, a, b, cfg, block_m=8, interpret=True)
    np.testing.assert_allclose(np.asarray(A_kern), np.asarray(A_core),
                               rtol=5e-5, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mass_ratio=st.floats(0.2, 5.0))
def test_coupling_nonnegative_finite(seed, mass_ratio):
    K, a, b = _problem(32, 48, seed, mass_ratio)
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=100)
    A, _ = sinkhorn_uot_fused(K, a, b, cfg)
    A = np.asarray(A)
    assert np.all(A >= 0) and np.all(np.isfinite(A))
