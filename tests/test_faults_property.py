"""Property test (hypothesis): under ANY seeded fault schedule and ANY
arrival order, both schedulers uphold the resolution + blast-radius
invariants:

* every submitted rid resolves via ``poll``/``run`` to exactly one
  coupling or typed ``RequestFailure`` — nothing vanishes, nothing
  double-resolves (take-once semantics);
* requests the injectors did NOT touch produce couplings bit-identical
  to a fault-free run of the same problems.

Seeded deterministic trials of the same invariant always run in
tests/test_faults.py::TestChaosProperty; this file widens the search to
hypothesis-chosen seeds/orders when hypothesis is installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import UOTConfig
from repro.cluster import ClusterScheduler
from repro.serve import RequestFailure, UOTScheduler, faults

from benchmarks.common import make_problem

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-5)
N_REQUESTS = 10
PROBLEMS = [make_problem(16, 48, reg=CFG.reg, seed=100 + i, peak=1.0)
            for i in range(N_REQUESTS)]


def _baseline():
    s = UOTScheduler(CFG, lanes_per_pool=4, chunk_iters=6, m_bucket=32,
                     impl="jnp", max_results=64)
    rids = [s.submit(*p) for p in PROBLEMS]
    return rids, s.run()


_BASE_RIDS, _BASE_RES = _baseline()


def _injector(seed):
    return faults.Compose([
        faults.NaNPayload(0.15, seed=seed),
        faults.StuckLane(0.1, seed=seed + 1),
        faults.LaneFault(0.05, seed=seed + 2),
    ])


def _check(make_sched, seed, order):
    inj = _injector(seed)
    s = make_sched(inj)
    rids = {}
    for i in order:
        rids[i] = s.submit(*PROBLEMS[i])
    res = s.run()
    for i, r in rids.items():
        out = res.get(r)
        if out is None:
            out = s.poll(r)
        assert out is not None, f"request {i} (rid {r}) never resolved"
        assert s.poll(r) is None, f"rid {r} resolved twice"
        assert isinstance(out, (np.ndarray, RequestFailure))
        if r not in inj.injected:
            assert isinstance(out, np.ndarray), (i, out)
            assert np.array_equal(out, _BASE_RES[_BASE_RIDS[i]]), \
                f"untouched request {i} diverged from fault-free run"


orders = st.permutations(range(N_REQUESTS))
seeds = st.integers(min_value=0, max_value=2 ** 16)
SETTINGS = settings(max_examples=10, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


@given(seed=seeds, order=orders)
@SETTINGS
def test_uot_scheduler_resolves_every_rid(seed, order):
    _check(lambda inj: UOTScheduler(
        CFG, lanes_per_pool=4, chunk_iters=6, m_bucket=32, impl="jnp",
        max_results=64, fault_injector=inj), seed, order)


@given(seed=seeds, order=orders)
@SETTINGS
def test_cluster_scheduler_resolves_every_rid(seed, order):
    _check(lambda inj: ClusterScheduler(
        CFG, num_devices=2, lanes_per_device=4, chunk_iters=6,
        m_bucket=32, impl="jnp", max_results=64, fault_injector=inj),
        seed, order)
