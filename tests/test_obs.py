"""Observability layer: registry semantics, span tracing, HBM-traffic
accounting — and the regressions that ride the same PR (clock/sleep
injection, ``window_dropped`` visibility, lost-result spans).

The traffic tests re-derive every accountant aggregate from its formula
key (``benchmarks.bench_chaos.verify_traffic`` — the same mechanical
check the chaos harness hard-asserts), so a charge that drifts from the
``kernels/ops.py`` dispatch-table formulas fails here first.
"""
import json
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro import obs as obslib
from repro.core import UOTConfig
from repro.kernels import ops
from repro.serve import (QueueFullError, UOTBatchEngine, UOTScheduler,
                         submit_with_retry)
from repro.cluster import ClusterScheduler
from benchmarks.common import make_problem as _common_problem
from benchmarks.bench_chaos import verify_traffic

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20, tol=1e-3)


def make_problem(m, n, seed, peak=1.0):
    return _common_problem(m, n, reg=CFG.reg, seed=seed, peak=peak)


def bundle(**kw):
    """Isolated obs bundle: no chaining to the process-global one, so
    assertions see exactly this test's charges/events."""
    kw.setdefault("chain", False)
    return obslib.Observability(**kw)


# ---- metrics registry ------------------------------------------------------


class TestRegistry:
    def test_counter_gauge_basics_and_kind_mismatch(self):
        reg = obslib.MetricsRegistry()
        c = reg.counter("x")
        c.inc()
        c.inc(4)
        assert c.value == 5
        assert reg.counter("x") is c          # same name -> same metric
        g = reg.gauge("y")
        g.set(2.5)
        assert g.value == 2.5
        with pytest.raises(TypeError):
            reg.gauge("x")                    # kind mismatch
        dump = reg.dump()
        assert dump["counters"]["x"] == 5
        assert dump["gauges"]["y"] == 2.5

    def test_histogram_percentiles_vs_numpy(self):
        """Bucketed estimates land within one 2x bucket factor of the
        exact ``np.percentile`` answer, and inside the observed range."""
        rng = np.random.default_rng(0)
        samples = rng.lognormal(mean=-5.0, sigma=1.5, size=5000)
        h = obslib.MetricsRegistry().histogram("lat")
        for s in samples:
            h.observe(float(s))
        for q in (50, 90, 99):
            est = h.percentile(q)
            exact = float(np.percentile(samples, q))
            assert exact / 2.0 <= est <= exact * 2.0, (q, est, exact)
            assert samples.min() <= est <= samples.max()
        snap = h.snapshot()
        assert snap["count"] == len(samples)
        assert snap["min"] == pytest.approx(float(samples.min()))
        assert snap["max"] == pytest.approx(float(samples.max()))
        assert snap["mean"] == pytest.approx(float(samples.mean()))

    def test_histogram_overflow_clamps_to_observed_max(self):
        h = obslib.MetricsRegistry().histogram(
            "h", buckets=obslib.geometric_buckets(1.0, 8.0))
        for v in (2.0, 1e6):                  # 1e6 overflows the top edge
            h.observe(v)
        assert h.percentile(99) <= 1e6

    def test_parent_chaining_forwards_everything(self):
        parent = bundle()
        child = bundle(parent=parent, chain=True)
        child.registry.counter("n").inc(3)
        child.registry.histogram("h").observe(0.5)
        child.traffic.charge_solve(route="solve", tier="streamed",
                                   M=8, N=16, s=4, T=10)
        assert parent.registry.counter("n").value == 3
        assert parent.registry.histogram("h").snapshot()["count"] == 1
        assert parent.traffic.totals() == child.traffic.totals()

    def test_counter_exact_under_threads(self):
        """Concurrent ``inc`` never drops a count — the property the
        async cluster step loop leans on."""
        c = obslib.MetricsRegistry().counter("hits")

        def hammer():
            for _ in range(10_000):
                c.inc()

        threads = [threading.Thread(target=hammer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 80_000


# ---- span tracer -----------------------------------------------------------


class TestTracer:
    def test_jsonl_roundtrip_and_audit(self, tmp_path):
        tr = obslib.SpanTracer(clock=lambda: 1.25)
        tr.emit(0, "submit", M=8, N=16, bucket=[64, 128])
        tr.emit(0, "complete", status="ok", iters=12, converged=True)
        tr.emit(1, "submit", M=8, N=16)
        path = tmp_path / "trace.jsonl"
        assert tr.write_jsonl(path) == 3
        reloaded = obslib.SpanTracer.from_events(
            obslib.SpanTracer.load_jsonl(path))
        assert reloaded.events == tr.events
        audit = tr.check_complete(submitted=[0, 1])
        assert audit["total"] == 2 and audit["missing"] == [1]
        assert not audit["multiple"]
        timeline = tr.render_timeline()
        assert isinstance(timeline, str) and timeline

    def test_disabled_bundle_swaps_in_null_twins(self):
        obs = bundle(enabled=False)
        obs.tracer.emit(0, "submit")
        assert obs.tracer.events == ()
        assert obs.traffic.charge_solve(route="solve", tier="streamed",
                                        M=8, N=16, s=4, T=10) == 0
        assert obs.traffic.records() == []
        # the registry stays live either way: stats() totals depend on it
        obs.registry.counter("still.live").inc()
        assert obs.registry.counter("still.live").value == 1


# ---- dispatch observer (kernels/ops.py) ------------------------------------


class TestDispatchObserver:
    def test_auto_routing_reports_decisions(self):
        K, a, b = make_problem(24, 32, 0)
        seen = []

        def cb(kind, **kw):
            seen.append((kind, kw))

        with ops.dispatch_observer(cb):
            ops.solve_fused(jnp.asarray(K), jnp.asarray(a), jnp.asarray(b),
                            CFG, impl="auto")
        assert seen, "auto dispatch must report its routing decision"
        for kind, kw in seen:
            assert kind in ("resident", "streamed")
            assert kw["M"] >= 24 and kw["N"] >= 32
            assert kw["itemsize"] in (2, 4)
            assert kw["num_iters"] == CFG.num_iters

    def test_explicit_impl_makes_no_routing_call(self):
        K, a, b = make_problem(24, 32, 0)
        seen = []
        with ops.dispatch_observer(lambda kind, **kw: seen.append(kind)):
            ops.solve_fused(jnp.asarray(K), jnp.asarray(a), jnp.asarray(b),
                            CFG, impl=None)
        assert seen == []


# ---- scheduler-driven spans + traffic --------------------------------------


def run_scheduler(n_dense=4, n_points=2, **kw):
    kw.setdefault("obs", bundle())
    kw.setdefault("impl", "jnp")
    sched = UOTScheduler(CFG, lanes_per_pool=4, chunk_iters=4, **kw)
    rids = []
    for i in range(n_dense):
        rids.append(sched.submit(*make_problem(24, 100, i)))
    rng = np.random.default_rng(7)
    for i in range(n_points):
        x = rng.normal(size=(16, 2)).astype(np.float32)
        y = rng.normal(size=(90, 2)).astype(np.float32)
        a = np.full(16, 1.0 / 16, np.float32)
        b = np.full(90, 1.0 / 90, np.float32)
        rids.append(sched.submit_points(x, y, a, b))
    sched.run()
    return sched, rids


class TestSchedulerObservability:
    def test_zero_span_loss_and_lifecycle_events(self):
        sched, rids = run_scheduler()
        audit = sched.obs.tracer.check_complete(submitted=rids)
        assert audit["total"] == len(rids)
        assert not audit["missing"] and not audit["multiple"]
        kinds = {e["event"] for e in sched.obs.tracer.events}
        assert {"submit", "place", "chunk", "evict", "complete"} <= kinds
        assert sched.stats()["completed"] == len(rids)

    def test_traffic_matches_dispatch_table_fp32(self):
        sched, _ = run_scheduler()
        recs = sched.obs.traffic.records()
        verify_traffic(recs)                  # formula-by-formula
        admits = [r for r in recs if r["kind"] == "admit"]
        assert {r["source"] for r in admits} == {"dense", "implicit"}
        imp = next(r for r in admits if r["source"] == "implicit")
        assert imp["d"] == 2 and imp["itemsize"] == 4
        chunks = [r for r in recs if r["kind"] == "chunk"]
        assert chunks and all(r["route"] == "lane" and r["itemsize"] == 4
                              for r in chunks)

    def test_traffic_bf16_storage_halves_itemsize(self):
        sched, _ = run_scheduler(storage_dtype=jnp.bfloat16)
        recs = sched.obs.traffic.records()
        verify_traffic(recs)
        chunks = [r for r in recs if r["kind"] == "chunk"]
        assert chunks and all(r["itemsize"] == 2 for r in chunks)

    def test_auto_impl_resident_chunks_charge_resident_tier(self):
        sched, _ = run_scheduler(impl="auto")
        recs = sched.obs.traffic.records()
        verify_traffic(recs)
        resident_routed = sched.obs.registry.counter(
            "serve.dispatch.resident").value
        chunk_tiers = {r["tier"] for r in recs if r["kind"] == "chunk"}
        if resident_routed:
            assert "resident" in chunk_tiers
        else:
            assert chunk_tiers == {"streamed"}

    def test_obs_false_still_counts_but_traces_nothing(self):
        sched, rids = run_scheduler(obs=False)
        assert not sched.obs.tracer.enabled
        assert sched.obs.tracer.events == ()
        assert sched.obs.traffic.records() == []
        assert sched.stats()["completed"] == len(rids)

    def test_chains_to_global_by_default(self):
        obslib.reset_global()
        try:
            sched, rids = run_scheduler(obs=None)
            g = obslib.get_global()
            assert (g.registry.counter("serve.submitted").value
                    == len(rids))
            assert g.traffic.totals()["bytes"] > 0
            # tracers are NOT globally merged (rid spaces per-scheduler)
            assert sched.obs.tracer.events
        finally:
            obslib.reset_global()

    def test_window_dropped_exposed_via_stats(self):
        """Regression: trimming the telemetry window must be visible —
        silent narrowing made aggregate stats lie about coverage."""
        sched, rids = run_scheduler(n_dense=6, n_points=0, max_log=2)
        st = sched.stats()
        dropped = st["window_dropped"]
        assert dropped["requests"] > 0
        assert (dropped["requests"]
                == sched.obs.registry.counter(
                    "serve.window_dropped_requests").value)
        assert len(sched.request_log) <= 2

    def test_lost_results_emit_lost_spans(self):
        sched, rids = run_scheduler(n_dense=4, n_points=0, max_results=1)
        assert sched.stats()["lost_results"] > 0
        lost = [e for e in sched.obs.tracer.events if e["event"] == "lost"]
        assert len(lost) == sched.stats()["lost_results"]
        # losing a coupling does not un-complete the request
        audit = sched.obs.tracer.check_complete(submitted=rids)
        assert not audit["missing"] and not audit["multiple"]


# ---- clock / sleep injection ----------------------------------------------


class TestSleepInjection:
    def _assert_injected_sleep_used(self, sched, submit, monkeypatch):
        def boom(_):
            raise AssertionError("time.sleep called despite injected sleep")

        monkeypatch.setattr(time, "sleep", boom)
        slept = []
        sched.sleep = slept.append
        submit()                              # fills max_queue=1
        with pytest.raises(QueueFullError):
            submit_with_retry(sched, *make_problem(24, 100, 9), attempts=3,
                              base_delay=1e-4)
        assert len(slept) == 2                # attempts-1 backoff sleeps
        assert all(d > 0 for d in slept)

    def test_scheduler_resolves_injected_sleep(self, monkeypatch):
        sched = UOTScheduler(CFG, lanes_per_pool=2, impl="jnp",
                             max_queue=1, obs=bundle())
        self._assert_injected_sleep_used(
            sched, lambda: sched.submit(*make_problem(24, 100, 0)),
            monkeypatch)

    def test_cluster_scheduler_resolves_injected_sleep(self, monkeypatch):
        cs = ClusterScheduler(CFG, num_devices=1, lanes_per_device=2,
                              impl="jnp", max_queue=1, obs=bundle())
        self._assert_injected_sleep_used(
            cs, lambda: cs.submit(*make_problem(24, 100, 0)), monkeypatch)


# ---- cluster scheduler: async thread safety + gang traffic -----------------


class TestClusterObservability:
    def test_async_step_loop_keeps_counters_exact(self):
        """Metric writes from the async chunk loop interleave with host
        threads hammering the same registry; totals stay exact."""
        obs = bundle()
        cs = ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                              impl="jnp", step_mode="async", obs=obs)
        rids = [cs.submit(*make_problem(24, 100, i)) for i in range(6)]
        c = obs.registry.counter("test.hammer")

        def hammer():
            for _ in range(10_000):
                c.inc()

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        cs.run()
        for t in threads:
            t.join()
        assert c.value == 40_000
        assert cs.stats()["completed"] == len(rids)
        assert obs.registry.counter("cluster.completed").value == len(rids)
        audit = cs.obs.tracer.check_complete(submitted=rids)
        assert not audit["missing"] and not audit["multiple"]
        verify_traffic(cs.obs.traffic.records())

    def test_gang_route_charges_collective_bytes(self):
        cs = ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                              impl="jnp", gang="auto",
                              lane_budget=lambda M, N: False, obs=bundle())
        rid = cs.submit(*make_problem(24, 100, 0))
        cs.run()
        recs = cs.obs.traffic.records()
        verify_traffic(recs)
        gang = [r for r in recs if r["route"] == "gang"]
        assert len(gang) == 1 and gang[0]["kind"] == "solve"
        assert gang[0]["coll_bytes"] > 0
        assert any(e["event"] == "gang" for e in cs.obs.tracer.events
                   if e["rid"] == rid)
        audit = cs.obs.tracer.check_complete(submitted=[rid])
        assert not audit["missing"] and not audit["multiple"]


# ---- batch engine (tier 2) -------------------------------------------------


class TestEngineObservability:
    def test_flush_charges_route_flush_per_request(self):
        obs = bundle()
        eng = UOTBatchEngine(CFG, max_batch=8, impl="jnp", obs=obs)
        for i in range(3):
            eng.submit(*make_problem(24, 100, i))
        rng = np.random.default_rng(3)
        eng.submit_points(rng.normal(size=(16, 2)).astype(np.float32),
                          rng.normal(size=(90, 2)).astype(np.float32),
                          np.full(16, 1.0 / 16, np.float32),
                          np.full(90, 1.0 / 90, np.float32))
        eng.flush()
        reg = obs.registry
        assert reg.counter("engine.submitted").value == 4
        assert reg.counter("engine.flushes").value == 1
        assert reg.counter("engine.flushed").value == 4
        recs = obs.traffic.records()
        verify_traffic(recs)
        solves = [r for r in recs if r["kind"] == "solve"]
        assert solves and all(r["route"] == "flush" for r in solves)
        assert sum(r["count"] for r in solves) == 4
        assert {r["source"] for r in solves} == {"dense", "implicit"}


# ---- direct formula spot checks -------------------------------------------


class TestFormulas:
    M, N, d = 64, 128, 3

    def test_cost_source(self):
        assert obslib.cost_source_bytes(self.M, self.N, 4) == 64 * 128 * 4
        assert obslib.cost_source_bytes(self.M, self.N, 2) == 64 * 128 * 2
        assert (obslib.cost_source_bytes(self.M, self.N, 4,
                                         source="implicit", d=self.d)
                == (64 + 128) * 4 * 4)

    @pytest.mark.parametrize("s", [4, 2])
    def test_solve_tiers(self, s):
        G = 64 * 128 * s
        assert (obslib.solve_bytes(self.M, self.N, s, 10)
                == G + 2 * 64 * 128 * s * 10)
        assert (obslib.solve_bytes(self.M, self.N, s, 10, tier="resident")
                == G + 2 * 64 * 128 * s)
        Gi = (64 + 128) * 4 * 4
        assert (obslib.solve_bytes(self.M, self.N, s, 10, tier="resident",
                                   source="implicit", d=self.d)
                == Gi + 64 * 128 * s)

    @pytest.mark.parametrize("s", [4, 2])
    def test_chunk_tiers(self, s):
        assert (obslib.chunk_bytes(8, self.M, self.N, s, 5)
                == 2 * 8 * 64 * 128 * s * 5)
        assert (obslib.chunk_bytes(8, self.M, self.N, s, 5,
                                   tier="resident")
                == 2 * 8 * 64 * 128 * s)

    def test_gang_and_flops(self):
        assert obslib.gang_collective_bytes(128, 10) == 2 * 128 * 4 * 10
        assert (obslib.modeled_flops(self.M, self.N, 10, lanes=3)
                == 4 * 64 * 128 * 10 * 3)
