"""Correctness of the core UOT solver family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    UOTConfig, gibbs_kernel, sinkhorn_uot_baseline, sinkhorn_uot_fused,
    sinkhorn_uot_uv, sinkhorn_uot_uv_fused, sinkhorn_uot_log, marginal_error,
)
from repro.core.problem import uot_cost


def make_problem(M=64, N=48, reg=0.1, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(M, 2)).astype(np.float32)
    Y = rng.normal(size=(N, 2)).astype(np.float32) + 0.5
    C = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    C = C / C.max()
    a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.3  # unequal masses: truly unbalanced
    K = np.exp(-C / reg) * (a[:, None] * b[None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b), jnp.asarray(C)


class TestFusedMatchesBaseline:
    """MAP-UOT (Alg. 1) must produce iterates identical to the 4-pass POT
    baseline — the paper's optimization is schedule-only."""

    @pytest.mark.parametrize("iters", [1, 7, 100])
    @pytest.mark.parametrize("reg_m", [0.5, 5.0, float("inf")])
    def test_iterates_equal(self, iters, reg_m):
        K, a, b, _ = make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=reg_m, num_iters=iters)
        A_base, _ = sinkhorn_uot_baseline(K, a, b, cfg)
        A_fused, _ = sinkhorn_uot_fused(K, a, b, cfg)
        np.testing.assert_allclose(A_base, A_fused, rtol=2e-5, atol=1e-8)

    def test_rectangular(self):
        K, a, b, _ = make_problem(M=33, N=129)
        cfg = UOTConfig(reg=0.1, reg_m=2.0, num_iters=50)
        A_base, _ = sinkhorn_uot_baseline(K, a, b, cfg)
        A_fused, _ = sinkhorn_uot_fused(K, a, b, cfg)
        np.testing.assert_allclose(A_base, A_fused, rtol=2e-5, atol=1e-8)

    def test_early_exit_tol(self):
        K, a, b, _ = make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=5000, tol=1e-6)
        A, stats = sinkhorn_uot_fused(K, a, b, cfg)
        assert int(stats["iters"]) < 5000
        assert float(stats["err"]) <= 1e-6


class TestBalancedLimit:
    def test_fi_one_matches_marginals(self):
        """reg_m = inf (fi=1) is balanced Sinkhorn-Knopp: marginals match."""
        K, a, b, _ = make_problem()
        b = b / b.sum() * a.sum()  # balanced needs equal mass
        cfg = UOTConfig(reg=0.1, reg_m=float("inf"), num_iters=500)
        A, _ = sinkhorn_uot_fused(K, a, b, cfg)
        # after a row rescale last, rows match exactly; cols approximately
        np.testing.assert_allclose(np.asarray(A.sum(1)), np.asarray(a), rtol=1e-4)
        assert float(marginal_error(A, a, b)) < 1e-3


class TestUVForm:
    def test_uv_fused_matches_uv(self):
        K, a, b, _ = make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=100)
        P1, (u1, v1), _ = sinkhorn_uot_uv(K, a, b, cfg)
        P2, (u2, v2), _ = sinkhorn_uot_uv_fused(K, a, b, cfg)
        np.testing.assert_allclose(P1, P2, rtol=1e-6)
        np.testing.assert_allclose(u1, u2, rtol=1e-6)

    def test_uv_matches_log_domain(self):
        """u/v linear-space solver and log-domain solver share semantics."""
        K, a, b, C = make_problem(reg=0.2)
        # log solver builds its own kernel from C without the ab weighting:
        Kplain = jnp.exp(-C / 0.2)
        cfg = UOTConfig(reg=0.2, reg_m=1.0, num_iters=300)
        P_uv, _, _ = sinkhorn_uot_uv(Kplain, a, b, cfg)
        P_log, _, _ = sinkhorn_uot_log(C, a, b, cfg)
        np.testing.assert_allclose(P_uv, P_log, rtol=1e-3, atol=1e-7)

    def test_uot_objective_converges(self):
        """Sinkhorn is dual ascent (primal need not fall monotonically);
        assert the primal objective and coupling converge."""
        K, a, b, C = make_problem(reg=0.2)
        Kplain = jnp.exp(-C / 0.2)
        costs, Ps = [], []
        for iters in (80, 320, 1280):
            cfg = UOTConfig(reg=0.2, reg_m=1.0, num_iters=iters)
            P, _, _ = sinkhorn_uot_uv(Kplain, a, b, cfg)
            costs.append(float(uot_cost(P, C, a, b, 0.2, 1.0)))
            Ps.append(np.asarray(P))
        assert abs(costs[2] - costs[1]) < 1e-5 * max(1.0, abs(costs[2]))
        np.testing.assert_allclose(Ps[1], Ps[2], rtol=1e-4, atol=1e-9)


class TestScalingFormProperties:
    def test_nonnegativity_and_finiteness(self):
        K, a, b, _ = make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=200)
        A, _ = sinkhorn_uot_fused(K, a, b, cfg)
        A = np.asarray(A)
        assert np.all(A >= 0)
        assert np.all(np.isfinite(A))

    def test_mass_between_marginal_masses(self):
        K, a, b, _ = make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=300)
        A, _ = sinkhorn_uot_fused(K, a, b, cfg)
        total = float(jnp.sum(A))
        lo, hi = sorted((float(a.sum()), float(b.sum())))
        assert 0 < total <= hi * 1.05


class TestLogDomainStability:
    def test_small_reg_stable_where_linear_underflows(self):
        """reg=0.005: exp(-C/reg) underflows fp32 for most entries; the
        log-domain solver must stay finite and mass-positive."""
        rng = np.random.default_rng(0)
        C = jnp.asarray(rng.uniform(0.1, 1.0, (48, 40)), jnp.float32)
        a = jnp.full((48,), 1.0 / 48)
        b = jnp.full((40,), 1.0 / 40)
        cfg = UOTConfig(reg=0.005, reg_m=1.0, num_iters=300)
        P, (f, g), _ = sinkhorn_uot_log(C, a, b, cfg)
        P = np.asarray(P)
        assert np.all(np.isfinite(P)) and P.sum() > 1e-4
        # linear-space kernel is mostly zero here (the failure mode)
        K = np.exp(-np.asarray(C) / 0.005)
        assert (K == 0).mean() > 0.5

    def test_respects_cfg_dtype_log_floor(self):
        """fp16 config + a zero marginal entry: the old hardcoded 1e-38
        floor is exactly 0 in fp16, so log() produced -inf potentials.
        The floor must come from the compute dtype's finfo.tiny."""
        rng = np.random.default_rng(1)
        C = jnp.asarray(rng.uniform(0, 1, (16, 16)), jnp.float32)
        a = rng.uniform(0.5, 1.5, 16).astype(np.float16)
        a[0] = 0.0  # zero-mass row: hits the log floor
        b = rng.uniform(0.5, 1.5, 16).astype(np.float16)
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=20,
                        dtype=jnp.float16)
        P, (f, g), _ = sinkhorn_uot_log(C, jnp.asarray(a), jnp.asarray(b),
                                        cfg)
        assert P.dtype == jnp.float16
        assert bool(jnp.isfinite(f).all()) and bool(jnp.isfinite(g).all())
        assert bool(jnp.isfinite(P).all())
        # potentials are computed at >= fp32 (the accumulation floor),
        # only the coupling is stored in cfg.dtype
        assert f.dtype == jnp.float32

    def test_bf16_cfg_matches_fp32_solution(self):
        rng = np.random.default_rng(2)
        C = jnp.asarray(rng.uniform(0, 1, (24, 20)), jnp.float32)
        a = jnp.asarray(rng.uniform(0.5, 1.5, 24) / 24, jnp.float32)
        b = jnp.asarray(rng.uniform(0.5, 1.5, 20) / 20 * 1.3, jnp.float32)
        cfg16 = UOTConfig(reg=0.05, reg_m=1.0, num_iters=100,
                          dtype=jnp.bfloat16)
        cfg32 = UOTConfig(reg=0.05, reg_m=1.0, num_iters=100)
        P16, _, _ = sinkhorn_uot_log(C, a, b, cfg16)
        P32, _, _ = sinkhorn_uot_log(C, a, b, cfg32)
        assert P16.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(P16, np.float32),
                                   np.asarray(P32), rtol=0, atol=2e-2)


class TestTranslationInvariant:
    """Séjourné et al. (2201.00730): the optimal dual translation after
    each update removes UOT Sinkhorn's slow mass-shuttling mode. Same
    fixed point, far fewer iterations on ill-conditioned (mass-imbalanced,
    large reg_m/reg) problems."""

    def _ill_conditioned(self, seed=0, mass_ratio=4.0):
        rng = np.random.default_rng(seed)
        C = rng.uniform(0, 1, (64, 64)).astype(np.float32)
        a = rng.uniform(0.5, 1.5, 64).astype(np.float32)
        b = rng.uniform(0.5, 1.5, 64).astype(np.float32)
        a = a / a.sum()
        b = b / b.sum() * mass_ratio
        return jnp.asarray(C), jnp.asarray(a), jnp.asarray(b)

    @pytest.mark.parametrize("reg_m", [1.0, 5.0])
    def test_uv_fewer_iterations_to_tol(self, reg_m):
        C, a, b = self._ill_conditioned()
        K = jnp.exp(-C / 0.05)
        plain = UOTConfig(reg=0.05, reg_m=reg_m, num_iters=20000, tol=1e-6)
        ti = UOTConfig(reg=0.05, reg_m=reg_m, num_iters=20000, tol=1e-6,
                       translation_invariant=True)
        P_p, _, s_p = sinkhorn_uot_uv(K, a, b, plain)
        P_t, _, s_t = sinkhorn_uot_uv(K, a, b, ti)
        assert float(s_t["err"]) <= 1e-6  # actually reached tol
        assert int(s_t["iters"]) < int(s_p["iters"])  # and strictly faster
        assert int(s_t["iters"]) <= int(s_p["iters"]) // 3
        np.testing.assert_allclose(np.asarray(P_t), np.asarray(P_p),
                                   rtol=0, atol=1e-5)

    def test_log_domain_fewer_iterations_to_tol(self):
        # large reg_m/reg: the regime where the scaling-space iterates
        # overflow fp32 and only the log-domain TI path is viable
        C, a, b = self._ill_conditioned(seed=1)
        plain = UOTConfig(reg=0.05, reg_m=20.0, num_iters=20000, tol=1e-6)
        ti = UOTConfig(reg=0.05, reg_m=20.0, num_iters=20000, tol=1e-6,
                       translation_invariant=True)
        P_p, _, s_p = sinkhorn_uot_log(C, a, b, plain)
        P_t, _, s_t = sinkhorn_uot_log(C, a, b, ti)
        assert float(s_t["err"]) <= 1e-6
        assert int(s_t["iters"]) <= int(s_p["iters"]) // 10
        np.testing.assert_allclose(np.asarray(P_t), np.asarray(P_p),
                                   rtol=0, atol=1e-5)

    def test_uv_fused_matches_uv_with_ti(self):
        C, a, b = self._ill_conditioned(seed=2)
        K = jnp.exp(-C / 0.05)
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=40,
                        translation_invariant=True)
        P_uv, (u1, v1), _ = sinkhorn_uot_uv(K, a, b, cfg)
        P_f, (u2, v2), _ = sinkhorn_uot_uv_fused(K, a, b, cfg)
        np.testing.assert_allclose(np.asarray(u1), np.asarray(u2),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(P_uv), np.asarray(P_f),
                                   rtol=1e-5, atol=1e-8)

    def test_balanced_is_noop_gauge(self):
        """reg_m=inf: translation is the exact gauge freedom of P — the TI
        flag must not change the coupling at all."""
        C, a, b = self._ill_conditioned(seed=3, mass_ratio=1.0)
        b = b / b.sum() * a.sum()
        K = jnp.exp(-C / 0.05)
        cfg = UOTConfig(reg=0.05, reg_m=float("inf"), num_iters=50)
        cfg_ti = UOTConfig(reg=0.05, reg_m=float("inf"), num_iters=50,
                           translation_invariant=True)
        P, _, _ = sinkhorn_uot_uv(K, a, b, cfg)
        P_ti, _, _ = sinkhorn_uot_uv(K, a, b, cfg_ti)
        np.testing.assert_array_equal(np.asarray(P), np.asarray(P_ti))


class TestPallasRouterPath:
    def test_sinkhorn_route_pallas_matches_jnp(self):
        from repro.models.moe import sinkhorn_route
        import jax
        logits = jax.random.normal(jax.random.PRNGKey(0), (64, 8)) * 2.0
        p1 = sinkhorn_route(logits, 2, num_iters=4, fi=0.7)
        p2 = sinkhorn_route(logits, 2, num_iters=4, fi=0.7, use_pallas=True)
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                                   rtol=1e-5, atol=1e-7)
