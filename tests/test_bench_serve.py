"""Guard the serving-bench path with a micro trace (the CI smoke's tier-1
twin — bench_serve must not rot between bench runs)."""
import numpy as np

from repro.core import UOTConfig
from benchmarks import bench_serve


def micro_cfg_and_trace():
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=12, tol=1e-3)
    trace = bench_serve.make_trace(
        4, rate_hz=500.0, seed=3, shapes=[(16, 100), (24, 120)],
        peak_range=(1.0, 4.0), reg=cfg.reg)
    return cfg, trace


def test_make_trace_shapes_and_arrivals():
    _, trace = micro_cfg_and_trace()
    arrivals = [t for t, *_ in trace]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    for _, K, a, b in trace:
        assert K.shape == (a.shape[0], b.shape[0])
        assert K.dtype == np.float32


def test_sim_flush_and_scheduler_cover_every_request():
    cfg, trace = micro_cfg_and_trace()
    flush_lat, flush_T = bench_serve.sim_flush(trace, cfg, max_batch=4,
                                               warmup=False)
    sched_lat, sched_T, sched = bench_serve.sim_scheduler(
        trace, cfg, lanes_per_pool=2, chunk_iters=4, warmup=False)
    assert len(flush_lat) == len(sched_lat) == len(trace)
    assert all(lat > 0 for lat in flush_lat + sched_lat)
    assert flush_T > 0 and sched_T > 0
    assert sched.stats()["completed"] == len(trace)
