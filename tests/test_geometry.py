"""Implicit cost geometries: mirrors, solver parity, dispatch, serving.

The geometry subsystem's contract is strict: for a point-cloud geometry,
the on-chip tile compute path and the dense path fed by the materializing
mirror produce **bit-identical couplings** (fp32 and bf16 alike) and
identical per-lane iteration counts, across solver tiers (streamed kernel,
jnp, resident, auto) — the tile source is a memory decision, never a math
decision. Grid geometries' per-axis contractions are associativity
*re-orderings* of the dense reductions, so their parity bars are
tolerance-based.

One scoped exception to bitwise-ness, asserted at tolerance instead: a
problem solved standalone vs inside a batch bucket with a *different
padded height* (the resident tier pads M to the sublane, a bucket pads to
its shape) crosses XLA whole-tile reductions of different trip counts,
whose accumulation grouping — and hence low bits — differ. Dense and
implicit stay bit-identical to *each other* at every fixed padded shape.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig, UOTProblem
from repro.core.log_domain import sinkhorn_uot_log
from repro.core.sinkhorn_uv import sinkhorn_uot_uv, sinkhorn_uot_uv_fused
from repro.geometry import (DenseGeometry, Geometry, GridGeometry,
                            PointCloudGeometry)
from repro.kernels import ops

IMPLS = ["kernel", "jnp", "resident", "auto"]
DTYPES = [jnp.float32, jnp.bfloat16]


def make_points(M, N, d=3, seed=0, mass=1.2):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (M, d)).astype(np.float32)
    y = rng.uniform(0, 1, (N, d)).astype(np.float32)
    a = (rng.uniform(0.5, 1.5, M) / M).astype(np.float32)
    b = (rng.uniform(0.5, 1.5, N) / N * mass).astype(np.float32)
    return x, y, jnp.asarray(a), jnp.asarray(b)


def solve(geom, a, b, cfg, impl, **kw):
    interpret = True if impl == "kernel" else None
    return ops.solve_fused(None, a, b, cfg, geometry=geom, impl=impl,
                           interpret=interpret, **kw)


class TestGeometryObjects:
    def test_pointcloud_cost_matches_cdist(self):
        x, y, _, _ = make_points(37, 53)
        g = PointCloudGeometry.from_points(x, y, scale=2.0)
        ref = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1) / 2.0
        np.testing.assert_allclose(np.asarray(g.cost()), ref, atol=1e-5)
        np.testing.assert_allclose(np.asarray(g.kernel(0.1)),
                                   np.exp(-ref / 0.1), atol=1e-5)
        assert g.shape == (37, 53) and g.is_implicit

    def test_pointcloud_valid_mask_zeros(self):
        x, y, _, _ = make_points(32, 48)
        g = PointCloudGeometry.from_points(x, y, m_valid=20, n_valid=30)
        K = np.asarray(g.kernel(0.1))
        assert (K[20:] == 0).all() and (K[:, 30:] == 0).all()
        assert (K[:20, :30] > 0).all()

    def test_masked_geometry_refuses_lazy_and_cost_paths(self):
        """Valid-count masks are a kernel-path construct: kernel() honors
        them, but cost() and the lazy applications must refuse instead of
        silently reducing over the padded coordinates' exp(0)-sized
        entries."""
        x, y, _, _ = make_points(32, 48)
        g = PointCloudGeometry.from_points(x, y, m_valid=20, n_valid=30)
        v = jnp.ones((48,), jnp.float32)
        u = jnp.ones((32,), jnp.float32)
        for call in (lambda: g.cost(),
                     lambda: g.apply_kernel(v, 0.1),
                     lambda: g.apply_kernel_T(u, 0.1),
                     lambda: g.apply_lse(v, 0.1),
                     lambda: g.apply_lse_T(u, 0.1)):
            with pytest.raises(ValueError, match="slice the"):
                call()
        assert np.asarray(g.kernel(0.1)).shape == (32, 48)  # still fine

    def test_pointcloud_dim_mismatch_raises(self):
        with pytest.raises(ValueError, match="coordinate dims"):
            PointCloudGeometry.from_points(np.zeros((4, 3)),
                                           np.zeros((5, 2)))

    def test_grid_mirrors_match_kron(self):
        rng = np.random.default_rng(1)
        Cx = rng.uniform(0, 1, (5, 6)).astype(np.float32)
        Cy = rng.uniform(0, 1, (7, 4)).astype(np.float32)
        g = GridGeometry((jnp.asarray(Cx), jnp.asarray(Cy)))
        assert g.shape == (35, 24)
        Cref = (Cx[:, None, :, None] + Cy[None, :, None, :]).reshape(35, 24)
        np.testing.assert_allclose(np.asarray(g.cost()), Cref, atol=1e-6)
        np.testing.assert_allclose(np.asarray(g.kernel(0.2)),
                                   np.exp(-Cref / 0.2), rtol=1e-5)

    @pytest.mark.parametrize("kind", ["pc", "grid", "dense"])
    def test_lazy_applications_match_dense(self, kind):
        rng = np.random.default_rng(2)
        if kind == "pc":
            x, y, _, _ = make_points(40, 60, seed=2)
            g = PointCloudGeometry.from_points(x, y)
        elif kind == "grid":
            g = GridGeometry((jnp.asarray(rng.uniform(0, 1, (8, 10))
                                          .astype(np.float32)),
                              jnp.asarray(rng.uniform(0, 1, (5, 6))
                                          .astype(np.float32))))
        else:
            g = DenseGeometry(jnp.asarray(rng.uniform(0, 1, (40, 60))
                                          .astype(np.float32)))
        M, N = g.shape
        K = np.asarray(g.kernel(0.2), np.float64)
        C = np.asarray(g.cost(), np.float64)
        v = rng.uniform(size=N).astype(np.float32)
        u = rng.uniform(size=M).astype(np.float32)
        np.testing.assert_allclose(np.asarray(g.apply_kernel(v, 0.2)),
                                   K @ v, rtol=1e-4, atol=1e-7)
        np.testing.assert_allclose(np.asarray(g.apply_kernel_T(u, 0.2)),
                                   u @ K, rtol=1e-4, atol=1e-7)
        zs = (v - 0.5) / 2

        def lse(A, axis):
            m = A.max(axis=axis, keepdims=True)
            return (np.log(np.exp(A - m).sum(axis=axis))
                    + np.squeeze(m, axis))

        np.testing.assert_allclose(np.asarray(g.apply_lse(zs, 0.2)),
                                   lse((zs[None, :] - C) / 0.2, 1),
                                   rtol=1e-4, atol=2e-5)
        zu = (u - 0.5) / 2
        np.testing.assert_allclose(np.asarray(g.apply_lse_T(zu, 0.2)),
                                   lse((zu[:, None] - C) / 0.2, 0),
                                   rtol=1e-4, atol=2e-5)

    def test_geometries_are_jit_transparent_pytrees(self):
        x, y, _, _ = make_points(16, 24)
        g = PointCloudGeometry.from_points(x, y, scale=2.0)
        leaves, treedef = jax.tree_util.tree_flatten(g)
        g2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert g2.scale == 2.0
        f = jax.jit(lambda geom, v: geom.apply_kernel(v, 0.1))
        v = jnp.ones((24,), jnp.float32)
        np.testing.assert_array_equal(np.asarray(f(g, v)),
                                      np.asarray(f(g2, v)))

    def test_uot_problem_carries_geometry(self):
        x, y, a, b = make_points(20, 30)
        p = UOTProblem.from_points(x, y, a, b, scale=3.0)
        assert p.shape == (20, 30)
        assert isinstance(p.geom(), PointCloudGeometry)
        K = p.initial_coupling(0.1)
        np.testing.assert_array_equal(np.asarray(K),
                                      np.asarray(p.geometry.kernel(0.1)))
        pd = UOTProblem.from_cost(p.cost_matrix(), a, b)
        assert isinstance(pd.geom(), DenseGeometry)
        with pytest.raises(ValueError, match="exactly one"):
            UOTProblem(a=a, b=b)


class TestSolveFusedParity:
    """DenseGeometry(C) vs PointCloudGeometry(x, y) with C = ||x-y||^2:
    identical couplings, bit for bit, across impl x dtype x tol."""

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("dtype", DTYPES, ids=["fp32", "bf16"])
    @pytest.mark.parametrize("tol", [None, 1e-5])
    def test_bitwise_couplings(self, impl, dtype, tol):
        x, y, a, b = make_points(100, 150, seed=1)
        g = PointCloudGeometry.from_points(x, y, scale=3.0)
        gd = DenseGeometry(g.cost())
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=40, tol=tol)
        Pd, csd = solve(gd, a, b, cfg, impl, storage_dtype=dtype)
        Pi, csi = solve(g, a, b, cfg, impl, storage_dtype=dtype)
        assert Pd.dtype == Pi.dtype == jnp.dtype(dtype)
        np.testing.assert_array_equal(np.asarray(Pd), np.asarray(Pi))
        np.testing.assert_array_equal(np.asarray(csd), np.asarray(csi))

    @pytest.mark.parametrize("impl", ["kernel", "jnp"])
    def test_bitwise_iteration_counts_resident(self, impl):
        # the resident tier reports per-lane counts: implicit and dense
        # must converge at exactly the same iteration
        x, y, a, b = make_points(64, 96, seed=2)
        g = PointCloudGeometry.from_points(x, y)
        gd = DenseGeometry(g.cost())
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=200, tol=1e-4)
        interpret = True if impl == "kernel" else None
        Pd, _, itd, errd = ops.solve_fused_resident(
            None, a, b, cfg, geometry=gd, impl=impl, interpret=interpret)
        Pi, _, iti, erri = ops.solve_fused_resident(
            None, a, b, cfg, geometry=g, impl=impl, interpret=interpret)
        assert int(itd) == int(iti) < 200  # tol actually fires
        np.testing.assert_array_equal(np.asarray(Pd), np.asarray(Pi))
        assert float(errd) == float(erri) <= 1e-4

    @pytest.mark.parametrize("impl", IMPLS)
    def test_batched_valid_counts_bitwise_vs_dense(self, impl):
        """A ragged bucket: per-problem valid counts mask the computed
        tiles to the exact zeros of the zero-padded dense stack."""
        rng = np.random.default_rng(3)
        B, d = 3, 3
        xs = rng.uniform(0, 1, (B, 64, d)).astype(np.float32)
        ys = rng.uniform(0, 1, (B, 96, d)).astype(np.float32)
        mv, nv = np.array([64, 40, 25]), np.array([96, 60, 96])
        A = np.zeros((B, 64), np.float32)
        Bm = np.zeros((B, 96), np.float32)
        for k in range(B):
            A[k, :mv[k]] = rng.uniform(0.5, 1.5, mv[k]) / mv[k]
            Bm[k, :nv[k]] = rng.uniform(0.5, 1.5, nv[k]) / nv[k] * 1.1
        g = PointCloudGeometry.from_points(xs, ys, m_valid=mv, n_valid=nv)
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=25, tol=1e-6)
        K = g.kernel(cfg.reg)      # masked dense stack, same padded shape
        Pd, csd = ops.solve_fused_batched(K, jnp.asarray(A),
                                          jnp.asarray(Bm), cfg, impl=impl,
                                          interpret=True)
        Pi, csi = ops.solve_fused_batched(None, jnp.asarray(A),
                                          jnp.asarray(Bm), cfg, impl=impl,
                                          interpret=True, geometry=g)
        np.testing.assert_array_equal(np.asarray(Pd), np.asarray(Pi))
        np.testing.assert_array_equal(np.asarray(csd), np.asarray(csi))
        for k in range(B):   # the masked region really is exact zeros
            assert (np.asarray(Pi[k, mv[k]:, :]) == 0.0).all()
            assert (np.asarray(Pi[k, :, nv[k]:]) == 0.0).all()

    @pytest.mark.parametrize("impl", ["kernel", "jnp"])
    def test_batched_valid_counts_match_standalone(self, impl):
        """Each bucketed problem equals its standalone solve. Bitwise when
        the padded heights coincide (streamed pads both to the same row
        block); the resident tier pads standalone solves to the sublane
        instead of the bucket, so cross-shape reductions differ in the
        low bits -> asserted at tolerance there (see module docstring)."""
        rng = np.random.default_rng(4)
        B, d = 3, 3
        xs = rng.uniform(0, 1, (B, 64, d)).astype(np.float32)
        ys = rng.uniform(0, 1, (B, 96, d)).astype(np.float32)
        mv, nv = np.array([64, 40, 25]), np.array([96, 60, 96])
        A = np.zeros((B, 64), np.float32)
        Bm = np.zeros((B, 96), np.float32)
        for k in range(B):
            A[k, :mv[k]] = rng.uniform(0.5, 1.5, mv[k]) / mv[k]
            Bm[k, :nv[k]] = rng.uniform(0.5, 1.5, nv[k]) / nv[k] * 1.1
        g = PointCloudGeometry.from_points(xs, ys, m_valid=mv, n_valid=nv)
        cfg = UOTConfig(reg=0.05, reg_m=1.0, num_iters=25, tol=1e-6)
        Pb, _ = ops.solve_fused_batched(None, jnp.asarray(A),
                                        jnp.asarray(Bm), cfg, impl=impl,
                                        interpret=True, geometry=g)
        for k in range(B):
            gk = PointCloudGeometry.from_points(xs[k, :mv[k]],
                                                ys[k, :nv[k]])
            Pk, _ = solve(gk, jnp.asarray(A[k, :mv[k]]),
                          jnp.asarray(Bm[k, :nv[k]]), cfg, impl)
            np.testing.assert_array_equal(
                np.asarray(Pb[k, :mv[k], :nv[k]]), np.asarray(Pk))

    def test_geometry_and_a0_are_exclusive(self):
        x, y, a, b = make_points(16, 24)
        g = PointCloudGeometry.from_points(x, y)
        cfg = UOTConfig(num_iters=2)
        with pytest.raises(ValueError, match="not both"):
            ops.solve_fused(jnp.ones((16, 24)), a, b, cfg, geometry=g)
        with pytest.raises(TypeError, match="Geometry"):
            ops.solve_fused(None, a, b, cfg, geometry=np.ones((16, 24)))


class TestDispatchExpansion:
    """Implicit geometries shrink the resident VMEM working set to the
    coupling, so impl='auto' routes shapes to the resident tier that the
    dense path must stream."""

    CFG = UOTConfig(reg=0.05, reg_m=1.0, num_iters=2)

    def test_implicit_budget_is_wider(self):
        # fp32: dense 16 B/elt vs implicit 12 B/elt against the same
        # budget — 1024x2048 is exactly the gap
        assert not ops.resident_fits(1024, 2048, self.CFG)
        assert ops.resident_fits(1024, 2048, self.CFG, implicit=True)
        # both agree on clearly-fitting and clearly-over shapes
        assert ops.resident_fits(256, 384, self.CFG, implicit=True)
        assert not ops.resident_fits(4096, 4096, self.CFG, implicit=True)

    def test_auto_routes_implicit_to_resident_where_dense_streams(self):
        M, N = 1024, 2048
        rng = np.random.default_rng(5)
        x = rng.uniform(0, 1, (M, 3)).astype(np.float32)
        y = rng.uniform(0, 1, (N, 3)).astype(np.float32)
        a = jnp.asarray((rng.uniform(0.5, 1.5, M) / M).astype(np.float32))
        b = jnp.asarray((rng.uniform(0.5, 1.5, N) / N).astype(np.float32))
        g = PointCloudGeometry.from_points(x, y)
        ops.reset_dispatch_stats()
        Pi, _ = ops.solve_fused(None, a, b, self.CFG, geometry=g,
                                impl="auto")
        assert ops.dispatch_stats() == {"resident": 1, "streamed": 0}
        ops.reset_dispatch_stats()
        Pd, _ = ops.solve_fused(None, a, b, self.CFG,
                                geometry=DenseGeometry(g.cost()),
                                impl="auto")
        assert ops.dispatch_stats() == {"resident": 0, "streamed": 1}
        np.testing.assert_allclose(np.asarray(Pi), np.asarray(Pd),
                                   rtol=1e-5, atol=1e-10)

    def test_explicit_resident_over_implicit_budget_raises(self):
        M, N = 4096, 4096
        rng = np.random.default_rng(6)
        gbig = PointCloudGeometry.from_points(
            rng.uniform(0, 1, (M, 2)).astype(np.float32),
            rng.uniform(0, 1, (N, 2)).astype(np.float32))
        ab = jnp.ones((M,), jnp.float32) / M
        bb = jnp.ones((N,), jnp.float32) / N
        with pytest.raises(ValueError, match="VMEM budget"):
            ops.solve_fused_resident(None, ab, bb, UOTConfig(num_iters=2),
                                     geometry=gbig)


class TestCoreSolversLazyGeometry:
    def test_uv_solver_geometry_matches_dense(self):
        x, y, a, b = make_points(60, 80, seed=7)
        g = PointCloudGeometry.from_points(x, y)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=80, tol=1e-7)
        Pd, _, sd = sinkhorn_uot_uv(g.kernel(cfg.reg), a, b, cfg)
        Pg, _, sg = sinkhorn_uot_uv(g, a, b, cfg)
        assert int(sd["iters"]) == int(sg["iters"])
        np.testing.assert_allclose(np.asarray(Pd), np.asarray(Pg),
                                   rtol=1e-4, atol=1e-9)
        Pf, _, _ = sinkhorn_uot_uv_fused(
            g, a, b, UOTConfig(reg=0.1, reg_m=1.0, num_iters=40))
        assert Pf.shape == (60, 80)

    def test_log_solver_geometry_matches_dense(self):
        x, y, a, b = make_points(50, 70, seed=8)
        g = PointCloudGeometry.from_points(x, y)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-7)
        Pd, _, sd = sinkhorn_uot_log(g.cost(), a, b, cfg)
        Pg, _, sg = sinkhorn_uot_log(g, a, b, cfg)
        assert int(sd["iters"]) == int(sg["iters"])
        np.testing.assert_allclose(np.asarray(Pd), np.asarray(Pg),
                                   rtol=1e-4, atol=1e-9)

    def test_grid_solvers_never_need_dense(self):
        rng = np.random.default_rng(9)
        g = GridGeometry((jnp.asarray(rng.uniform(0, 1, (8, 10))
                                      .astype(np.float32)),
                          jnp.asarray(rng.uniform(0, 1, (6, 5))
                                      .astype(np.float32))))
        M, N = g.shape
        a = jnp.asarray((rng.uniform(0.5, 1.5, M) / M).astype(np.float32))
        b = jnp.asarray((rng.uniform(0.5, 1.5, N) / N).astype(np.float32))
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60, tol=1e-7)
        Pd, _, sd = sinkhorn_uot_log(g.cost(), a, b, cfg)
        Pg, fg, sg = sinkhorn_uot_log(g, a, b, cfg)
        assert int(sd["iters"]) == int(sg["iters"])
        np.testing.assert_allclose(np.asarray(Pd), np.asarray(Pg),
                                   rtol=1e-4, atol=1e-9)
        # materialize=False: the whole solve (including the return) stays
        # O(M + N) for a grid geometry
        Pn, (f, gpot), _ = sinkhorn_uot_log(g, a, b, cfg,
                                            materialize=False)
        assert Pn is None and f.shape == (M,) and gpot.shape == (N,)
        Pu_d, _, su_d = sinkhorn_uot_uv(g.kernel(cfg.reg), a, b, cfg)
        Pu_g, _, su_g = sinkhorn_uot_uv(g, a, b, cfg)
        assert int(su_d["iters"]) == int(su_g["iters"])
        np.testing.assert_allclose(np.asarray(Pu_d), np.asarray(Pu_g),
                                   rtol=1e-4, atol=1e-9)


class TestServingGeometry:
    CFG = UOTConfig(reg=0.05, reg_m=1.0, num_iters=30, tol=1e-6)

    def _problems(self):
        out = []
        for s, (M, N) in enumerate([(50, 70), (50, 70), (30, 40),
                                    (50, 70)]):
            x, y, a, b = make_points(M, N, seed=10 + s)
            out.append((x, y, a, b))
        return out

    def test_engine_points_bitwise_vs_dense(self):
        from repro.serve import UOTBatchEngine
        ep = UOTBatchEngine(self.CFG, interpret=True)
        ed = UOTBatchEngine(self.CFG, interpret=True)
        rids = []
        for x, y, a, b in self._problems():
            g = PointCloudGeometry.from_points(x, y)
            rids.append((ep.submit_points(x, y, a, b),
                         ed.submit(np.asarray(g.kernel(self.CFG.reg)),
                                   a, b)))
        rp, rd = ep.flush(), ed.flush()
        assert not ep.pending
        for rid_p, rid_d in rids:
            np.testing.assert_array_equal(np.asarray(rp[rid_p]),
                                          np.asarray(rd[rid_d]))

    def test_scheduler_points_bitwise_vs_dense(self):
        """geometry path through solve_fused_stepped: a coordinate
        request's lane trajectory is bit-identical to dense submission of
        the mirror kernel — same pool, same stepped solves."""
        from repro.serve import UOTScheduler
        sp = UOTScheduler(self.CFG, interpret=True, lanes_per_pool=3)
        sd = UOTScheduler(self.CFG, interpret=True, lanes_per_pool=3)
        rids = []
        for x, y, a, b in self._problems():
            g = PointCloudGeometry.from_points(x, y)
            rids.append((sp.submit_points(x, y, a, b),
                         sd.submit(np.asarray(g.kernel(self.CFG.reg)),
                                   a, b)))
        op_, od = sp.run(), sd.run()
        for rid_p, rid_d in rids:
            np.testing.assert_array_equal(op_[rid_p], od[rid_d])
        itp = {t.rid: t.iters for t in sp.request_log}
        itd = {t.rid: t.iters for t in sd.request_log}
        assert [itp[r] for r, _ in rids] == [itd[r] for _, r in rids]

    def test_scheduler_mixed_dense_and_point_requests_share_pool(self):
        from repro.serve import UOTScheduler
        s = UOTScheduler(self.CFG, interpret=True, lanes_per_pool=4)
        probs = self._problems()
        rid_refs = []
        for i, (x, y, a, b) in enumerate(probs):
            g = PointCloudGeometry.from_points(x, y)
            if i % 2:
                rid = s.submit(np.asarray(g.kernel(self.CFG.reg)), a, b)
            else:
                rid = s.submit_points(x, y, a, b)
            Pref, _ = solve(g, a, b, self.CFG, "jnp")
            rid_refs.append((rid, np.asarray(Pref)))
        out = s.run()
        for rid, Pref in rid_refs:
            np.testing.assert_allclose(out[rid], Pref, rtol=1e-5,
                                       atol=1e-10)

    def test_stepped_lane_admit_geometry_materialization(self):
        """Direct stepped-API check: admitting the device-materialized
        mirror kernel equals admitting the host-shipped dense copy."""
        x, y, a, b = make_points(40, 60, seed=20)
        g = PointCloudGeometry.from_points(x, y)
        K = g.kernel(self.CFG.reg)
        st1 = ops.make_lane_state(2, 64, 128, self.CFG)
        st2 = ops.make_lane_state(2, 64, 128, self.CFG)
        st1 = ops.lane_admit(st1, 0, K, a, b)
        st2 = ops.lane_admit(st2, 0, jnp.asarray(np.asarray(K)), a, b)
        for _ in range(3):
            st1 = ops.solve_fused_stepped(st1, 4, self.CFG, impl="jnp")
            st2 = ops.solve_fused_stepped(st2, 4, self.CFG, impl="jnp")
        np.testing.assert_array_equal(np.asarray(st1.P),
                                      np.asarray(st2.P))
        np.testing.assert_array_equal(np.asarray(st1.iters),
                                      np.asarray(st2.iters))
