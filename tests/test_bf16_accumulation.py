"""bf16-storage error accumulation over long iteration counts (ROADMAP
open item): resident (rounds ONCE per solve) vs streamed (rounds every
iteration) against the fp32 reference.

Why this isn't obvious: the streamed tier re-rounds the coupling to bf16
on every HBM writeback, so a naive model predicts error growing like
O(sqrt(T) * eps_bf16) over T iterations, which would eventually blow the
documented parity bars. The measured behavior is different — the
Sinkhorn/MAP-UOT iteration is a contraction toward its fixed point, so
per-iteration rounding acts as *bounded re-injected noise*, not a random
walk: the iterate converges to a slightly perturbed fixed point and the
error SATURATES.

Measured growth curve (B=4 stack of 64x128 problems, reg=0.1, reg_m=1,
peaky costs, jnp impl on CPU; max pointwise error relative to the fp32
iterate's scale, and worst per-problem total-mass relative error):

    iters   pointwise: streamed / resident     mass: streamed / resident
      25        5.4e-3    /   2.0e-3             2.0e-4   /   6e-5
     100        5.4e-3    /   2.0e-3             1.9e-4   /   6e-5
     400        5.4e-3    /   2.0e-3             1.8e-4   /   6e-5

i.e. flat from 25 to 400 iterations, streamed a constant ~2.7x above
resident (whose floor is the one-time rounding of the init + final
writeback). The documented acceptance bars from the ROADMAP — 5e-2
pointwise, 1e-2 on total mass, originally recorded at 25 iterations —
therefore hold at 100 and 400 with more than an order of magnitude of
margin, and bf16 storage is safe for long-running solves, not just the
short serving chunks it was introduced for.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig
from repro.kernels import ops

# the ROADMAP-documented bf16 parity bars (recorded at 25 iterations)
POINTWISE_BAR = 5e-2
MASS_BAR = 1e-2

ITER_SWEEP = [25, 100, 400]


def make_stack(B=4, M=64, N=128, reg=0.1, seed=3):
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, (B, M, N)).astype(np.float32)
    C *= rng.uniform(1, 4, (B, 1, 1)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, (B, M)).astype(np.float32)
    a /= a.sum(1, keepdims=True)
    b = rng.uniform(0.5, 1.5, (B, N)).astype(np.float32)
    b = b / b.sum(1, keepdims=True) * 1.3
    K = np.exp(-C / reg) * (a[:, :, None] * b[:, None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def _errors(P, ref):
    """(max pointwise rel-to-scale, worst per-problem mass rel error)."""
    P = np.asarray(P, np.float32)
    point = np.abs(P - ref).max() / np.abs(ref).max()
    mass = np.abs(P.sum(axis=(1, 2)) / ref.sum(axis=(1, 2)) - 1).max()
    return point, mass


@pytest.mark.parametrize("iters", ITER_SWEEP)
def test_bf16_error_saturates_within_bars(iters):
    K, a, b = make_stack()
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=iters, tol=None)
    P_ref, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp",
                                       storage_dtype=jnp.float32)
    ref = np.asarray(P_ref, np.float32)
    P_str, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp",
                                       storage_dtype=jnp.bfloat16)
    P_res, _, it_res, _ = ops.solve_fused_resident(
        K, a, b, cfg, impl="jnp", storage_dtype=jnp.bfloat16)
    assert (np.asarray(it_res) == iters).all()

    p_str, m_str = _errors(P_str, ref)
    p_res, m_res = _errors(P_res, ref)
    # the documented bars hold at EVERY count in the sweep, not just the
    # 25 iterations they were recorded at
    assert p_str <= POINTWISE_BAR and p_res <= POINTWISE_BAR, (p_str, p_res)
    assert m_str <= MASS_BAR and m_res <= MASS_BAR, (m_str, m_res)
    # rounding-once dominates rounding-every-iteration at every horizon
    assert p_res <= p_str + 1e-6
    assert m_res <= m_str + 1e-7


def test_bf16_streamed_error_does_not_grow_with_iterations():
    """The saturation claim itself: the streamed per-iteration rounding
    error at 400 iterations is no worse than ~the 25-iteration error
    (contraction re-absorbs the noise; it is not a random walk)."""
    K, a, b = make_stack()
    errs = {}
    for iters in (ITER_SWEEP[0], ITER_SWEEP[-1]):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=iters, tol=None)
        P_ref, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp",
                                           storage_dtype=jnp.float32)
        P_str, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp",
                                           storage_dtype=jnp.bfloat16)
        errs[iters] = _errors(P_str, np.asarray(P_ref, np.float32))
    assert errs[ITER_SWEEP[-1]][0] <= 2.0 * errs[ITER_SWEEP[0]][0], errs
    assert errs[ITER_SWEEP[-1]][1] <= 2.0 * errs[ITER_SWEEP[0]][1], errs
