"""Batched + mixed-precision solving path: kernels, wrappers, serving.

Pallas kernels run with ``impl='kernel', interpret=True`` so the real
(batch, row_blocks) grid schedule executes on CPU CI; the vectorized XLA
path (``impl='jnp'``, the non-TPU default) is held to the same parity bars.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (UOTConfig, sinkhorn_uot_fused,
                        sinkhorn_uot_fused_batched)
from repro.kernels import ops, ref
from repro.kernels.uot_batched import (
    batched_colsum, batched_fused_iteration, batched_materialize_coupling,
    batched_uv_iteration)
from repro.serve import UOTBatchEngine


def rand(shape, seed=0, dtype=jnp.float32, lo=0.1, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape), dtype=dtype)


def make_stack(B, M, N, reg=0.1, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.uniform(0, 1, size=(B, M, N)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=(B, M)).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=(B, N)).astype(np.float32)
    a = a / a.sum(axis=1, keepdims=True)
    b = b / b.sum(axis=1, keepdims=True) * 1.2
    K = np.exp(-C / reg) * (a[:, :, None] * b[:, None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


class TestBatchedKernels:
    @pytest.mark.parametrize("B,M,N,bm", [
        (1, 8, 128, 8), (3, 32, 128, 8), (4, 64, 256, 16), (2, 128, 384, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fused_iteration_matches_ref(self, B, M, N, bm, dtype):
        A = rand((B, M, N), seed=B + M + N, dtype=dtype)
        fcol = rand((B, N), seed=1)
        a = rand((B, M), seed=2)
        out, cs = batched_fused_iteration(A, fcol, a, fi=0.9, block_m=bm,
                                          interpret=True)
        out_r, cs_r = ref.batched_fused_iteration_ref(A, fcol, a, fi=0.9)
        if dtype == jnp.bfloat16:
            tol = dict(rtol=2e-2, atol=1e-3)
        else:
            tol = dict(rtol=2e-6, atol=1e-8)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(out_r.astype(dtype), np.float32), **tol)
        np.testing.assert_allclose(
            cs, cs_r, rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)

    def test_matches_single_problem_kernel_per_slice(self):
        """The batched grid must reproduce the single-problem kernel exactly
        (same block schedule per problem -> same accumulation order)."""
        from repro.kernels.uot_fused import fused_iteration
        B, M, N, bm = 3, 64, 256, 16
        A, fcol, a = rand((B, M, N)), rand((B, N), 1), rand((B, M), 2)
        out, cs = batched_fused_iteration(A, fcol, a, fi=0.9, block_m=bm,
                                          interpret=True)
        for i in range(B):
            out_i, cs_i = fused_iteration(A[i], fcol[i], a[i], fi=0.9,
                                          block_m=bm, interpret=True)
            np.testing.assert_array_equal(np.asarray(out[i]),
                                          np.asarray(out_i))
            np.testing.assert_array_equal(np.asarray(cs[i]), np.asarray(cs_i))

    def test_colsum(self):
        A = rand((3, 96, 256))
        np.testing.assert_allclose(
            batched_colsum(A, block_m=32, interpret=True),
            ref.batched_colsum_ref(A), rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_uv_iteration(self, dtype):
        B, M, N = 2, 64, 128
        K = rand((B, M, N), dtype=dtype)
        v, a = rand((B, N), 5), rand((B, M), 6)
        u, ktu = batched_uv_iteration(K, v, a, fi=0.9, block_m=16,
                                      interpret=True)
        u_r, ktu_r = ref.batched_uv_iteration_ref(K, v, a, fi=0.9)
        rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(u, u_r, rtol=rtol)
        np.testing.assert_allclose(ktu, ktu_r, rtol=rtol)

    def test_materialize(self):
        B, M, N = 2, 64, 128
        K = rand((B, M, N))
        u, v = rand((B, M), 7), rand((B, N), 8)
        P = batched_materialize_coupling(K, u, v, block_m=16, interpret=True)
        np.testing.assert_allclose(P, ref.batched_materialize_coupling_ref(
            K, u, v), rtol=2e-6)


class TestSolveFusedBatched:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=25)

    @pytest.mark.parametrize("impl", ["kernel", "jnp"])
    def test_matches_per_sample_loop(self, impl):
        """ISSUE-1 acceptance: batched == loop of solve_fused to 1e-5."""
        K, a, b = make_stack(4, 48, 130)
        P, cs = ops.solve_fused_batched(K, a, b, self.CFG, block_m=16,
                                        interpret=True, impl=impl)
        for i in range(4):
            P_i, cs_i = ops.solve_fused(K[i], a[i], b[i], self.CFG,
                                        block_m=16, interpret=True)
            np.testing.assert_allclose(P[i], P_i, rtol=1e-5, atol=1e-8)
            np.testing.assert_allclose(cs[i], cs_i, rtol=1e-5)

    def test_matches_vmap_semantic_reference(self):
        K, a, b = make_stack(3, 40, 96)
        P, _ = ops.solve_fused_batched(K, a, b, self.CFG, block_m=8,
                                       interpret=True, impl="kernel")
        P_ref, _ = sinkhorn_uot_fused_batched(K, a, b, self.CFG)
        np.testing.assert_allclose(P, P_ref, rtol=3e-5, atol=1e-8)

    @pytest.mark.parametrize("impl", ["kernel", "jnp"])
    def test_bf16_storage_tolerance(self, impl):
        """bf16 storage / fp32 accumulation stays within bf16 rounding of
        the fp32 solve (relative error ~2^-8 per stored value)."""
        K, a, b = make_stack(3, 64, 128, seed=1)
        P32, _ = ops.solve_fused_batched(K, a, b, self.CFG, block_m=16,
                                         interpret=True, impl=impl)
        Pbf, _ = ops.solve_fused_batched(K, a, b, self.CFG, block_m=16,
                                         interpret=True, impl=impl,
                                         storage_dtype=jnp.bfloat16)
        assert Pbf.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(Pbf, np.float32),
                                   np.asarray(P32), rtol=5e-2, atol=1e-4)
        # mass must be preserved to bf16 tolerance too, not just pointwise
        np.testing.assert_allclose(
            np.asarray(Pbf, np.float32).sum(), np.asarray(P32).sum(),
            rtol=1e-2)

    def test_bf16_via_cfg_dtype(self):
        """UOTConfig(dtype=bf16) selects the storage mode without a kwarg."""
        K, a, b = make_stack(2, 32, 128)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=10,
                        dtype=jnp.bfloat16)
        P, _ = ops.solve_fused_batched(K, a, b, cfg, block_m=16,
                                       interpret=True)
        assert P.dtype == jnp.bfloat16

    def test_solve_uv_batched_matches_per_sample(self):
        K, a, b = make_stack(3, 48, 96)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30)
        for impl in ["kernel", "jnp"]:
            P, (u, v) = ops.solve_uv_batched(K, a, b, cfg, block_m=16,
                                             interpret=True, impl=impl)
            for i in range(3):
                P_i, (u_i, v_i) = ops.solve_uv(K[i], a[i], b[i], cfg,
                                               block_m=16, interpret=True)
                np.testing.assert_allclose(P[i], P_i, rtol=1e-5, atol=1e-8)
                np.testing.assert_allclose(u[i], u_i, rtol=1e-5)
                np.testing.assert_allclose(v[i], v_i, rtol=1e-5)


class TestRaggedBucketing:
    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20)

    def test_bucket_problems_groups_by_padded_shape(self):
        shapes = [(20, 100), (60, 128), (17, 90), (65, 128), (64, 128)]
        buckets = ops.bucket_problems(shapes, m_bucket=64, n_bucket=128)
        assert buckets[(64, 128)] == [0, 1, 2, 4]
        assert buckets[(128, 128)] == [3]

    def test_ragged_solve_matches_standalone(self):
        """Padding a problem up to its bucket shape must not change its
        answer (zero rows/cols carry no mass, factors stay 1)."""
        rng = np.random.default_rng(3)
        problems = []
        for (m, n) in [(20, 100), (32, 128), (17, 100), (64, 200), (20, 100)]:
            problems.append((
                jnp.asarray(rng.uniform(0.1, 2, (m, n)), jnp.float32),
                jnp.asarray(rng.uniform(0.1, 2, (m,)), jnp.float32),
                jnp.asarray(rng.uniform(0.1, 2, (n,)), jnp.float32)))
        results = ops.solve_fused_bucketed(problems, self.CFG,
                                           interpret=True, max_batch=2)
        for (A0, a, b), (P, cs) in zip(problems, results):
            assert P.shape == A0.shape
            P_i, cs_i = ops.solve_fused(A0, a, b, self.CFG, interpret=True)
            np.testing.assert_allclose(P, P_i, rtol=1e-5, atol=1e-8)
            np.testing.assert_allclose(cs, cs_i, rtol=1e-5)


class TestUOTBatchEngine:
    def test_submit_flush_parity(self):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20)
        engine = UOTBatchEngine(cfg, max_batch=3, interpret=True)
        rng = np.random.default_rng(7)
        probs = {}
        for (m, n) in [(24, 100), (60, 120), (24, 100), (100, 250)]:
            K = rng.uniform(0.1, 2, (m, n)).astype(np.float32)
            a = rng.uniform(0.1, 2, m).astype(np.float32)
            b = rng.uniform(0.1, 2, n).astype(np.float32)
            rid = engine.submit(K, a, b)
            probs[rid] = (K, a, b)
        assert engine.pending == 4
        out = engine.flush()
        assert engine.pending == 0
        assert set(out) == set(probs)
        for rid, (K, a, b) in probs.items():
            P_i, _ = ops.solve_fused(jnp.asarray(K), jnp.asarray(a),
                                     jnp.asarray(b), cfg, interpret=True)
            np.testing.assert_allclose(out[rid], P_i, rtol=1e-5, atol=1e-8)

    def test_flush_empty(self):
        engine = UOTBatchEngine(UOTConfig(num_iters=5), interpret=True)
        assert engine.flush() == {}

    def test_repeat_flushes_reuse_compiled_solves(self):
        """Flushes whose bucket shapes repeat must hit the jit cache, even
        when queue depths jitter (batch is canonicalized to powers of 2)."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=5)
        engine = UOTBatchEngine(cfg, max_batch=8, interpret=True,
                                impl="jnp")
        rng = np.random.default_rng(11)

        def enqueue(n, mn):
            for _ in range(n):
                m, n_ = mn
                engine.submit(rng.uniform(0.1, 2, (m, n_)).astype(np.float32),
                              rng.uniform(0.1, 2, m).astype(np.float32),
                              rng.uniform(0.1, 2, n_).astype(np.float32))

        ops.reset_bucketed_cache_stats()
        enqueue(3, (20, 100))
        engine.flush()
        s1 = engine.cache_stats()
        assert s1 == {"hits": 0, "misses": 1}
        # _cache_size is a private jax API; use it when present for a
        # stronger no-recompile assertion, but don't depend on it
        sizer = getattr(ops.solve_fused_batched, "_cache_size", None)
        jit_entries = sizer() if sizer else None

        # same bucket, different queue depth within the same pow2 chunk
        enqueue(4, (24, 90))
        engine.flush()
        s2 = engine.cache_stats()
        assert s2 == {"hits": 1, "misses": 1}
        if sizer:
            assert sizer() == jit_entries, \
                "repeat flush recompiled the bucket solve"

        # a genuinely new chunk size is a miss exactly once
        enqueue(7, (20, 100))
        engine.flush()
        assert engine.cache_stats() == {"hits": 1, "misses": 2}
        enqueue(6, (20, 100))
        engine.flush()
        assert engine.cache_stats() == {"hits": 2, "misses": 2}

    def test_canonical_batch(self):
        assert [ops.canonical_batch(n, 8) for n in (1, 2, 3, 5, 8)] == \
            [1, 2, 4, 8, 8]
        assert ops.canonical_batch(33, 48) == 48


class TestPerLaneEarlyExit:
    """cfg.tol on the batched path: converged lanes freeze, loop ends when
    every lane (not each lane's worst-case budget) is done."""

    def _stack(self):
        # peaky cost (slow) + flat cost (fast) in one stack
        from benchmarks.common import make_problem
        probs = [make_problem(32, 128, reg=0.1, seed=5 + i, peak=peak)
                 for i, peak in enumerate((1.0, 6.0))]
        return tuple(jnp.stack(xs) for xs in zip(*probs))

    @pytest.mark.parametrize("impl", ["jnp", "kernel"])
    def test_each_lane_matches_its_single_problem_tol_solve(self, impl):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=300, tol=1e-4)
        K, a, b = self._stack()
        P, cs = ops.solve_fused_batched(K, a, b, cfg, block_m=16,
                                        interpret=True, impl=impl)
        iter_counts = []
        for i in range(2):
            A_core, stats = sinkhorn_uot_fused(K[i], a[i], b[i], cfg)
            iter_counts.append(int(stats["iters"]))
            np.testing.assert_allclose(P[i], A_core, rtol=3e-5, atol=1e-8)
        assert iter_counts[0] < iter_counts[1], \
            "test needs heterogeneous convergence to mean anything"

    def test_matches_stepped_lane_pool(self):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=300, tol=1e-4)
        K, a, b = self._stack()
        P, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp")
        st = ops.make_lane_state(2, 32, 128, cfg)
        for i in range(2):
            st = ops.lane_admit(st, jnp.int32(i), K[i], a[i], b[i])
        for _ in range(100):
            st = ops.solve_fused_stepped(st, 6, cfg, impl="jnp")
            if bool(np.asarray(ops.lane_done(st, cfg.num_iters)).all()):
                break
        np.testing.assert_allclose(st.P, P, rtol=1e-6, atol=1e-9)


class TestJnpBatchedReference:
    def test_vmap_reference_matches_loop(self):
        K, a, b = make_stack(3, 30, 70)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=15)
        P, stats = sinkhorn_uot_fused_batched(K, a, b, cfg)
        assert P.shape == K.shape
        assert stats["iters"].shape == (3,)
        for i in range(3):
            P_i, _ = sinkhorn_uot_fused(K[i], a[i], b[i], cfg)
            np.testing.assert_allclose(P[i], P_i, rtol=1e-6, atol=1e-9)


class TestBlockPicker:
    def test_mixed_itemsize_earns_larger_blocks(self):
        # same N: bf16 storage fits at least the fp32 block, usually larger
        assert ops.pick_block_m(4096, 65536, 2) >= ops.pick_block_m(
            4096, 65536, 4)

    def test_clamps_to_problem_height(self):
        assert ops.pick_block_m(256, 256) <= 256
        assert ops.pick_block_m(8, 128) == 8

    def test_bf16_sublane_floor(self):
        assert ops.pick_block_m(8, 10_000_000, 2) == 16
        assert ops.sublane_for(jnp.bfloat16) == 16
        assert ops.sublane_for(jnp.float32) == 8
