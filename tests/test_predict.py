"""Iteration prediction (core.predict): analytic model shape, the
truncation-error inverse, and the online predictor's calibration
criterion — p90 relative error <= 30% on a held-out half of a
(reg, reg_m, imbalance) sweep against the log-domain solver's actual
iteration counts (the PR's acceptance bar for the service-time model).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import UOTConfig, sinkhorn_uot_log
from repro.core.predict import (IterPredictor, analytic_iters,
                                estimate_truncation_error, predict_iters)


def _cfg(reg=0.05, reg_m=1.0, tol=1e-4, num_iters=400):
    return UOTConfig(reg=reg, reg_m=reg_m, num_iters=num_iters, tol=tol,
                     translation_invariant=True)


class TestAnalytic:
    def test_no_tol_runs_the_cap(self):
        assert analytic_iters(_cfg(tol=None)) == 400.0

    def test_tighter_tol_more_iters(self):
        loose = analytic_iters(_cfg(tol=1e-2))
        tight = analytic_iters(_cfg(tol=1e-6))
        assert tight > loose

    def test_weaker_relaxation_more_iters(self):
        # larger reg_m -> fi closer to 1 -> slower contraction
        fast = analytic_iters(_cfg(reg_m=0.1))
        slow = analytic_iters(_cfg(reg_m=10.0))
        assert slow > fast

    def test_balanced_limit_is_the_cap(self):
        assert analytic_iters(_cfg(reg_m=float("inf"))) == 400.0

    def test_clipped_to_config_range(self):
        assert 1.0 <= analytic_iters(_cfg(reg_m=100.0, tol=1e-12)) <= 400.0

    def test_predict_iters_reads_marginals(self):
        class P:
            a = np.full(8, 0.25)
            b = np.full(8, 0.125)

        bal = analytic_iters(_cfg())
        imb = predict_iters(P(), _cfg())
        assert imb >= bal   # imbalance can only add iterations

    def test_truncation_error_inverts(self):
        cfg = _cfg()
        # running the analytically-predicted count lands near tol
        iters = analytic_iters(cfg)
        err = estimate_truncation_error(cfg, iters)
        assert err == pytest.approx(cfg.tol, rel=1e-6)
        # truncating earlier is worse, monotonically
        assert (estimate_truncation_error(cfg, iters / 4)
                > estimate_truncation_error(cfg, iters / 2) > err)


def _actual_iters(cfg, a, b, C):
    _, _, stats = sinkhorn_uot_log(jnp.asarray(C), jnp.asarray(a),
                                   jnp.asarray(b), cfg)
    return int(stats["iters"])


class TestOnlineCalibration:
    def test_p90_relative_error_under_30pct(self):
        """The acceptance criterion: observe half the sweep, predict the
        other half; p90 of |pred - actual| / actual must be <= 0.30."""
        rng = np.random.default_rng(0)
        M, N = 24, 32
        C = np.abs(rng.normal(size=(M, 1)) - rng.normal(size=(1, N))) ** 2
        samples = []
        for reg in (0.02, 0.05, 0.1):
            for reg_m in (0.3, 1.0, 3.0):
                for imb in (1.0, 1.5, 2.2):
                    for jit in range(2):
                        a = rng.uniform(0.5, 1.0, M)
                        b = rng.uniform(0.5, 1.0, N)
                        a /= a.sum()
                        b /= b.sum() / imb
                        cfg = _cfg(reg=reg, reg_m=reg_m)
                        samples.append(
                            (cfg, a, b, _actual_iters(cfg, a, b, C)))
        rng.shuffle(samples)
        pred = IterPredictor()
        half = len(samples) // 2
        for cfg, a, b, actual in samples[:half]:
            pred.observe(cfg, actual, bucket=(M, N),
                         mass_a=float(a.sum()), mass_b=float(b.sum()))
        errs = []
        for cfg, a, b, actual in samples[half:]:
            p = pred.predict(cfg, bucket=(M, N), mass_a=float(a.sum()),
                             mass_b=float(b.sum()))
            errs.append(abs(p - actual) / actual)
        assert float(np.percentile(errs, 90)) <= 0.30

    def test_cold_predictor_falls_back_to_analytic(self):
        cfg = _cfg()
        pred = IterPredictor()
        assert pred.predict(cfg, bucket=(8, 8)) == analytic_iters(cfg)

    def test_observation_moves_the_prediction(self):
        cfg = _cfg()
        pred = IterPredictor()
        base = analytic_iters(cfg)
        pred.observe(cfg, base * 2.0, bucket=(8, 8))
        assert pred.predict(cfg, bucket=(8, 8)) == pytest.approx(
            base * 2.0, rel=1e-6)
        # an unseen bucket uses the global cell, not the raw analytic
        assert pred.predict(cfg, bucket=(64, 64)) == pytest.approx(
            base * 2.0, rel=1e-6)

    def test_snapshot_shape(self):
        pred = IterPredictor()
        pred.observe(_cfg(), 10.0, bucket=(8, 8))
        snap = pred.snapshot()
        # one observe populates the fine cell, its (reg, reg_m) regime
        # cell, and the global cell
        assert "global" in snap and len(snap) == 3
