"""Distributed UOT solvers — run in a subprocess with 8 forced host devices
(XLA device count is locked at first jax init, so the flag must be set in a
fresh interpreter; see tests/_distributed_check.py)."""
import os
import subprocess
import sys
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_solvers_match_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "_distributed_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "DISTRIBUTED_CHECK_PASSED" in proc.stdout
