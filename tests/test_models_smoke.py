"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + prefill/decode on CPU; assert shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch, smoke_config, SMOKE_SHAPE
from repro.models.model import build_model


def make_batch(cfg, key, B=2, S=32):
    kt, kl, ki = jax.random.split(key, 3)
    V = cfg.vocab_size
    if cfg.family == "audio":
        K = cfg.num_codebooks
        return {"tokens": jax.random.randint(kt, (B, K, S), 0, V),
                "labels": jax.random.randint(kl, (B, K, S), 0, V)}
    if cfg.family == "vlm":
        n_img = cfg.num_image_tokens
        S_txt = S - n_img
        return {"tokens": jax.random.randint(kt, (B, S_txt), 0, V),
                "labels": jax.random.randint(kl, (B, S_txt), 0, V),
                "image_embeds": 0.1 * jax.random.normal(
                    ki, (B, n_img, cfg.d_model))}
    return {"tokens": jax.random.randint(kt, (B, S), 0, V),
            "labels": jax.random.randint(kl, (B, S), 0, V)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def _setup(self, arch):
        cfg = smoke_config(get_arch(arch))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1), B=2, S=32)
        return cfg, m, params, batch

    def test_forward_loss_finite(self, arch):
        cfg, m, params, batch = self._setup(arch)
        loss, metrics = jax.jit(m.forward)(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), (arch, float(loss))
        # random init: loss should be near log(vocab)
        assert 0.0 < float(loss) < 3 * np.log(cfg.vocab_size) + 2

    def test_train_grad_step(self, arch):
        cfg, m, params, batch = self._setup(arch)

        def loss_fn(p):
            loss, _ = m.forward(p, batch)
            return loss

        grads = jax.jit(jax.grad(loss_fn))(params)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
        gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                   for g in flat)))
        assert 0 < gnorm < 1e6, (arch, gnorm)

    def test_decode_step(self, arch):
        cfg, m, params, batch = self._setup(arch)
        B = 2
        cache = m.init_cache(B, cache_len=64)
        if cfg.family == "audio":
            tok = jnp.zeros((B, cfg.num_codebooks, 1), jnp.int32)
        else:
            tok = jnp.zeros((B, 1), jnp.int32)
        logits, cache2 = jax.jit(m.decode_step)(params, cache, tok,
                                                jnp.int32(0))
        if cfg.family == "audio":
            assert logits.shape == (B, cfg.num_codebooks, 1, cfg.padded_vocab)
        else:
            assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), arch
        # structure preserved
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)

    def test_prefill_matches_decode(self, arch):
        """Prefill then one decode step == running S+1 tokens at once
        (checks cache correctness end to end)."""
        cfg, m, params, batch = self._setup(arch)
        if cfg.family in ("vlm",):
            pytest.skip("vlm prefill covered by forward; decode tested above")
        if cfg.family == "moe":
            # sinkhorn routing is population-dependent; prefill(S) vs
            # prefill(S+1) legitimately route differently. Compare the
            # population-independent top-k path.
            import dataclasses
            cfg = dataclasses.replace(cfg, router="topk")
            m = build_model(cfg)
        B, S = 2, 16
        key = jax.random.PRNGKey(3)
        if cfg.family == "audio":
            toks = jax.random.randint(key, (B, cfg.num_codebooks, S + 1), 0,
                                      cfg.vocab_size)
            prompt = {"tokens": toks[..., :S]}
            next_tok = toks[..., S:S + 1]
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
            prompt = {"tokens": toks[:, :S]}
            next_tok = toks[:, S:S + 1]

        logits_p, cache = jax.jit(
            lambda p, b: m.prefill(p, b, cache_len=64))(params, prompt)
        logits_d, _ = jax.jit(m.decode_step)(params, cache, next_tok,
                                             jnp.int32(S))

        # reference: full forward logits at position S via prefill of S+1
        full = {"tokens": toks}
        logits_full, _ = jax.jit(
            lambda p, b: m.prefill(p, b, cache_len=64))(params, full)
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32).squeeze(),
            np.asarray(logits_full, np.float32).squeeze(),
            rtol=2e-2, atol=2e-2)


def test_param_counts_match_assignment():
    """Full configs instantiate analytically near their nameplate sizes."""
    # Bounds sanity-check the ASSIGNED dims (which are authoritative even
    # where they disagree with a checkpoint's nameplate: e.g. the assigned
    # moonshot dims [48L x 64e x d_ff 1408] total ~28B, not 16B; phi4's 3.8B
    # nameplate assumes tied embeddings over its 200k vocab).
    expect = {
        "granite-34b": (30e9, 40e9),
        "phi4-mini-3.8b": (3.0e9, 4.8e9),
        "smollm-360m": (0.25e9, 0.5e9),
        "granite-3-2b": (2.0e9, 3.3e9),
        "olmoe-1b-7b": (5.5e9, 8e9),
        "moonshot-v1-16b-a3b": (20e9, 32e9),
        "xlstm-350m": (0.15e9, 0.55e9),
        "zamba2-7b": (5.5e9, 9.5e9),
        "llava-next-34b": (30e9, 40e9),
        "musicgen-medium": (1.2e9, 2.5e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
