"""Pallas kernel correctness: sweeps of shapes/dtypes vs pure-jnp oracles.

Kernels execute in interpret=True mode on CPU (the kernel body runs in
Python with the same tiling/grid semantics as on TPU).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig, sinkhorn_uot_fused, sinkhorn_uot_uv
from repro.kernels import ops, ref
from repro.kernels.uot_fused import fused_iteration, colsum
from repro.kernels.uot_halfpass import (
    scale_rows_accum_cols, scale_cols_accum_rows)
from repro.kernels.uot_uv_fused import uv_iteration, materialize_coupling


def rand(shape, seed=0, dtype=jnp.float32, lo=0.1, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape), dtype=dtype)


TOL = {jnp.float32: dict(rtol=2e-6, atol=1e-8),
       jnp.bfloat16: dict(rtol=2e-2, atol=1e-3)}


class TestFusedIterationKernel:
    @pytest.mark.parametrize("M,N,bm", [
        (8, 128, 8), (64, 128, 8), (64, 256, 16), (256, 384, 64),
        (512, 128, 256), (128, 1024, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, M, N, bm, dtype):
        A = rand((M, N), seed=M + N, dtype=dtype)
        fcol = rand((N,), seed=1)
        a = rand((M,), seed=2)
        fi = 0.9
        out, cs = fused_iteration(A, fcol, a, fi=fi, block_m=bm, interpret=True)
        out_r, cs_r = ref.fused_iteration_ref(A, fcol, a, fi=fi)
        tol = TOL[dtype]
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out_r.astype(dtype), np.float32), **tol)
        np.testing.assert_allclose(cs, cs_r, rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)

    @pytest.mark.parametrize("fi", [1.0, 0.5, 0.909])
    def test_fi_variants(self, fi):
        A = rand((64, 256))
        fcol, a = rand((256,), 1), rand((64,), 2)
        out, cs = fused_iteration(A, fcol, a, fi=fi, block_m=16, interpret=True)
        out_r, cs_r = ref.fused_iteration_ref(A, fcol, a, fi=fi)
        np.testing.assert_allclose(out, out_r, rtol=2e-6)
        np.testing.assert_allclose(cs, cs_r, rtol=2e-6)

    def test_zero_rows_are_noop(self):
        """Zero padding invariance: padded rows/cols stay zero, sums exact."""
        A = rand((32, 128))
        A = A.at[16:, :].set(0.0)
        fcol, a = rand((128,), 1), rand((32,), 2).at[16:].set(0.0)
        out, cs = fused_iteration(A, fcol, a, fi=0.9, block_m=8, interpret=True)
        assert float(jnp.abs(out[16:, :]).max()) == 0.0

    def test_colsum_kernel(self):
        A = rand((96, 256))
        np.testing.assert_allclose(
            colsum(A, block_m=32, interpret=True), ref.colsum_ref(A), rtol=1e-6)


class TestHalfpassKernels:
    @pytest.mark.parametrize("M,N,bm,bn", [
        (64, 256, 16, 128), (128, 512, 32, 256), (256, 1024, 64, 512),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_scale_rows(self, M, N, bm, bn, dtype):
        A = rand((M, N), dtype=dtype)
        frow = rand((M,), 3)
        out, cs = scale_rows_accum_cols(A, frow, block_m=bm, block_n=bn,
                                        interpret=True)
        out_r, cs_r = ref.scale_rows_accum_cols_ref(A, frow)
        tol = TOL[dtype]
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out_r.astype(dtype), np.float32), **tol)
        np.testing.assert_allclose(cs, cs_r, rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)

    @pytest.mark.parametrize("M,N,bm,bn", [
        (64, 256, 16, 128), (128, 512, 32, 256),
    ])
    def test_scale_cols(self, M, N, bm, bn):
        A = rand((M, N))
        fcol = rand((N,), 4)
        out, rs = scale_cols_accum_rows(A, fcol, block_m=bm, block_n=bn,
                                        interpret=True)
        out_r, rs_r = ref.scale_cols_accum_rows_ref(A, fcol)
        np.testing.assert_allclose(out, out_r, rtol=2e-6)
        np.testing.assert_allclose(rs, rs_r, rtol=2e-6)


class TestUVKernel:
    @pytest.mark.parametrize("M,N,bm", [(64, 128, 8), (128, 384, 32),
                                        (256, 1024, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_uv_iteration(self, M, N, bm, dtype):
        K = rand((M, N), dtype=dtype)
        v = rand((N,), 5)
        a = rand((M,), 6)
        u, ktu = uv_iteration(K, v, a, fi=0.9, block_m=bm, interpret=True)
        u_r, ktu_r = ref.uv_iteration_ref(K, v, a, fi=0.9)
        rtol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(u, u_r, rtol=rtol)
        np.testing.assert_allclose(ktu, ktu_r, rtol=rtol)

    def test_materialize(self):
        K = rand((64, 256))
        u, v = rand((64,), 7), rand((256,), 8)
        P = materialize_coupling(K, u, v, block_m=16, interpret=True)
        np.testing.assert_allclose(P, ref.materialize_coupling_ref(K, u, v),
                                   rtol=2e-6)


class TestAssembledSolvers:
    """Kernel-built solvers must match the core jnp solvers end to end."""

    def make_problem(self, M=100, N=77, reg=0.1, seed=0):
        rng = np.random.default_rng(seed)
        C = rng.uniform(0, 1, size=(M, N)).astype(np.float32)
        a = rng.uniform(0.5, 1.5, size=M).astype(np.float32)
        b = rng.uniform(0.5, 1.5, size=N).astype(np.float32)
        a, b = a / a.sum(), b / b.sum() * 1.2
        K = np.exp(-C / reg) * (a[:, None] * b[None, :])
        return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)

    def test_solve_fused_matches_core(self):
        K, a, b = self.make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40)
        A_core, _ = sinkhorn_uot_fused(K, a, b, cfg)
        A_kern, _ = ops.solve_fused(K, a, b, cfg, block_m=16, interpret=True)
        np.testing.assert_allclose(A_kern, A_core, rtol=3e-5, atol=1e-8)

    def test_solve_halfpass_matches_core(self):
        K, a, b = self.make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40)
        A_core, _ = sinkhorn_uot_fused(K, a, b, cfg)
        A_kern, _ = ops.solve_halfpass(K, a, b, cfg, block_m=16, block_n=128,
                                       interpret=True)
        np.testing.assert_allclose(A_kern, A_core, rtol=3e-5, atol=1e-8)

    def test_solve_uv_matches_core(self):
        K, a, b = self.make_problem()
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=60)
        P_core, (u_c, v_c), _ = sinkhorn_uot_uv(K, a, b, cfg)
        P_kern, (u_k, v_k) = ops.solve_uv(K, a, b, cfg, block_m=16,
                                          interpret=True)
        np.testing.assert_allclose(v_k, v_c, rtol=3e-5)
        np.testing.assert_allclose(P_kern, P_core, rtol=3e-4, atol=1e-8)

    def test_block_autotune_bounds(self):
        assert ops.pick_block_m(10_000, 512) == 512
        bm = ops.pick_block_m(100_000, 1_000_000)
        assert bm >= 8 and 2 * bm * 1_000_000 * 4 <= 2 * ops._VMEM_BUDGET_BYTES
