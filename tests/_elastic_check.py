"""Subprocess: elastic checkpoint restore across DIFFERENT mesh shapes.

Phase 1 (argv[1] == 'save'): 8 devices, state sharded over (4 data, 2 model),
train 3 steps, checkpoint.
Phase 2 (argv[1] == 'restore'): 4 devices, rebuild a (2, 2) mesh, restore the
same checkpoint with the new shardings, train 2 more steps — proving
scale-down restart works (checkpoint tensors are stored unsharded).
"""
import os
import sys

PHASE = sys.argv[1]
N_DEV = 8 if PHASE == "save" else 4
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEV} "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_arch, smoke_config  # noqa: E402
from repro.data.pipeline import SyntheticTokenPipeline  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.optim import OptConfig  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402
from repro.train import checkpoint as ckpt  # noqa: E402
from repro.train.train_step import init_train_state, make_train_step  # noqa: E402

CKPT = sys.argv[2]


def main():
    cfg = smoke_config(get_arch("granite-3-2b"))
    model = build_model(cfg)
    pipe = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=4)
    shape = (4, 2) if PHASE == "save" else (2, 2)
    mesh = jax.make_mesh(shape, ("data", "model"))

    state = init_train_state(model, jax.random.PRNGKey(0))
    state_shapes = jax.eval_shape(lambda: state)
    sspecs = shd.state_specs(cfg, state_shapes, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                             is_leaf=lambda x: isinstance(x, P))

    step_fn = jax.jit(make_train_step(model, OptConfig(lr=1e-3)),
                      in_shardings=(shardings, None),
                      out_shardings=(shardings, None))

    if PHASE == "save":
        state = jax.device_put(state, shardings)
        for i in range(3):
            state, m = step_fn(state, pipe.batch_at(i))
        ckpt.save(CKPT, int(state["step"]), state)
        print("SAVED", float(m["loss"]))
    else:
        state, step = ckpt.restore(CKPT, state, shardings=shardings)
        assert step == 3
        # verify placement landed on the new 4-device mesh
        leaf = jax.tree.leaves(state["params"])[0]
        assert len(leaf.sharding.device_set) in (1, 2, 4)
        for i in range(step, step + 2):
            state, m = step_fn(state, pipe.batch_at(i))
        assert int(state["step"]) == 5
        assert np.isfinite(float(m["loss"]))
        print("RESTORED_AND_TRAINED", float(m["loss"]))


if __name__ == "__main__":
    main()
