"""Optimizer, data pipeline, checkpointing, trainer fault tolerance."""
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.train_step import (
    init_train_state, make_train_step, quantize_int8, dequantize_int8,
    compress_grads_with_feedback)
from repro.train.trainer import Trainer, TrainerConfig


class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)
        cfg = OptConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
        for _ in range(300):
            g = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(g, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        cfg = OptConfig(lr=0.1, grad_clip=1.0)
        _, _, m = adamw_update({"w": jnp.full(3, 100.0)}, opt, params, cfg)
        assert float(m["grad_norm"]) > 100
        assert float(m["clip"]) < 0.01

    def test_schedule(self):
        s = cosine_schedule(jnp.int32(0), warmup=10, total=100)
        assert float(s) == 0.0
        s = cosine_schedule(jnp.int32(10), warmup=10, total=100)
        assert abs(float(s) - 1.0) < 1e-5
        s_end = cosine_schedule(jnp.int32(100), warmup=10, total=100)
        assert abs(float(s_end) - 0.1) < 1e-5


class TestDataPipeline:
    def test_deterministic_and_seekable(self):
        cfg = smoke_config(get_arch("granite-3-2b"))
        p = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=4)
        b1 = p.batch_at(7)
        b2 = p.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = p.batch_at(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_host_sharding_partitions_batch(self):
        cfg = smoke_config(get_arch("granite-3-2b"))
        full = SyntheticTokenPipeline(cfg, seq_len=16, global_batch=8)
        shards = [SyntheticTokenPipeline(cfg, seq_len=16, global_batch=8,
                                         shard_id=i, num_shards=4)
                  for i in range(4)]
        assert all(s.shard_batch == 2 for s in shards)
        # shards are mutually distinct
        t = [np.asarray(s.batch_at(0)["tokens"]) for s in shards]
        assert not np.array_equal(t[0], t[1])

    def test_labels_are_shifted_tokens(self):
        cfg = smoke_config(get_arch("granite-3-2b"))
        p = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=2)
        b = p.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape

    def test_vlm_audio_batches(self):
        for arch in ("llava-next-34b", "musicgen-medium"):
            cfg = smoke_config(get_arch(arch))
            p = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=2)
            b = p.batch_at(0)
            if cfg.family == "vlm":
                assert b["image_embeds"].shape == (2, cfg.num_image_tokens,
                                                   cfg.d_model)
            else:
                assert b["tokens"].shape[1] == cfg.num_codebooks


class TestQuantization:
    def test_int8_roundtrip_error_feedback(self):
        g = {"a": jnp.array([0.1, -0.5, 2.0]), "b": jnp.ones((4, 4)) * 0.01}
        q, s = quantize_int8(g)
        deq = dequantize_int8(q, s)
        err0 = max(float(jnp.abs(x - y).max())
                   for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(deq)))
        assert err0 < 2.0 / 127
        # error feedback: two steps of the same grad — accumulated result
        # approaches 2x the true grad (bias is corrected over time)
        sent1, e1 = compress_grads_with_feedback(g, None)
        sent2, e2 = compress_grads_with_feedback(g, e1)
        total = jax.tree.map(lambda x, y: x + y, sent1, sent2)
        for t, ref in zip(jax.tree.leaves(total), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(t), 2 * np.asarray(ref),
                                       atol=2e-2)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "nested": {"b": jnp.ones(4, jnp.int32)}}
        ckpt.save(str(tmp_path), 5, tree)
        out, step = ckpt.restore(str(tmp_path), tree)
        assert step == 5
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])

    def test_latest_and_gc(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_step(str(tmp_path)) == 5
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(kept) == 3  # gc keeps last 3

    def test_structure_mismatch_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), {"DIFFERENT": jnp.zeros(2)})


class TestTrainStep:
    def _mk(self, **kw):
        cfg = smoke_config(get_arch("granite-3-2b"))
        model = build_model(cfg)
        pipe = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=4)
        return cfg, model, pipe

    def test_loss_decreases(self):
        cfg, model, pipe = self._mk()
        step = jax.jit(make_train_step(model, OptConfig(lr=1e-3),
                                       total_steps=60, warmup=5))
        state = init_train_state(model, jax.random.PRNGKey(0))
        losses = []
        for i in range(40):
            state, m = step(state, pipe.batch_at(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:5]

    def test_microbatch_equivalence(self):
        cfg, model, pipe = self._mk()
        batch = pipe.batch_at(0)
        s1 = init_train_state(model, jax.random.PRNGKey(0))
        s2 = jax.tree.map(jnp.copy, s1)
        step1 = jax.jit(make_train_step(model, OptConfig(), microbatches=1))
        step2 = jax.jit(make_train_step(model, OptConfig(), microbatches=2))
        s1, m1 = step1(s1, batch)
        s2, m2 = step2(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-4)
        # params close (not identical: grad averaging order differs)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])))
        assert d < 5e-3


class TestTrainerFaultTolerance:
    def test_recovers_from_injected_failures(self, tmp_path):
        cfg = smoke_config(get_arch("granite-3-2b"))
        model = build_model(cfg)
        pipe = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=4)
        tcfg = TrainerConfig(total_steps=12, ckpt_every=4,
                             ckpt_dir=str(tmp_path), warmup=2)
        trainer = Trainer(model, pipe, OptConfig(lr=1e-3), tcfg,
                          failure_schedule={6: RuntimeError("node died"),
                                            9: RuntimeError("nan blowup")})
        state = trainer.run(jax.random.PRNGKey(0))
        assert int(state["step"]) == 12
        assert trainer.restarts == 2
        assert ckpt.latest_step(str(tmp_path)) == 12

    def test_resume_from_checkpoint_is_exact(self, tmp_path):
        cfg = smoke_config(get_arch("granite-3-2b"))
        model = build_model(cfg)
        pipe = SyntheticTokenPipeline(cfg, seq_len=32, global_batch=4)

        # run 8 steps straight
        tcfg_a = TrainerConfig(total_steps=8, ckpt_every=100,
                               ckpt_dir=str(tmp_path / "a"), warmup=2)
        ta = Trainer(model, pipe, OptConfig(lr=1e-3), tcfg_a)
        sa = ta.run(jax.random.PRNGKey(0))

        # run 4 steps, "crash", resume to 8 (checkpoint at 4)
        tcfg_b1 = TrainerConfig(total_steps=4, schedule_total=8,
                                ckpt_every=4,
                                ckpt_dir=str(tmp_path / "b"), warmup=2)
        tb = Trainer(model, pipe, OptConfig(lr=1e-3), tcfg_b1)
        tb.run(jax.random.PRNGKey(0))
        tcfg_b2 = TrainerConfig(total_steps=8, ckpt_every=100,
                                ckpt_dir=str(tmp_path / "b"), warmup=2)
        tb2 = Trainer(model, pipe, OptConfig(lr=1e-3), tcfg_b2)
        sb = tb2.run(jax.random.PRNGKey(0))

        for a, b in zip(jax.tree.leaves(sa["params"]),
                        jax.tree.leaves(sb["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
