"""Subprocess body for cluster-runtime tests on 8 forced host devices.

XLA flags must be set before jax import (device count locks at first
init), so pytest runs this in a fresh interpreter — see
tests/test_cluster_distributed.py. Asserts the acceptance property of the
cluster tier: on a REAL 8-device mesh, the shard_map'd cluster scheduler's
results are bit-identical per request to a single-device ``UOTScheduler``
run of the same trace — across placement policies, step modes, and the
per-device-loop oracle.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import UOTConfig, sinkhorn_uot_fused  # noqa: E402
from repro.serve import UOTScheduler  # noqa: E402
from repro.cluster import (ClusterScheduler, cluster_admit,  # noqa: E402
                           cluster_mesh, cluster_stepped,
                           make_cluster_lane_state)
from repro.kernels import ops  # noqa: E402


def make_problem(m, n, seed, peak=1.0, reg=0.1):
    r = np.random.default_rng(seed)
    C = r.uniform(0, 1, (m, n)).astype(np.float32) * peak
    a = r.uniform(0.5, 1.5, m).astype(np.float32)
    b = r.uniform(0.5, 1.5, n).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.2
    return np.exp(-C / reg) * (a[:, None] * b[None, :]), a, b


def workload(seed, n_requests=16):
    r = np.random.default_rng(seed)
    shapes = [(8, 100), (20, 128), (32, 64), (16, 90), (24, 120)]
    return [make_problem(*shapes[r.integers(len(shapes))],
                         seed=seed * 1000 + i,
                         peak=float(r.uniform(1.0, 8.0)))
            for i in range(n_requests)]


def check_sharded_advance_bit_identity(mesh, cfg):
    """One shard_map launch == per-device loop == single-device pool."""
    K, a, b = make_problem(30, 100, 7, peak=4.0)
    st = ops.lane_admit(ops.make_lane_state(2, 32, 128, cfg),
                        jnp.int32(0), jnp.asarray(K), jnp.asarray(a),
                        jnp.asarray(b))
    cs = make_cluster_lane_state(8, 2, 32, 128, cfg, mesh=mesh)
    cs = cluster_admit(cs, jnp.int32(5), jnp.int32(0), jnp.asarray(K),
                       jnp.asarray(a), jnp.asarray(b))
    cs_loop = cs
    for _ in range(12):
        st = ops.solve_fused_stepped(st, 4, cfg, impl="jnp")
        cs = cluster_stepped(cs, 4, cfg, mesh=mesh, impl="jnp")
        cs_loop = cluster_stepped(cs_loop, 4, cfg, mesh=None, impl="jnp")
    assert np.array_equal(np.asarray(cs.lanes.P[5, 0]), np.asarray(st.P[0]))
    assert int(cs.lanes.iters[5, 0]) == int(st.iters[0])
    for a_leaf, b_leaf in zip(jax.tree_util.tree_leaves(cs),
                              jax.tree_util.tree_leaves(cs_loop)):
        assert np.array_equal(np.asarray(a_leaf), np.asarray(b_leaf))
    print("sharded advance bit-identity: OK")


def check_scheduler_bit_identity(mesh, cfg):
    """The acceptance property: every request's coupling from the 8-device
    mesh scheduler equals the single-device UOTScheduler's, bit for bit,
    for every placement policy and step mode."""
    probs = workload(3)
    ref = UOTScheduler(cfg, lanes_per_pool=2, chunk_iters=3, m_bucket=32,
                       impl="jnp")
    rids = [ref.submit(*p) for p in probs]
    ref_out = ref.run()
    expected = [ref_out[r] for r in rids]
    for kwargs in [dict(placement="least_loaded", step_mode="sync"),
                   dict(placement="bucket_affinity", step_mode="sync"),
                   dict(placement="least_loaded", step_mode="async")]:
        cs = ClusterScheduler(cfg, mesh=mesh, lanes_per_device=2,
                              chunk_iters=3, m_bucket=32, impl="jnp",
                              **kwargs)
        crids = [cs.submit(*p) for p in probs]
        out = cs.run()
        assert cs.pending == 0 and cs.in_flight == 0
        for cr, expect in zip(crids, expected):
            assert np.array_equal(out[cr], expect), kwargs
        st = cs.stats()
        assert st["completed"] == len(probs)
        assert sum(v["completed"] for v in st["devices"].values()) \
            == len(probs)
        print(f"scheduler bit-identity {kwargs}: OK "
              f"(devices used: "
              f"{sum(1 for v in st['devices'].values() if v['placed'])})")


def check_points_requests(mesh, cfg):
    """Coordinate payloads through the mesh == dense submission."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(24, 3)).astype(np.float32)
    y = rng.normal(size=(100, 3)).astype(np.float32) + 0.3
    a = rng.uniform(0.5, 1.5, 24).astype(np.float32)
    b = rng.uniform(0.5, 1.5, 100).astype(np.float32)
    a, b = a / a.sum(), b / b.sum() * 1.2
    from repro.geometry import PointCloudGeometry
    g = PointCloudGeometry.from_points(x, y, scale=2.0)
    dense = ClusterScheduler(cfg, mesh=mesh, lanes_per_device=2,
                             m_bucket=32, impl="jnp")
    rd = dense.submit(np.asarray(g.kernel(cfg.reg)), a, b)
    pts = ClusterScheduler(cfg, mesh=mesh, lanes_per_device=2,
                           m_bucket=32, impl="jnp")
    rp = pts.submit_points(x, y, a, b, scale=2.0)
    assert np.array_equal(dense.run()[rd], pts.run()[rp])
    print("points == dense through the mesh: OK")


def check_gang_escape_hatch(mesh, cfg):
    """Over-budget requests run on the row-sharded gang across the same
    mesh the lane pools shard over — one submit API, two tiers."""
    cs = ClusterScheduler(cfg, mesh=mesh, lanes_per_device=2, impl="jnp",
                          lane_budget=lambda Mb, Nb: Mb * Nb <= 64 * 128)
    small = make_problem(16, 100, 11)
    Kb, ab, bb = make_problem(300, 256, 12)
    r_small = cs.submit(*small)
    r_gang = cs.submit(Kb, ab, bb)
    out = cs.run()
    assert r_small in out and r_gang in out
    cfg_fixed = UOTConfig(reg=cfg.reg, reg_m=cfg.reg_m,
                          num_iters=cfg.num_iters)
    ref, _ = sinkhorn_uot_fused(jnp.asarray(Kb), jnp.asarray(ab),
                                jnp.asarray(bb), cfg_fixed)
    np.testing.assert_allclose(out[r_gang], np.asarray(ref), rtol=3e-5,
                               atol=1e-8)
    st = cs.stats()
    assert st["gang_completed"] == 1 and st["router"]["gang_routed"] == 1
    print("gang escape hatch on the mesh: OK")


def main():
    assert jax.device_count() == 8, jax.device_count()
    cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)
    mesh = cluster_mesh(8)
    check_sharded_advance_bit_identity(mesh, cfg)
    check_scheduler_bit_identity(mesh, cfg)
    check_points_requests(mesh, cfg)
    check_gang_escape_hatch(mesh, cfg)


if __name__ == "__main__":
    main()
    print("CLUSTER_CHECK_PASSED")
