"""VMEM-resident solver tier: parity, convergence semantics, dispatch.

The resident kernels run whole solves (or whole scheduler chunks) with each
lane's tile on-chip, so the contract is: same iterate, same per-lane
iteration count as the streamed tier — exactly for fp32, and for bf16
storage the resident trajectory is the fp32 trajectory rounded ONCE (the
streamed path's per-iteration rounding disappears by design, so bf16 parity
is held against the fp32 reference, not bit-against-streamed). Kernels run
with ``impl='kernel', interpret=True`` so the real lane-grid schedule
executes on CPU CI; the jnp mirror is held to the same bars.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UOTConfig, sinkhorn_uot_fused
from repro.kernels import ops

IMPLS = ["jnp", "kernel"]


def make_stack(B, M, N, reg=0.1, seed=0, peak_spread=True):
    """Random problem stack; with ``peak_spread`` the per-problem cost
    scale varies so tol-based runs converge at different iteration counts
    (the interesting case for per-lane early exit)."""
    rng = np.random.default_rng(seed)
    peaks = rng.uniform(1.0, 6.0, B) if peak_spread else np.ones(B)
    C = rng.uniform(0, 1, size=(B, M, N)).astype(np.float32)
    C *= peaks[:, None, None]
    a = rng.uniform(0.5, 1.5, size=(B, M)).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=(B, N)).astype(np.float32)
    a = a / a.sum(axis=1, keepdims=True)
    b = b / b.sum(axis=1, keepdims=True) * 1.3
    K = np.exp(-C / reg) * (a[:, :, None] * b[:, None, :])
    return jnp.asarray(K), jnp.asarray(a), jnp.asarray(b)


def _resident(K, a, b, cfg, impl, **kw):
    interpret = True if impl == "kernel" else None
    return ops.solve_fused_resident(K, a, b, cfg, impl=impl,
                                    interpret=interpret, **kw)


class TestResidentOneShot:
    """One-shot resident solves vs the core streamed reference."""

    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("tol", [None, 1e-3])
    def test_fp32_matches_core_iterates_and_counts(self, impl, tol):
        B, M, N = 3, 40, 200
        K, a, b = make_stack(B, M, N, seed=1)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=25, tol=tol)
        P, colsum, iters, err = _resident(K, a, b, cfg, impl)
        for i in range(B):
            P_ref, stats = sinkhorn_uot_fused(K[i], a[i], b[i], cfg)
            np.testing.assert_allclose(np.asarray(P[i]), np.asarray(P_ref),
                                       rtol=2e-6, atol=1e-9)
            assert int(iters[i]) == int(stats["iters"])
            np.testing.assert_allclose(np.asarray(colsum[i]),
                                       np.asarray(P_ref).sum(0),
                                       rtol=1e-5, atol=1e-9)
        if tol is not None:
            # the peak spread must actually exercise heterogeneous counts
            assert len(set(np.asarray(iters).tolist())) > 1
            assert (np.asarray(err) <= tol).all()

    @pytest.mark.parametrize("tol", [None, 1e-3])
    def test_kernel_matches_jnp_mirror(self, tol):
        B, M, N = 4, 24, 130
        K, a, b = make_stack(B, M, N, seed=2)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=tol)
        Pk, csk, itk, errk = _resident(K, a, b, cfg, "kernel")
        Pj, csj, itj, errj = _resident(K, a, b, cfg, "jnp")
        np.testing.assert_allclose(np.asarray(Pk), np.asarray(Pj),
                                   rtol=1e-6, atol=1e-9)
        np.testing.assert_array_equal(np.asarray(itk), np.asarray(itj))
        np.testing.assert_allclose(np.asarray(csk), np.asarray(csj),
                                   rtol=1e-6, atol=1e-9)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_bf16_storage_rounds_once(self, impl):
        """Resident bf16 = fp32 trajectory downcast at the end: it must
        match the fp32 core solve to one-rounding tolerance AND be at
        least as close to it as the streamed bf16 path, whose per-iteration
        rounding accumulates."""
        B, M, N = 3, 32, 140
        K, a, b = make_stack(B, M, N, seed=3)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=25,
                        dtype=jnp.bfloat16)
        cfg32 = UOTConfig(reg=0.1, reg_m=1.0, num_iters=25)
        P, _, iters, _ = _resident(K, a, b, cfg, impl)
        assert P.dtype == jnp.bfloat16
        P_stream, _ = ops.solve_fused_batched(K, a, b, cfg, impl="jnp")
        res_err = stream_err = 0.0
        for i in range(B):
            P_ref = np.asarray(sinkhorn_uot_fused(
                K[i], a[i], b[i], cfg32)[0])
            scale = np.abs(P_ref).max()
            res_err = max(res_err, np.abs(
                np.asarray(P[i], np.float32) - P_ref).max() / scale)
            stream_err = max(stream_err, np.abs(
                np.asarray(P_stream[i], np.float32) - P_ref).max() / scale)
        assert res_err <= 2 ** -8  # one bf16 rounding of the final iterate
        assert res_err <= stream_err + 1e-6

    def test_single_problem_2d_entry(self):
        M, N = 40, 200
        K, a, b = make_stack(1, M, N, seed=4)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=4000, tol=1e-5)
        P, colsum, iters, err = ops.solve_fused_resident(
            K[0], a[0], b[0], cfg, impl="jnp")
        assert P.shape == (M, N) and colsum.shape == (N,)
        P_ref, stats = sinkhorn_uot_fused(K[0], a[0], b[0], cfg)
        np.testing.assert_allclose(np.asarray(P), np.asarray(P_ref),
                                   rtol=2e-6, atol=1e-9)
        assert int(iters) == int(stats["iters"]) < 4000
        assert float(err) <= 1e-5


class TestResidentStepped:
    """LaneState chunk advance: resident chunks == streamed chunks."""

    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=1e-3)

    def _pool(self, L=4, M=28, N=130, seed=5, cfg=None):
        cfg = cfg or self.CFG
        K, a, b = make_stack(L, M, N, seed=seed)
        st = ops.make_lane_state(L, M, N, cfg)
        return ops.lane_admit(st, jnp.arange(L), K, a, b)

    @pytest.mark.parametrize("flavor", IMPLS)
    @pytest.mark.parametrize("tol", [None, 1e-3])
    def test_matches_streamed_stepped(self, flavor, tol):
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=30, tol=tol)
        st_s = st_r = self._pool(cfg=cfg)
        interpret = True if flavor == "kernel" else None
        for _ in range(10):
            st_s = ops.solve_fused_stepped(st_s, 4, cfg, impl="jnp")
            st_r = ops.solve_fused_stepped_resident(
                st_r, 4, cfg, impl=flavor, interpret=interpret)
        np.testing.assert_array_equal(np.asarray(st_r.iters),
                                      np.asarray(st_s.iters))
        np.testing.assert_array_equal(np.asarray(st_r.converged),
                                      np.asarray(st_s.converged))
        np.testing.assert_allclose(np.asarray(st_r.P), np.asarray(st_s.P),
                                   rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(np.asarray(st_r.frow),
                                   np.asarray(st_s.frow),
                                   rtol=1e-6, atol=1e-9)

    def test_chunk_boundary_invariance(self):
        """A lane's answer must not depend on the chunking — including a
        lane that converges mid-chunk and one that is inactive."""
        st0 = self._pool()
        st0 = ops.lane_evict(st0, jnp.int32(2))  # one free lane in the pool
        fine = coarse = st0
        for _ in range(30):
            fine = ops.solve_fused_stepped_resident(
                fine, 1, self.CFG, impl="kernel", interpret=True)
        for _ in range(5):
            coarse = ops.solve_fused_stepped_resident(
                coarse, 6, self.CFG, impl="kernel", interpret=True)
        np.testing.assert_array_equal(np.asarray(fine.iters),
                                      np.asarray(coarse.iters))
        np.testing.assert_allclose(np.asarray(fine.P), np.asarray(coarse.P),
                                   rtol=1e-7, atol=1e-10)
        # the freed lane stayed zero and ran no iterations
        assert not np.asarray(fine.active)[2]
        assert np.asarray(fine.iters)[2] == 0
        assert np.abs(np.asarray(fine.P[2])).max() == 0.0

    def test_finished_bf16_lane_roundtrips_bit_exact(self):
        """The per-chunk up/downcast must be the identity for lanes that
        run zero iterations, whatever the storage dtype — a frozen bf16
        tile crossing a chunk boundary must not pick up a re-rounding."""
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=10, tol=1e-2,
                        dtype=jnp.bfloat16)
        st = self._pool(cfg=cfg)
        for _ in range(10):
            st = ops.solve_fused_stepped_resident(
                st, 5, cfg, impl="kernel", interpret=True)
        done = np.asarray(ops.lane_done(st, cfg.num_iters))
        assert done.all()  # every lane finished: converged or at the cap
        before = np.asarray(st.P).copy()
        st2 = ops.solve_fused_stepped_resident(
            st, 3, cfg, impl="kernel", interpret=True)
        np.testing.assert_array_equal(np.asarray(st2.P), before)
        np.testing.assert_array_equal(np.asarray(st2.iters),
                                      np.asarray(st.iters))


class TestDispatch:
    """resident_fits boundary + impl='auto' routing."""

    CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=2)

    def test_fits_boundary_exact(self):
        # fp32 model: Mp*Np*(2*4 + 2*4) + vectors <= 32 MiB. At Np = 1024
        # the largest fitting Mp is 2048 minus the vector overhead rows.
        assert ops.resident_fits(2040, 1024, self.CFG)
        assert not ops.resident_fits(2056, 1024, self.CFG)
        # bf16 storage earns more rows at the same budget (12 B/elt)
        assert ops.resident_fits(2720, 1024, self.CFG,
                                 storage_dtype=jnp.bfloat16)
        assert not ops.resident_fits(2736, 1024, self.CFG,
                                     storage_dtype=jnp.bfloat16)
        # the serving bucket shapes the tier was built for are way inside
        assert ops.resident_fits(256, 384, self.CFG)
        assert ops.resident_fits(256, 384, self.CFG,
                                 storage_dtype=jnp.bfloat16)

    def test_auto_routes_over_budget_problem_to_streamed(self):
        """A problem just over budget must dispatch streamed — and still
        produce the right answer."""
        M, N = 2056, 1024  # just over the fp32 boundary above
        rng = np.random.default_rng(7)
        K = jnp.asarray(rng.uniform(0.1, 1.0, (1, M, N)), jnp.float32)
        a = jnp.asarray(rng.uniform(0.5, 1.5, (1, M)), jnp.float32)
        b = jnp.asarray(rng.uniform(0.5, 1.5, (1, N)), jnp.float32)
        ops.reset_dispatch_stats()
        P_auto, _ = ops.solve_fused_batched(K, a, b, self.CFG, impl="auto")
        assert ops.dispatch_stats() == {"resident": 0, "streamed": 1}
        P_jnp, _ = ops.solve_fused_batched(K, a, b, self.CFG, impl="jnp")
        np.testing.assert_allclose(np.asarray(P_auto), np.asarray(P_jnp),
                                   rtol=1e-6, atol=1e-9)

    def test_auto_routes_fitting_problem_to_resident(self):
        K, a, b = make_stack(2, 24, 130, seed=8)
        ops.reset_dispatch_stats()
        P_auto, cs_auto = ops.solve_fused_batched(K, a, b, self.CFG,
                                                  impl="auto")
        assert ops.dispatch_stats() == {"resident": 1, "streamed": 0}
        P_jnp, cs_jnp = ops.solve_fused_batched(K, a, b, self.CFG,
                                                impl="jnp")
        np.testing.assert_allclose(np.asarray(P_auto), np.asarray(P_jnp),
                                   rtol=1e-6, atol=1e-9)
        # single-problem entry point routes too
        ops.reset_dispatch_stats()
        P1, _ = ops.solve_fused(K[0], a[0], b[0], self.CFG, impl="auto")
        assert ops.dispatch_stats()["resident"] == 1
        np.testing.assert_allclose(np.asarray(P1), np.asarray(P_jnp[0]),
                                   rtol=1e-6, atol=1e-9)

    def test_auto_over_budget_keeps_tol_semantics(self):
        """solve_fused(impl='auto') must honor cfg.tol on BOTH sides of
        the dispatch boundary — the streamed fallback goes through the
        per-lane early-exit path, not the legacy fixed-iteration loop."""
        M, N = 2056, 1024
        rng = np.random.default_rng(11)
        K = jnp.asarray(rng.uniform(0.1, 1.0, (M, N)), jnp.float32)
        a = jnp.asarray(rng.uniform(0.5, 1.5, M), jnp.float32)
        b = jnp.asarray(rng.uniform(0.5, 1.5, N), jnp.float32)
        cfg = UOTConfig(reg=0.1, reg_m=1.0, num_iters=50, tol=1e-2)
        P_auto, _ = ops.solve_fused(K, a, b, cfg, impl="auto")
        stats = sinkhorn_uot_fused(K, a, b, cfg)[1]
        assert int(stats["iters"]) < 50  # tol actually fires here
        P_ref, _ = ops.solve_fused_batched(K[None], a[None], b[None], cfg,
                                           impl="jnp")
        np.testing.assert_allclose(np.asarray(P_auto), np.asarray(P_ref[0]),
                                   rtol=1e-6, atol=1e-9)

    def test_explicit_resident_over_budget_raises(self):
        K = jnp.zeros((4096, 4096), jnp.float32)
        with pytest.raises(ValueError, match="VMEM budget"):
            ops.solve_fused(K, jnp.ones(4096), jnp.ones(4096), self.CFG,
                            impl="resident")

    def test_stepped_auto_keeps_bf16_pools_streamed(self):
        """Sub-fp32 pools round per iteration on the streamed path; auto
        must not switch them to per-chunk rounding."""
        cfg32 = UOTConfig(reg=0.1, reg_m=1.0, num_iters=8, tol=1e-3)
        cfg16 = UOTConfig(reg=0.1, reg_m=1.0, num_iters=8, tol=1e-3,
                          dtype=jnp.bfloat16)
        st32 = ops.make_lane_state(2, 24, 130, cfg32)
        st16 = ops.make_lane_state(2, 24, 130, cfg16)
        ops.reset_dispatch_stats()
        ops.solve_fused_stepped(st32, 2, cfg32, impl="auto")
        ops.solve_fused_stepped(st16, 2, cfg16, impl="auto")
        assert ops.dispatch_stats() == {"resident": 1, "streamed": 1}

    def test_bucketed_auto_resolves_per_chunk(self):
        K, a, b = make_stack(2, 24, 100, seed=9)
        problems = [(np.asarray(K[i]), np.asarray(a[i]), np.asarray(b[i]))
                    for i in range(2)]
        res_auto = ops.solve_fused_bucketed(problems, self.CFG, impl="auto")
        res_jnp = ops.solve_fused_bucketed(problems, self.CFG, impl="jnp")
        for (Pa, _), (Pj, _) in zip(res_auto, res_jnp):
            np.testing.assert_allclose(Pa, Pj, rtol=1e-6, atol=1e-9)
