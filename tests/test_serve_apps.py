"""Serving engine + UOT applications integration tests."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import UOTConfig
from repro.core.applications import color_transfer, wasserstein_distance
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def tiny_cfg():
    return dataclasses.replace(
        get_arch("granite-3-2b"),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, loss_chunks=2)


class TestServeEngine:
    def test_generate_shapes_and_determinism(self):
        cfg = tiny_cfg()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=2, cache_len=64)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, 256, size=8).astype(np.int32)
                   for _ in range(2)]
        o1 = engine.generate(prompts, max_new_tokens=8)
        o2 = engine.generate(prompts, max_new_tokens=8)
        assert all(len(o) == 8 for o in o1)
        for a, b in zip(o1, o2):
            np.testing.assert_array_equal(a, b)  # greedy = deterministic

    def test_generation_matches_stepwise_forward(self):
        """Engine output == argmax chain from repeated prefill (oracle)."""
        cfg = tiny_cfg()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, batch_size=1, cache_len=64)
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, 256, size=8).astype(np.int32)
        out = engine.generate([prompt], max_new_tokens=4)[0]

        seq = list(prompt)
        oracle = []
        for _ in range(4):
            logits, _ = jax.jit(
                lambda p, b: model.prefill(p, b, cache_len=64))(
                    params, {"tokens": jnp.asarray([seq])})
            nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
            oracle.append(nxt)
            seq.append(nxt)
        assert out.tolist() == oracle


class TestApplications:
    def test_color_transfer_moves_palette(self):
        rng = np.random.default_rng(0)
        src = rng.uniform(0.6, 1.0, size=(128, 3)).astype(np.float32)
        dst = rng.uniform(0.0, 0.4, size=(128, 3)).astype(np.float32)
        mapped, P = color_transfer(jnp.asarray(src), jnp.asarray(dst))
        m = np.asarray(mapped)
        assert np.linalg.norm(m.mean(0) - dst.mean(0)) < \
            np.linalg.norm(src.mean(0) - dst.mean(0)) * 0.2
        assert np.all(np.isfinite(m))

    def test_wasserstein_separates_distributions(self):
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.normal(size=(64, 2)).astype(np.float32))
        Y_near = X + 0.01
        Y_far = X + 3.0
        d_near, _ = wasserstein_distance(X, Y_near)
        d_far, _ = wasserstein_distance(X, Y_far)
        assert float(d_near) < float(d_far)
