"""Measured-performance layer: phase timers, kernel-launch profiling,
the persistent measurement store, measurement-driven dispatch, and the
perf-regression gate.

The dispatch tests exercise the real ``ops`` auto-resolution — a store
claiming streamed is faster must actually flip a resident-eligible
solve to the streamed tier, and an empty store must leave the static
``resident_fits`` verdict untouched.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro import obs as obslib
from repro.core import UOTConfig
from repro.core.predict import measured_seconds_per_iter
from repro.kernels import ops
from repro.obs.profile import cell_key, parse_cell_key
from repro.obs.measure import (MeasurementMismatch, MeasurementStore,
                               MeasuredDispatch, machine_fingerprint)
from repro.serve import UOTScheduler
from repro.cluster import ClusterScheduler
from benchmarks.common import bench_meta, check_payload

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20, tol=1e-3)


def bundle(**kw):
    kw.setdefault("chain", False)
    return obslib.Observability(**kw)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _problem(m, n, seed=0):
    rng = np.random.default_rng(seed)
    K = rng.uniform(0.1, 1.0, size=(m, n)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=m).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=n).astype(np.float32)
    return K, a / a.sum(), b / b.sum() * 1.2


# ---- cell keys -------------------------------------------------------------


class TestCellKey:
    def test_round_trip(self):
        key = cell_key("chunk", 64, 128, 4, "streamed", "implicit",
                       lanes=8, iters=6)
        assert key == "chunk|64x128|s4|streamed|implicit|L8|T6"
        p = parse_cell_key(key)
        assert p == {"kernel": "chunk", "M": 64, "N": 128, "itemsize": 4,
                     "impl": "streamed", "source": "implicit", "lanes": 8,
                     "iters": 6}


# ---- phase timer -----------------------------------------------------------


class TestPhaseTimer:
    def test_nested_total_and_exclusive(self):
        reg = obslib.MetricsRegistry()
        clk = FakeClock()
        ph = obslib.PhaseTimer(reg, clock=clk)
        with ph.phase("outer"):
            clk.t = 1.0
            with ph.phase("inner"):
                clk.t = 3.0
            clk.t = 4.0
        outer = reg.histogram("profile.phase.outer").snapshot()
        outer_self = reg.histogram("profile.phase.outer.self").snapshot()
        inner = reg.histogram("profile.phase.inner").snapshot()
        inner_self = reg.histogram("profile.phase.inner.self").snapshot()
        assert outer["sum"] == pytest.approx(4.0)   # 0 -> 4
        assert inner["sum"] == pytest.approx(2.0)   # 1 -> 3
        # outer exclusive = total minus the nested child
        assert outer_self["sum"] == pytest.approx(2.0)
        assert inner_self["sum"] == pytest.approx(2.0)
        assert outer["count"] == inner["count"] == 1

    def test_exception_still_records(self):
        reg = obslib.MetricsRegistry()
        clk = FakeClock()
        ph = obslib.PhaseTimer(reg, clock=clk)
        with pytest.raises(ValueError):
            with ph.phase("boom"):
                clk.t = 2.0
                raise ValueError("x")
        assert reg.histogram("profile.phase.boom").snapshot()["sum"] == \
            pytest.approx(2.0)

    def test_null_twin(self):
        ph = obslib.NullPhaseTimer()
        assert not ph.enabled
        with ph.phase("anything"):
            pass


# ---- kernel profiler -------------------------------------------------------


class TestKernelProfiler:
    KW = dict(kernel="solve", M=64, N=128, itemsize=4, impl="resident")

    def test_first_call_split_from_steady_state(self):
        reg = obslib.MetricsRegistry()
        prof = obslib.KernelProfiler(reg)
        key = cell_key("solve", 64, 128, 4, "resident")
        prof.observe_launch(seconds=0.5, **self.KW)     # compile call
        prof.observe_launch(seconds=0.010, **self.KW)
        prof.observe_launch(seconds=0.020, **self.KW)
        prof.observe_launch(seconds=0.030, **self.KW)
        # the 500ms compile call must not pollute the steady median
        assert prof.median_us(key) == pytest.approx(20_000.0)
        cells = prof.cells()
        assert cells[key]["count"] == 4
        assert cells[key]["first_us"] == pytest.approx(500_000.0)
        assert reg.histogram("profile.compile." + key).snapshot()[
            "count"] == 1
        assert reg.histogram("profile.kernel." + key).snapshot()[
            "count"] == 3

    def test_median_none_until_steady_sample(self):
        prof = obslib.KernelProfiler()
        key = cell_key("solve", 64, 128, 4, "resident")
        assert prof.median_us(key) is None
        prof.observe_launch(seconds=0.5, **self.KW)
        assert prof.median_us(key) is None              # compile only
        prof.observe_launch(seconds=0.010, **self.KW)
        assert prof.median_us(key) == pytest.approx(10_000.0)

    def test_null_twin(self):
        prof = obslib.NullKernelProfiler()
        prof.observe_launch(kernel="solve", M=1, N=1, itemsize=4,
                            impl="resident", seconds=1.0)
        assert prof.cells() == {}
        assert not prof.enabled


# ---- measurement store -----------------------------------------------------


class TestMeasurementStore:
    def test_ingest_and_round_trip(self, tmp_path):
        prof = obslib.KernelProfiler()
        kw = dict(kernel="chunk", M=64, N=128, itemsize=4, impl="streamed",
                  lanes=4, iters=6)
        prof.observe_launch(seconds=0.5, **kw)
        prof.observe_launch(seconds=0.010, **kw)
        store = MeasurementStore()
        assert store.ingest(prof) == 1
        # idempotent: profiler cells are cumulative, re-ingest replaces
        assert store.ingest(prof) == 1
        path = tmp_path / "measure.json"
        store.save(path)
        loaded = MeasurementStore.load(path)
        key = cell_key("chunk", 64, 128, 4, "streamed", lanes=4, iters=6)
        assert loaded.us_per_call(key) == pytest.approx(10_000.0)
        assert loaded.fingerprint["id"] == machine_fingerprint()["id"]

    def test_foreign_fingerprint_rejected(self, tmp_path):
        fp = dict(machine_fingerprint())
        fp["id"] = "feedfeedfeed"
        store = MeasurementStore(fingerprint=fp)
        store.record(cell_key("solve", 8, 8, 4, "resident"), 100.0, count=3)
        path = tmp_path / "foreign.json"
        store.save(path)
        with pytest.raises(MeasurementMismatch):
            MeasurementStore.load(path)
        loaded = MeasurementStore.load(path, allow_mismatch=True)
        assert loaded.cells

    def test_schema_mismatch_rejected(self, tmp_path):
        path = tmp_path / "old.json"
        path.write_text(json.dumps({"schema_version": 99, "cells": {}}))
        with pytest.raises(MeasurementMismatch):
            MeasurementStore.load(path)

    def test_us_per_lane_iter_normalizes_and_weights(self):
        store = MeasurementStore(fingerprint={"id": "t"})
        # 2 steady samples at 10 us/lane-iter, 1 steady at 20
        store.record(cell_key("chunk", 64, 128, 4, "streamed",
                              lanes=2, iters=5), 100.0, count=3)
        store.record(cell_key("chunk", 64, 128, 4, "streamed",
                              lanes=4, iters=5), 400.0, count=2)
        out = store.us_per_lane_iter(kernel="chunk", M=64, N=128)
        assert out == pytest.approx((2 * 10.0 + 1 * 20.0) / 3)
        # compile-only cells (count=1 -> 0 steady samples) don't count
        store2 = MeasurementStore(fingerprint={"id": "t"})
        store2.record(cell_key("chunk", 64, 128, 4, "streamed"),
                      100.0, count=1)
        assert store2.us_per_lane_iter(kernel="chunk") is None

    def test_achieved_bandwidth(self):
        store = MeasurementStore(fingerprint={"id": "t"})
        key = cell_key("chunk", 64, 128, 4, "streamed", lanes=2, iters=5)
        store.record(key, 100.0, count=3)
        ach = store.achieved()
        nbytes = obslib.chunk_bytes(2, 64, 128, 4, 5, tier="streamed")
        assert ach[key]["modeled_bytes"] == nbytes
        assert ach[key]["achieved_gbps"] == \
            pytest.approx(nbytes / 100e-6 / 1e9)
        assert 0 < ach[key]["measured_roofline_fraction"] < float("inf")


# ---- measurement-driven dispatch -------------------------------------------


def _solve_store(M, N, *, res_us, str_us, itemsize=4, iters=CFG.num_iters):
    store = MeasurementStore(fingerprint={"id": "t"})
    store.record(cell_key("solve", M, N, itemsize, "resident", iters=iters),
                 res_us, count=3)
    store.record(cell_key("solve", M, N, itemsize, "streamed", iters=iters),
                 str_us, count=3)
    return store


class TestMeasuredDispatch:
    def test_advises_faster_tier_or_defers(self):
        adv = MeasuredDispatch(_solve_store(32, 32, res_us=200.0,
                                            str_us=100.0))
        assert adv.advise(M=32, N=32, itemsize=4) == "streamed"
        adv = MeasuredDispatch(_solve_store(32, 32, res_us=100.0,
                                            str_us=200.0))
        assert adv.advise(M=32, N=32, itemsize=4) == "resident"
        # one-sided data -> no opinion
        one = MeasurementStore(fingerprint={"id": "t"})
        one.record(cell_key("solve", 32, 32, 4, "resident"), 100.0, count=3)
        assert MeasuredDispatch(one).advise(M=32, N=32, itemsize=4) is None
        assert MeasuredDispatch(MeasurementStore(
            fingerprint={"id": "t"})).advise(M=32, N=32, itemsize=4) is None

    def test_margin_biases_toward_static(self):
        store = _solve_store(32, 32, res_us=100.0, str_us=80.0)
        assert MeasuredDispatch(store).advise(
            M=32, N=32, itemsize=4) == "streamed"
        # 1.25x faster doesn't clear a 2x margin
        assert MeasuredDispatch(store, margin=2.0).advise(
            M=32, N=32, itemsize=4) == "resident"

    def test_ops_auto_routes_by_measurement(self):
        """The acceptance flip: same call, same shape — the store's
        verdict decides the tier."""
        M = N = 32
        assert ops.resident_fits(M, N, CFG)
        K, a, b = _problem(M, N)
        Ks = jnp.asarray(K)[None], jnp.asarray(a)[None], jnp.asarray(b)[None]

        def solve():
            with ops.dispatch_counters() as counters:
                ops.solve_fused_batched(Ks[0], Ks[1], Ks[2], CFG,
                                        impl="auto", interpret=True)
            return counters

        # no advisor: the static budget says resident
        c = solve()
        assert c == {"resident": 1, "streamed": 0}
        # store says streamed is faster: the same call flips tiers
        slow_res = MeasuredDispatch(
            _solve_store(M, N, res_us=900.0, str_us=100.0))
        with ops.dispatch_advisor(slow_res):
            c = solve()
        assert c == {"resident": 0, "streamed": 1}
        # store agreeing with the static budget keeps resident
        fast_res = MeasuredDispatch(
            _solve_store(M, N, res_us=100.0, str_us=900.0))
        with ops.dispatch_advisor(fast_res):
            c = solve()
        assert c == {"resident": 1, "streamed": 0}
        # an empty store has no opinion: static budget again
        empty = MeasuredDispatch(MeasurementStore(fingerprint={"id": "t"}))
        with ops.dispatch_advisor(empty):
            c = solve()
        assert c == {"resident": 1, "streamed": 0}

    def test_advice_cannot_override_static_semantics(self):
        """A shape over the VMEM budget is streamed no matter what the
        measurements claim — correctness constraints are not advisory."""
        M, N = 2048, 4096
        assert not ops.resident_fits(M, N, CFG)
        lie = MeasuredDispatch(_solve_store(M, N, res_us=1.0, str_us=900.0))
        K, a, b = _problem(M, N)
        with ops.dispatch_advisor(lie), ops.dispatch_counters() as c:
            ops.solve_fused_batched(jnp.asarray(K)[None],
                                    jnp.asarray(a)[None],
                                    jnp.asarray(b)[None], CFG,
                                    impl="auto", interpret=True)
        assert c == {"resident": 0, "streamed": 1}


# ---- measured seconds-per-iter ---------------------------------------------


class TestMeasuredSecondsPerIter:
    def _chunk_store(self, us=120.0, lanes=4, iters=6, M=64, N=128):
        store = MeasurementStore(fingerprint={"id": "t"})
        store.record(cell_key("chunk", M, N, 4, "streamed",
                              lanes=lanes, iters=iters), us, count=3)
        return store

    def test_converts_store_rate(self):
        store = self._chunk_store(us=120.0, lanes=4, iters=6)
        assert measured_seconds_per_iter(store) == \
            pytest.approx(120e-6 / 24)
        assert measured_seconds_per_iter(None) is None
        assert measured_seconds_per_iter(
            MeasurementStore(fingerprint={"id": "t"})) is None

    def test_serve_scheduler_uses_store_before_any_completion(self):
        store = self._chunk_store(us=240.0, lanes=4, iters=6)
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=5,
                             interpret=True, measurements=store)
        assert sched._seconds_per_iter() == pytest.approx(240e-6 / 24)
        # per-bucket lookup falls back to the aggregate for a cold bucket
        assert sched._seconds_per_iter((999, 999)) == \
            pytest.approx(240e-6 / 24)
        # pinned wins over measured: a pinned value asserts units
        pinned = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=5,
                              interpret=True, measurements=store,
                              seconds_per_iter=1.5)
        assert pinned._seconds_per_iter() == 1.5

    def test_cluster_scheduler_uses_store(self):
        store = self._chunk_store(us=240.0, lanes=4, iters=6)
        sched = ClusterScheduler(CFG, num_devices=1, lanes_per_device=2,
                                 chunk_iters=5, interpret=True,
                                 measurements=store)
        assert sched._seconds_per_iter() == pytest.approx(240e-6 / 24)


# ---- scheduler integration -------------------------------------------------


class TestSchedulerProfiling:
    # no tol: every request runs the full 20 iterations = 4 chunks, so
    # the chunk cell gets steady-state samples past its compile call
    CFG_RUN = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20)

    def _drive(self, sched, n=2):
        rids = []
        for i in range(n):
            K, a, b = _problem(12, 16, seed=i)
            rids.append(sched.submit(K, a, b))
        for _ in range(12):
            sched.step()
        for rid in rids:
            sched.poll(rid)
        return sched

    def test_serve_phases_and_cells(self):
        sched = self._drive(UOTScheduler(
            self.CFG_RUN, lanes_per_pool=2, chunk_iters=5, interpret=True,
            obs=bundle()))
        cells = sched.obs.profile.cells()
        assert cells and all(k.startswith("chunk|") for k in cells)
        reg = sched.obs.registry.dump()["histograms"]
        for name in ("serve.evict", "serve.admit", "serve.chunk",
                     "serve.poll"):
            full = f"profile.phase.{name}"
            assert reg[full]["count"] > 0, full
            assert f"{full}.self" in reg
        # ingest -> the store now predicts this scheduler's chunk cost
        store = MeasurementStore()
        assert store.ingest(sched.obs.profile) > 0
        assert measured_seconds_per_iter(store) > 0

    def test_cluster_phases_and_cells(self):
        sched = self._drive(ClusterScheduler(
            self.CFG_RUN, num_devices=1, lanes_per_device=2, chunk_iters=5,
            interpret=True, obs=bundle()))
        assert sched.obs.profile.cells()
        reg = sched.obs.registry.dump()["histograms"]
        for name in ("cluster.prep", "cluster.evict", "cluster.admit",
                     "cluster.gang", "cluster.chunk", "cluster.poll"):
            assert reg[f"profile.phase.{name}"]["count"] > 0, name

    def test_async_cluster_skips_launch_profiling(self):
        # the per-launch sync would destroy the async mode's host/device
        # overlap — phases still record, kernel cells must not
        sched = self._drive(ClusterScheduler(
            self.CFG_RUN, num_devices=1, lanes_per_device=2, chunk_iters=5,
            interpret=True, step_mode="async", obs=bundle()))
        assert sched.obs.profile.cells() == {}
        reg = sched.obs.registry.dump()["histograms"]
        assert reg["profile.phase.cluster.chunk"]["count"] > 0

    def test_cells_roll_up_to_global(self):
        # default (chained) bundles feed the process-global profiler's
        # cells, so OBS_<suite>.json dumps carry measured cells
        obslib.reset_global()
        sched = self._drive(UOTScheduler(
            self.CFG_RUN, lanes_per_pool=2, chunk_iters=5, interpret=True))
        try:
            local = sched.obs.profile.cells()
            global_cells = obslib.get_global().profile.cells()
            assert set(local) <= set(global_cells)
            assert global_cells
        finally:
            obslib.reset_global()

    def test_obs_false_profiles_nothing(self):
        sched = self._drive(UOTScheduler(
            self.CFG_RUN, lanes_per_pool=2, chunk_iters=5, interpret=True,
            obs=False))
        assert not sched.obs.profile.enabled
        assert sched.obs.profile.cells() == {}
        assert not any(k.startswith("profile.")
                       for k in sched.obs.registry.dump()["histograms"])


# ---- perf-regression gate --------------------------------------------------


def _payload(us_by_name, fp_id="same", meta=True):
    p = {"records": [{"name": n, "us_per_call": us, "derived": ""}
                     for n, us in us_by_name.items()]}
    if meta:
        p["meta"] = {"schema_version": 2, "fingerprint": {"id": fp_id}}
    return p


class TestCheckPayload:
    def test_identical_passes(self):
        base = _payload({"a": 1000.0, "b": 2000.0})
        out = check_payload(_payload({"a": 1000.0, "b": 2000.0}), base)
        assert out["status"] == "ok" and out["compared"] == 2

    def test_injected_slowdown_fails(self):
        base = _payload({"a": 1000.0, "b": 2000.0})
        out = check_payload(_payload({"a": 2000.0, "b": 2000.0}), base,
                            threshold=1.3)
        assert out["status"] == "fail"
        assert [f["name"] for f in out["failures"]] == ["a"]
        assert out["failures"][0]["ratio"] == pytest.approx(2.0)

    def test_within_threshold_passes(self):
        base = _payload({"a": 1000.0})
        assert check_payload(_payload({"a": 1250.0}), base,
                             threshold=1.3)["status"] == "ok"

    def test_machine_mismatch_skips(self):
        base = _payload({"a": 1000.0}, fp_id="other")
        out = check_payload(_payload({"a": 9000.0}), base)
        assert out["status"] == "skip"
        assert "fingerprint" in out["reason"]

    def test_missing_meta_skips(self):
        base = _payload({"a": 1000.0}, meta=False)
        assert check_payload(_payload({"a": 9000.0}),
                             base)["status"] == "skip"

    def test_noise_floor_and_sentinels_ignored(self):
        # sub-min_us baselines and non-positive sentinels never fail
        base = _payload({"tiny": 10.0, "neg": -1.0, "big": 1000.0})
        fresh = _payload({"tiny": 90.0, "neg": -1.0, "big": 1100.0})
        out = check_payload(fresh, base, min_us=50.0)
        assert out["status"] == "ok" and out["compared"] == 1


class TestBenchMeta:
    def test_provenance_keys(self):
        meta = bench_meta()
        assert meta["schema_version"] == 2
        assert meta["fingerprint"]["id"] == machine_fingerprint()["id"]
        for k in ("git_sha", "jax", "jaxlib", "backend", "device_kind"):
            assert k in meta
