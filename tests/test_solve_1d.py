"""Exact 1-D (un)balanced OT + sliced UOT (core.solve_1d, geometry.sliced).

Validation strategy (certificate-based — the solver REPORTS its own
accuracy, so the assertions lean on the certificates instead of magic
tolerances):

* balanced: exact parity with an LP oracle (scipy linprog) for p in
  {1, 2}, plan marginal feasibility;
* unbalanced: dual feasibility (f + g <= c everywhere), weak duality,
  the certified gap honest against an entropic reference (dual lower-
  bounds the reference objective universally; primal exceeds it by at
  most a few certified gaps);
* jnp twin: parity with the host path, vmap shape contract;
* sliced: per-slice parity with the host solver, convergence in n_proj
  toward a high-n_proj sliced reference, the statistical-lower-bound
  property vs a dense solve, and the lifted coupling's mass accounting.
"""
import numpy as np
import pytest
import scipy.optimize

import jax.numpy as jnp

from repro.core import UOTConfig, sinkhorn_uot_log
from repro.core.problem import uot_cost
from repro.core.solve_1d import (Plan1D, Solve1DResult, solve_1d,
                                 solve_1d_balanced_np, solve_1d_np,
                                 uot_objective_np)
from repro.geometry.sliced import (lift_coupling_np, sliced_directions,
                                   sliced_uot)


def _random_1d(rng, M, N, imbalance=1.0):
    x = rng.normal(size=M)
    y = rng.normal(size=N) + 0.25
    a = rng.uniform(0.2, 1.0, size=M)
    b = rng.uniform(0.2, 1.0, size=N)
    a /= a.sum()
    b /= b.sum() / imbalance
    return x, a, y, b


def _lp_cost(x, a, y, b, p, cost_scale):
    """Balanced 1-D OT by LP — the oracle the merge must match."""
    M, N = len(x), len(y)
    C = cost_scale * np.abs(x[:, None] - y[None, :]) ** p
    A_eq, b_eq = [], []
    for i in range(M):
        row = np.zeros(M * N)
        row[i * N:(i + 1) * N] = 1.0
        A_eq.append(row)
        b_eq.append(a[i])
    for j in range(N):
        row = np.zeros(M * N)
        row[j::N] = 1.0
        A_eq.append(row)
        b_eq.append(b[j])
    res = scipy.optimize.linprog(C.ravel(), A_eq=np.array(A_eq),
                                 b_eq=np.array(b_eq), bounds=(0, None),
                                 method="highs")
    assert res.status == 0
    return res.fun


class TestBalanced:
    @pytest.mark.parametrize("p", [1, 2])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_lp_parity(self, p, seed):
        rng = np.random.default_rng(seed)
        x, a, y, b = _random_1d(rng, 7, 9)
        plan = solve_1d_balanced_np(x, a, y, b, p=p, cost_scale=1.3)
        ref = _lp_cost(x, a, y, b, p, 1.3)
        assert plan.cost == pytest.approx(ref, abs=1e-7)

    def test_plan_marginals(self):
        rng = np.random.default_rng(3)
        x, a, y, b = _random_1d(rng, 12, 5)
        plan = solve_1d_balanced_np(x, a, y, b)
        ra = np.zeros(12)
        rb = np.zeros(5)
        np.add.at(ra, plan.i, plan.w)
        np.add.at(rb, plan.j, plan.w)
        np.testing.assert_allclose(ra, a, atol=1e-12)
        np.testing.assert_allclose(rb, b, atol=1e-12)

    def test_rho_inf_reduces_to_balanced(self):
        rng = np.random.default_rng(4)
        x, a, y, b = _random_1d(rng, 8, 8)
        res = solve_1d_np(x, a, y, b, rho=float("inf"))
        plan = solve_1d_balanced_np(x, a, y, b)
        assert res.primal == pytest.approx(plan.cost, abs=1e-12)
        assert res.dual <= res.primal + 1e-9


class TestUnbalanced:
    @pytest.mark.parametrize("rho", [0.05, 0.5, 5.0])
    @pytest.mark.parametrize("imbalance", [1.0, 1.6])
    def test_certificates(self, rho, imbalance):
        rng = np.random.default_rng(10)
        x, a, y, b = _random_1d(rng, 14, 11, imbalance)
        res = solve_1d_np(x, a, y, b, rho=rho, n_fw=32)
        # dual feasibility: f + g <= c on every pair
        C = np.abs(x[:, None] - y[None, :]) ** 2
        slack = res.f[:, None] + res.g[None, :] - C
        assert slack.max() <= 1e-7
        # weak duality + a nonnegative certified gap
        assert res.dual <= res.primal + 1e-9
        assert res.gap >= 0.0
        # the delivered plan's true objective IS the reported primal
        P = np.zeros((14, 11))
        np.add.at(P, (res.plan.i, res.plan.j), res.plan.w)
        assert uot_objective_np(P, C, a, b, rho) == pytest.approx(
            res.primal, rel=1e-6, abs=1e-9)

    @pytest.mark.parametrize("rho", [0.1, 1.0])
    def test_vs_entropic_reference(self, rho):
        """The certificate is honest against an independent solver: the
        dual lower-bounds the entropic reference objective (which upper-
        bounds the true optimum), and the primal exceeds the reference
        by at most a few certified gaps."""
        rng = np.random.default_rng(11)
        x, a, y, b = _random_1d(rng, 16, 12, 1.3)
        C = np.abs(x[:, None] - y[None, :]) ** 2
        res = solve_1d_np(x, a, y, b, rho=rho, n_fw=48)
        cfg = UOTConfig(reg=0.01, reg_m=rho, num_iters=3000, tol=1e-9,
                        translation_invariant=True)
        P_ref, _, _ = sinkhorn_uot_log(jnp.asarray(C), jnp.asarray(a),
                                       jnp.asarray(b), cfg)
        ref = uot_objective_np(np.asarray(P_ref), C, a, b, rho)
        scale = max(abs(ref), 1.0)
        assert res.dual <= ref + 1e-6 * scale
        assert res.primal <= ref + max(3.0 * res.gap, 1e-3 * scale)


class TestJnpTwin:
    @pytest.mark.parametrize("rho", [0.2, 2.0])
    def test_parity_with_host(self, rho):
        rng = np.random.default_rng(20)
        x, a, y, b = _random_1d(rng, 10, 13, 1.2)
        out = solve_1d(x, a, y, b, rho, n_fw=24)
        ref = solve_1d_np(x, a, y, b, rho=rho, n_fw=24)
        scale = max(abs(ref.primal), 1e-3)
        # fp32 trajectory vs fp64 trajectory: same envelope up to fp32
        assert float(out["primal"]) == pytest.approx(
            ref.primal, abs=2e-2 * scale)
        assert float(out["dual"]) == pytest.approx(
            ref.dual, abs=2e-2 * scale)
        assert float(out["gap"]) >= 0.0

    def test_vmap_shapes(self):
        import jax
        rng = np.random.default_rng(21)
        M, N, S = 9, 7, 8
        xs = rng.normal(size=(S, M)).astype(np.float32)
        ys = rng.normal(size=(S, N)).astype(np.float32)
        a = np.full(M, 1.0 / M, np.float32)
        b = np.full(N, 1.0 / N, np.float32)

        def one(xi, yi):
            return solve_1d(xi, a, yi, b, 0.5, n_fw=8)

        out = jax.vmap(one)(jnp.asarray(xs), jnp.asarray(ys))
        assert out["primal"].shape == (S,)
        assert out["seg_i"].shape == (S, M + N)
        assert out["seg_w"].shape == (S, M + N)
        assert np.all(np.asarray(out["gap"]) >= 0.0)


class TestSliced:
    def _clouds(self, seed=30, M=24, N=20, d=3):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(M, d))
        y = rng.normal(size=(N, d)) + 0.3
        a = rng.uniform(0.3, 1.0, size=M)
        b = rng.uniform(0.3, 1.0, size=N)
        a /= a.sum()
        b /= b.sum()
        return x, y, a, b

    def test_directions_unit_norm(self):
        theta = np.asarray(sliced_directions(4, 16, seed=1))
        np.testing.assert_allclose(np.linalg.norm(theta, axis=1), 1.0,
                                   atol=1e-6)

    def test_per_slice_parity(self):
        """Each slice of the vmapped launch brackets the same optimum as
        a host fp64 1-D solve of the same projected problem: the two
        certified [dual, primal] intervals must overlap, so the primal
        values differ by at most the sum of the certified gaps (the
        fp32 and fp64 FW *trajectories* may diverge — the certificates
        are what both paths guarantee)."""
        x, y, a, b = self._clouds()
        d = x.shape[1]
        rho = 1.0
        res = sliced_uot(x, y, a, b, rho=rho, n_proj=4, seed=2, n_fw=24)
        theta = np.asarray(sliced_directions(d, 4, seed=2))
        for s in range(4):
            ref = solve_1d_np(x @ theta[s], a, y @ theta[s], b, rho=rho,
                              cost_scale=float(d), n_fw=24)
            scale = max(abs(ref.primal), 1e-3)
            gap_s = res.primal[s] - res.dual[s]
            slack = gap_s + ref.gap + 2e-2 * scale
            # both intervals contain the optimum -> primals are within
            # the combined certified slack, and each dual stays below
            # the other path's primal
            assert abs(res.primal[s] - ref.primal) <= slack
            assert res.dual[s] <= ref.primal + 2e-2 * scale
            assert ref.dual <= res.primal[s] + 2e-2 * scale

    def test_n_proj_convergence(self):
        """More projections -> closer to the many-projection sliced
        value (the estimator converges to the sliced functional)."""
        x, y, a, b = self._clouds(seed=31)
        ref = sliced_uot(x, y, a, b, rho=0.5, n_proj=512, seed=99).cost
        errs = []
        for n_proj in (4, 64):
            got = sliced_uot(x, y, a, b, rho=0.5, n_proj=n_proj,
                             seed=7).cost
            errs.append(abs(got - ref) / abs(ref))
        assert errs[1] < errs[0]
        assert errs[1] < 0.2

    def test_lower_bound_vs_dense(self):
        """mean(dual) is a statistical lower bound on the true UOT cost:
        the projection of the dense optimal plan is feasible per slice
        with identical KL terms."""
        x, y, a, b = self._clouds(seed=32, M=16, N=14)
        rho = 1.0
        C = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
        cfg = UOTConfig(reg=0.02, reg_m=rho, num_iters=2000, tol=1e-8,
                        translation_invariant=True)
        P_ref, _, _ = sinkhorn_uot_log(jnp.asarray(C), jnp.asarray(a),
                                       jnp.asarray(b), cfg)
        dense = uot_objective_np(np.asarray(P_ref), C, a, b, rho)
        res = sliced_uot(x, y, a, b, rho=rho, n_proj=256, seed=5)
        # 4 sigma of slack on the Monte-Carlo estimate of the bound
        assert res.lower_bound <= dense + 4.0 * res.std_err + res.mean_gap

    def test_est_error_and_lift(self):
        x, y, a, b = self._clouds(seed=33)
        res = sliced_uot(x, y, a, b, rho=0.5, n_proj=8, seed=3)
        assert res.est_error >= res.mean_gap >= 0.0
        P = lift_coupling_np(res, x.shape[0], y.shape[0])
        assert P.shape == (x.shape[0], y.shape[0])
        assert np.all(P >= 0.0)
        # lifted mass = mean over slices of each slice's plan mass
        assert P.sum() == pytest.approx(float(res.seg_w.sum()) / 8,
                                        rel=1e-6)
