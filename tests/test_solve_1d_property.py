"""Property tests (hypothesis): the 1-D solver's certificates hold for
ANY instance — dual feasibility, weak duality, LP parity of the balanced
merge, and sliced error shrinking in n_proj.

Seeded deterministic instances of the same properties always run in
tests/test_solve_1d.py; this file widens the search to hypothesis-chosen
supports, weights, and (rho, imbalance) when hypothesis is installed
(mirrors tests/test_faults_property.py's guard).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.solve_1d import (solve_1d_balanced_np, solve_1d_np,
                                 uot_objective_np)
from repro.geometry.sliced import sliced_uot

finite = dict(allow_nan=False, allow_infinity=False)


def _instance(seed, M, N, imbalance):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=M)
    y = rng.normal(size=N)
    a = rng.uniform(0.1, 1.0, size=M)
    b = rng.uniform(0.1, 1.0, size=N)
    a /= a.sum()
    b /= b.sum() / imbalance
    return x, a, y, b


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), M=st.integers(2, 24),
       N=st.integers(2, 24),
       rho=st.floats(0.02, 20.0, **finite),
       imbalance=st.floats(0.3, 3.0, **finite),
       p=st.sampled_from([1, 2]))
def test_certificates_hold_everywhere(seed, M, N, rho, imbalance, p):
    """For ANY instance: dual feasible, weak duality, gap >= 0, and the
    delivered plan's true objective equals the reported primal."""
    x, a, y, b = _instance(seed, M, N, imbalance)
    res = solve_1d_np(x, a, y, b, rho=rho, p=p, n_fw=16)
    C = np.abs(x[:, None] - y[None, :]) ** p
    assert (res.f[:, None] + res.g[None, :] - C).max() <= 1e-6
    assert res.dual <= res.primal + 1e-8
    assert res.gap >= 0.0
    P = np.zeros((M, N))
    np.add.at(P, (res.plan.i, res.plan.j), res.plan.w)
    obj = uot_objective_np(P, C, a, b, rho)
    assert obj == pytest.approx(res.primal, rel=1e-6, abs=1e-8)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), M=st.integers(2, 16),
       N=st.integers(2, 16), p=st.sampled_from([1, 2]))
def test_balanced_monotone_optimal(seed, M, N, p):
    """The quantile-merge plan can never be beaten by a random feasible
    perturbation toward another coupling (exactness spot check without
    an LP per example: optimality against the independent coupling)."""
    x, a, y, b = _instance(seed, M, N, 1.0)
    plan = solve_1d_balanced_np(x, a, y, b, p=p)
    C = np.abs(x[:, None] - y[None, :]) ** p
    indep = np.outer(a, b) / a.sum()
    assert plan.cost <= float((indep * C).sum()) + 1e-9
    # and its marginals are exact
    ra = np.zeros(M)
    rb = np.zeros(N)
    np.add.at(ra, plan.i, plan.w)
    np.add.at(rb, plan.j, plan.w)
    np.testing.assert_allclose(ra, a, atol=1e-10)
    np.testing.assert_allclose(rb, b, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), rho=st.floats(0.1, 5.0, **finite))
def test_sliced_error_shrinks_in_n_proj(seed, rho):
    """The Monte-Carlo half of the sliced label shrinks with more
    projections; the certified half stays a valid gap."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(12, 3))
    y = rng.normal(size=(10, 3))
    a = np.full(12, 1.0 / 12)
    b = np.full(10, 1.0 / 10)
    lo = sliced_uot(x, y, a, b, rho=rho, n_proj=8, seed=seed)
    hi = sliced_uot(x, y, a, b, rho=rho, n_proj=128, seed=seed)
    assert hi.std_err <= lo.std_err + 1e-12
    assert lo.mean_gap >= 0.0 and hi.mean_gap >= 0.0
    assert hi.est_error <= lo.est_error + lo.mean_gap + 1e-9
