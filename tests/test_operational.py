"""Operational telemetry plane: rolling windows, burn-rate SLO alerting,
exporters, and the black-box flight recorder (PR 10).

Everything here runs on injected fake clocks, so windowed deltas, burn
rates, and hysteresis transitions are bit-deterministic. The scheduler
integration tests drive the same DES loops the benches use and assert
the alert-correctness contract end to end: a breach fires the matching
SLO alert with a flight-recorder capture attached, a clean run fires
nothing, and ``obs=False`` swaps in the null plane.
"""
import json
import urllib.request

import numpy as np
import pytest

from repro import obs as obslib
from repro.core import UOTConfig
from repro.obs.registry import MetricsRegistry, percentile_from_state
from repro.obs.windows import NullWindowedAggregator, WindowedAggregator
from repro.obs.slo import (SLO, CounterDelta, CounterRate, CounterRatio,
                           GaugeSeries, HistPercentile, NullSLOMonitor,
                           SLOMonitor, default_slos)
from repro.obs.flight import FlightRecorder, NullFlightRecorder
from repro.obs.export import (Exporter, parse_prometheus_text,
                              prometheus_text, render_dashboard, serve_http,
                              snapshot_delta)
from repro.serve import UOTScheduler
from repro.cluster import ClusterScheduler
from benchmarks.common import make_problem as _common_problem

CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=20, tol=1e-3)


def make_problem(m, n, seed, peak=1.0):
    return _common_problem(m, n, reg=CFG.reg, seed=seed, peak=peak)


def bundle(**kw):
    kw.setdefault("chain", False)
    return obslib.Observability(**kw)


# ---- percentile totality (the 0-/1-observation hardening) ------------------


class TestPercentileFromState:
    BUCKETS = (0.001, 0.01, 0.1, 1.0)

    def test_zero_observations_return_zero_never_nan(self):
        counts = (0, 0, 0, 0, 0)
        for q in (0.0, 50.0, 99.0, 100.0):
            v = percentile_from_state(self.BUCKETS, counts, q)
            assert v == 0.0 and np.isfinite(v)

    def test_one_observation_clamped_inside_its_bucket(self):
        counts = (0, 1, 0, 0, 0)          # one value in (0.001, 0.01]
        for q in (1.0, 50.0, 99.0):
            v = percentile_from_state(self.BUCKETS, counts, q)
            assert 0.001 <= v <= 0.01 and np.isfinite(v)

    def test_one_observation_with_known_extremes_is_exact(self):
        counts = (0, 1, 0, 0, 0)
        v = percentile_from_state(self.BUCKETS, counts, 99.0,
                                  lo=0.004, hi=0.004)
        assert v == 0.004

    def test_overflow_bucket_falls_back_to_hi_or_last_edge(self):
        counts = (0, 0, 0, 0, 3)          # all above the last edge
        assert percentile_from_state(self.BUCKETS, counts, 99.0) == 1.0
        v = percentile_from_state(self.BUCKETS, counts, 99.0, hi=7.5)
        assert 1.0 <= v <= 7.5 and np.isfinite(v)

    def test_matches_cumulative_histogram_estimator(self):
        reg = MetricsRegistry()
        h = reg.histogram("x", buckets=self.BUCKETS)
        rng = np.random.default_rng(0)
        vals = rng.uniform(0.002, 0.5, 200)
        for v in vals:
            h.observe(v)
        counts, _, _ = h.raw()
        for q in (50, 90, 99):
            est = percentile_from_state(self.BUCKETS, counts, q)
            true = np.percentile(vals, q)
            # within one (geometric) bucket of the true order statistic
            lo_i = max(0, int(np.searchsorted(self.BUCKETS, true)) - 1)
            assert est >= self.BUCKETS[lo_i] * 0.999
            assert est <= self.BUCKETS[
                min(len(self.BUCKETS) - 1,
                    int(np.searchsorted(self.BUCKETS, true)) + 1)]

    def test_delta_of_snapshots_is_total(self):
        """The windowed path: subtracting cumulative states stays total
        at every windowed population size (incl. 0 and 1)."""
        reg = MetricsRegistry()
        h = reg.histogram("x", buckets=self.BUCKETS)
        h.observe(0.005)
        s0 = h.raw()
        dc = tuple(a - b for a, b in zip(h.raw()[0], s0[0]))
        assert percentile_from_state(self.BUCKETS, dc, 99.0) == 0.0
        h.observe(0.05)
        dc = tuple(a - b for a, b in zip(h.raw()[0], s0[0]))
        v = percentile_from_state(self.BUCKETS, dc, 99.0)
        assert 0.01 <= v <= 0.1 and np.isfinite(v)


# ---- rolling windows -------------------------------------------------------


class TestWindowedAggregator:
    def _fixture(self, max_window=100.0, max_samples=4096):
        reg = MetricsRegistry()
        t = [0.0]
        agg = WindowedAggregator(reg, clock=lambda: t[0],
                                 max_window=max_window,
                                 max_samples=max_samples)
        return reg, t, agg

    def test_counter_delta_and_rate(self):
        reg, t, agg = self._fixture()
        c = reg.counter("ops")
        c.inc(10)
        t[0] = 10.0
        agg.tick()
        c.inc(5)
        t[0] = 20.0
        agg.tick()
        w = agg.window(10.0)
        assert w.counter_delta("ops") == 5
        assert w.rate("ops") == pytest.approx(0.5)
        # construction-time baseline: pre-first-tick activity is windowed
        assert agg.window(100.0).counter_delta("ops") == 15

    def test_gauge_is_last_value_not_delta(self):
        reg, t, agg = self._fixture()
        g = reg.gauge("depth")
        g.set(3.0)
        t[0] = 10.0
        agg.tick()
        g.set(7.0)
        assert agg.window(10.0).gauge("depth") == 7.0

    def test_histogram_windowed_percentiles(self):
        reg, t, agg = self._fixture()
        h = reg.histogram("lat", buckets=(0.01, 0.1, 1.0))
        h.observe(0.05)
        t[0] = 10.0
        agg.tick()
        for _ in range(20):
            h.observe(0.5)
        t[0] = 20.0
        w = agg.window(10.0)       # only the 0.5s population
        assert w.hist_count("lat") == 20
        assert 0.1 <= w.percentile("lat", 99) <= 1.0
        assert w.hist_mean("lat") == pytest.approx(0.5)
        # quiet window -> empty population -> 0.0, never NaN
        agg.tick()
        t[0] = 30.0
        agg.tick()
        wq = agg.window(5.0)
        assert wq.hist_count("lat") == 0
        assert wq.percentile("lat", 99) == 0.0

    def test_cold_start_span_is_actual_coverage(self):
        reg, t, agg = self._fixture()
        reg.counter("ops").inc(4)
        t[0] = 5.0
        w = agg.window(60.0)       # ring is only 5s old
        assert w.span == pytest.approx(5.0)
        assert w.requested == 60.0
        assert w.rate("ops") == pytest.approx(4 / 5.0)

    def test_pruning_keeps_horizon_baseline(self):
        reg, t, agg = self._fixture(max_window=50.0)
        for i in range(1, 201):
            t[0] = float(i)
            agg.tick()
        # samples older than the horizon are dropped, except one at or
        # before it (the full-width window's baseline)
        assert agg.samples <= 53
        w = agg.window(50.0)
        assert w.span >= 50.0 - 1e-9

    def test_max_samples_hard_cap(self):
        reg, t, agg = self._fixture(max_window=1e9, max_samples=16)
        for i in range(1, 100):
            t[0] = float(i)
            agg.tick()
        assert agg.samples <= 16

    def test_fresh_false_reads_last_tick(self):
        reg, t, agg = self._fixture()
        c = reg.counter("ops")
        t[0] = 10.0
        agg.tick()
        c.inc(3)               # after the tick: invisible to fresh=False
        w_stale = agg.window(10.0, fresh=False)
        w_fresh = agg.window(10.0)
        assert w_stale.counter_delta("ops") == 0
        assert w_fresh.counter_delta("ops") == 3

    def test_dump_shape_and_json(self):
        reg, t, agg = self._fixture()
        reg.counter("ops").inc(2)
        reg.histogram("lat").observe(0.1)
        t[0] = 10.0
        d = agg.window(10.0).dump()
        json.dumps(d)
        assert d["counters"]["ops"]["delta"] == 2
        assert set(d["histograms"]["lat"]) == {"count", "mean", "p50",
                                               "p90", "p99"}

    def test_null_twin(self):
        agg = NullWindowedAggregator()
        assert not agg.enabled and agg.samples == 0
        agg.tick()
        w = agg.window(60.0)
        assert w.counter_delta("x") == 0 and w.rate("x") == 0.0
        assert w.percentile("h", 99) == 0.0


# ---- SLO burn-rate alerting ------------------------------------------------


class _Recorder:
    def __init__(self):
        self.alerts = []

    def __call__(self, alert):
        self.alerts.append(alert)


class TestSLOMonitor:
    def _fixture(self, slos, tracer=None):
        reg = MetricsRegistry()
        t = [0.0]
        agg = WindowedAggregator(reg, clock=lambda: t[0])
        cb = _Recorder()
        mon = SLOMonitor(agg, slos, registry=reg, tracer=tracer,
                         clock=lambda: t[0], on_alert=(cb,))
        return reg, t, agg, mon, cb

    def _round(self, t, agg, mon, dt=1.0):
        t[0] += dt
        agg.tick()
        return mon.evaluate()

    def test_fires_only_when_both_windows_burn(self):
        slo = SLO("miss", objective=0.1, window=60.0,
                  series=CounterRatio("bad", "total"), patience=1)
        reg, t, agg, mon, cb = self._fixture([slo])
        bad, total = reg.counter("bad"), reg.counter("total")
        # sustained breach: 50% miss rate vs 10% objective
        for _ in range(3):
            total.inc(10)
            bad.inc(5)
            self._round(t, agg, mon)
        assert mon.firing() == ["miss"]
        assert mon.fired("miss")
        assert [a.state for a in cb.alerts] == ["firing"]
        a = cb.alerts[0]
        assert a.burn_fast >= 1.0 and a.burn_slow >= 1.0
        assert "miss" in a.describe()

    def test_long_resolved_breach_does_not_fire(self):
        """Slow window still hot, fast window clean -> no alert (the
        multi-window rule's whole point)."""
        slo = SLO("miss", objective=0.1, window=60.0,
                  series=CounterRatio("bad", "total"), patience=1)
        reg, t, agg, mon, cb = self._fixture([slo])
        bad, total = reg.counter("bad"), reg.counter("total")
        total.inc(10)
        bad.inc(8)                        # breach long ago...
        for _ in range(10):               # ...windows advance past it
            t[0] += 1.0
            agg.tick()
        for _ in range(5):                # clean traffic, monitor live
            total.inc(10)
            self._round(t, agg, mon)
        st = mon.states()["miss"]
        assert st["burn_slow"] > 1.0      # slow window never forgot
        assert st["burn_fast"] < 1.0      # but the breach is over
        assert not mon.firing() and not cb.alerts

    def test_patience_hysteresis_and_resolve(self):
        slo = SLO("miss", objective=0.1, window=60.0,
                  series=CounterRatio("bad", "total"), patience=2)
        reg, t, agg, mon, cb = self._fixture([slo])
        bad, total = reg.counter("bad"), reg.counter("total")
        total.inc(10)
        bad.inc(5)
        self._round(t, agg, mon)
        assert not mon.firing()           # 1 hot round < patience=2
        total.inc(10)
        bad.inc(5)
        self._round(t, agg, mon)
        assert mon.firing() == ["miss"]   # 2 consecutive -> fires
        # recovery: clean traffic, fast burn sinks below clear_ratio
        for _ in range(30):
            total.inc(10)
            self._round(t, agg, mon)
        assert not mon.firing()
        assert [a.state for a in cb.alerts] == ["firing", "resolved"]

    def test_min_count_gates_sparse_data(self):
        slo = SLO("miss", objective=0.1, window=60.0,
                  series=CounterRatio("bad", "total"), patience=1,
                  min_count=8)
        reg, t, agg, mon, cb = self._fixture([slo])
        reg.counter("bad").inc(1)
        reg.counter("total").inc(1)       # 100% of ONE request
        for _ in range(3):
            self._round(t, agg, mon)
        assert not mon.firing() and not cb.alerts

    def test_counter_delta_fires_on_first_event(self):
        slo = SLO("quarantine", objective=0.5, window=60.0,
                  series=CounterDelta("quarantines"), patience=1)
        reg, t, agg, mon, cb = self._fixture([slo])
        self._round(t, agg, mon)
        assert not mon.firing()
        reg.counter("quarantines").inc()
        self._round(t, agg, mon)
        assert mon.firing() == ["quarantine"]

    def test_routing_registry_tracer_and_gauges(self):
        tracer = obslib.SpanTracer()
        slo = SLO("miss", objective=0.1, window=60.0,
                  series=CounterRatio("bad", "total"), patience=1)
        reg, t, agg, mon, cb = self._fixture([slo], tracer=tracer)
        reg.counter("bad").inc(5)
        reg.counter("total").inc(10)
        self._round(t, agg, mon)
        assert reg.counter("slo.alerts.firing").value == 1
        assert reg.gauge("slo.miss.firing").value == 1.0
        assert reg.gauge("slo.miss.burn").value > 1.0
        # the alert event rides the control-plane rid -1 and is excluded
        # from the span-loss audit
        ev = [e for e in tracer.events if e["event"] == "alert"]
        assert len(ev) == 1 and ev[0]["rid"] == -1
        assert -1 not in tracer.rids()
        audit = tracer.check_complete()
        assert audit["total"] == 0 and not audit["missing"]

    def test_duplicate_name_rejected_and_bad_objective(self):
        slo = SLO("x", objective=0.1, window=60.0,
                  series=CounterDelta("c"))
        reg, t, agg, mon, cb = self._fixture([slo])
        with pytest.raises(ValueError):
            mon.add(SLO("x", objective=0.2, window=60.0,
                        series=CounterDelta("c")))
        with pytest.raises(ValueError):
            SLO("bad", objective=0.0, window=60.0,
                series=CounterDelta("c"))
        with pytest.raises(ValueError):
            SLO("bad", objective=0.1, window=-1.0,
                series=CounterDelta("c"))

    def test_series_readings(self):
        reg = MetricsRegistry()
        t = [0.0]
        agg = WindowedAggregator(reg, clock=lambda: t[0])
        reg.counter("c").inc(10)
        reg.gauge("g").set(2.5)
        reg.histogram("h", buckets=(0.1, 1.0)).observe(0.5)
        t[0] = 10.0
        w = agg.window(10.0)
        assert CounterRate("c").value(w) == pytest.approx(1.0)
        assert GaugeSeries("g").value(w) == 2.5
        assert 0.1 <= HistPercentile("h", 99).value(w) <= 1.0
        assert CounterRatio("c", "missing").value(w) is None
        assert HistPercentile("missing").value(w) is None

    def test_default_slos_and_null_twin(self):
        slos = default_slos("serve", window=30.0)
        assert {s.name for s in slos} == {"serve_deadline_miss",
                                          "serve_degrade_fraction"}
        assert all(s.window == 30.0 for s in slos)
        null = NullSLOMonitor()
        assert not null.enabled
        assert null.evaluate() == [] and not null.fired("x")
        assert null.dump()["enabled"] is False


# ---- flight recorder -------------------------------------------------------


class TestFlightRecorder:
    def _recorder(self, **kw):
        t = [0.0]
        kw.setdefault("clock", lambda: t[0])
        return t, FlightRecorder(**kw)

    def test_ring_is_bounded(self):
        t, fl = self._recorder(capacity=8)
        for i in range(50):
            fl.record_round(i, queued=i)
        rounds = fl.rounds()
        assert len(rounds) == 8
        assert [r["step"] for r in rounds] == list(range(42, 50))

    def test_notes_attach_to_the_open_round(self):
        t, fl = self._recorder()
        fl.note("place", rid=3, lane=1)
        fl.note("fault", rid=4, tag="nan_payload")
        fl.record_round(0, queued=2)
        fl.record_round(1, queued=1)
        r0, r1 = fl.rounds()
        assert [e["kind"] for e in r0["events"]] == ["place", "fault"]
        assert r1["events"] == []

    def test_dump_freezes_open_notes_and_bounds_history(self):
        t, fl = self._recorder(keep_dumps=2)
        fl.record_round(0, queued=1)
        fl.note("quarantine", device=2)
        d = fl.dump("quarantine", reason="drill")
        assert d.trigger == "quarantine" and d.reason == "drill"
        assert d.rounds[-1].get("open") is True
        assert d.rounds[-1]["events"][0]["kind"] == "quarantine"
        for i in range(5):
            fl.dump(f"t{i}")
        assert len(fl.dumps) == 2
        assert fl.triggered("t") and not fl.triggered("alert:")

    def test_jsonl_roundtrip_and_render(self, tmp_path):
        t, fl = self._recorder()
        fl.note("place", rid=1, lane=0)
        fl.record_round(0, queued=3, in_flight=2, occupancy=0.5)
        fl.note("alert", slo="miss", state="firing")
        fl.record_round(1, queued=1, in_flight=2, occupancy=1.0)
        d = fl.dump("alert:miss", reason="test breach")
        path = tmp_path / "flight.jsonl"
        lines = fl.write_jsonl(path, dump=d)
        assert lines == 1 + len(d.rounds)
        back = FlightRecorder.load_jsonl(path)
        assert back.trigger == "alert:miss"
        assert len(back.rounds) == len(d.rounds)
        assert back.rounds[0]["events"][0]["kind"] == "place"
        text = FlightRecorder.render(back)
        assert "alert:miss" in text and "test breach" in text
        assert "P1" in text and "Amiss" in text      # event glyphs
        with pytest.raises(ValueError):
            bad = tmp_path / "bad.jsonl"
            bad.write_text('{"not": "a header"}\n')
            FlightRecorder.load_jsonl(bad)

    def test_null_twin(self, tmp_path):
        fl = NullFlightRecorder()
        assert not fl.enabled
        fl.note("x")
        fl.record_round(0)
        assert fl.rounds() == [] and fl.dump("t") is None
        assert not fl.triggered("")
        assert fl.write_jsonl(tmp_path / "empty.jsonl") == 0


# ---- exporters -------------------------------------------------------------


class TestExport:
    def _bundle(self):
        obs = bundle(enabled=True)
        reg = obs.registry
        reg.counter("serve.completed").inc(42)
        reg.gauge("serve.occupancy").set(0.75)
        h = reg.histogram("serve.latency_s", buckets=(0.01, 0.1, 1.0))
        for v in (0.05, 0.05, 0.5):
            h.observe(v)
        obs.attach_operational(
            slos=(SLO("miss", objective=0.1, window=60.0,
                      series=CounterRatio("serve.deadline_misses",
                                          "serve.completed")),))
        return obs

    def test_prometheus_text_parses_and_is_cumulative(self):
        obs = self._bundle()
        obs.windows.tick()
        obs.slo.evaluate()
        text = prometheus_text(obs.registry, slo=obs.slo)
        fam = parse_prometheus_text(text)

        def only(name):
            (labels, value), = fam[name]
            assert labels == {}
            return value

        assert only("serve_completed_total") == 42.0
        assert only("serve_occupancy") == 0.75
        # histogram buckets are CUMULATIVE and +Inf equals _count
        bkt = {l["le"]: v for l, v in fam["serve_latency_s_bucket"]}
        assert bkt["0.1"] == 2.0
        assert bkt["+Inf"] == 3.0
        assert only("serve_latency_s_count") == 3.0
        assert only("serve_latency_s_sum") == pytest.approx(0.6)
        # SLO gauges carry the slo label
        assert any(l.get("slo") == "miss" for l, _ in fam["slo_burn_rate"])
        assert any(l.get("slo") == "miss" for l, _ in fam["slo_firing"])

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError):
            parse_prometheus_text("!!! not exposition\n")
        with pytest.raises(ValueError):
            parse_prometheus_text("# HELP only comments\n")

    def test_snapshot_and_delta(self):
        obs = self._bundle()
        exp = obs.exporter
        s0 = exp.snapshot()
        json.dumps(s0, default=str)
        assert s0["enabled"] and "windows" in s0 and "slo" in s0
        obs.registry.counter("serve.completed").inc(8)
        s1 = exp.snapshot()
        d = snapshot_delta(s0, s1)
        assert d["counters"]["serve.completed"] == 8

    def test_http_scrape_endpoint(self):
        obs = self._bundle()
        obs.windows.tick()
        obs.slo.evaluate()
        srv = serve_http(obs.exporter)
        try:
            with urllib.request.urlopen(f"{srv.url}/metrics") as r:
                assert r.status == 200
                assert "text/plain" in r.headers["Content-Type"]
                fam = parse_prometheus_text(r.read().decode())
            assert "serve_completed_total" in fam
            with urllib.request.urlopen(f"{srv.url}/snapshot.json") as r:
                snap = json.loads(r.read().decode())
            assert snap["enabled"] is True
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(f"{srv.url}/nope")
        finally:
            srv.close()

    def test_dashboard_renders(self):
        obs = self._bundle()
        obs.windows.tick()
        obs.slo.evaluate()
        text = render_dashboard(obs.exporter.snapshot())
        assert "operational telemetry" in text
        assert "throughput" in text and "latency" in text

    def test_null_exporter_under_obs_false(self):
        obs = bundle(enabled=False)
        assert not obs.exporter.enabled
        snap = obs.exporter.snapshot()
        assert snap["enabled"] is False
        json.dumps(snap, default=str)


# ---- scheduler integration -------------------------------------------------


def _drive(sched, problems, now, deadline=None):
    rids = [sched.submit(K, a, b, deadline=deadline)
            for K, a, b in problems]
    while sched.pending or sched.in_flight:
        sched.step()
        now[0] += 1e-3
    return rids


class TestServeSchedulerPlane:
    def _problems(self, k=6):
        return [make_problem(12, 14, seed=s) for s in range(k)]

    def test_breach_fires_alert_with_flight_capture(self):
        now = [0.0]
        slos = (SLO("serve_deadline_miss", objective=0.05, window=60.0,
                    series=CounterRatio("serve.deadline_misses",
                                        "serve.deadlined_completed"),
                    patience=1, min_count=1),)
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                             impl="jnp", clock=lambda: now[0],
                             obs=bundle(enabled=True, clock=lambda: now[0]),
                             slos=slos, op_interval=1)
        _drive(sched, self._problems(), now, deadline=1e-9)  # all miss
        assert sched.obs.slo.fired("serve_deadline_miss")
        assert sched.flight.triggered("alert:serve_deadline_miss")
        d = next(dd for dd in sched.flight.dumps
                 if dd.trigger.startswith("alert:"))
        assert d.rounds and d.reason
        # the capture holds real per-round scheduler state
        closed = [r for r in d.rounds if r.get("step") is not None]
        assert all("queued" in r and "occupancy" in r for r in closed)

    def test_clean_run_fires_zero_alerts(self):
        now = [0.0]
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                             impl="jnp", clock=lambda: now[0],
                             obs=bundle(enabled=True, clock=lambda: now[0]),
                             slos=default_slos("serve", window=30.0),
                             op_interval=1)
        _drive(sched, self._problems(), now, deadline=now[0] + 1e6)
        assert not sched.obs.slo.alerts
        assert not sched.flight.triggered("alert:")
        assert sched.obs.windows.samples > 1
        assert len(sched.flight.rounds()) > 0

    def test_obs_false_swaps_in_null_plane(self):
        now = [0.0]
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                             impl="jnp", clock=lambda: now[0], obs=False)
        _drive(sched, self._problems(3), now)
        assert not sched.obs.windows.enabled
        assert not sched.obs.slo.enabled
        assert not sched.flight.enabled
        assert not sched.exporter.enabled
        assert sched.stats()["completed"] == 3

    def test_request_failure_dumps_flight(self):
        now = [0.0]
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                             impl="jnp", clock=lambda: now[0],
                             obs=bundle(enabled=True, clock=lambda: now[0]))
        K, a, b = make_problem(12, 14, seed=0)
        K = np.array(K, copy=True)
        K[3, 4] = np.nan                   # poisons the lane in flight
        sched.submit(K, a, b)
        while sched.pending or sched.in_flight:
            sched.step()
            now[0] += 1e-3
        assert sched.flight.triggered("request_failure"), \
            [d.trigger for d in sched.flight.dumps]

    def test_op_interval_decimation_still_evaluates_on_drain(self):
        now = [0.0]
        sched = UOTScheduler(CFG, lanes_per_pool=2, chunk_iters=4,
                             impl="jnp", clock=lambda: now[0],
                             obs=bundle(enabled=True, clock=lambda: now[0]),
                             slos=(SLO("done", objective=0.5, window=60.0,
                                       series=CounterDelta(
                                           "serve.completed"),
                                       patience=1),),
                             op_interval=1000)
        _drive(sched, self._problems(3), now)
        # interval never hit, but the drained-step evaluation ran
        assert sched.obs.slo.fired("done")

    def test_shared_bundle_keeps_callers_plane(self):
        obs = bundle(enabled=True)
        obs.attach_operational(slos=(SLO(
            "mine", objective=1.0, window=60.0,
            series=CounterDelta("x")),))
        sched = UOTScheduler(CFG, lanes_per_pool=2, impl="jnp", obs=obs)
        assert [s.name for s in sched.obs.slo.slos] == ["mine"]


class TestClusterSchedulerPlane:
    def test_quarantine_dumps_and_alerts(self):
        now = [0.0]
        slos = (SLO("cluster_quarantine", objective=0.5, window=60.0,
                    series=CounterDelta("cluster.devices_quarantined"),
                    patience=1),)
        cs = ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                              chunk_iters=4, impl="jnp",
                              clock=lambda: now[0],
                              obs=bundle(enabled=True,
                                         clock=lambda: now[0]),
                              slos=slos, op_interval=1)
        for s in range(4):
            cs.submit(*make_problem(12, 14, seed=s))
        cs.step()                          # lanes active on both devices
        now[0] += 1e-3
        cs.inject_device_fault(0)
        while cs.pending or cs.in_flight:
            cs.step()
            now[0] += 1e-3
        assert cs.stats()["device_health"][0] == "quarantined"
        assert cs.flight.triggered("quarantine")
        assert cs.obs.slo.fired("cluster_quarantine")
        assert cs.flight.triggered("alert:cluster_quarantine")
        # every request still resolved on the surviving device
        assert cs.stats()["completed"] == 4
        # the quarantine capture carries the injection note
        q = next(d for d in cs.flight.dumps if d.trigger == "quarantine")
        kinds = [e["kind"] for r in q.rounds for e in r.get("events", ())]
        assert "fault" in kinds and "quarantine" in kinds

    def test_exporter_snapshot_covers_cluster_namespace(self):
        now = [0.0]
        cs = ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                              chunk_iters=4, impl="jnp",
                              clock=lambda: now[0],
                              obs=bundle(enabled=True,
                                         clock=lambda: now[0]),
                              slos=default_slos("cluster", window=30.0))
        for s in range(3):
            cs.submit(*make_problem(12, 14, seed=s))
        while cs.pending or cs.in_flight:
            cs.step()
            now[0] += 1e-3
        fam = parse_prometheus_text(cs.exporter.prometheus())
        assert "cluster_completed_total" in fam
        snap = cs.exporter.snapshot()
        json.dumps(snap, default=str)
        assert snap["slo"]["slos"]
