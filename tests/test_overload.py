"""Overload model (repro.serve.overload + both schedulers' predictive
admission): the brownout hysteresis controller, SLO-feasibility refusal
(``InfeasibleDeadline``), the degrade ladder's labeled levels (truncated
Sinkhorn at 1, sliced 1-D at 2), calibrated ``QueueFullError`` backoff
hints, and the shed-accounting regression (window_dropped counts records
trimmed at APPEND time, not only at snapshots).

Everything runs on the jnp impl with fake clocks and a PINNED
seconds_per_iter, so feasibility decisions are deterministic — no wall
time anywhere.
"""
import numpy as np
import pytest

from repro.core import UOTConfig
from repro.serve import (BrownoutController, InfeasibleDeadline,
                         QueueFullError, RequestFailure, UOTScheduler,
                         queue_pressure, submit_with_retry)
from repro.cluster import ClusterScheduler

from benchmarks.common import make_problem


CFG = UOTConfig(reg=0.1, reg_m=1.0, num_iters=40, tol=1e-3)


def _sched(t, **kw):
    kw.setdefault("impl", "jnp")
    kw.setdefault("m_bucket", 32)
    kw.setdefault("n_bucket", 32)
    return UOTScheduler(CFG, clock=lambda: t[0], sleep=lambda s: None, **kw)


def _cluster(t, **kw):
    kw.setdefault("impl", "jnp")
    kw.setdefault("m_bucket", 32)
    kw.setdefault("n_bucket", 32)
    return ClusterScheduler(CFG, num_devices=2, lanes_per_device=2,
                            clock=lambda: t[0], sleep=lambda s: None, **kw)


def _points(seed, M=12, N=10, d=3):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(M, d)).astype(np.float32)
    y = rng.normal(size=(N, d)).astype(np.float32)
    a = rng.uniform(0.5, 1.0, M)
    b = rng.uniform(0.5, 1.0, N)
    return x, y, a / a.sum(), b / b.sum()


class TestBrownoutController:
    def test_hysteresis_ladder(self):
        bc = BrownoutController(high=2.0, low=0.5, patience=3, max_level=2)
        # patience rounds above `high` before stepping up — not one spike
        assert bc.observe(3.0) == 0
        assert bc.observe(3.0) == 0
        assert bc.observe(3.0) == 1
        # mid-band (between low and high) resets both counters
        assert bc.observe(1.0) == 1
        assert bc.observe(3.0) == 1
        assert bc.observe(3.0) == 1
        assert bc.observe(3.0) == 2
        # capped at max_level
        assert bc.observe(3.0) == 2
        # recovery needs `patience` rounds BELOW `low`
        assert bc.observe(0.1) == 2
        assert bc.observe(0.1) == 2
        assert bc.observe(0.1) == 1

    def test_queue_pressure_units(self):
        assert queue_pressure(8, 4) == 2.0
        assert queue_pressure(3, 0) == 3.0   # lane count clamped to 1


class TestFeasibilityAdmission:
    def test_infeasible_deadline_refused_typed(self):
        """With a pinned service-time model, a deadline the prediction
        cannot meet is refused BEFORE queueing — typed, with the
        prediction attached, and the rid resolves via poll."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=10.0,
                   shed_policy="drop")
        K, a, b = make_problem(8, 8, seed=0)
        with pytest.raises(InfeasibleDeadline) as exc:
            s.submit(K, a, b, deadline=t[0] + 0.5)
        err = exc.value
        assert err.reason == "infeasible_deadline"
        assert err.deadline == pytest.approx(0.5)
        assert err.predicted_finish > err.deadline
        assert err.predicted_iters > 0
        # the rid still resolves: a 'rejected' disposition, never pending
        out = s.poll(err.rid)
        assert isinstance(out, RequestFailure) and out.status == "rejected"
        assert s.stats()["admission_infeasible"] == 1
        assert s.pending == 0

    def test_feasible_deadline_admitted_and_served(self):
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=1e-6,
                   shed_policy="drop")
        K, a, b = make_problem(8, 8, seed=1)
        rid = s.submit(K, a, b, deadline=t[0] + 1e6)
        out = s.run()
        assert rid in out

    def test_gate_inert_without_shed_policy(self):
        """shed_policy='none': prediction powers ordering + hints but
        never refuses work (the historical serve-everything contract)."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=10.0)
        K, a, b = make_problem(8, 8, seed=2)
        rid = s.submit(K, a, b, deadline=t[0] + 0.5)   # hopeless, admitted
        assert rid in s.run()
        assert s.stats()["admission_infeasible"] == 0


class TestDegradeLadder:
    def test_level1_truncated_and_labeled(self):
        """An infeasible dense request under shed_policy='degrade' runs
        the truncated budget and carries the truncation-error label."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=10.0,
                   shed_policy="degrade", chunk_iters=4, degrade_iters=4)
        K, a, b = make_problem(8, 8, seed=3)
        rid = s.submit(K, a, b, deadline=t[0] + 0.5)
        out = s.run()
        assert rid in out
        rec = next(r for r in s.request_log if r.rid == rid)
        assert rec.degrade_level == 1
        assert rec.shed == "degraded"
        assert rec.iters <= 4             # the reduced budget, not the cap
        assert rec.est_error is not None and rec.est_error > CFG.tol
        assert s.stats()["degrade_levels"][1] == 1

    def test_level2_sliced_same_round(self):
        """An infeasible POINT request degrades to the sliced 1-D tier:
        completes in the same scheduling round, no lane, certified error
        label, nonneg coupling of the right shape."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=10.0,
                   shed_policy="degrade")
        x, y, a, b = _points(4)
        rid = s.submit_points(x, y, a, b, deadline=t[0] + 0.5)
        out = s.step()
        assert rid in out
        P = out[rid]
        assert P.shape == (12, 10) and np.all(np.isfinite(P))
        assert np.all(P >= 0.0)
        rec = next(r for r in s.request_log if r.rid == rid)
        assert rec.degrade_level == 2 and rec.lane == -1
        assert rec.status == "ok" and rec.converged
        assert rec.est_error is not None and rec.est_error >= 0.0
        assert s.stats()["degrade_levels"][2] == 1

    def test_dense_requests_cap_at_level1(self):
        """No coordinates to project -> the ladder tops out at the
        deepest truncation, never the sliced tier."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=10.0,
                   shed_policy="degrade")
        K, a, b = make_problem(8, 8, seed=5)
        rid = s.submit(K, a, b, deadline=t[0] + 0.5)
        assert rid in s.run()
        rec = next(r for r in s.request_log if r.rid == rid)
        assert rec.degrade_level == 1

    def test_brownout_degrades_new_admissions(self):
        """Sustained queue pressure walks the brownout level up and new
        admissions shed accuracy until the backlog drains."""
        t = [0.0]
        s = _sched(t, predictive=True, shed_policy="degrade",
                   lanes_per_pool=2,
                   brownout=BrownoutController(high=0.5, low=0.1,
                                               patience=1))
        for i in range(8):
            K, a, b = make_problem(8, 8, seed=10 + i)
            s.submit(K, a, b)
        out = s.run()
        assert len(out) == 8
        assert s.brownout.level >= 1 or s.stats()["shed_degraded"] > 0
        degraded = [r for r in s.request_log if r.degrade_level == 1]
        assert degraded and all(r.est_error is not None for r in degraded)


class TestBackpressureHints:
    def test_queue_full_carries_depth_and_hint(self):
        """After one completion calibrates the model, QueueFullError
        carries the observed depth and a positive drain-time hint."""
        t = [0.0]
        s = _sched(t, predictive=True, seconds_per_iter=0.01, max_queue=2)
        K, a, b = make_problem(8, 8, seed=20)
        rid = s.submit(K, a, b)
        assert rid in s.run()             # calibrates _iters_ewma
        s.submit(K, a, b)
        s.submit(K, a, b)
        with pytest.raises(QueueFullError) as exc:
            s.submit(K, a, b)
        assert exc.value.queue_depth == 2
        assert exc.value.retry_after is not None
        assert exc.value.retry_after > 0.0

    def test_uncalibrated_hint_is_none(self):
        t = [0.0]
        s = _sched(t, max_queue=1)
        K, a, b = make_problem(8, 8, seed=21)
        s.submit(K, a, b)
        with pytest.raises(QueueFullError) as exc:
            s.submit(K, a, b)
        assert exc.value.queue_depth == 1
        assert exc.value.retry_after is None

    def test_submit_with_retry_uses_hint_as_base(self):
        """A retry_after hint replaces base_delay as the backoff base;
        without it the historical capped-exponential applies."""

        class _Full:
            def __init__(self, fails, retry_after):
                self.fails, self.retry_after, self.calls = fails, retry_after, 0

            def submit(self):
                self.calls += 1
                if self.calls <= self.fails:
                    raise QueueFullError("full", queue_depth=5,
                                         retry_after=self.retry_after)
                return 42

        delays = []
        sched = _Full(fails=1, retry_after=0.8)
        assert submit_with_retry(sched, sleep=delays.append) == 42
        assert len(delays) == 1 and 0.4 <= delays[0] <= 0.8

        delays.clear()
        sched = _Full(fails=1, retry_after=None)
        assert submit_with_retry(sched, sleep=delays.append,
                                 base_delay=0.05) == 42
        assert len(delays) == 1 and 0.025 <= delays[0] <= 0.05

    def test_submit_with_retry_gives_up(self):
        class _Always:
            def submit(self):
                raise QueueFullError("full", queue_depth=1)

        with pytest.raises(QueueFullError):
            submit_with_retry(_Always(), attempts=3, sleep=lambda d: None)


class TestShedAccountingRegression:
    def test_window_dropped_counts_append_time_trims(self):
        """Regression: shed-drop records land in the telemetry log
        BETWEEN occupancy snapshots — trimming (and the window_dropped
        counter) must happen at append time, or drops silently vanish
        uncounted. Five drops into a 2-record window => 3 counted."""
        t = [0.0]
        s = _sched(t, shed_policy="drop", max_log=2)
        rids = []
        for i in range(5):
            K, a, b = make_problem(8, 8, seed=30 + i)
            rids.append(s.submit(K, a, b, deadline=-1.0))  # already expired
        s.step()
        assert len(s.request_log) == 2
        st = s.stats()
        assert st["shed_dropped"] == 5
        assert st["window_dropped"]["requests"] == 3
        # the disposition store shares the max_log window: the newest
        # drops still resolve, and what fell off is COUNTED, not silent
        assert st["window_dropped"]["dispositions"] == 3
        for rid in rids[-2:]:
            out = s.poll(rid)
            assert isinstance(out, RequestFailure)
            assert out.status == "rejected"


class TestClusterOverload:
    def test_cluster_infeasible_refused(self):
        t = [0.0]
        c = _cluster(t, predictive=True, seconds_per_iter=10.0,
                     shed_policy="drop")
        K, a, b = make_problem(8, 8, seed=40)
        with pytest.raises(InfeasibleDeadline):
            c.submit(K, a, b, deadline=t[0] + 0.5)
        assert c.stats()["admission_infeasible"] == 1

    def test_gang_routed_requests_exempt_from_gate(self):
        """The lane-calibrated service model doesn't describe gang
        solves: a gang-routed request is never feasibility-refused."""
        t = [0.0]
        c = _cluster(t, predictive=True, seconds_per_iter=10.0,
                     shed_policy="drop", lane_budget=lambda m, n: False)
        K, a, b = make_problem(8, 8, seed=41)
        rid = c.submit(K, a, b, deadline=t[0] + 0.5)   # hopeless; admitted
        assert rid >= 0 and c.pending == 1
        assert c.stats()["admission_infeasible"] == 0

    def test_cluster_sliced_route_labeled(self):
        """Cluster level-2 completions are recorded route='sliced',
        device=-1, with the certified error label."""
        t = [0.0]
        c = _cluster(t, predictive=True, seconds_per_iter=10.0,
                     shed_policy="degrade")
        x, y, a, b = _points(42)
        rid = c.submit_points(x, y, a, b, deadline=t[0] + 0.5)
        out = c.step()
        assert rid in out
        rec = next(r for r in c.request_log if r.rid == rid)
        assert rec.route == "sliced" and rec.device == -1
        assert rec.degrade_level == 2
        assert rec.est_error is not None and rec.est_error >= 0.0

    def test_cluster_queue_full_carries_depth(self):
        t = [0.0]
        c = _cluster(t, max_queue=1)
        K, a, b = make_problem(8, 8, seed=43)
        c.submit(K, a, b)
        with pytest.raises(QueueFullError) as exc:
            c.submit(K, a, b)
        assert exc.value.queue_depth == 1
        assert exc.value.retry_after is None
