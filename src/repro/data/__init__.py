from repro.data.pipeline import SyntheticTokenPipeline, make_batch_specs

__all__ = ["SyntheticTokenPipeline", "make_batch_specs"]
