"""Deterministic, seekable, host-shardable synthetic token pipeline.

Properties that matter at cluster scale:
  * **Seekable**: ``batch_at(step)`` is a pure function of (seed, step,
    shard) — restart/resume after failure reproduces the exact stream with
    no state files (the checkpoint only stores the step counter).
  * **Host-sharded**: each data-parallel host generates only its shard
    (``shard_id/num_shards``); no central dispenser, no IO bottleneck.
  * **Structured**: tokens follow a Zipf-ish marginal + a Markov-style
    repetition pattern so the LM loss actually decreases during the
    end-to-end example runs (pure-uniform tokens cannot be learned).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticTokenPipeline:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards

    def _tokens(self, key, batch, length):
        V = self.cfg.vocab_size
        k1, k2, k3 = jax.random.split(key, 3)
        # Zipf-ish marginal via squaring a uniform (cheap, heavy head)
        u = jax.random.uniform(k1, (batch, length))
        base = (u * u * (V - 1)).astype(jnp.int32)
        # repetition: with p=0.3, copy the token 1 step back (learnable)
        rep = jax.random.bernoulli(k2, 0.3, (batch, length))
        shifted = jnp.roll(base, 1, axis=1)
        toks = jnp.where(rep, shifted, base)
        return jnp.clip(toks, 0, V - 1)

    def batch_at(self, step: int):
        """Batch for ``step`` for this shard — pure function, O(1) seek."""
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            self.shard_id)
        B, S = self.shard_batch, self.seq_len

        if cfg.family == "audio":
            K = cfg.num_codebooks
            toks = self._tokens(key, B, (S + 1) * K).reshape(B, K, S + 1)
            return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            S_txt = S - n_img
            toks = self._tokens(key, B, S_txt + 1)
            img = 0.02 * jax.random.normal(
                jax.random.fold_in(key, 7), (B, n_img, cfg.d_model))
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                    "image_embeds": img}
        toks = self._tokens(key, B, S + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     dtype_embeds=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (the dry-run's input_specs; no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            K = cfg.num_codebooks
            b = {"tokens": jax.ShapeDtypeStruct((B, K, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, K, S), i32)}
        elif cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            b = {"tokens": jax.ShapeDtypeStruct((B, S - n_img), i32),
                 "labels": jax.ShapeDtypeStruct((B, S - n_img), i32),
                 "image_embeds": jax.ShapeDtypeStruct((B, n_img, cfg.d_model),
                                                      dtype_embeds)}
        else:
            b = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            b.pop("labels", None)
        return b
    # decode: one new token against a cache of S
    if cfg.family == "audio":
        return {"tokens": jax.ShapeDtypeStruct((B, cfg.num_codebooks, 1), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
