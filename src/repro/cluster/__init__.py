"""Multi-device UOT serving runtime — the fourth serving tier.

``repro.serve`` ends at one device's lane pool; ``repro.core.distributed``
starts at one problem spanning the whole mesh. This package is the layer
between them: live traffic served across EVERY device in a mesh, with the
over-sized tail routed into the distributed gang solvers — one submit API
over both regimes.

* ``lanes`` — ``ClusterLaneState``: per-device ``ops.LaneState`` pools
  stacked along a mesh axis, all advanced in ONE ``shard_map``-ped chunk
  launch (``cluster_stepped``; collective-free — per-lane math never
  crosses devices), with (device, lane)-addressed admit/evict and a
  per-device-loop fallback for 1-chip hosts that doubles as the
  bit-identity oracle.
* ``scheduler`` — ``ClusterScheduler``: the request router. Least-loaded /
  bucket-affinity placement onto device shards, cross-bucket lane sharing
  into wider pools (``share_pools``), per-device backpressure + telemetry
  rolled into cluster-wide ``stats()``, an async double-buffered step loop
  (host admission prep for chunk t+1 overlaps device chunk t), and the
  large-problem escape hatch into ``core.distributed.gang_solve``.

Serving results are placement-, order-, and step-mode-invariant and
bit-identical to the single-device ``UOTScheduler`` (tested on 8 forced
host devices; ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
reproduces the CI mesh on any machine).
"""
from repro.cluster.lanes import (ClusterLaneState, cluster_admit,
                                 cluster_done, cluster_evict, cluster_mesh,
                                 cluster_stepped, make_cluster_lane_state)
from repro.cluster.scheduler import ClusterRequestTelemetry, ClusterScheduler

__all__ = ["ClusterLaneState", "ClusterScheduler",
           "ClusterRequestTelemetry", "cluster_admit", "cluster_done",
           "cluster_evict", "cluster_mesh", "cluster_stepped",
           "make_cluster_lane_state"]
