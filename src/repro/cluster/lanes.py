"""Device-sharded lane pools: every device's solver lanes in one launch.

``repro.serve.scheduler`` advances one device's ``ops.LaneState`` pool per
chunk. This module stacks D such pools along a leading *device* axis into a
``ClusterLaneState`` and advances ALL of them in ONE ``shard_map``-ped
stepped launch: each mesh device holds its own (L, Mp, Np) slice and runs
exactly the single-device chunk program on it, with **zero collectives** —
per-lane math never crosses lanes, so it certainly never crosses devices.
The only cross-device traffic in the whole serving loop is admission
payloads routed to the owning shard and the O(D*L) lifecycle flags the host
reads between chunks.

Correctness contract (what makes a cluster of lane pools serveable at all):
per-lane math is arrival-order / occupancy / placement invariant — a
problem's trajectory is a function of its own (K, a, b) alone — so WHICH
device and lane a request lands on cannot change its result. The
per-device block the shard_map body sees has the same shape and runs the
same ops as a single-device pool of L lanes, making cluster results
bit-identical to the single-device scheduler's (property-tested, and
asserted request-by-request in tests/_cluster_check.py on 8 forced host
devices).

Two advance modes:

* ``cluster_stepped(..., mesh=mesh)`` — the production form: one
  ``shard_map`` launch over the mesh axis advances every device's pool.
* ``cluster_stepped(..., mesh=None)`` — the degenerate/simulation form for
  single-device hosts (and the bit-identity oracle): a Python loop of D
  per-device launches, each *identical* in shape and program to the
  single-device scheduler's pool advance.

``lane_admit``'s ``m_valid`` / ``n_valid`` masking carries over:
``cluster_admit`` records each lane's live extent, so one physical pool can
host lanes of several padded shapes (the router's cross-bucket sharing
path).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.problem import UOTConfig
from repro.kernels import ops


@dataclasses.dataclass
class ClusterLaneState:
    """D stacked lane pools: a ``LaneState`` whose every field carries a
    leading (D,) device axis (P is (D, L, Mp, Np), iters (D, L), ...).

    A registered pytree. With a mesh the leaves are placed sharded along
    the device axis (``make_cluster_lane_state(mesh=...)``), so the
    ``shard_map`` advance touches only device-local bytes; without one the
    leading axis is an ordinary batch dimension (simulation mode).
    """

    lanes: ops.LaneState

    @property
    def num_devices(self) -> int:
        return self.lanes.P.shape[0]

    @property
    def lanes_per_device(self) -> int:
        return self.lanes.P.shape[1]

    def device_state(self, d: int) -> ops.LaneState:
        """Device ``d``'s pool as a plain single-device ``LaneState``."""
        return jax.tree_util.tree_map(lambda x: x[d], self.lanes)


jax.tree_util.register_dataclass(
    ClusterLaneState, data_fields=["lanes"], meta_fields=[])


def cluster_mesh(num_devices: int | None = None,
                 axis: str = "devices") -> Mesh:
    """1-D mesh over the first ``num_devices`` local devices (default all)."""
    n = jax.device_count() if num_devices is None else num_devices
    return jax.make_mesh((n,), (axis,))


def make_cluster_lane_state(num_devices: int, lanes_per_device: int, M: int,
                            N: int, cfg: UOTConfig, *, mesh: Mesh | None = None,
                            axis: str = "devices", block_m: int | None = None,
                            storage_dtype=None) -> ClusterLaneState:
    """Empty D-device pool stack for problems of (padded) shape up to (M, N).

    Built by stacking ``ops.make_lane_state`` D times, so every device's
    slice has exactly the single-device pool's padded shape (the
    bit-identity anchor). With ``mesh`` the stack is placed sharded along
    ``axis`` (one pool slice resident per device).
    """
    st = ops.make_lane_state(lanes_per_device, M, N, cfg, block_m=block_m,
                             storage_dtype=storage_dtype)
    lanes = jax.tree_util.tree_map(
        lambda x: jnp.repeat(x[None], num_devices, axis=0), st)
    if mesh is not None:
        if mesh.shape[axis] != num_devices:
            raise ValueError(f"mesh axis {axis!r} has {mesh.shape[axis]} "
                             f"devices, want {num_devices}")
        sharding = NamedSharding(mesh, P(axis))
        lanes = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sharding), lanes)
    return ClusterLaneState(lanes=lanes)


@jax.jit
def cluster_admit(cstate: ClusterLaneState, device, lane, K: jax.Array,
                  a: jax.Array, b: jax.Array, m_valid=None,
                  n_valid=None) -> ClusterLaneState:
    """Load problem(s) into (device, lane) slot(s) of the stacked pools.

    ``device`` / ``lane`` are traced ints (K (M, N)) or (k,) int vectors
    (K (k, M, N)) — a whole scheduling round's admissions across ALL
    devices land in one update. Payload padding/masking and the
    stored-matrix colsum initialization are shared with ``ops.lane_admit``
    (same helper), so a cluster lane's trajectory is bit-identical to the
    same problem admitted into a single-device pool.
    """
    st = cstate.lanes
    Mp, Np = st.P.shape[2:]
    Kp, ap, bp, mv, nv = ops._pad_admit_payload(Mp, Np, K, a, b, m_valid,
                                                n_valid, st.P.dtype)
    idx = (device, lane)
    return ClusterLaneState(lanes=ops.LaneState(
        P=st.P.at[idx].set(Kp),
        colsum=st.colsum.at[idx].set(Kp.astype(jnp.float32).sum(-2)),
        a=st.a.at[idx].set(ap),
        b=st.b.at[idx].set(bp),
        frow=st.frow.at[idx].set(1.0),
        iters=st.iters.at[idx].set(0),
        converged=st.converged.at[idx].set(False),
        active=st.active.at[idx].set(True),
        m_valid=st.m_valid.at[idx].set(mv),
        n_valid=st.n_valid.at[idx].set(nv),
        healthy=st.healthy.at[idx].set(True)))


@jax.jit
def cluster_evict(cstate: ClusterLaneState, device, lane) -> ClusterLaneState:
    """Free (device, lane) slot(s): zero the problems, drop the flags —
    one update however many lanes retire across however many devices."""
    st = cstate.lanes
    idx = (device, lane)
    return ClusterLaneState(lanes=ops.LaneState(
        P=st.P.at[idx].set(jnp.zeros(st.P.shape[2:], st.P.dtype)),
        colsum=st.colsum.at[idx].set(0.0),
        a=st.a.at[idx].set(0.0),
        b=st.b.at[idx].set(0.0),
        frow=st.frow.at[idx].set(1.0),
        iters=st.iters.at[idx].set(0),
        converged=st.converged.at[idx].set(False),
        active=st.active.at[idx].set(False),
        m_valid=st.m_valid.at[idx].set(0),
        n_valid=st.n_valid.at[idx].set(0),
        healthy=st.healthy.at[idx].set(True)))


def cluster_done(cstate: ClusterLaneState, max_iters: int) -> jax.Array:
    """(D, L) bool: slot holds a finished problem (converged, capped, or
    frozen unhealthy — see ``ops.lane_done``)."""
    return ops.lane_done(cstate.lanes, max_iters)


@jax.jit
def cluster_poison_device(cstate: ClusterLaneState,
                          device) -> ClusterLaneState:
    """Corrupt device ``device``'s entire pool slice with NaN — the
    device-blackout fault model (an HBM/interconnect failure clobbering
    one shard's resident state, while the host-side request payloads stay
    intact). The chaos harness (``repro.serve.faults``) injects through
    this; the lane-health detector then flags every active lane of the
    device in its next chunk, which is the signature
    ``ClusterScheduler`` quarantines on. Inactive lanes' NaNs are inert:
    admission overwrites P/colsum/frow wholesale, so a blacked-out slot
    is clean again the moment it is refilled (tested)."""
    st = cstate.lanes
    nan = jnp.nan
    return ClusterLaneState(lanes=dataclasses.replace(
        st,
        P=st.P.at[device].set(jnp.asarray(nan, st.P.dtype)),
        colsum=st.colsum.at[device].set(nan),
        frow=st.frow.at[device].set(nan)))


@functools.lru_cache(maxsize=None)
def _cluster_stepped_fn(mesh: Mesh, axis: str, n_iters: int, cfg: UOTConfig,
                        block_m, interpret, impl):
    """Compiled one-launch advance of a whole pool stack over ``mesh``.

    The shard_map body squeezes the per-device (1, L, ...) block to a plain
    single-device ``LaneState``, runs the ordinary stepped chunk on it, and
    restores the device dim. No collectives — check_rep is moot, but False
    matches the other shard_map solvers. Cached per (mesh, axis, chunk,
    cfg, flavor): building re-wraps shard_map + jit.
    """

    def advance_block(st: ops.LaneState) -> ops.LaneState:
        sq = jax.tree_util.tree_map(lambda x: jnp.squeeze(x, 0), st)
        out = ops.solve_fused_stepped(sq, n_iters, cfg, block_m=block_m,
                                      interpret=interpret, impl=impl)
        return jax.tree_util.tree_map(lambda x: x[None], out)

    sharded = shard_map(advance_block, mesh=mesh, in_specs=(P(axis),),
                        out_specs=P(axis), check_rep=False)
    return jax.jit(sharded)


def cluster_stepped(cstate: ClusterLaneState, n_iters: int, cfg: UOTConfig,
                    *, mesh: Mesh | None = None, axis: str = "devices",
                    block_m: int | None = None,
                    interpret: bool | None = None,
                    impl: str | None = None) -> ClusterLaneState:
    """Advance every device's lane pool by up to ``n_iters`` iterations.

    With ``mesh``: ONE ``shard_map``-ped launch over ``axis`` — device d
    runs the standard stepped chunk on its own (L, Mp, Np) slice,
    collective-free. Without: a Python loop of D per-device launches whose
    shapes and programs are identical to the single-device scheduler's
    advance (the bit-identity oracle, and the fallback on 1-device hosts).

    ``impl`` semantics match ``ops.solve_fused_stepped`` ('auto' included);
    'auto' is resolved HERE, eagerly and once per call — by the pool's
    padded per-device shape, which is the same on every device — so the
    decision lands in ``ops.dispatch_stats`` once per cluster chunk and the
    compiled shard_map body is specialized to the resolved tier.
    ('kernel' inside shard_map is the TPU path; CPU meshes use 'jnp'.)
    """
    interp = ops._interpret_default(interpret)
    impl_r = ops._impl_default(impl, interp)
    if impl_r in ("auto", "resident"):
        Mp, Np = cstate.lanes.P.shape[2:]
        sdt = cstate.lanes.P.dtype
        if ops._resolve_auto(impl_r, Mp, Np, cfg, sdt, stepped_sdt=sdt):
            impl_r = "resident"
        else:
            impl_r = ops._impl_default(None, interp)
    if mesh is None:
        outs = [
            ops.solve_fused_stepped(cstate.device_state(d), n_iters, cfg,
                                    block_m=block_m, interpret=interpret,
                                    impl=impl_r)
            for d in range(cstate.num_devices)]
        return ClusterLaneState(lanes=jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs))
    if mesh.shape[axis] != cstate.num_devices:
        raise ValueError(f"pool stack has {cstate.num_devices} device "
                         f"slices but mesh axis {axis!r} has "
                         f"{mesh.shape[axis]} devices")
    fn = _cluster_stepped_fn(mesh, axis, n_iters, cfg, block_m, interpret,
                             impl_r)
    return ClusterLaneState(lanes=fn(cstate.lanes))
