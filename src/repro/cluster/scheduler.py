"""Multi-device UOT serving: a request router over sharded lane pools.

``ClusterScheduler`` is the fourth serving tier (see ``repro.serve``'s
ladder): ``UOTScheduler``'s continuous batching, scaled from one device's
lane pool to every device in a mesh, plus an escape hatch into the
row-sharded gang solvers for problems no lane pool can hold. One submit
API covers the whole range — a request is never rejected for its shape.

Architecture, in the order a request experiences it:

* **routing** — ``submit`` classifies by padded bucket shape: problems
  within the lane-pool budget join the (global, EDF-ordered) lane queue;
  over-budget problems join the gang queue and run on
  ``core.distributed.gang_solve`` (the paper's Tianhe-1 row-sharded
  design) instead of being refused. ``submit_points`` ships coordinate
  payloads — O((M+N)*(d+1)) floats, so routing them to ANY device shard
  costs the same handful of bytes; the Gibbs kernel materializes on-device
  at admission exactly as in the single-device scheduler.
* **placement** — at admission the router picks a device shard for each
  request: ``placement='least_loaded'`` balances active lanes across the
  mesh; ``'bucket_affinity'`` packs a bucket's traffic onto the devices
  already serving it (fewer pools per device, warmer reuse), spilling
  least-loaded when the affinity set is full. With ``share_pools=True``
  the affinity path may drop a request into a *wider* existing pool using
  per-lane ``m_valid``/``n_valid`` masking (cross-bucket lane sharing) —
  zero-padding is exact, so the answer is bit-identical either way.
  Placement cannot change results — per-lane math is placement-invariant
  (property-tested) — only latency and memory layout.
* **advance** — each bucket's ``ClusterLaneState`` pool stack advances ALL
  devices' lanes in one ``shard_map``-ped chunk launch
  (``cluster_stepped``); between chunks finished lanes are evicted
  (results returned immediately) and freed slots refilled EDF, exactly the
  single-device loop but with (device, lane) slots.
* **backpressure** — cluster-wide: ``max_queue`` waiting requests raise
  ``QueueFullError``. Per-device: a device at ``device_active_cap`` (or
  with no free lane) refuses placements and the router spills or leaves
  the request queued (``router['placement_stalls']``), so one hot device
  sheds load to the rest of the mesh instead of queueing it privately.
* **telemetry** — per-request ``ClusterRequestTelemetry`` (device + route
  on top of the single-device record), per-device placement/completion
  counters and occupancy, router decision counts, and the scheduler's own
  ``impl='auto'`` dispatch decisions (via ``ops.dispatch_counters`` — the
  per-context counters, so concurrent schedulers don't clobber each
  other) — all rolled up in ``stats()``.

The async double-buffered step loop (``step_mode='async'``): a scheduling
round's *decision-free* host work — EDF presort and payload padding for the
next admissions — runs while the previous chunk is still executing on the
devices, and the ``jax.block_until_ready`` barrier of the sync loop is
deferred to the moment eviction actually reads the chunk's lifecycle flags.
Decisions consume exactly the values the sync loop consumes, so results
and iteration counts are bit-identical between the modes (tested); only
wall-clock overlap differs. ``step_mode='sync'`` is the fallback that
blocks right after each dispatch.

Bit-identity contract (the acceptance property): for any trace, every
request's coupling equals — bit for bit — what a single-device
``UOTScheduler`` returns for the same problem, whatever the placement,
arrival order, chunk interleaving, device count, or step mode
(tests/test_cluster.py in-process, tests/_cluster_check.py on 8 forced
host devices).

Fault containment (on top of ``UOTScheduler``'s ladder — admission
validation, lane-health detection, typed dispositions, chaos hook — all
inherited with the same semantics):

* **device quarantine** — the blackout signature is *every* active lane
  of a device (>= 2 of them) unhealthy in the same round: that is not a
  bad payload, it is bad HARDWARE state (HBM/interconnect corruption of
  one shard — the ``cluster_poison_device`` fault model). The device is
  quarantined: drained (its in-flight requests leave their lanes),
  excluded from all future placement, and surfaced as
  ``stats()['device_health']``. Quarantine is one-way — returning a
  flapping device to service is an operator decision, not a scheduler
  heuristic.
* **drain = requeue-first** — a drained (or individually poisoned)
  request whose host-side payload is intact simply goes back in the
  admission queue (``retries`` 0 -> 1) and lands on a healthy device,
  where its fresh lane solve is bit-identical to the fault-free answer
  (``status='ok'``, ``retries=1``). Only a SECOND corruption of the same
  request escalates to the log-domain tier
  (``status='retried_ok'``/'failed') — so transient device faults cost a
  bounce, not a semantics change, and a poisonous payload (NaN kernel)
  cannot ping-pong between devices forever.
* **all-quarantined fallback** — if no healthy device shard remains, the
  lane queue drains into the gang path (``gang='auto'``), which solves
  per request without lane pools; serving capacity degrades, requests
  still resolve.
* **gang wall-clock timeout** — ``gang_timeout=`` bounds the gang tier's
  latency at solve granularity (a fused launch cannot be preempted
  mid-flight): a breaching solve still delivers its coupling but is
  recorded ``status='timed_out'``, and subsequent gang solves run the
  degraded ``degrade_iters`` budget — coarse answers at bounded latency,
  the ``shed_policy='degrade'`` contract applied to the gang. The gang
  mesh itself is NOT narrowed by quarantine: the blackout model poisons
  lane-pool *state*, which gang solves never read.

Overload model (``predictive=True``; the ``UOTScheduler`` semantics —
see ``repro.serve``'s overload model section — applied to the LANE
route): SLO-feasibility admission (``InfeasibleDeadline`` under
``shed_policy='drop'``, immediate ladder walk under ``'degrade'``),
least-slack admission ordering once the cluster-wide service-time model
calibrates, a brownout controller on total backlog over healthy lane
capacity, and the degrade ladder ending in the host-side sliced 1-D
tier (``route='sliced'``, never occupies a (device, lane) slot). The
feasibility gate never judges gang-routed requests — the lane-
calibrated model does not describe row-sharded gang solves; the gang
tier keeps its latched ``gang_timeout`` degradation instead. A point
request the ladder walked to level 2 is taken by the sliced tier from
EITHER queue (it is route-independent and cheaper than any launch).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obslib
from repro.core.problem import UOTConfig
from repro.core import distributed
from repro.core.health import (InvalidProblemError, escalate_log_solve,
                               validate_problem)
from repro.core.predict import (IterPredictor, estimate_truncation_error,
                                measured_seconds_per_iter)
from repro.geometry import PointCloudGeometry
from repro.geometry.sliced import lift_coupling_np, sliced_uot
from repro.kernels import ops
from repro.serve.overload import (BrownoutController, InfeasibleDeadline,
                                  queue_pressure)
from repro.serve.scheduler import (_COUNTER_NAMES, QueueFullError,
                                   RequestFailure, RequestTelemetry,
                                   ScheduledRequest)
from repro.cluster.lanes import (ClusterLaneState, cluster_admit,
                                 cluster_done, cluster_evict,
                                 cluster_poison_device, cluster_stepped,
                                 make_cluster_lane_state)


@dataclasses.dataclass
class ClusterRequestTelemetry(RequestTelemetry):
    """Per-request record with the cluster placement on top: which device
    shard served the lanes (-1 for gang/sliced/dropped requests) and which
    route the request took ('lane', 'gang', 'sliced' — the level-2
    degrade tier, solved host-side off any lane — or 'dropped')."""

    device: int = -1
    route: str = "lane"


class _ClusterPool:
    """One bucket's device-stacked lane pools + host-side bookkeeping.

    ``requests`` / ``admitted_at`` are keyed by (device, lane) slots. The
    pool may be *wider* than a resident request's own bucket when the
    router shares pools cross-bucket — per-slot valid extents live in the
    device state (``m_valid``/``n_valid``) and in each request's shape.
    """

    def __init__(self, bucket: tuple[int, int], num_devices: int,
                 lanes_per_device: int, cfg: UOTConfig, *, mesh, axis,
                 storage_dtype=None):
        self.bucket = bucket
        self.cfg = cfg
        self.state = make_cluster_lane_state(
            num_devices, lanes_per_device, bucket[0], bucket[1], cfg,
            mesh=mesh, axis=axis, storage_dtype=storage_dtype)
        self.requests: dict[tuple[int, int], ScheduledRequest] = {}
        self.admitted_at: dict[tuple[int, int], float] = {}
        self.idle_steps = 0

    @property
    def num_devices(self) -> int:
        return self.state.num_devices

    @property
    def lanes_per_device(self) -> int:
        return self.state.lanes_per_device

    def free_lanes(self, device: int) -> list[int]:
        return [l for l in range(self.lanes_per_device)
                if (device, l) not in self.requests]

    def device_active(self, device: int) -> int:
        return sum(1 for d, _ in self.requests if d == device)

    @property
    def occupancy(self) -> float:
        return len(self.requests) / (self.num_devices
                                     * self.lanes_per_device)

    def per_device_occupancy(self) -> list[float]:
        return [self.device_active(d) / self.lanes_per_device
                for d in range(self.num_devices)]


class ClusterScheduler:
    """Deadline-aware continuous batching across a device mesh.

    Usage::

        mesh = cluster_mesh()                      # all local devices
        sched = ClusterScheduler(UOTConfig(num_iters=100, tol=1e-4),
                                 mesh=mesh, lanes_per_device=8)
        rid = sched.submit(K, a, b, deadline=now + 0.5)
        big = sched.submit(K_huge, a2, b2)         # -> row-sharded gang
        results = sched.run()                      # {rid: coupling}

    Without a mesh (``num_devices=`` instead) the device axis is simulated
    with per-device launches — same results, no shard_map — which is the
    1-chip fallback and the oracle the mesh path is tested against.

    Constructor knobs beyond ``UOTScheduler``'s: ``placement``
    ('least_loaded' | 'bucket_affinity'), ``share_pools`` (cross-bucket
    lane sharing on the affinity path), ``device_active_cap`` (per-device
    admission ceiling), ``step_mode`` ('sync' | 'async' double-buffered
    loop), and the gang escape hatch (``gang='auto'`` routes lane-budget
    failures to ``core.distributed.gang_solve``; ``lane_budget`` overrides
    the predicate, default ``ops.resident_fits`` on the bucket shape;
    ``gang_per_step`` bounds how many gang solves one round runs).
    """

    def __init__(self, cfg: UOTConfig, *, mesh=None, axis: str = "devices",
                 num_devices: int | None = None, lanes_per_device: int = 8,
                 chunk_iters: int = 4, max_queue: int = 1024,
                 m_bucket: int = 64, n_bucket: int = 128,
                 storage_dtype=None, interpret: bool | None = None,
                 impl: str | None = None, max_log: int = 10_000,
                 max_results: int = 256, pool_idle_ttl: int | None = 100,
                 shed_policy: str = "none", degrade_iters: int | None = None,
                 placement: str = "least_loaded", share_pools: bool = False,
                 device_active_cap: int | None = None,
                 step_mode: str = "sync", gang: str = "auto",
                 gang_per_step: int = 1, gang_overlapped: bool = False,
                 gang_timeout: float | None = None,
                 lane_budget: Callable[[int, int], bool] | None = None,
                 validate: bool = True, retry_escalate: bool = True,
                 escalate_factor: int = 2, fault_injector=None,
                 predictive: bool = False,
                 seconds_per_iter: float | None = None,
                 measurements=None,
                 feasibility_margin: float = 1.0,
                 brownout: "BrownoutController | None" = None,
                 predictor: "IterPredictor | None" = None,
                 sliced_n_proj: int = 32, sliced_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 obs: "obslib.Observability | bool | None" = None,
                 slos=None, op_interval: int = 4):
        if lanes_per_device < 1:
            raise ValueError("lanes_per_device must be >= 1")
        if chunk_iters < 1:
            raise ValueError("chunk_iters must be >= 1")
        if placement not in ("least_loaded", "bucket_affinity"):
            raise ValueError(f"placement must be 'least_loaded' or "
                             f"'bucket_affinity', got {placement!r}")
        if step_mode not in ("sync", "async"):
            raise ValueError(f"step_mode must be 'sync' or 'async', "
                             f"got {step_mode!r}")
        if shed_policy not in ("none", "drop", "degrade"):
            raise ValueError(f"shed_policy must be 'none', 'drop' or "
                             f"'degrade', got {shed_policy!r}")
        if gang not in ("auto", "never"):
            raise ValueError(f"gang must be 'auto' or 'never', got {gang!r}")
        if share_pools and placement != "bucket_affinity":
            # documented scope: cross-bucket sharing is an affinity-path
            # feature (full generalization is a ROADMAP item) — refuse
            # loudly rather than silently sharing under another policy
            raise ValueError("share_pools requires "
                             "placement='bucket_affinity'")
        if mesh is not None:
            if axis not in mesh.shape:
                raise ValueError(f"mesh has no axis {axis!r}")
            mesh_n = mesh.shape[axis]
            if num_devices is not None and num_devices != mesh_n:
                raise ValueError(f"num_devices={num_devices} != mesh axis "
                                 f"size {mesh_n}")
            num_devices = mesh_n
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.num_devices = num_devices or 1
        self.lanes_per_device = lanes_per_device
        self.chunk_iters = chunk_iters
        self.max_queue = max_queue
        self.m_bucket = m_bucket
        self.n_bucket = n_bucket
        self.storage_dtype = storage_dtype
        self.interpret = interpret
        self.impl = impl
        self.max_log = max_log
        self.max_results = max_results
        self.pool_idle_ttl = pool_idle_ttl
        self.shed_policy = shed_policy
        self.degrade_iters = (chunk_iters if degrade_iters is None
                              else degrade_iters)
        self.placement = placement
        self.share_pools = share_pools
        self.device_active_cap = device_active_cap
        self.step_mode = step_mode
        self.gang = gang
        self.gang_per_step = gang_per_step
        self.gang_overlapped = gang_overlapped
        self.gang_timeout = gang_timeout
        # Fault containment (same knobs as UOTScheduler): typed admission
        # validation, the log-domain escalation gate for twice-corrupted
        # requests, and the chaos hook (repro.serve.faults).
        self.validate = validate
        self.retry_escalate = retry_escalate
        self.escalate_factor = escalate_factor
        self.fault_injector = fault_injector
        # Overload model — same semantics as UOTScheduler (see its ctor
        # comment and repro.serve's overload model section): feasibility
        # admission, least-slack EDF, and the degrade ladder on the LANE
        # path. The gang tier keeps its existing expired-shed + latched
        # gang_timeout degradation: the lane-calibrated service-time
        # model does not describe row-sharded gang solves, so the
        # feasibility gate never judges gang-routed requests.
        self.predictive = predictive
        self.feasibility_margin = feasibility_margin
        self.predictor = (predictor if predictor is not None
                          else IterPredictor())
        self.brownout = brownout
        if predictive and brownout is None and shed_policy == "degrade":
            self.brownout = BrownoutController()
        self.sliced_n_proj = sliced_n_proj
        self.sliced_seed = sliced_seed
        self._spi_pinned = seconds_per_iter
        self._spi_ewma: float | None = None
        self._iters_ewma: float | None = None
        # Measured performance (see UOTScheduler's ctor comment): a
        # MeasurementStore feeds the service-time model (pinned >
        # measured > completion EWMA) and makes impl='auto' chunk
        # dispatch measurement-driven via ops.dispatch_advisor.
        self.measurements = measurements
        self._advisor = (obslib.MeasuredDispatch(measurements)
                         if measurements is not None else None)
        self._pending_completed: dict[int, np.ndarray] = {}
        # lane-pool budget: buckets failing it route to the gang. The
        # default is the resident-tier VMEM predicate — a conservative
        # proxy for "small enough to multiplex a lane pool with"; pass
        # your own (Mb, Nb) -> bool to widen or tighten the boundary.
        self._lane_budget = lane_budget or (
            lambda Mb, Nb: ops.resident_fits(
                Mb, Nb, cfg, storage_dtype=storage_dtype))
        self.clock = clock
        self.sleep = sleep
        # Observability bundle (see UOTScheduler / repro.obs): metric
        # names are "cluster.*"; the tracer's place/chunk events carry
        # the device shard, and gang solves get their own span events.
        if obs is None:
            obs = obslib.Observability(clock=clock)
        elif obs is False:
            obs = obslib.Observability(enabled=False, clock=clock,
                                       chain=False)
        self.obs = obs
        # Operational plane (mirrors UOTScheduler): rolling windows,
        # ``slos=`` burn-rate alerting, and the flight recorder, with
        # the cluster's extra dump_on triggers — device quarantine and
        # gang_timeout — wired where those breaches latch.
        if not obs.windows.enabled or slos:
            obs.attach_operational(slos=slos or (), clock=clock,
                                   on_alert=(self._on_alert,))
        self.flight = obs.flight
        self.exporter = obs.exporter
        # window tick + SLO evaluation run every ``op_interval`` rounds
        # (and whenever the scheduler drains): the full-registry
        # snapshot is the plane's only per-round O(metrics) cost, and
        # decimating it keeps the whole plane inside bench_obs's <= 5%
        # bar without losing alerting resolution (burn-rate windows are
        # many rounds wide by construction)
        self.op_interval = max(1, int(op_interval))
        reg = obs.registry
        self._c = {k: reg.counter("cluster." + k)
                   for k in _COUNTER_NAMES + (
                       "requeued", "gang_timeouts", "gang_completed",
                       "devices_quarantined")}
        self._h_wait = reg.histogram("cluster.wait_s")
        self._h_latency = reg.histogram("cluster.latency_s")
        self._h_iters = reg.histogram("cluster.iters",
                                      buckets=obslib.DEFAULT_COUNT_BUCKETS)
        self._g_queued = reg.gauge("cluster.queued")
        self._g_gang_queued = reg.gauge("cluster.gang_queued")
        self._g_in_flight = reg.gauge("cluster.in_flight")
        self._g_occupancy = reg.gauge("cluster.occupancy")

        self._queue: list[ScheduledRequest] = []
        self._gang_queue: list[ScheduledRequest] = []
        self._pools: dict[tuple[int, int], _ClusterPool] = {}
        self._prepped: dict[int, tuple] = {}   # rid -> bucket-padded payload
        self._next_rid = 0
        self._results: dict[int, np.ndarray] = {}
        self._steps = 0
        self.request_log: list[ClusterRequestTelemetry] = []
        self.occupancy_log: list[dict] = []
        # running totals live in ``self._c`` registry counters (exact,
        # survive log trimming, dumped process-wide); the per-device
        # rollup lists and one-way health states stay plain host state
        self._device_placed = [0] * self.num_devices
        self._device_completed = [0] * self.num_devices
        # rid -> RequestFailure, kept apart from the size-bounded coupling
        # store (same rationale as UOTScheduler._dispositions)
        self._dispositions: dict[int, RequestFailure] = {}
        self._gang_degrade = False      # latched by a gang_timeout breach
        # per-device serving state: 'ok' | 'quarantined' (one-way)
        self._device_health = ["ok"] * self.num_devices
        self._router = {k: reg.counter("cluster.router." + k)
                        for k in ("least_loaded", "affinity_hits",
                                  "affinity_spills", "shared_pool",
                                  "placement_stalls", "gang_routed")}
        self._c_dispatch = {k: reg.counter("cluster.dispatch." + k)
                            for k in ("resident", "streamed")}
        # overload-model observability (mirrors "serve.*"; zeros unless
        # predictive admission / the degrade ladder are enabled)
        self._c_infeasible = reg.counter("cluster.admission.infeasible")
        self._c_degrade = {lvl: reg.counter(f"cluster.degrade.l{lvl}")
                           for lvl in (1, 2)}
        self._g_brownout = reg.gauge("cluster.degrade.brownout_level")
        self._h_pred_err = reg.histogram("cluster.predict.rel_err")

    # ---- submission -------------------------------------------------------

    def _check_backpressure(self) -> None:
        depth = len(self._queue) + len(self._gang_queue)
        if depth >= self.max_queue:
            raise QueueFullError(
                f"queue at max_queue={self.max_queue}; retry later",
                queue_depth=depth,
                retry_after=self._retry_after_hint())

    def _log_request(self, rec: ClusterRequestTelemetry) -> None:
        """THE append path for request telemetry: append + trim-and-count
        immediately (see ``UOTScheduler._log_request`` — trimming only at
        the occupancy snapshot missed records appended between steps)."""
        self.request_log.append(rec)
        excess = len(self.request_log) - self.max_log
        if excess > 0:
            self._c["window_dropped_requests"].inc(excess)
            del self.request_log[:excess]

    # ---- service-time model (predictive=True; see UOTScheduler) -----------

    def _healthy_lanes(self) -> int:
        healthy = sum(1 for h in self._device_health if h == "ok")
        return max(1, healthy * self.lanes_per_device)

    def _seconds_per_iter(self, bucket=None) -> float | None:
        """Pinned > measured chunk rate (per-bucket, then aggregate) >
        completion EWMA > None (``UOTScheduler._seconds_per_iter``)."""
        if self._spi_pinned is not None:
            return self._spi_pinned
        if self.measurements is not None:
            M, N = bucket if bucket is not None else (None, None)
            spi = measured_seconds_per_iter(self.measurements, M=M, N=N)
            if spi is None and bucket is not None:
                spi = measured_seconds_per_iter(self.measurements)
            if spi is not None:
                return spi
        return self._spi_ewma

    def _predict_request_iters(self, req: ScheduledRequest) -> float:
        return self.predictor.predict(
            self.cfg, bucket=req.bucket,
            mass_a=float(req.a.sum()), mass_b=float(req.b.sum()))

    def _predicted_service(self, req: ScheduledRequest) -> float | None:
        spi = self._seconds_per_iter(req.bucket)
        if not self.predictive or spi is None:
            return None
        if req.predicted_iters is None:
            req.predicted_iters = self._predict_request_iters(req)
        return req.predicted_iters * spi

    def _retry_after_hint(self) -> float | None:
        spi = self._seconds_per_iter()
        if (not self.predictive or spi is None
                or self._iters_ewma is None):
            return None
        depth = len(self._queue) + len(self._gang_queue)
        return (depth * self._iters_ewma * spi) / self._healthy_lanes()

    def _feasibility_gate(self, req: ScheduledRequest, now: float,
                          rid: int) -> None:
        """Refuse or degrade a LANE-route request whose SLO is already
        unmeetable (``UOTScheduler._feasibility_gate`` semantics). Gang-
        routed requests are exempt: the lane-calibrated service model
        does not describe row-sharded gang solves."""
        if (not self.predictive or req.deadline is None
                or self.shed_policy == "none"):
            return
        if self.gang == "auto" and not self._lane_budget(*req.bucket):
            return
        service = self._predicted_service(req)
        if service is None:
            return
        finish = now + self.feasibility_margin * service
        if finish <= req.deadline:
            return
        if self.shed_policy == "drop":
            self._c_infeasible.inc()
            self.obs.tracer.emit(rid, "shed", policy="infeasible",
                                 predicted_finish=finish,
                                 deadline=req.deadline)
            err = InfeasibleDeadline(
                f"request {rid} cannot meet its deadline: predicted "
                f"finish {finish:.4f} > deadline {req.deadline:.4f} "
                f"(predicted {req.predicted_iters:.0f} iters)",
                rid=rid, deadline=req.deadline, predicted_finish=finish,
                predicted_iters=req.predicted_iters)
            self._reject(rid, req.bucket, req.deadline, err, now)
        self._c_infeasible.inc()
        self._degrade(req, self.max_degrade_level(req))

    def _degrade_if_infeasible(self, req: ScheduledRequest,
                               now: float) -> None:
        """Admission-time feasibility re-check against the REMAINING
        deadline budget (``UOTScheduler._degrade_if_infeasible`` — the
        submit-time gate cannot see queue wait). Lane path only: the
        gang queue never reaches this, preserving the gang exemption."""
        if (self.shed_policy != "degrade" or not self.predictive
                or req.deadline is None or req.degrade_level > 0):
            return
        spi = self._seconds_per_iter()
        service = self._predicted_service(req)
        if spi is None or service is None:
            return
        if now + self.feasibility_margin * service <= req.deadline:
            return
        lvl1 = min(self.cfg.num_iters, self.degrade_iters) * spi
        level = (1 if now + self.feasibility_margin * lvl1 <= req.deadline
                 else self.max_degrade_level(req))
        self._c_infeasible.inc()
        self.obs.tracer.emit(req.rid, "shed", policy="infeasible_wait",
                             level=level)
        self._degrade(req, level)

    def max_degrade_level(self, req: ScheduledRequest) -> int:
        """Level 2 (sliced) needs coordinates to project and a finite
        marginal relaxation; dense/balanced requests top out at level 1."""
        return (2 if req.K is None and np.isfinite(self.cfg.reg_m)
                else 1)

    def _degrade(self, req: ScheduledRequest, level: int) -> None:
        """Apply degrade-ladder ``level`` (idempotent upward — see
        ``UOTScheduler._degrade``)."""
        level = min(level, self.max_degrade_level(req))
        if level <= req.degrade_level:
            return
        req.degrade_level = level
        if req.shed != "degraded":
            req.shed = "degraded"
            self._c["shed_degraded"].inc()
        self._c_degrade[level].inc()
        self.obs.tracer.emit(req.rid, "degrade", level=level)
        self.obs.flight.note("degrade", rid=req.rid, level=level)
        if level == 1:
            req.max_iters = min(self.cfg.num_iters, self.degrade_iters)
            req.est_error = estimate_truncation_error(
                self.cfg, req.max_iters,
                mass_a=float(req.a.sum()), mass_b=float(req.b.sum()))

    def _complete_sliced(self, req: ScheduledRequest, now: float) -> None:
        """Finish a level-2 request on the host sliced tier (no lane, no
        device, no M*N compute) and deliver it this scheduling round via
        the pending buffer — ``UOTScheduler._complete_sliced`` with the
        cluster telemetry record (``device=-1, route='sliced'``)."""
        M, N = req.shape
        res = sliced_uot(req.x, req.y, req.a, req.b,
                         rho=float(self.cfg.reg_m), scale=req.scale,
                         n_proj=self.sliced_n_proj, seed=self.sliced_seed)
        P = lift_coupling_np(res, M, N).astype(np.float32)
        req.est_error = res.est_error
        self._pending_completed[req.rid] = self._results[req.rid] = P
        self._trim_results()
        self._record(ClusterRequestTelemetry(
            rid=req.rid, bucket=req.bucket, lane=-1,
            arrival=req.arrival, admitted=now, completed=now,
            iters=0, converged=True, deadline=req.deadline,
            shed="degraded", status="ok", retries=req.retries,
            degrade_level=2, est_error=res.est_error,
            predicted_iters=req.predicted_iters,
            device=-1, route="sliced"))

    def _route(self, req: ScheduledRequest) -> None:
        """Lane pool or gang, by the lane-pool budget of the bucket."""
        if self.gang == "auto" and not self._lane_budget(*req.bucket):
            self._router["gang_routed"].inc()
            self._gang_queue.append(req)
            self.obs.tracer.emit(req.rid, "queue",
                                 depth=len(self._gang_queue), route="gang")
        else:
            self._queue.append(req)
            self.obs.tracer.emit(req.rid, "queue", depth=len(self._queue),
                                 route="lane")

    def _store_disposition(self, failure: RequestFailure) -> None:
        self._dispositions[failure.rid] = failure
        while len(self._dispositions) > self.max_log:
            self._dispositions.pop(next(iter(self._dispositions)))
            self._c["window_dropped_dispositions"].inc()
        fl = self.obs.flight
        if fl.enabled:
            fl.note("failure", rid=failure.rid, status=failure.status)
            if failure.status == "failed":
                # dump_on RequestFailure (see UOTScheduler)
                fl.dump("request_failure",
                        reason=f"rid {failure.rid}: {failure.reason}")

    def _reject(self, rid: int, bucket, deadline,
                err: InvalidProblemError, now: float) -> None:
        """Refused admission: telemetry + a typed disposition so
        ``poll(rid)`` resolves, then re-raise (rid attached)."""
        self._c["rejected"].inc()
        self._log_request(ClusterRequestTelemetry(
            rid=rid, bucket=bucket, lane=-1, arrival=now, admitted=now,
            completed=now, iters=0, converged=False, deadline=deadline,
            status="rejected", device=-1, route="rejected"))
        self.obs.tracer.emit(rid, "complete", status="rejected",
                             reason=err.reason)
        self._store_disposition(RequestFailure(
            rid=rid, status="rejected", reason=f"{err.reason}: {err}"))
        raise err

    def submit(self, K, a, b, *, deadline: float | None = None,
               priority: int = 0) -> int:
        """Enqueue a problem; returns its request id. Problems too large
        for any lane pool are routed to the row-sharded gang solver
        instead of being rejected (``gang='auto'``); ``QueueFullError``
        applies cluster-wide across both queues. ``InvalidProblemError``
        semantics match ``UOTScheduler.submit``."""
        self._check_backpressure()
        K = np.asarray(K)
        a = np.asarray(a)
        b = np.asarray(b)
        rid = self._next_rid
        self._next_rid += 1
        fault = None
        if self.fault_injector is not None:
            K, a, b, fault = self.fault_injector.on_submit(rid, K, a, b)
        M, N = K.shape
        bucket = ops.bucket_shape(M, N, self.m_bucket, self.n_bucket)
        now = self.clock()
        self._c["submitted"].inc()
        self.obs.tracer.emit(rid, "submit", M=M, N=N, bucket=list(bucket),
                             kind="dense", deadline=deadline,
                             priority=priority)
        if self.validate:
            try:
                validate_problem(self.cfg, a, b, shape=(M, N), rid=rid)
            except InvalidProblemError as err:
                self._reject(rid, bucket, deadline, err, now)
        req = ScheduledRequest(
            rid=rid, K=K, a=a, b=b, shape=(M, N), bucket=bucket,
            arrival=now, deadline=deadline, priority=priority, fault=fault)
        self._feasibility_gate(req, now, rid)   # may raise / degrade
        self._route(req)
        return rid

    def submit_points(self, x, y, a, b, *, scale: float = 1.0,
                      deadline: float | None = None,
                      priority: int = 0) -> int:
        """Enqueue a point-cloud problem (squared-Euclidean cost of the
        coordinate clouds). The payload is ``(M + N) * (d + 1)`` floats —
        which is what makes coordinate requests cheap to route to ANY
        device shard: the kernel matrix materializes on the owning device
        at admission, bit-identical to dense submission of
        ``geometry.kernel(cfg.reg)`` (single-device contract, inherited)."""
        self._check_backpressure()
        g = PointCloudGeometry.from_points(x, y, scale=scale)
        M, N = g.shape
        a = np.asarray(a)
        b = np.asarray(b)
        rid = self._next_rid
        self._next_rid += 1
        fault = None
        if self.fault_injector is not None:
            _, a, b, fault = self.fault_injector.on_submit(rid, None, a, b)
        bucket = ops.bucket_shape(M, N, self.m_bucket, self.n_bucket)
        now = self.clock()
        self._c["submitted"].inc()
        self.obs.tracer.emit(rid, "submit", M=M, N=N, bucket=list(bucket),
                             kind="points", deadline=deadline,
                             priority=priority)
        if self.validate:
            try:
                validate_problem(self.cfg, a, b, shape=(M, N), rid=rid)
            except InvalidProblemError as err:
                self._reject(rid, bucket, deadline, err, now)
        req = ScheduledRequest(
            rid=rid, K=None, a=a, b=b, shape=(M, N), bucket=bucket,
            arrival=now, deadline=deadline, priority=priority,
            x=np.asarray(g.x), y=np.asarray(g.y), xn=np.asarray(g.xn),
            yn=np.asarray(g.yn), scale=float(scale), fault=fault)
        self._feasibility_gate(req, now, rid)   # may raise / degrade
        self._route(req)
        return rid

    @property
    def pending(self) -> int:
        """Requests waiting for a lane or a gang slot."""
        return len(self._queue) + len(self._gang_queue)

    @property
    def in_flight(self) -> int:
        """Requests currently occupying lanes."""
        return sum(len(p.requests) for p in self._pools.values())

    def poll(self, rid: int):
        """The terminal disposition of ``rid``: the finished coupling, a
        ``RequestFailure`` (failed / rejected / lost), or None only while
        genuinely pending. Take semantics — handed out exactly once."""
        with self.obs.phases.phase("cluster.poll"):
            out = self._results.pop(rid, None)
            if out is not None:
                self.obs.tracer.emit(rid, "poll", resolved="coupling")
                return out
            out = self._dispositions.pop(rid, None)
            self.obs.tracer.emit(
                rid, "poll",
                resolved="failure" if out is not None else "pending")
            return out

    # ---- the scheduling loop ---------------------------------------------

    def step(self) -> dict[int, np.ndarray]:
        """One scheduling round: prep -> evict -> admit -> gang -> advance.

        Returns this round's completions ``{rid: P (M, N)}`` (host numpy,
        also retained for ``poll``). In the async double-buffered mode the
        previous round's chunk is typically still running on the devices
        when this round's payload prep executes; the first device-blocking
        read is eviction's lifecycle-flag fetch. The sync mode blocks at
        the end of the round instead, right after dispatch.
        """
        if self.fault_injector is not None:
            self.fault_injector.on_step(self)
        if self.brownout is not None:
            self._g_brownout.set(self.brownout.observe(queue_pressure(
                len(self._queue) + len(self._gang_queue),
                self._healthy_lanes())))
        ph = self.obs.phases
        with ph.phase("cluster.prep"):
            self._prep_admissions()
        with ph.phase("cluster.evict"):
            completed = self._evict_finished()
        with ph.phase("cluster.admit"):
            self._admit_queued()
        with ph.phase("cluster.gang"):
            completed.update(self._solve_gang())
        if self._pending_completed:
            # level-2 (sliced) completions produced during admission /
            # gang triage — delivered with this round's evictions
            completed.update(self._pending_completed)
            self._pending_completed.clear()
        with ph.phase("cluster.chunk"):
            self._advance_pools()
            if self.step_mode == "sync":
                for pool in self._pools.values():
                    jax.block_until_ready(pool.state.lanes.P)
        self._steps += 1
        self._snapshot_occupancy()
        self._operational_round()
        return completed

    def _on_alert(self, alert) -> None:
        """SLO alert routing (see UOTScheduler._on_alert): note the
        transition in the black box, freeze it when an alert fires."""
        fl = self.obs.flight
        fl.note("alert", slo=alert.name, state=alert.state,
                burn=alert.burn_fast)
        if alert.state == "firing":
            fl.dump(f"alert:{alert.name}", reason=alert.describe())

    def _operational_round(self) -> None:
        """Per-round operational-plane upkeep (null twins under
        obs=False): flight round with the cluster's device-health
        summary, windows tick, SLO evaluation."""
        obs = self.obs
        if obs.flight.enabled:
            obs.flight.record_round(
                self._steps, queued=len(self._queue),
                gang_queued=len(self._gang_queue),
                in_flight=self.in_flight,
                occupancy=self._g_occupancy.value,
                quarantined=self._device_health.count("quarantined"),
                deadline_misses=self._c["deadline_misses"].value)
        if (self._steps % self.op_interval == 0
                or (not self.in_flight and not self.pending)):
            obs.windows.tick()
            obs.slo.evaluate()

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Step until queues and lanes drain (or ``max_steps`` more steps
        ran); returns all completions."""
        start = self._steps
        out: dict[int, np.ndarray] = {}
        while self.pending or self.in_flight:
            out.update(self.step())
            if max_steps is not None and self._steps - start >= max_steps:
                break
        out.update(self._evict_finished())   # final chunk's completions
        return out

    # ---- internals --------------------------------------------------------

    def _prep_admissions(self) -> None:
        """Decision-free host work for the NEXT admissions: pad each queued
        dense payload to its bucket shape once and cache it. In the async
        loop this runs while the previous chunk is still executing on the
        devices — the 'host admission for chunk t+1 overlaps device chunk
        t' half of the double buffer. Cached payloads are consumed (and
        the cache pruned) at admission; re-padding to a *wider* shared
        pool, when the router goes that way, starts from the cached bucket
        copy."""
        for req in self._queue:
            if req.K is not None and req.rid not in self._prepped:
                Mb, Nb = req.bucket
                M, N = req.shape
                Kp = np.zeros((Mb, Nb), np.float32)
                ap = np.zeros(Mb, np.float32)
                bp = np.zeros(Nb, np.float32)
                Kp[:M, :N] = req.K
                ap[:M] = req.a
                bp[:N] = req.b
                self._prepped[req.rid] = (Kp, ap, bp)

    def _request_kernel(self, req: ScheduledRequest) -> np.ndarray:
        """The request's (M, N) matrix for an off-lane re-solve (dense
        payload or the geometry's Gibbs mirror)."""
        if req.K is not None:
            return req.K
        g = PointCloudGeometry(
            x=jnp.asarray(req.x), y=jnp.asarray(req.y),
            xn=jnp.asarray(req.xn), yn=jnp.asarray(req.yn),
            scale=req.scale)
        return np.asarray(g.kernel(self.cfg.reg))

    def _escalate(self, req: ScheduledRequest):
        """Log-domain retry of a twice-corrupted request (the requeue
        bounce is the FIRST retry — see the module docstring); returns
        ``(P or None, iters)``."""
        if not self.retry_escalate or req.retries >= 2:
            return None, 0
        req.retries += 1
        P, stats, ok = escalate_log_solve(
            self._request_kernel(req), req.a, req.b, self.cfg,
            factor=self.escalate_factor)
        return (P if ok else None), stats["iters"]

    def _requeue(self, req: ScheduledRequest) -> None:
        """Bounce an intact-payload request back through admission: the
        quarantine/poison recovery whose eventual answer is bit-identical
        to the fault-free lane solve (placement invariance). The
        bucket-padded ``_prepped`` cache entry, if any, is still valid."""
        req.retries += 1
        self._c["requeued"].inc()
        self.obs.tracer.emit(req.rid, "requeue", retries=req.retries)
        self.obs.flight.note("requeue", rid=req.rid, retries=req.retries)
        self._queue.append(req)

    def _trim_results(self) -> None:
        while len(self._results) > self.max_results:
            old = next(iter(self._results))
            self._results.pop(old)
            self._c["lost_results"].inc()
            self.obs.tracer.emit(old, "lost")
            self._store_disposition(RequestFailure(
                rid=old, status="lost",
                reason="coupling evicted from the bounded result store "
                       "(max_results) before it was polled"))

    def _scan_device_health(self, flags: dict, completed: dict) -> None:
        """Quarantine devices showing the blackout signature: EVERY active
        lane of the device (>= 2) unhealthy in the same round. A single
        bad lane on an otherwise-fine device is payload/lane poison and is
        handled per-request at eviction; all-lanes-at-once is hardware.
        Quarantined devices are drained (requests bounce back through
        admission) and never receive another placement."""
        active = [0] * self.num_devices
        unhealthy = [0] * self.num_devices
        for bucket, (iters_, conv_, healthy_) in flags.items():
            pool = self._pools[bucket]
            for (d, l) in pool.requests:
                active[d] += 1
                unhealthy[d] += int(not healthy_[d, l])
        for d in range(self.num_devices):
            if (self._device_health[d] == "ok" and active[d] >= 2
                    and unhealthy[d] == active[d]):
                self._device_health[d] = "quarantined"
                self._c["devices_quarantined"].inc()
                fl = self.obs.flight
                if fl.enabled:
                    # dump_on quarantine: the blackout signature is an
                    # incident — capture the rounds that led up to it
                    fl.note("quarantine", device=d, active=active[d])
                    fl.dump("quarantine",
                            reason=f"device {d}: all {active[d]} active "
                                   "lanes unhealthy in one round")
                for bucket in flags:
                    pool = self._pools[bucket]
                    drained = [s for s in pool.requests if s[0] == d]
                    for slot in drained:
                        req = pool.requests.pop(slot)
                        pool.admitted_at.pop(slot)
                        self._c["unhealthy_evictions"].inc()
                        if req.retries == 0:
                            self._requeue(req)
                        else:
                            self._finish_escalated(req, slot,
                                                   pool.bucket,
                                                   completed)
                # no cluster_evict scrub for the drained slots: the whole
                # device slice is already poison and will never be placed
                # to again — scrubbing it would only burn a launch

    def _finish_escalated(self, req: ScheduledRequest, slot, bucket,
                          completed: dict) -> None:
        """Terminal handling for a request past its requeue bounce: one
        log-domain escalation, then a typed failure."""
        d, l = slot
        now = self.clock()
        self.obs.tracer.emit(req.rid, "escalate", retries=req.retries + 1)
        P, n_iters = self._escalate(req)
        if P is not None:
            self._c["retried_ok"].inc()
            completed[req.rid] = self._results[req.rid] = P
            self._trim_results()
            status = "retried_ok"
        else:
            self._c["failed"].inc()
            self._store_disposition(RequestFailure(
                rid=req.rid, status="failed",
                reason="lane state went non-finite twice and the "
                       "log-domain escalation did not recover",
                retries=req.retries))
            status = "failed"
        self._record(ClusterRequestTelemetry(
            rid=req.rid, bucket=bucket, lane=l, arrival=req.arrival,
            admitted=req.arrival, completed=now, iters=n_iters,
            converged=False, deadline=req.deadline, shed=req.shed,
            status=status, retries=req.retries, device=d, route="lane"))

    def _evict_finished(self) -> dict[int, np.ndarray]:
        completed: dict[int, np.ndarray] = {}
        now = self.clock()
        # the first (and in async mode, only) device-blocking read of the
        # in-flight chunk: O(D*L) lifecycle flags per occupied pool
        flags = {
            bucket: (np.asarray(pool.state.lanes.iters),
                     np.asarray(pool.state.lanes.converged),
                     np.asarray(pool.state.lanes.healthy))
            for bucket, pool in self._pools.items() if pool.requests}
        tr = self.obs.tracer
        if tr.enabled:
            # per-request chunk progress (with the serving device), from
            # the host flag copies this pass already fetched — tracing
            # adds zero extra device syncs
            for bucket, (iters_, conv_, healthy_) in flags.items():
                for (d, l), req in self._pools[bucket].requests.items():
                    tr.emit(req.rid, "chunk", lane=l, device=d,
                            iters=int(iters_[d, l]),
                            converged=bool(conv_[d, l]),
                            healthy=bool(healthy_[d, l]))
        # device-level triage first: the blackout signature drains whole
        # devices (requests requeue), so the per-lane loop below only ever
        # sees isolated poison on devices that stay in service
        self._scan_device_health(flags, completed)
        for bucket, (iters, conv, healthy) in flags.items():
            pool = self._pools[bucket]
            finished = [
                slot for slot, req in list(pool.requests.items())
                if not healthy[slot] or conv[slot] or iters[slot] >= (
                    req.max_iters if req.max_iters is not None
                    else self.cfg.num_iters)]
            if not finished:
                continue
            for slot in finished:
                d, l = slot
                req = pool.requests.pop(slot)
                admitted = pool.admitted_at.pop(slot)
                M, N = req.shape
                P = None
                if healthy[slot]:
                    P = np.asarray(pool.state.lanes.P[d, l])[:M, :N].copy()
                    # host-side double check on the one evicted slice:
                    # poison landing after the convergence latch froze the
                    # lane never crosses the detector's window
                    if not np.all(np.isfinite(P)):
                        P = None
                tr.emit(req.rid, "evict", lane=l, device=d,
                        iters=int(iters[slot]), converged=bool(conv[slot]),
                        healthy=bool(healthy[slot] and P is not None))
                if P is None:
                    self._c["unhealthy_evictions"].inc()
                    if req.retries == 0:
                        # intact host payload -> bounce through admission
                        # to a healthy device; the eviction scatter below
                        # scrubs this lane's NaNs out of the pool
                        self._requeue(req)
                        continue
                    self._finish_escalated(req, slot, pool.bucket,
                                           completed)
                    continue
                timed_out = (self.cfg.tol is not None and not conv[slot]
                             and req.max_iters is None)
                self._c["timed_out"].inc(int(timed_out))
                completed[req.rid] = self._results[req.rid] = P
                self._trim_results()
                n_iters = int(iters[slot])
                rec = ClusterRequestTelemetry(
                    rid=req.rid, bucket=pool.bucket, lane=l,
                    arrival=req.arrival, admitted=admitted,
                    completed=now, iters=n_iters,
                    converged=bool(conv[slot]), deadline=req.deadline,
                    shed=req.shed,
                    status="timed_out" if timed_out else "ok",
                    retries=req.retries, device=d, route="lane",
                    degrade_level=req.degrade_level,
                    est_error=req.est_error,
                    predicted_iters=req.predicted_iters)
                self._record(rec)
                self._device_completed[d] += 1
                if (self.predictive and n_iters > 0
                        and req.max_iters is None):
                    # close the control loop (full lane solves only —
                    # truncated budgets would bias the model): feed the
                    # predictor, refine seconds-per-iteration, audit the
                    # prediction's relative error
                    self.predictor.observe(
                        self.cfg, n_iters, bucket=pool.bucket,
                        mass_a=float(req.a.sum()),
                        mass_b=float(req.b.sum()))
                    a_ = 0.25
                    self._iters_ewma = (
                        n_iters if self._iters_ewma is None
                        else self._iters_ewma + a_ * (n_iters
                                                      - self._iters_ewma))
                    dt = (now - admitted) / n_iters
                    if dt > 0.0:
                        self._spi_ewma = (
                            dt if self._spi_ewma is None
                            else self._spi_ewma
                            + a_ * (dt - self._spi_ewma))
                    if req.predicted_iters:
                        self._h_pred_err.observe(
                            abs(req.predicted_iters - n_iters) / n_iters)
            # one pool update for the round's evictions across all
            # devices; indices padded with duplicates -> one jit
            # signature — and the zeroing scrubs poisoned lanes' NaNs
            # off devices that remain in service
            pad = (pool.num_devices * pool.lanes_per_device
                   - len(finished))
            slots = finished + [finished[-1]] * pad
            devs = jnp.asarray([s[0] for s in slots], jnp.int32)
            lns = jnp.asarray([s[1] for s in slots], jnp.int32)
            pool.state = cluster_evict(pool.state, devs, lns)
        return completed

    def inject_lane_fault(self, rid: int) -> bool:
        """Chaos/drill hook: NaN the (device, lane) slot currently holding
        ``rid`` (state corruption with an intact host payload — recovers
        via requeue, bit-identical). False when rid is not in a lane."""
        for pool in self._pools.values():
            for (d, l), req in pool.requests.items():
                if req.rid == rid:
                    st = pool.state.lanes
                    pool.state = ClusterLaneState(
                        lanes=dataclasses.replace(
                            st,
                            P=st.P.at[d, l].set(
                                jnp.asarray(jnp.nan, st.P.dtype)),
                            colsum=st.colsum.at[d, l].set(jnp.nan),
                            frow=st.frow.at[d, l].set(jnp.nan)))
                    return True
        return False

    def inject_device_fault(self, device: int) -> None:
        """Chaos/drill hook: black out one device shard — NaN its entire
        pool-slice state in every pool (``cluster_poison_device``). The
        next eviction round sees every active lane of the device
        unhealthy and quarantines it."""
        self.obs.flight.note("fault", device=device, tag="blackout")
        for pool in self._pools.values():
            pool.state = cluster_poison_device(pool.state, device)

    def _record(self, rec: ClusterRequestTelemetry) -> None:
        """Terminal bookkeeping shared by every SERVED completion path
        (lane eviction, escalation, gang): running counters, latency and
        iteration histograms, and the span's terminal 'complete' event.
        Shed-drops and admission rejections record inline instead — they
        never solved anything and must not skew the served aggregates."""
        if rec.deadline is not None and rec.route != "dropped":
            self._c["deadlined_completed"].inc()
            self._c["deadline_misses"].inc(int(rec.missed))
        self._c["completed"].inc()
        self._h_wait.observe(rec.wait)
        self._h_latency.observe(rec.latency)
        self._h_iters.observe(rec.iters)
        self.obs.tracer.emit(rec.rid, "complete", status=rec.status,
                             iters=rec.iters, retries=rec.retries,
                             device=rec.device, route=rec.route)
        self._log_request(rec)

    def _shed_at_admission(self, req: ScheduledRequest, now: float) -> bool:
        """Same deadline shedding as the single-device scheduler; dropped
        requests get a telemetry-only cluster record."""
        if (self.shed_policy == "none" or req.deadline is None
                or now <= req.deadline):
            return False
        if self.shed_policy == "drop":
            self._c["shed_dropped"].inc()
            self._c["rejected"].inc()
            self._prepped.pop(req.rid, None)
            self._log_request(ClusterRequestTelemetry(
                rid=req.rid, bucket=req.bucket, lane=-1,
                arrival=req.arrival, admitted=now, completed=now,
                iters=0, converged=False, deadline=req.deadline,
                shed="dropped", status="rejected", device=-1,
                route="dropped"))
            self.obs.tracer.emit(req.rid, "shed", policy="drop")
            self.obs.flight.note("shed", rid=req.rid, policy="drop")
            self.obs.tracer.emit(req.rid, "complete", status="rejected",
                                 reason="deadline passed at admission "
                                        "(shed_policy='drop')")
            self._store_disposition(RequestFailure(
                rid=req.rid, status="rejected",
                reason="deadline already passed at admission "
                       "(shed_policy='drop')"))
            return True
        # 'degrade': an expired deadline walks the ladder — level 1
        # normally, deeper when the brownout controller says the whole
        # cluster is already shedding accuracy
        self.obs.tracer.emit(req.rid, "shed", policy="degrade")
        level = max(1, self.brownout.level if self.brownout else 0)
        self._degrade(req, level)
        return False

    def _device_active(self, device: int) -> int:
        return sum(p.device_active(device) for p in self._pools.values())

    def _pool_for(self, req: ScheduledRequest) -> tuple[_ClusterPool, bool]:
        """The pool this request solves in (created on demand); True when
        an existing *wider* pool is shared cross-bucket instead."""
        pool = self._pools.get(req.bucket)
        if pool is not None:
            return pool, False
        if self.share_pools:
            # bucket-affinity cross-bucket sharing: a wider existing pool
            # with a free slot hosts the request via valid-extent masking
            # (zero-padding is exact -> bit-identical results), instead of
            # allocating a new D-device pool stack for a one-off shape
            Mb, Nb = req.bucket
            for bucket in sorted(self._pools):
                cand = self._pools[bucket]
                if (bucket[0] >= Mb and bucket[1] >= Nb
                        and any(cand.free_lanes(d)
                                for d in range(self.num_devices))):
                    self._router["shared_pool"].inc()
                    return cand, True
        pool = self._pools[req.bucket] = _ClusterPool(
            req.bucket, self.num_devices, self.lanes_per_device, self.cfg,
            mesh=self.mesh, axis=self.axis,
            storage_dtype=self.storage_dtype)
        return pool, False

    def _pick_device(self, pool: _ClusterPool) -> int | None:
        """Placement policy: the device shard that takes the next lane."""
        cap = self.device_active_cap
        candidates = [d for d in range(self.num_devices)
                      if self._device_health[d] == "ok"
                      and pool.free_lanes(d)
                      and (cap is None or self._device_active(d) < cap)]
        if not candidates:
            return None
        if self.placement == "bucket_affinity":
            hot = [d for d in candidates if pool.device_active(d) > 0]
            if hot:
                self._router["affinity_hits"].inc()
                # pack: the busiest shard of THIS bucket that still has room
                return max(hot, key=lambda d: (pool.device_active(d), -d))
            self._router["affinity_spills"].inc()
        else:
            self._router["least_loaded"].inc()
        return min(candidates, key=lambda d: (self._device_active(d), d))

    def _admit_queued(self) -> None:
        if not self._queue:
            return
        if (self.gang == "auto"
                and all(h != "ok" for h in self._device_health)):
            # no healthy device shard remains: the gang path still solves
            # per request without touching lane-pool state — degraded
            # capacity, but every request keeps resolving
            self._router["gang_routed"].inc(len(self._queue))
            for req in self._queue:
                self.obs.tracer.emit(req.rid, "queue",
                                     depth=len(self._gang_queue) + 1,
                                     route="gang")
            self._gang_queue.extend(self._queue)
            self._queue = []
            return
        now = self.clock()
        remaining: list[ScheduledRequest] = []
        placements: dict[tuple[int, int], list] = {}   # pool bucket -> slots
        stalled = False
        # predicted-finish-time EDF when the service model is calibrated
        # (least slack = deadline minus predicted service); else plain EDF
        if self.predictive and self._seconds_per_iter() is not None:
            def admit_key(r):
                return r.slack_key(self._predicted_service(r))
        else:
            admit_key = ScheduledRequest.edf_key
        brownout_level = (self.brownout.level
                          if (self.brownout is not None
                              and self.shed_policy == "degrade") else 0)
        for req in sorted(self._queue, key=admit_key):
            if req.shed is None and self._shed_at_admission(req, now):
                continue
            self._degrade_if_infeasible(req, now)
            if brownout_level:
                # sustained overload: new admissions shed accuracy so
                # the backlog drains faster than it grows
                self._degrade(req, brownout_level)
            if req.degrade_level >= 2 and req.K is None:
                # level 2: solve NOW on the host sliced tier — never
                # occupies a (device, lane) slot
                self._prepped.pop(req.rid, None)
                self._complete_sliced(req, now)
                continue
            pool, _shared = self._pool_for(req)
            device = self._pick_device(pool)
            if device is None:
                stalled = True
                remaining.append(req)
                continue
            lane = pool.free_lanes(device)[0]
            pool.requests[(device, lane)] = req
            pool.admitted_at[(device, lane)] = now
            self._device_placed[device] += 1
            self.obs.flight.note("place", rid=req.rid, lane=lane,
                                 device=device)
            self.obs.tracer.emit(req.rid, "place", lane=lane, device=device,
                                 bucket=list(pool.bucket), route="lane")
            placements.setdefault(pool.bucket, []).append(
                (device, lane, req))
        if stalled:
            self._router["placement_stalls"].inc()
        for bucket, placed in placements.items():
            dense = [p for p in placed if p[2].K is not None]
            points: dict[tuple[int, float], list] = {}
            for d, l, r in placed:
                if r.K is None:
                    points.setdefault((r.x.shape[1], r.scale),
                                      []).append((d, l, r))
            if dense:
                self._admit_dense(bucket, dense)
            for (dim, scale), group in points.items():
                self._admit_points(bucket, group, dim, scale)
        self._queue = remaining

    def _admit_dense(self, bucket, placed) -> None:
        pool = self._pools[bucket]
        Mb, Nb = bucket
        # pow2-canonical batch (the bucketed-flush trick), NOT the full
        # D*L capacity: one admission ships one bucket-sized payload, not
        # 64, while jit signatures stay O(log capacity) per payload kind;
        # the index tail is duplicate slots (idempotent scatter)
        cap = ops.canonical_batch(
            len(placed), pool.num_devices * pool.lanes_per_device)
        Kp = np.zeros((cap, Mb, Nb), np.float32)
        ap = np.zeros((cap, Mb), np.float32)
        bp = np.zeros((cap, Nb), np.float32)
        mv = np.zeros(cap, np.int32)
        nv = np.zeros(cap, np.int32)
        devs = np.empty(cap, np.int32)
        lns = np.empty(cap, np.int32)
        for j in range(cap):
            d, l, req = placed[min(j, len(placed) - 1)]
            M, N = req.shape
            prep = self._prepped.pop(req.rid, None)
            if prep is not None and prep[0].shape == (Mb, Nb):
                Kp[j], ap[j], bp[j] = prep
            else:
                # shared wider pool (or unprepped request): pad from the
                # bucket-padded cache if present, else from the raw payload
                src = prep[0] if prep is not None else req.K
                sm, sn = src.shape
                Kp[j, :sm, :sn] = src
                ap[j, :M] = req.a
                bp[j, :N] = req.b
            mv[j], nv[j] = M, N
            devs[j], lns[j] = d, l
        self.obs.traffic.charge_admission(
            route="lane", M=Mb, N=Nb, s=4, source="dense",
            count=len(placed))
        pool.state = cluster_admit(
            pool.state, jnp.asarray(devs), jnp.asarray(lns),
            jnp.asarray(Kp), jnp.asarray(ap), jnp.asarray(bp),
            m_valid=jnp.asarray(mv), n_valid=jnp.asarray(nv))

    def _admit_points(self, bucket, placed, dim: int, scale: float) -> None:
        """Coordinate-payload admission: ship O((M+N)*(d+1)) floats per
        request, materialize the masked Gibbs stack on-device through the
        geometry mirror (bit-identical to dense submission), one pool
        update per (d, scale) group."""
        pool = self._pools[bucket]
        Mb, Nb = bucket
        cap = ops.canonical_batch(
            len(placed), pool.num_devices * pool.lanes_per_device)
        xs = np.zeros((cap, Mb, dim), np.float32)
        xns = np.zeros((cap, Mb), np.float32)
        ys = np.zeros((cap, Nb, dim), np.float32)
        yns = np.zeros((cap, Nb), np.float32)
        mv = np.zeros(cap, np.int32)
        nv = np.zeros(cap, np.int32)
        ap = np.zeros((cap, Mb), np.float32)
        bp = np.zeros((cap, Nb), np.float32)
        devs = np.empty(cap, np.int32)
        lns = np.empty(cap, np.int32)
        for j in range(cap):
            d, l, req = placed[min(j, len(placed) - 1)]
            M, N = req.shape
            xs[j, :M], xns[j, :M] = req.x, req.xn
            ys[j, :N], yns[j, :N] = req.y, req.yn
            mv[j], nv[j] = M, N
            ap[j, :M] = req.a
            bp[j, :N] = req.b
            devs[j], lns[j] = d, l
        g = PointCloudGeometry(
            x=jnp.asarray(xs), y=jnp.asarray(ys), xn=jnp.asarray(xns),
            yn=jnp.asarray(yns), m_valid=jnp.asarray(mv),
            n_valid=jnp.asarray(nv), scale=scale)
        self.obs.traffic.charge_admission(
            route="lane", M=Mb, N=Nb, s=4, source="implicit", d=dim,
            count=len(placed))
        pool.state = cluster_admit(
            pool.state, jnp.asarray(devs), jnp.asarray(lns),
            g.kernel(self.cfg.reg), jnp.asarray(ap), jnp.asarray(bp),
            m_valid=jnp.asarray(mv), n_valid=jnp.asarray(nv))

    def _solve_gang(self) -> dict[int, np.ndarray]:
        """Run up to ``gang_per_step`` over-budget requests on the
        row-sharded gang (the whole mesh per solve). Without a mesh the
        escape hatch degrades to the per-request tier-1 solve — still
        served, still one submit API."""
        if not self._gang_queue:
            return {}
        completed: dict[int, np.ndarray] = {}
        self._gang_queue.sort(key=ScheduledRequest.edf_key)
        budget = self.gang_per_step
        while self._gang_queue and budget > 0:
            req = self._gang_queue.pop(0)
            now = self.clock()
            if req.shed is None and self._shed_at_admission(req, now):
                continue
            if req.degrade_level >= 2 and req.K is None:
                # a point request the shed ladder walked to level 2:
                # the sliced tier is route-independent (host-side, no
                # mesh) and cheaper than any gang launch — take it and
                # keep the gang budget for requests that need the mesh
                self._complete_sliced(req, now)
                continue
            budget -= 1
            t0 = self.clock()
            if req.K is None:
                g = PointCloudGeometry(
                    x=jnp.asarray(req.x), y=jnp.asarray(req.y),
                    xn=jnp.asarray(req.xn), yn=jnp.asarray(req.yn),
                    scale=req.scale)
                K = g.kernel(self.cfg.reg)
            else:
                K = req.K
            # a degraded gang request runs its reduced budget, like a lane
            iters = (self.cfg.num_iters if req.max_iters is None
                     else min(req.max_iters, self.cfg.num_iters))
            if self._gang_degrade:
                # a previous solve breached gang_timeout: keep the gang
                # tier's latency bounded by running the degraded budget
                # (the shed 'degrade' contract applied to the gang)
                iters = min(iters, self.degrade_iters)
            cfg = (self.cfg if iters == self.cfg.num_iters
                   else dataclasses.replace(self.cfg, num_iters=iters))
            if self.mesh is not None:
                P, _ = distributed.gang_solve(
                    self.mesh, self.axis, K, req.a, req.b, cfg,
                    storage_dtype=self.storage_dtype,
                    overlapped=self.gang_overlapped)
            else:
                P, _ = ops.solve_fused(
                    jnp.asarray(K), jnp.asarray(req.a), jnp.asarray(req.b),
                    cfg, interpret=self.interpret,
                    storage_dtype=self.storage_dtype)
                P = np.asarray(P)
            done = self.clock()
            status = "ok"
            if (self.gang_timeout is not None
                    and done - t0 > self.gang_timeout):
                # a fused launch can't be preempted: the breaching solve
                # still delivers, is recorded timed_out, and latches the
                # degraded budget for the solves after it
                self._c["gang_timeouts"].inc()
                self._gang_degrade = True
                status = "timed_out"
                self._c["timed_out"].inc()
                fl = self.obs.flight
                if fl.enabled:
                    # dump_on gang_timeout: the latch permanently
                    # degrades the gang tier — incident-worthy
                    fl.note("gang_timeout", rid=req.rid,
                            elapsed=done - t0)
                    fl.dump("gang_timeout",
                            reason=f"rid {req.rid}: gang solve took "
                                   f"{done - t0:.3f}s > "
                                   f"{self.gang_timeout:.3f}s; degraded "
                                   "budget latched")
            completed[req.rid] = self._results[req.rid] = P
            self._trim_results()
            self._c["gang_completed"].inc()
            M, N = req.shape
            gang_devices = (self.num_devices if self.mesh is not None else 1)
            self.obs.tracer.emit(req.rid, "gang", devices=gang_devices,
                                 iters=iters, status=status)
            # gang traffic: the streamed per-request formula on the
            # row-sharded stack + the per-device ring all-reduce bytes
            # (charge_solve adds the collective term for route='gang')
            s = (np.dtype(self.storage_dtype).itemsize
                 if self.storage_dtype is not None else 4)
            self.obs.traffic.charge_solve(
                route="gang", tier="streamed", M=M, N=N, s=s, T=iters,
                source="dense" if req.K is not None else "implicit",
                d=None if req.K is not None else int(req.x.shape[1]))
            self._record(ClusterRequestTelemetry(
                rid=req.rid, bucket=req.bucket, lane=-1,
                arrival=req.arrival, admitted=now, completed=done,
                iters=iters, converged=False, deadline=req.deadline,
                shed=req.shed, status=status, retries=req.retries,
                device=-1, route="gang"))
        return completed

    def _charge_chunk(self, pool: _ClusterPool, counters: dict) -> None:
        """Chunk-advance accounting (see ``UOTScheduler._charge_chunk``):
        one shard_map launch advances the whole device-stacked pool, so
        ``L`` spans every device's lanes."""
        for k, v in counters.items():
            if v:
                self._c_dispatch[k].inc(v)
        if not self.obs.traffic.enabled:
            return
        tier = "resident" if counters["resident"] > 0 else "streamed"
        Mb, Nb = pool.bucket
        self.obs.traffic.charge_chunk(
            route="lane", tier=tier,
            L=pool.num_devices * pool.lanes_per_device, M=Mb, N=Nb,
            s=jnp.dtype(pool.state.lanes.P.dtype).itemsize,
            chunk_iters=self.chunk_iters)

    def _advance_pools(self) -> None:
        # The launch profiler forces a block_until_ready per timed
        # launch; in async mode that sync would destroy the deliberate
        # host/device overlap the double-buffered loop exists for, so
        # kernel profiling is sync-mode only. Phase timers (pure host
        # timestamps) and the dispatch advisor stay on in both modes.
        profiler = (self.obs.profile if self.step_mode == "sync"
                    else None)
        advisor = self._advisor
        for bucket, pool in list(self._pools.items()):
            if pool.requests:
                pool.idle_steps = 0
                with ops.dispatch_counters() as counters, \
                        ops.launch_profiler(profiler), \
                        (ops.dispatch_advisor(advisor)
                         if advisor is not None
                         else contextlib.nullcontext()):
                    pool.state = cluster_stepped(
                        pool.state, self.chunk_iters, self.cfg,
                        mesh=self.mesh, axis=self.axis,
                        interpret=self.interpret, impl=self.impl)
                self._charge_chunk(pool, counters)
            else:
                pool.idle_steps += 1
                if (self.pool_idle_ttl is not None
                        and pool.idle_steps > self.pool_idle_ttl):
                    del self._pools[bucket]

    def _snapshot_occupancy(self) -> None:
        occ = {str(b): p.occupancy for b, p in self._pools.items()}
        self.occupancy_log.append({
            "step": self._steps,
            "queued": len(self._queue),
            "gang_queued": len(self._gang_queue),
            "deadline_misses": self._c["deadline_misses"].value,  # running
            "pools": occ,
            "device_active": [self._device_active(d)
                              for d in range(self.num_devices)],
        })
        self._g_queued.set(len(self._queue))
        self._g_gang_queued.set(len(self._gang_queue))
        self._g_in_flight.set(self.in_flight)
        self._g_occupancy.set(sum(occ.values()) / len(occ) if occ else 0.0)
        # count what falls off the bounded telemetry window so the
        # narrowing of stats()' aggregates is visible, not silent.
        # Request records trim at append time (_log_request — every
        # producer path); the occupancy window's one producer is here.
        self._c["window_dropped_occupancy"].inc(
            max(0, len(self.occupancy_log) - self.max_log))
        del self.occupancy_log[:-self.max_log]

    # ---- telemetry --------------------------------------------------------

    def stats(self) -> dict:
        """Cluster-wide serving telemetry: the single-device aggregate keys
        (over the retained window; running deadline/shed counters exact),
        plus per-device placement/completion/occupancy rollups, router
        decision counts, gang totals, and this scheduler's own
        ``impl='auto'`` dispatch decisions."""
        lanes_cap = self.lanes_per_device
        device_occ = [[] for _ in range(self.num_devices)]
        for snap in self.occupancy_log:
            for d, active in enumerate(snap["device_active"]):
                device_occ[d].append(active / max(1, lanes_cap))
        c = self._c
        cluster = {
            "deadline_misses": c["deadline_misses"].value,
            "miss_rate": (c["deadline_misses"].value
                          / c["deadlined_completed"].value
                          if c["deadlined_completed"].value else 0.0),
            "shed_dropped": c["shed_dropped"].value,
            "shed_degraded": c["shed_degraded"].value,
            "gang_completed": c["gang_completed"].value,
            "router": {k: v.value for k, v in self._router.items()},
            "dispatch": {k: v.value for k, v in self._c_dispatch.items()},
            # fault-containment rollup (running totals, exact — registry
            # counters "cluster.*" in self.obs.registry)
            "rejected": c["rejected"].value,
            "failed": c["failed"].value,
            "retried_ok": c["retried_ok"].value,
            "timed_out": c["timed_out"].value,
            "unhealthy_evictions": c["unhealthy_evictions"].value,
            "lost_results": c["lost_results"].value,
            "requeued": c["requeued"].value,
            "gang_timeouts": c["gang_timeouts"].value,
            "window_dropped": {
                "requests": c["window_dropped_requests"].value,
                "occupancy": c["window_dropped_occupancy"].value,
                "dispositions": c["window_dropped_dispositions"].value,
            },
            # overload-model totals (zeros when the features are off)
            "admission_infeasible": self._c_infeasible.value,
            "degrade_levels": {lvl: ctr.value
                               for lvl, ctr in self._c_degrade.items()},
            "brownout_level": (self.brownout.level
                               if self.brownout is not None else 0),
            "seconds_per_iter": self._seconds_per_iter(),
            "device_health": list(self._device_health),
            "devices": {
                d: {"placed": self._device_placed[d],
                    "completed": self._device_completed[d],
                    "active": self._device_active(d),
                    "health": self._device_health[d],
                    "occupancy_mean": (float(np.mean(device_occ[d]))
                                       if device_occ[d] else 0.0)}
                for d in range(self.num_devices)},
        }
        status_counts: dict[str, int] = {}
        for t in self.request_log:
            status_counts[t.status] = status_counts.get(t.status, 0) + 1
        cluster["status_counts"] = status_counts
        # dropped / admission-rejected requests never solved anything —
        # excluded from the aggregates, which describe served work
        served = [t for t in self.request_log
                  if t.shed != "dropped" and t.status != "rejected"]
        if not served:
            return {"completed": 0, "steps": self._steps, "wait_mean": 0.0,
                    "wait_p99": 0.0, "latency_p50": 0.0, "latency_p99": 0.0,
                    "iters_mean": 0.0, "iters_max": 0,
                    "converged_frac": 0.0, "occupancy_mean": 0.0, **cluster}
        waits = np.array([t.wait for t in served])
        lats = np.array([t.latency for t in served])
        iters = np.array([t.iters for t in served])
        occ = [o for snap in self.occupancy_log
               for o in snap["pools"].values()]
        return {
            "completed": len(served),
            "steps": self._steps,
            "wait_mean": float(waits.mean()),
            "wait_p99": float(np.percentile(waits, 99)),
            "latency_p50": float(np.percentile(lats, 50)),
            "latency_p99": float(np.percentile(lats, 99)),
            "iters_mean": float(iters.mean()),
            "iters_max": int(iters.max()),
            "converged_frac": float(np.mean([t.converged for t in served])),
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            **cluster,
        }
