from repro.optim.adamw import adamw_init, adamw_update, OptConfig
from repro.optim.schedule import cosine_schedule

__all__ = ["adamw_init", "adamw_update", "OptConfig", "cosine_schedule"]
