"""AdamW from scratch (optax is not available in this environment).

State layout mirrors the param pytree: {"m": tree, "v": tree, "count": i32}.
All moments fp32 regardless of param dtype; weight decay decoupled; global
gradient-norm clipping built in (fused into the same tree traversal).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: OptConfig, lr_scale=1.0):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "clip": clip}
