"""jit-able training step: loss -> grads -> AdamW, with optional
microbatching (gradient accumulation) and int8 gradient compression.

TrainState is a plain dict pytree: {"params", "opt", "step"} — params are
fp32 masters; the forward pass casts to bf16 internally (models.model).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule

TrainState = dict


def init_train_state(model, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (cross-replica trick)
# ---------------------------------------------------------------------------

def quantize_int8(tree):
    """Per-leaf symmetric int8 quantization. Returns (q_tree, scales)."""
    def q(x):
        x = x.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s
    leaves, tdef = jax.tree.flatten(tree)
    qs = [q(x) for x in leaves]
    return tdef.unflatten([a for a, _ in qs]), tdef.unflatten([b for _, b in qs])


def dequantize_int8(q_tree, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scales)


def compress_grads_with_feedback(grads, error):
    """Quantize grads + carried error; return (to_send, new_error).

    The all-reduce then runs on int8 payloads (4x wire bytes saved); the
    quantization residual is fed back into the next step (error feedback,
    1-bit-Adam style) so the scheme stays unbiased over time.
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, error)
    q, s = quantize_int8(corrected)
    deq = dequantize_int8(q, s)
    new_error = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return deq, new_error


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------

def make_train_step(model, opt_cfg: OptConfig, *, total_steps: int = 10000,
                    warmup: int = 100, microbatches: int = 1,
                    compress: bool = False) -> Callable:
    """Build train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the per-host batch on axis 0 and accumulates
    grads in fp32 (sequential lax.scan — memory-bound activations shrink by
    the microbatch factor; the classic PP-free accumulation).
    """

    def loss_fn(params, batch):
        loss, metrics = model.forward(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(acc, mbatch):
            (loss, metrics), grads = grad_fn(params, mbatch)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                acc_g, grads)
            return (acc_g, acc_l + loss / microbatches), metrics

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        (grads, loss), metrics = jax.lax.scan(body, (zero_g, jnp.float32(0)),
                                              mb)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss, metrics, grads

    def train_step(state: TrainState, batch, grad_error=None):
        params, opt, step = state["params"], state["opt"], state["step"]
        loss, metrics, grads = compute_grads(params, batch)

        new_error = None
        if compress:
            grads, new_error = compress_grads_with_feedback(grads, grad_error)

        lr_scale = cosine_schedule(step, warmup=warmup, total=total_steps)
        params, opt, opt_metrics = adamw_update(grads, opt, params, opt_cfg,
                                                lr_scale)
        state = {"params": params, "opt": opt, "step": step + 1}
        metrics = dict(metrics, loss=loss, lr_scale=lr_scale, **opt_metrics)
        if compress:
            return state, metrics, new_error
        return state, metrics

    return train_step
