"""Fault-tolerant training loop.

Cluster-scale behaviours implemented (and simulated in tests):
  * checkpoint/restart: periodic async checkpoints; on ANY step failure the
    loop restores the latest checkpoint and continues (the data pipeline is
    seekable, so the token stream realigns exactly);
  * elastic re-meshing: on simulated device loss the trainer rebuilds a
    smaller mesh and re-places the restored state (checkpoint tensors are
    stored unsharded — see train.checkpoint);
  * straggler detection: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged and counted (on a real cluster
    this hook triggers hot-spare swap; here it feeds metrics);
  * failure injection for tests: ``failure_schedule`` maps step -> exception.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.train_step import make_train_step, init_train_state
from repro.optim.adamw import OptConfig


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    schedule_total: int | None = None   # LR-schedule horizon (default: total)
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    warmup: int = 10
    microbatches: int = 1
    max_restarts: int = 5
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, model, pipeline, opt_cfg: OptConfig,
                 tcfg: TrainerConfig,
                 failure_schedule: dict[int, Exception] | None = None,
                 jit: bool = True):
        self.model = model
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        step_fn = make_train_step(
            model, opt_cfg,
            total_steps=tcfg.schedule_total or tcfg.total_steps,
            warmup=tcfg.warmup, microbatches=tcfg.microbatches)
        self.train_step = jax.jit(step_fn) if jit else step_fn
        self.checkpointer = ckpt.AsyncCheckpointer()
        self.failure_schedule = failure_schedule or {}
        self.metrics_log: list[dict] = []
        self.restarts = 0
        self.stragglers = 0

    # -- state ---------------------------------------------------------------

    def init_or_restore(self, key):
        state = init_train_state(self.model, key)
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            state, step = ckpt.restore(self.tcfg.ckpt_dir, state)
            state = jax.tree.map(jax.numpy.asarray, state)
        return state

    # -- loop ----------------------------------------------------------------

    def run(self, key):
        state = self.init_or_restore(key)
        ewma = None
        while int(state["step"]) < self.tcfg.total_steps:
            step = int(state["step"])
            try:
                if step in self.failure_schedule:
                    exc = self.failure_schedule.pop(step)
                    raise exc
                batch = self.pipeline.batch_at(step)
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > self.tcfg.straggler_factor * ewma:
                    self.stragglers += 1
                if step % self.tcfg.log_every == 0 or \
                        step + 1 == self.tcfg.total_steps:
                    rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    rec.update(step=step, sec=dt)
                    self.metrics_log.append(rec)
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.checkpointer.save(self.tcfg.ckpt_dir, step + 1,
                                           state)
            except (RuntimeError, ValueError, FloatingPointError) as e:
                # device failure / NaN blowup path: restore & continue
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                self.checkpointer.wait()
                state = self.init_or_restore(key)
        self.checkpointer.wait()
        ckpt.save(self.tcfg.ckpt_dir, int(state["step"]), state)
        return state
