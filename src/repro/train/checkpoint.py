"""Checkpointing: atomic, resumable, shard-aware, numpy-backed.

Design points for cluster scale (orbax is unavailable offline; the layout
mirrors what a real deployment needs):
  * **Atomic**: written to ``<dir>/tmp.<step>`` then os.rename'd — a crash
    mid-write never corrupts the latest checkpoint.
  * **Async**: ``save_async`` snapshots to host RAM (device_get) and writes
    on a daemon thread so the train loop is blocked only for the D2H copy.
  * **Self-describing**: the pytree structure is stored as a flattened
    key-path -> tensor mapping (npz) + a JSON manifest with step/config —
    restore works without the original object.
  * **Elastic**: tensors are stored unsharded (gathered); ``restore`` can
    re-place them onto ANY mesh via jax.device_put with new shardings —
    scale-up/scale-down restarts just work (tested).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        key = re.sub(r"[\[\]'\.]", "", key)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Blocking save. Returns the checkpoint path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(exist_ok=True)

    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "state.npz", **arrays)
    manifest = {"step": int(step), "keys": sorted(arrays.keys()),
                "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir)
    return str(final)


_KEEP = 3


def _gc(ckpt_dir: pathlib.Path, keep: int = _KEEP):
    ckpts = sorted(ckpt_dir.glob("step_*"))
    for old in ckpts[:-keep]:
        import shutil
        shutil.rmtree(old, ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot on the caller thread (D2H), write on a daemon thread."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None):
        arrays_tree = jax.tree.map(lambda v: np.asarray(jax.device_get(v)),
                                   tree)
        self.wait()

        def _write():
            self.last_path = save(ckpt_dir, step, arrays_tree, extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    ckpts = sorted(d.glob("step_*"))
    if not ckpts:
        return None
    return int(ckpts[-1].name.split("_")[1])


def restore(ckpt_dir: str, like_tree, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like_tree``.

    shardings: optional matching pytree of NamedSharding — tensors are
    device_put directly to their (possibly different-mesh) placement.
    Returns (tree, step).
    """
    d = pathlib.Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = d / f"step_{step:08d}"
    data = np.load(path / "state.npz")

    flat_like = _flatten_with_paths(like_tree)
    if set(flat_like.keys()) != set(data.files):
        missing = set(flat_like) - set(data.files)
        extra = set(data.files) - set(flat_like)
        raise ValueError(f"checkpoint/tree mismatch: missing={missing} "
                         f"extra={extra}")

    flat_shard = (_flatten_with_paths(shardings)
                  if shardings is not None else {})

    leaves_like, tdef = jax.tree_util.tree_flatten(like_tree)
    paths = list(_flatten_with_paths(like_tree).keys())
    out = []
    for key, leaf in zip(paths, leaves_like):
        arr = data[key]
        if flat_shard:
            arr = jax.device_put(arr, flat_shard[key])
        out.append(arr)
    return tdef.unflatten(out), step
