"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _safe_pow_ref(target, sums, fi: float):
    safe = jnp.where(sums > 0, sums, 1.0)
    ratio = jnp.where(sums > 0, target / safe, 1.0)
    if fi == 1.0:
        return ratio
    return jnp.power(ratio, fi)


def fused_iteration_ref(A, factor_col, a, *, fi: float):
    """Oracle for kernels.uot_fused.fused_iteration."""
    A = A.astype(jnp.float32)
    A = A * factor_col.astype(jnp.float32)[None, :]
    rowsum = A.sum(axis=1)
    frow = _safe_pow_ref(a.astype(jnp.float32), rowsum, fi)
    A = A * frow[:, None]
    return A, A.sum(axis=0)


def colsum_ref(A):
    return A.astype(jnp.float32).sum(axis=0)


def scale_rows_accum_cols_ref(A, frow):
    out = A.astype(jnp.float32) * frow.astype(jnp.float32)[:, None]
    return out, out.sum(axis=0)


def scale_cols_accum_rows_ref(A, fcol):
    out = A.astype(jnp.float32) * fcol.astype(jnp.float32)[None, :]
    return out, out.sum(axis=1)


def uv_iteration_ref(K, v, a, *, fi: float):
    """Oracle for kernels.uot_uv_fused.uv_iteration."""
    K = K.astype(jnp.float32)
    Kv = K @ v.astype(jnp.float32)
    u = _safe_pow_ref(a.astype(jnp.float32), Kv, fi)
    return u, K.T @ u


def materialize_coupling_ref(K, u, v):
    return (u.astype(jnp.float32)[:, None] * K.astype(jnp.float32)
            * v.astype(jnp.float32)[None, :])


# ---- batched oracles (vmap of the single-problem oracles) -----------------

def batched_fused_iteration_ref(A, factor_col, a, *, fi: float):
    """Oracle for kernels.uot_batched.batched_fused_iteration."""
    return jax.vmap(lambda A_, f_, a_: fused_iteration_ref(A_, f_, a_, fi=fi)
                    )(A, factor_col, a)


def batched_colsum_ref(A):
    return A.astype(jnp.float32).sum(axis=1)


def batched_uv_iteration_ref(K, v, a, *, fi: float):
    """Oracle for kernels.uot_batched.batched_uv_iteration."""
    return jax.vmap(lambda K_, v_, a_: uv_iteration_ref(K_, v_, a_, fi=fi)
                    )(K, v, a)


def batched_materialize_coupling_ref(K, u, v):
    return jax.vmap(materialize_coupling_ref)(K, u, v)
