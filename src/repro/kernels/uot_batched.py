"""Batched MAP-UOT fused Pallas kernels: a stack of problems in one launch.

Serving solves *many* small/medium UOT problems per step, not one large one.
A Python loop of single-problem kernels pays B dispatches (and B paddings);
a naive ``vmap`` of the jnp solver loses the explicit single-pass schedule.
These kernels instead run Algorithm 1 over a 2-D grid ``(batch, row_blocks)``
with the row dimension innermost, so each problem keeps the HBM-minimal
read+write-once schedule and its own ``(1, N)`` column-sum accumulator block
(revisited across consecutive grid steps — the TPU revisit rule — exactly as
in the single-problem kernel; the batch dimension just concatenates those
per-problem sequential sweeps).

Mixed precision: ``A`` may be stored bf16 while every reduction/factor stays
fp32 (``acc_dtype``). On a bandwidth-bound kernel this halves bytes moved:
per problem per iteration the traffic is ``M*N*(itemsize_in + itemsize_out)``
bytes + O(M + N), i.e. 2 MB/iter for a 512x512 fp32 problem and 1 MB bf16.

Shapes are pre-padded by ``ops.solve_fused_batched`` (zero rows/cols are
no-ops for the rescaling math, proven for the single-problem path and
re-asserted for this one in tests/test_batched.py).

Cost source: these kernels *load* their tiles. The implicit-geometry
solve (``ops.solve_fused_batched(geometry=...)``) replaces the initial
colsum and iteration-1 launches with the tile-compute twins in
``uot_geometry`` (Gibbs tiles evaluated in VMEM from coordinates, masked
per-problem valid counts standing in for zero padding), then continues
with these kernels from iteration 2 — bit-identical iterates either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.uot_fused import _safe_pow


def _batched_fused_iter_kernel(fcol_ref, a_ref, A_ref, out_ref, colsum_ref, *,
                               fi: float, acc_dtype):
    i = pl.program_id(1)  # row block within the current problem (innermost)

    blk = A_ref[...].astype(acc_dtype)           # (1, bm, N)
    fcol = fcol_ref[...].astype(acc_dtype)       # (1, 1, N)

    blk = blk * fcol                             # I: column rescale
    rowsum = jnp.sum(blk, axis=2, keepdims=True)  # II: (1, bm, 1)
    frow = _safe_pow(a_ref[...].astype(acc_dtype), rowsum, fi)
    blk = blk * frow                             # III: row rescale

    out_ref[...] = blk.astype(out_ref.dtype)

    # IV: per-problem column-sum accumulator. With the row dimension
    # innermost, problem b's (1, 1, N) accumulator block sees its
    # row-block steps consecutively, so no cross-problem interleaving.
    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(blk, axis=1, keepdims=True).astype(colsum_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fi", "block_m", "interpret", "acc_dtype"))
def batched_fused_iteration(A: jax.Array, factor_col: jax.Array,
                            a: jax.Array, *, fi: float, block_m: int = 256,
                            interpret: bool = False, acc_dtype=jnp.float32):
    """One MAP-UOT iteration for a stack of problems.

    A: (B, M, N); factor_col: (B, N); a: (B, M). M % block_m == 0 and
    N % 128 == 0 (pre-padded by the ops wrapper). Returns
    (A_next, next_colsum) with next_colsum of shape (B, N) in acc_dtype.
    """
    B, M, N = A.shape
    assert M % block_m == 0, (M, block_m)
    grid = (B, M // block_m)

    kernel = functools.partial(_batched_fused_iter_kernel, fi=fi,
                               acc_dtype=acc_dtype)
    out, colsum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # fcol
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # a (RPD)
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # A tile
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # A' tile
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # colsum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), A.dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
        ],
        interpret=interpret,
    )(factor_col.reshape(B, 1, N), a.reshape(B, M, 1), A)
    return out, colsum.reshape(B, N)


def _batched_fused_iter_frow_kernel(mask_ref, fcol_ref, a_ref, A_ref,
                                    out_ref, colsum_ref, frow_ref, *,
                                    fi: float, acc_dtype):
    i = pl.program_id(1)

    blk_in = A_ref[...].astype(acc_dtype)        # (1, bm, N)
    fcol = fcol_ref[...].astype(acc_dtype)       # (1, 1, N)

    blk = blk_in * fcol                          # I: column rescale
    rowsum = jnp.sum(blk, axis=2, keepdims=True)  # II: (1, bm, 1)
    frow = _safe_pow(a_ref[...].astype(acc_dtype), rowsum, fi)
    blk = blk * frow                             # III: row rescale

    # Lane freeze happens HERE, inside the single pass: a masked-out lane
    # writes back its input tile unchanged (bit-exact), so freezing costs
    # no extra memory pass — the tile was already in VMEM.
    blk = jnp.where(mask_ref[...] > 0, blk, blk_in)

    out_ref[...] = blk.astype(out_ref.dtype)
    frow_ref[...] = frow.astype(frow_ref.dtype)

    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(blk, axis=1, keepdims=True).astype(colsum_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fi", "block_m", "interpret", "acc_dtype"))
def batched_fused_iteration_frow(A: jax.Array, factor_col: jax.Array,
                                 a: jax.Array, mask: jax.Array, *, fi: float,
                                 block_m: int = 256, interpret: bool = False,
                                 acc_dtype=jnp.float32):
    """One masked batched MAP-UOT iteration that also emits the row factors.

    The steppable-solver form of ``batched_fused_iteration``: ``mask``
    (B,) float (1.0 = update, 0.0 = frozen) selects per lane between the
    rescaled tile and the unchanged input *inside* the kernel — same
    read+write-once traffic as the unmasked kernel, no second pass — and a
    third output returns the per-row rescale factors ``frow`` (B, M) (an
    O(M)-per-problem write, negligible against the M*N tile traffic) so
    the caller can observe the per-lane stationarity drift. A frozen
    lane's colsum output is the recomputation from its unchanged tile;
    ``ops._stepped_iter`` re-selects the carried value so bf16 storage
    keeps carried-colsum semantics. Returns (A_next, next_colsum, frow);
    frow is the *computed* factor even for frozen lanes (callers mask it).
    """
    B, M, N = A.shape
    assert M % block_m == 0, (M, block_m)
    grid = (B, M // block_m)

    kernel = functools.partial(_batched_fused_iter_frow_kernel, fi=fi,
                               acc_dtype=acc_dtype)
    out, colsum, frow = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, i: (b, 0, 0)),        # mask
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # fcol
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # a (RPD)
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # A tile
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # A' tile
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # colsum
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # frow
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), A.dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
            jax.ShapeDtypeStruct((B, M, 1), acc_dtype),
        ],
        interpret=interpret,
    )(mask.reshape(B, 1, 1).astype(jnp.float32),
      factor_col.reshape(B, 1, N), a.reshape(B, M, 1), A)
    return out, colsum.reshape(B, N), frow.reshape(B, M)


def _batched_colsum_kernel(A_ref, colsum_ref, *, acc_dtype):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(
        A_ref[...].astype(acc_dtype), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret",
                                             "acc_dtype"))
def batched_colsum(A: jax.Array, *, block_m: int = 256,
                   interpret: bool = False, acc_dtype=jnp.float32):
    """Per-problem initial column sums: (B, M, N) -> (B, N)."""
    B, M, N = A.shape
    assert M % block_m == 0
    out = pl.pallas_call(
        functools.partial(_batched_colsum_kernel, acc_dtype=acc_dtype),
        grid=(B, M // block_m),
        in_specs=[pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0))],
        out_specs=pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
        interpret=interpret,
    )(A)
    return out.reshape(B, N)


def _batched_uv_iter_kernel(v_ref, a_ref, K_ref, u_ref, ktu_ref, *,
                            fi: float, acc_dtype):
    i = pl.program_id(1)

    blk = K_ref[...].astype(acc_dtype)            # (1, bm, N) read-only
    v = v_ref[...].astype(acc_dtype)              # (1, 1, N)

    Kv = jnp.sum(blk * v, axis=2, keepdims=True)  # (1, bm, 1)
    u = _safe_pow(a_ref[...].astype(acc_dtype), Kv, fi)
    u_ref[...] = u.astype(u_ref.dtype)

    @pl.when(i == 0)
    def _init():
        ktu_ref[...] = jnp.zeros_like(ktu_ref)

    ktu_ref[...] += jnp.sum(blk * u, axis=1, keepdims=True).astype(ktu_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fi", "block_m", "interpret",
                                             "acc_dtype"))
def batched_uv_iteration(K: jax.Array, v: jax.Array, a: jax.Array, *,
                         fi: float, block_m: int = 256,
                         interpret: bool = False, acc_dtype=jnp.float32):
    """Batched read-only u/v pass: K (B, M, N), v (B, N), a (B, M).

    Returns (u, KTu) of shapes (B, M) and (B, N) in acc_dtype.
    """
    B, M, N = K.shape
    assert M % block_m == 0
    u, ktu = pl.pallas_call(
        functools.partial(_batched_uv_iter_kernel, fi=fi, acc_dtype=acc_dtype),
        grid=(B, M // block_m),
        in_specs=[
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # v
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # a
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # K tile
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # u
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # K^T u
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, 1), acc_dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
        ],
        interpret=interpret,
    )(v.reshape(B, 1, N), a.reshape(B, M, 1), K)
    return u.reshape(B, M), ktu.reshape(B, N)


def _batched_materialize_kernel(u_ref, v_ref, K_ref, P_ref, *, acc_dtype):
    blk = K_ref[...].astype(acc_dtype)
    P_ref[...] = (blk * u_ref[...].astype(acc_dtype)
                  * v_ref[...].astype(acc_dtype)).astype(P_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret",
                                             "acc_dtype", "out_dtype"))
def batched_materialize_coupling(K: jax.Array, u: jax.Array, v: jax.Array, *,
                                 block_m: int = 256, interpret: bool = False,
                                 acc_dtype=jnp.float32, out_dtype=jnp.float32):
    """P_b = diag(u_b) K_b diag(v_b) for every problem in the stack."""
    B, M, N = K.shape
    assert M % block_m == 0
    P = pl.pallas_call(
        functools.partial(_batched_materialize_kernel, acc_dtype=acc_dtype),
        grid=(B, M // block_m),
        in_specs=[
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), out_dtype),
        interpret=interpret,
    )(u.reshape(B, M, 1), v.reshape(B, 1, N), K)
    return P
