"""Pallas TPU kernels for the MAP-UOT hot path.

- ``uot_fused``: full fused iteration (col rescale + row rescale + colsum
  accumulation) — one HBM read + one write per iteration. The paper's kernel.
- ``uot_halfpass``: two half-fused passes with 2-D tiling for very wide
  matrices (the paper's GPU part-2/part-4 split).
- ``uot_uv_fused``: beyond-paper read-only pass in u/v-potential space.
- ``ops``: padding/block-size/interpret handling + assembled solvers.
- ``ref``: pure-jnp oracles.

All kernels validate on CPU via ``interpret=True``; block shapes are
(8k, 128m)-aligned for the TPU VPU.
"""
from repro.kernels import ops, ref, uot_fused, uot_halfpass, uot_uv_fused

__all__ = ["ops", "ref", "uot_fused", "uot_halfpass", "uot_uv_fused"]
