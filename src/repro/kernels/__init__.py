"""Pallas TPU kernels for the MAP-UOT hot path.

- ``uot_fused``: full fused iteration (col rescale + row rescale + colsum
  accumulation) — one HBM read + one write per iteration. The paper's kernel.
- ``uot_halfpass``: two half-fused passes with 2-D tiling for very wide
  matrices (the paper's GPU part-2/part-4 split).
- ``uot_uv_fused``: beyond-paper read-only pass in u/v-potential space.
- ``uot_batched``: stacked problems on a (batch, row_blocks) grid — one
  launch for B problems, per-problem column-sum accumulators.
- ``uot_resident``: lane-grid kernels that keep a problem's WHOLE tile in
  VMEM across a ``lax.while_loop`` of iterations (one-shot and
  LaneState-stepped) — per-solve instead of per-iteration HBM traffic,
  with the tol convergence check folded into the on-chip loop. Includes
  ``resident_solve_pc``, the implicit-geometry twin whose tile is
  COMPUTED in VMEM from point-cloud coordinates (per-solve coupling
  traffic: write MN, no read; coupling-only VMEM budget).
- ``uot_geometry``: the streamed tiers' implicit-geometry twins — initial
  colsum, materialize, and first-iteration kernels that evaluate
  squared-Euclidean Gibbs tiles on-chip from O((M+N)*d) coordinates
  (``repro.geometry.PointCloudGeometry``), so no M*N cost array ever
  exists in HBM and couplings still match the dense-load path
  bit-for-bit.
- ``ops``: padding/block-size/interpret handling + assembled solvers
  (single, batched, shape-bucketed ragged, steppable) + the
  resident-vs-streamed auto-dispatch (``impl='auto'`` routed by
  ``resident_fits``, implicit-geometry-aware; see the dispatch table in
  ``ops``'s docstring) + ``geometry=`` threading.
- ``ref``: pure-jnp oracles.

Two memory tiers, picked per problem shape:

* **streamed** (``uot_fused``/``uot_batched``/``uot_halfpass``): each
  iteration streams the coupling HBM -> VMEM -> HBM through a row-block
  grid — read MN + write MN bytes *per iteration*, the paper's floor.
* **resident** (``uot_resident``): the whole (padded) tile fits the VMEM
  budget, so the solve loads it once, iterates on-chip, stores once —
  read MN + write MN bytes *per solve*; a 25-iteration solve moves 25x
  fewer coupling bytes.

All kernels validate on CPU via ``interpret=True``; block shapes are
(8k, 128m)-aligned for the TPU VPU ((16k, 128m) for bf16 storage). Every
kernel takes ``acc_dtype`` (fp32 default) so the coupling/Gibbs matrix can
be stored bf16 while reductions and factors stay fp32 (the resident tier
upcasts once on load and downcasts once on store, so bf16 there rounds
per solve, not per iteration).
"""
from repro.kernels import (ops, ref, uot_batched, uot_fused, uot_geometry,
                           uot_halfpass, uot_resident, uot_uv_fused)

__all__ = ["ops", "ref", "uot_batched", "uot_fused", "uot_geometry",
           "uot_halfpass", "uot_resident", "uot_uv_fused"]
