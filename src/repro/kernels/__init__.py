"""Pallas TPU kernels for the MAP-UOT hot path.

- ``uot_fused``: full fused iteration (col rescale + row rescale + colsum
  accumulation) — one HBM read + one write per iteration. The paper's kernel.
- ``uot_halfpass``: two half-fused passes with 2-D tiling for very wide
  matrices (the paper's GPU part-2/part-4 split).
- ``uot_uv_fused``: beyond-paper read-only pass in u/v-potential space.
- ``uot_batched``: stacked problems on a (batch, row_blocks) grid — one
  launch for B problems, per-problem column-sum accumulators.
- ``ops``: padding/block-size/interpret handling + assembled solvers
  (single, batched, and shape-bucketed ragged batching).
- ``ref``: pure-jnp oracles.

All kernels validate on CPU via ``interpret=True``; block shapes are
(8k, 128m)-aligned for the TPU VPU ((16k, 128m) for bf16 storage). Every
kernel takes ``acc_dtype`` (fp32 default) so the coupling/Gibbs matrix can
be stored bf16 while reductions and factors stay fp32.
"""
from repro.kernels import (ops, ref, uot_batched, uot_fused, uot_halfpass,
                           uot_uv_fused)

__all__ = ["ops", "ref", "uot_batched", "uot_fused", "uot_halfpass",
           "uot_uv_fused"]
