"""jit'd public wrappers over the Pallas UOT kernels.

Handles: zero-padding to hardware-aligned shapes (the rescaling math is
invariant to zero rows/cols), VMEM-aware block-size selection, interpret-mode
fallback on non-TPU backends, and full solver loops assembled from kernels.

Batched & mixed-precision solving
---------------------------------
Serving solves many small/medium problems per step. ``solve_fused_batched``
and ``solve_uv_batched`` run a whole stack of same-shape problems in ONE
kernel launch over a ``(batch, row_blocks)`` grid (see ``uot_batched``),
keeping the per-problem single-pass HBM schedule; ``solve_fused_bucketed``
extends this to ragged problem lists by shape-bucketed zero-padding (pad each
problem to its bucket's (M, N) — zero rows/cols are exact no-ops for the
rescaling math).

All solvers accept a bf16 *storage* mode (``storage_dtype=jnp.bfloat16`` or
``UOTConfig(dtype=jnp.bfloat16)``): the coupling matrix lives in bf16 in
HBM/VMEM while every reduction and rescale factor is computed fp32
(``acc_dtype``). On a bandwidth-bound kernel this halves bytes moved:
fused traffic per problem per iteration is ``M*N*2*itemsize + O(M+N)`` bytes
— 2 MB for 512x512 fp32, 1 MB bf16. ``pick_block_m`` budgets VMEM with the
storage and accumulator itemsizes separately.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors
from repro.kernels import uot_batched, uot_fused, uot_halfpass, uot_uv_fused

# TPU v5e VMEM is 128 MiB; keep the working set (in + out + accumulators,
# double-buffered) comfortably under half of it.
_VMEM_BUDGET_BYTES = 32 * 1024 * 1024
_LANE = 128       # TPU lane width (minor dim alignment)
_SUBLANE = 8      # fp32 sublane count (16 for bf16 — see sublane_for)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default(interpret):
    return (not on_tpu()) if interpret is None else interpret


def _sublane(itemsize: int) -> int:
    return 2 * _SUBLANE if itemsize < 4 else _SUBLANE


def sublane_for(dtype) -> int:
    """Minor-2 dim alignment: 8 rows fp32, 16 rows for 2-byte types."""
    return _sublane(jnp.dtype(dtype).itemsize)


def _storage(cfg: UOTConfig, storage_dtype):
    return jnp.dtype(storage_dtype if storage_dtype is not None else cfg.dtype)


def pick_block_m(M: int, N: int, itemsize: int = 4,
                 acc_itemsize: int = 4) -> int:
    """Largest power-of-two row block whose VMEM working set fits the budget.

    The working set per grid step is the in + out tiles in the storage dtype
    (``itemsize`` bytes/elt, double-buffered by the pipeline) plus the fp32
    compute copy of the tile (``acc_itemsize``): ``bm * N * (2*itemsize +
    acc_itemsize)`` bytes. Mixed precision (bf16 storage) therefore earns a
    larger block than fp32 at the same budget. The block is also clamped to
    not exceed the (padded) problem height — no point padding M past the
    next power of two.
    """
    sub = _sublane(itemsize)
    bytes_per_row = N * (2 * itemsize + acc_itemsize)
    bm = 512
    while bm > sub and (bm * bytes_per_row > _VMEM_BUDGET_BYTES
                        or bm >= 2 * M):
        bm //= 2
    return max(bm, sub)


def pad_to(x: jax.Array, m_mult: int, n_mult: int) -> jax.Array:
    """Zero-pad the last two dims to multiples (works for 2-D and 3-D)."""
    M, N = x.shape[-2:]
    pm = (-M) % m_mult
    pn = (-N) % n_mult
    if pm or pn:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
        x = jnp.pad(x, pad)
    return x


def pad_vec(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the last dim to a multiple (works for (M,) and (B, M))."""
    p = (-x.shape[-1]) % mult
    if not p:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, p)]
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "storage_dtype"))
def solve_fused(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                *, block_m: int | None = None, interpret: bool | None = None,
                storage_dtype=None):
    """MAP-UOT solve built entirely from the fused Pallas kernel.

    Matches core.sinkhorn_uot_fused iterates (asserted in tests). Inputs of
    arbitrary shape; zero-padded internally to (block_m, 128) multiples.
    ``storage_dtype`` (default ``cfg.dtype``) sets the in-HBM dtype of the
    coupling matrix; accumulation/factors stay fp32.
    """
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Ap = pad_to(A0.astype(sdt), bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    colsum = uot_fused.colsum(Ap, block_m=bm, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, colsum = uot_fused.fused_iteration(
            A, fcol, ap, fi=fi, block_m=bm, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


def _impl_default(impl, interpret):
    """'kernel' (Pallas) on TPU; vectorized 'jnp' elsewhere.

    Interpret-mode pallas emulation scans the grid carrying the WHOLE stack
    through a while_loop with full-buffer dynamic updates per grid step —
    O(grid * B*M*N) traffic — so it is for validation, not speed. Tests pin
    ``impl='kernel', interpret=True`` to exercise the real kernel schedule.
    """
    if impl is None:
        return "kernel" if (on_tpu() and not interpret) else "jnp"
    if impl not in ("kernel", "jnp"):
        raise ValueError(f"impl must be 'kernel' or 'jnp', got {impl!r}")
    return impl


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "storage_dtype", "impl"))
def solve_fused_batched(A0: jax.Array, a: jax.Array, b: jax.Array,
                        cfg: UOTConfig, *, block_m: int | None = None,
                        interpret: bool | None = None, storage_dtype=None,
                        impl: str | None = None):
    """MAP-UOT solve for a stack of same-shape problems in one launch.

    A0: (B, M, N); a: (B, M); b: (B, N). On TPU (``impl='kernel'``) one
    ``(batch, row_blocks)``-grid pallas_call per iteration covers the whole
    stack — one dispatch instead of B, with each problem keeping the
    read+write-once schedule and its own (1, N) column-sum accumulator.
    ``impl='jnp'`` (the non-TPU default) runs the identical padded
    iteration math vectorized over the batch in XLA. Returns (P, colsum)
    of shapes (B, M, N) and (B, N).
    """
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    B, M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Ap = pad_to(A0.astype(sdt), bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    if impl == "jnp":
        colsum = Ap.astype(jnp.float32).sum(axis=1)

        def body(_, carry):
            A, colsum = carry
            fcol = rescale_factors(bp, colsum, fi)
            blk = A.astype(jnp.float32) * fcol[:, None, :]
            rowsum = blk.sum(axis=2)
            frow = rescale_factors(ap, rowsum, fi)
            blk = blk * frow[:, :, None]
            return blk.astype(sdt), blk.sum(axis=1)
    else:
        colsum = uot_batched.batched_colsum(
            Ap, block_m=bm, interpret=interpret)

        def body(_, carry):
            A, colsum = carry
            fcol = rescale_factors(bp, colsum, fi)
            return uot_batched.batched_fused_iteration(
                A, fcol, ap, fi=fi, block_m=bm, interpret=interpret)

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:, :M, :N], colsum[:, :N]


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "block_n",
                                             "interpret", "storage_dtype"))
def solve_halfpass(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                   *, block_m: int = 256, block_n: int = 512,
                   interpret: bool | None = None, storage_dtype=None):
    """Wide-N fallback: iteration = two half-fused passes (paper GPU design).

    Supports the same bf16-storage / fp32-accumulation mode as solve_fused.
    """
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    Ap = pad_to(A0.astype(sdt), block_m, block_n)
    ap = pad_vec(a, block_m)
    bp = pad_vec(b, block_n)
    fi = cfg.fi

    # initial column sums via a rows-scale pass with unit factors
    _, colsum = uot_halfpass.scale_rows_accum_cols(
        Ap, jnp.ones((Ap.shape[0],), jnp.float32),
        block_m=block_m, block_n=block_n, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, rowsum = uot_halfpass.scale_cols_accum_rows(
            A, fcol, block_m=block_m, block_n=block_n, interpret=interpret)
        frow = rescale_factors(ap, rowsum, fi)
        A, colsum = uot_halfpass.scale_rows_accum_cols(
            A, frow, block_m=block_m, block_n=block_n, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "materialize"))
def solve_uv(K: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig, *,
             block_m: int | None = None, interpret: bool | None = None,
             materialize: bool = True):
    """Beyond-paper read-only-pass solver (POT u/v semantics).

    K may be bf16 (accumulation fp32). Returns (P or None, (u, v)).
    """
    interpret = _interpret_default(interpret)
    M, N = K.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(K.dtype).itemsize)
    Kp = pad_to(K, bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    v0 = jnp.ones((Kp.shape[1],), jnp.float32)

    def body(_, v):
        u, ktu = uot_uv_fused.uv_iteration(
            Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)
        return rescale_factors(bp, ktu, fi)

    v = jax.lax.fori_loop(0, cfg.num_iters, body, v0)
    # one extra half-iteration to get the final u consistent with v
    u, _ = uot_uv_fused.uv_iteration(
        Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)

    if materialize:
        P = uot_uv_fused.materialize_coupling(
            Kp, u, v, block_m=bm, interpret=interpret)[:M, :N]
    else:
        P = None
    return P, (u[:M], v[:N])


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "materialize", "impl"))
def solve_uv_batched(K: jax.Array, a: jax.Array, b: jax.Array,
                     cfg: UOTConfig, *, block_m: int | None = None,
                     interpret: bool | None = None, materialize: bool = True,
                     impl: str | None = None):
    """Batched read-only-pass u/v solver: K (B, M, N), a (B, M), b (B, N).

    K may be bf16 (accumulation fp32). ``impl`` as in solve_fused_batched.
    Returns (P or None, (u, v)) with P (B, M, N) fp32, u (B, M), v (B, N).
    """
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    B, M, N = K.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(K.dtype).itemsize)
    Kp = pad_to(K, bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    v0 = jnp.ones((B, Kp.shape[2]), jnp.float32)

    if impl == "jnp":
        def uv_iter(v):
            Kv = jnp.einsum("bmn,bn->bm", Kp.astype(jnp.float32), v)
            u = rescale_factors(ap, Kv, fi)
            ktu = jnp.einsum("bmn,bm->bn", Kp.astype(jnp.float32), u)
            return u, ktu
    else:
        def uv_iter(v):
            return uot_batched.batched_uv_iteration(
                Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)

    def body(_, v):
        _, ktu = uv_iter(v)
        return rescale_factors(bp, ktu, fi)

    v = jax.lax.fori_loop(0, cfg.num_iters, body, v0)
    u, _ = uv_iter(v)

    if not materialize:
        return None, (u[:, :M], v[:, :N])
    if impl == "jnp":
        P = (u[:, :, None] * Kp.astype(jnp.float32)
             * v[:, None, :])[:, :M, :N]
    else:
        P = uot_batched.batched_materialize_coupling(
            Kp, u, v, block_m=bm, interpret=interpret)[:, :M, :N]
    return P, (u[:, :M], v[:, :N])


# ---- shape-bucketed ragged batching ---------------------------------------

def bucket_shape(M: int, N: int, m_bucket: int = 64,
                 n_bucket: int = _LANE) -> tuple[int, int]:
    """The padded (M, N) bucket a problem of shape (M, N) lands in."""
    return (M + (-M) % m_bucket, N + (-N) % n_bucket)


def bucket_problems(shapes, m_bucket: int = 64, n_bucket: int = _LANE):
    """Group problem indices by padded-shape bucket.

    ``shapes`` is a sequence of (M, N). Returns ``{(Mb, Nb): [indices]}``
    with insertion order preserved within each bucket.
    """
    buckets: dict[tuple[int, int], list[int]] = {}
    for idx, (M, N) in enumerate(shapes):
        buckets.setdefault(bucket_shape(M, N, m_bucket, n_bucket),
                           []).append(idx)
    return buckets


def solve_fused_bucketed(problems, cfg: UOTConfig, *,
                         interpret: bool | None = None, storage_dtype=None,
                         impl: str | None = None, max_batch: int = 64,
                         m_bucket: int = 64, n_bucket: int = _LANE):
    """Solve a ragged list of problems via shape-bucketed batched launches.

    ``problems`` is a sequence of (A0, a, b) triples with per-problem shapes.
    Problems are grouped into padded-shape buckets; each bucket is zero-padded
    to its (Mb, Nb), stacked, and solved by ``solve_fused_batched`` in chunks
    of at most ``max_batch``. Zero padding is exact (padded rows/cols carry
    zero mass and unit factors), so each answer equals its standalone solve.

    Returns a list of (P, colsum) aligned with the input order.
    """
    shapes = [tuple(p[0].shape) for p in problems]
    results: list = [None] * len(problems)
    for (Mb, Nb), idxs in bucket_problems(shapes, m_bucket, n_bucket).items():
        for lo in range(0, len(idxs), max_batch):
            chunk = idxs[lo:lo + max_batch]
            A = jnp.stack([pad_to(problems[i][0], Mb, Nb)
                           for i in chunk])
            a = jnp.stack([pad_vec(problems[i][1], Mb) for i in chunk])
            b = jnp.stack([pad_vec(problems[i][2], Nb) for i in chunk])
            P, colsum = solve_fused_batched(
                A, a, b, cfg, interpret=interpret,
                storage_dtype=storage_dtype, impl=impl)
            for k, i in enumerate(chunk):
                M, N = shapes[i]
                results[i] = (P[k, :M, :N], colsum[k, :N])
    return results
