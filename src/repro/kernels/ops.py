"""jit'd public wrappers over the Pallas UOT kernels.

Handles: zero-padding to hardware-aligned shapes (the rescaling math is
invariant to zero rows/cols), VMEM-aware block-size selection, interpret-mode
fallback on non-TPU backends, and full solver loops assembled from kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors
from repro.kernels import uot_fused, uot_halfpass, uot_uv_fused

# TPU v5e VMEM is 128 MiB; keep the working set (in + out + accumulators,
# double-buffered) comfortably under half of it.
_VMEM_BUDGET_BYTES = 32 * 1024 * 1024
_LANE = 128       # TPU lane width (minor dim alignment)
_SUBLANE = 8      # fp32 sublane count (use 16 for bf16)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default(interpret):
    return (not on_tpu()) if interpret is None else interpret


def pick_block_m(M: int, N: int, itemsize: int = 4) -> int:
    """Largest power-of-two row block (multiple of 8) whose (bm, N) in+out
    tiles fit the VMEM budget."""
    bm = 512
    while bm > _SUBLANE and 2 * bm * N * itemsize > _VMEM_BUDGET_BYTES:
        bm //= 2
    return max(bm, _SUBLANE)


def pad_to(x: jax.Array, m_mult: int, n_mult: int) -> jax.Array:
    M, N = x.shape
    pm = (-M) % m_mult
    pn = (-N) % n_mult
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def pad_vec(x: jax.Array, mult: int) -> jax.Array:
    p = (-x.shape[0]) % mult
    return jnp.pad(x, (0, p)) if p else x


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret"))
def solve_fused(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                *, block_m: int | None = None, interpret: bool | None = None):
    """MAP-UOT solve built entirely from the fused Pallas kernel.

    Matches core.sinkhorn_uot_fused iterates (asserted in tests). Inputs of
    arbitrary shape; zero-padded internally to (block_m, 128) multiples.
    """
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(A0.dtype).itemsize)
    Ap = pad_to(A0.astype(cfg.dtype), bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    colsum = uot_fused.colsum(Ap, block_m=bm, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, colsum = uot_fused.fused_iteration(
            A, fcol, ap, fi=fi, block_m=bm, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "block_n",
                                             "interpret"))
def solve_halfpass(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                   *, block_m: int = 256, block_n: int = 512,
                   interpret: bool | None = None):
    """Wide-N fallback: iteration = two half-fused passes (paper GPU design)."""
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    Ap = pad_to(A0.astype(cfg.dtype), block_m, block_n)
    ap = pad_vec(a, block_m)
    bp = pad_vec(b, block_n)
    fi = cfg.fi

    # initial column sums via a rows-scale pass with unit factors
    _, colsum = uot_halfpass.scale_rows_accum_cols(
        Ap, jnp.ones((Ap.shape[0],), jnp.float32),
        block_m=block_m, block_n=block_n, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, rowsum = uot_halfpass.scale_cols_accum_rows(
            A, fcol, block_m=block_m, block_n=block_n, interpret=interpret)
        frow = rescale_factors(ap, rowsum, fi)
        A, colsum = uot_halfpass.scale_rows_accum_cols(
            A, frow, block_m=block_m, block_n=block_n, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "materialize"))
def solve_uv(K: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig, *,
             block_m: int | None = None, interpret: bool | None = None,
             materialize: bool = True):
    """Beyond-paper read-only-pass solver (POT u/v semantics).

    K may be bf16 (accumulation fp32). Returns (P or None, (u, v)).
    """
    interpret = _interpret_default(interpret)
    M, N = K.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(K.dtype).itemsize)
    Kp = pad_to(K, bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    v0 = jnp.ones((Kp.shape[1],), jnp.float32)

    def body(_, v):
        u, ktu = uot_uv_fused.uv_iteration(
            Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)
        return rescale_factors(bp, ktu, fi)

    v = jax.lax.fori_loop(0, cfg.num_iters, body, v0)
    # one extra half-iteration to get the final u consistent with v
    u, _ = uot_uv_fused.uv_iteration(
        Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)

    if materialize:
        P = uot_uv_fused.materialize_coupling(
            Kp, u, v, block_m=bm, interpret=interpret)[:M, :N]
    else:
        P = None
    return P, (u[:M], v[:N])
