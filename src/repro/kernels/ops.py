"""jit'd public wrappers over the Pallas UOT kernels.

Handles: zero-padding to hardware-aligned shapes (the rescaling math is
invariant to zero rows/cols), VMEM-aware block-size selection, interpret-mode
fallback on non-TPU backends, and full solver loops assembled from kernels.

Batched & mixed-precision solving
---------------------------------
Serving solves many small/medium problems per step. ``solve_fused_batched``
and ``solve_uv_batched`` run a whole stack of same-shape problems in ONE
kernel launch over a ``(batch, row_blocks)`` grid (see ``uot_batched``),
keeping the per-problem single-pass HBM schedule; ``solve_fused_bucketed``
extends this to ragged problem lists by shape-bucketed zero-padding (pad each
problem to its bucket's (M, N) — zero rows/cols are exact no-ops for the
rescaling math).

All solvers accept a bf16 *storage* mode (``storage_dtype=jnp.bfloat16`` or
``UOTConfig(dtype=jnp.bfloat16)``): the coupling matrix lives in bf16 in
HBM/VMEM while every reduction and rescale factor is computed fp32
(``acc_dtype``). On a bandwidth-bound kernel this halves bytes moved:
fused traffic per problem per iteration is ``M*N*2*itemsize + O(M+N)`` bytes
— 2 MB for 512x512 fp32, 1 MB bf16. ``pick_block_m`` budgets VMEM with the
storage and accumulator itemsizes separately.

Steppable solving (continuous batching)
---------------------------------------
``LaneState`` + ``solve_fused_stepped`` expose the batched solve as
explicit carried state advanced a chunk of iterations per call, with
per-lane ``lane_admit`` / ``lane_evict`` / ``lane_done`` lifecycle — the
substrate for ``repro.serve.scheduler``'s continuous batching. With
``cfg.tol`` set, both the stepped and the one-shot batched solves freeze
each lane at the iterate where its row-factor stationarity reaches tol
(identical to the single-problem solvers' early exit, per lane).
``repro.cluster`` stacks per-device ``LaneState`` pools along a mesh axis
and advances every device's pool in one ``shard_map``-ped stepped launch
(the multi-device serving tier); per-lane ``m_valid`` / ``n_valid``
extents let one physical pool host several padded shapes (cross-bucket
lane sharing — see ``lane_admit``).

Resident tier & auto-dispatch
-----------------------------
When a problem's whole padded tile fits the VMEM budget
(``resident_fits``), the streamed per-iteration HBM schedule is beatable:
``uot_resident`` loads each lane's tile on-chip once, iterates to
convergence in a ``lax.while_loop``, and stores once — per-solve instead of
per-iteration traffic. ``impl='auto'`` on the solve entry points routes
between the two tiers by that static budget test (decisions are observable
via ``dispatch_stats``). The budget is only the *fallback*: with a
``dispatch_advisor()`` installed (``repro.obs.measure.MeasuredDispatch``
over a persisted measurement store), a resident-eligible 'auto'
resolution routes by *measured* per-tier cost instead — when both tiers
of the (kernel, shape, dtype, source) cell hold steady-state wall-clock
data, the measured-faster tier wins; cells without data defer to the
static budget. Correctness constraints are never advised away: shapes
over the VMEM budget and sub-fp32 stepped pools stay streamed
regardless of measurements.

Cost geometries
---------------
``geometry=`` on the solve entry points names the cost *source* instead
of a materialized ``A0`` (see ``repro.geometry``). Dense/grid geometries
materialize their Gibbs mirror once and take the historical path. For
implicit geometries (``PointCloudGeometry``) the kernel path computes
Gibbs tiles on-chip from ``O((M + N) * d)`` coordinates — no M*N cost
array ever exists in HBM — and ``resident_fits(implicit=True)`` budgets
only the coupling (no input tile), so shapes the dense tier must stream
run resident under a geometry. Couplings match the dense-load path
bit-for-bit (both dtypes).

Per-solve coupling HBM traffic by (workload x tier x cost source), with
``s`` = storage itemsize, ``T`` = iterations run, ``G`` = the cost-source
read: ``G = M*N*s`` for a dense ``A0`` (materialize/ship + first read)
vs ``G = (M+N)*(d+1)*4`` coordinate bytes for an implicit geometry
(and the solve's write-side first touch of the coupling drops from
"write K then rewrite A1" to "write A1 only"):

====================  ==========================  =========================
workload              resident (fits VMEM;        streamed (over budget)
                      implicit budget is
                      coupling-only)
====================  ==========================  =========================
per-request           ``G + 2*M*N*s`` per solve   ``G + 2*M*N*s * T``
``solve_fused``       (implicit: ``G + M*N*s``    (implicit: the colsum
                      — no tile read, store       pass and iteration 1
                      once)                       read coords, not K)
bucketed batch        ``B*(G + 2*M*N*s)`` per     ``B*(G + 2*M*N*s * T)``
``solve_fused_        chunk solve (one
batched/bucketed``    lane-grid launch, lanes
                      early-exit independently)
scheduler chunk       ``2*L*M*N*s`` per CHUNK     ``2*L*M*N*s *
``solve_fused_        (fp32 pools; bf16 pools     chunk_iters`` per chunk
stepped``             stay streamed to keep       (admission pays ``G``
                      chunk-boundary              once per request either
                      invariance)                 way — coordinates ship
                                                  host->device, K is
                                                  device-materialized)
====================  ==========================  =========================

(+ O(M+N) factor/marginal traffic per launch in every cell. On non-TPU
backends the resident tier is the jnp mirror — same iteration fusion in one
XLA executable — and implicit geometries materialize their masked Gibbs
mirror on-device (the host still never ships an M*N operand); the table's
traffic formulas describe the TPU kernels. The cluster tier —
``repro.cluster``'s sharded lane pools — is the scheduler row times D
devices: per-device traffic is unchanged, the only cross-device bytes are
admission payloads to the owning shard. Problems too large for any lane
pool bypass this table entirely and run on the row-sharded gang solvers,
``core.distributed.gang_solve``: O(N) allreduce bytes per iteration.)

Traffic accounting: the table above is executable. ``repro.obs.traffic``
implements each cell as a formula function (``solve_bytes`` /
``chunk_bytes`` / ``cost_source_bytes`` / ``gang_collective_bytes``) and
the serving tiers charge a ``TrafficAccountant`` at every dispatch
decision — ``dispatch_observer()`` below exposes each ``impl='auto'``
routing with its (M, N, itemsize, num_iters) so per-solve bytes are
charged without re-deriving the routing. Charged ``T`` is the iteration
BUDGET (modeled upper bound): per-lane tol early exit happens on device
and is invisible to the host without extra syncs. tests/test_obs.py
asserts the accountant against this table cell by cell.

Measured performance: ``launch_profiler()`` below is the wall-clock twin
of ``dispatch_observer()`` — it times every routed solve/chunk launch
(to completion; installing it syncs each launch) keyed by the SAME table
parameters, so ``repro.obs.measure`` divides each cell's modeled bytes
by its measured seconds into achieved GB/s and a measured roofline
fraction, and ``dispatch_advisor()`` feeds those measurements back into
the 'auto' routing above.

bf16 storage on the resident tier upcasts once at load and downcasts once
at store, so the per-iteration bf16 rounding of the streamed path
disappears: resident bf16 iterates are the fp32 trajectory rounded once.

Two dispatch rows live OUTSIDE this table:

* the **log-domain escalation** path. Every tier above iterates in
  scaling space, which has a documented fp32 overflow regime
  (``core.sinkhorn_uv``: the mass-imbalance mode is a factor
  ``(Sa/Sb)**(rho/(2*eps))``). Problems classified into that regime by
  ``core.health.uv_safe`` — and lanes whose state goes non-finite in
  flight (``LaneState.healthy``) — are not retried here at all: the
  serving schedulers route them to ``core.sinkhorn_uot_log`` via
  ``core.health.escalate_log_solve``, whose potential-space iterates
  carry the same mode additively. That path trades the paper's HBM
  schedule for numerical range; it is the containment tier, not a
  performance tier.
* the **sliced 1-D degrade** path. Under overload
  (``shed_policy='degrade'`` + ``predictive=True``) point-cloud
  requests can leave the Sinkhorn family entirely: ``core.solve_1d``'s
  exact O((M+N) log(M+N)) 1-D solver, averaged over ``n_proj`` random
  projections by ``geometry.sliced`` — O(n_proj * (M+N)) memory, no
  M*N bytes or FLOPs anywhere, certified per-slice optimality gap on
  the label. Iteration-count feasibility for the rows above is judged
  *before* admission by ``core.predict`` (analytic contraction rate +
  online EWMA correction — the schedulers' service-time model). These
  are the accuracy-for-capacity tiers, not performance tiers.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convergence import lane_factor_drift
from repro.core.problem import UOTConfig, rescale_factors
from repro.geometry import Geometry, PointCloudGeometry
from repro.kernels import (uot_batched, uot_fused, uot_geometry,
                           uot_halfpass, uot_resident, uot_uv_fused)

# TPU v5e VMEM is 128 MiB; keep the working set (in + out + accumulators,
# double-buffered) comfortably under half of it.
_VMEM_BUDGET_BYTES = 32 * 1024 * 1024
_LANE = 128       # TPU lane width (minor dim alignment)
_SUBLANE = 8      # fp32 sublane count (16 for bf16 — see sublane_for)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default(interpret):
    return (not on_tpu()) if interpret is None else interpret


def _sublane(itemsize: int) -> int:
    return 2 * _SUBLANE if itemsize < 4 else _SUBLANE


def sublane_for(dtype) -> int:
    """Minor-2 dim alignment: 8 rows fp32, 16 rows for 2-byte types."""
    return _sublane(jnp.dtype(dtype).itemsize)


def _storage(cfg: UOTConfig, storage_dtype):
    return jnp.dtype(storage_dtype if storage_dtype is not None else cfg.dtype)


def pick_block_m(M: int, N: int, itemsize: int = 4,
                 acc_itemsize: int = 4) -> int:
    """Largest power-of-two row block whose VMEM working set fits the budget.

    The working set per grid step is the in + out tiles in the storage dtype
    (``itemsize`` bytes/elt, double-buffered by the pipeline) plus the fp32
    compute copy of the tile (``acc_itemsize``): ``bm * N * (2*itemsize +
    acc_itemsize)`` bytes. Mixed precision (bf16 storage) therefore earns a
    larger block than fp32 at the same budget. The block is also clamped to
    not exceed the (padded) problem height — no point padding M past the
    next power of two.
    """
    sub = _sublane(itemsize)
    bytes_per_row = N * (2 * itemsize + acc_itemsize)
    bm = 512
    while bm > sub and (bm * bytes_per_row > _VMEM_BUDGET_BYTES
                        or bm >= 2 * M):
        bm //= 2
    return max(bm, sub)


def resident_fits(M: int, N: int, cfg: UOTConfig, *, storage_dtype=None,
                  budget_bytes: int | None = None,
                  implicit: bool = False) -> bool:
    """Whether a (M, N) problem can run on the VMEM-resident solver tier.

    The dense resident kernel (``uot_resident.resident_solve``) holds, per
    grid step (= per lane): the in and out tiles in the storage dtype
    (double-buffered by the pipeline), the fp32 working copy carried
    through the iteration loop, one fp32 temporary for the rescale
    products, and the O(M+N) factor/marginal vectors —
    ``Mp*Np*(2*s + 2*4)`` + vector bytes against the same budget
    ``pick_block_m`` uses for the streamed tier.

    ``implicit=True`` is the budget of the implicit-geometry kernel
    (``resident_solve_pc``): the cost operand is O((M + N) * d)
    coordinates computed into the working tile on-chip, so there is **no
    input tile** — the M*N-sized VMEM residents shrink to the coupling
    alone (out tile + fp32 working copy + rescale temporary:
    ``Mp*Np*(s + 2*4)``). At fp32 that is 12 bytes/element against the
    dense tier's 16, which is what lets ``impl='auto'`` route shapes to
    the resident tier under an implicit geometry that the dense path must
    stream (e.g. 1024x2048 fp32: 24 MiB implicit vs 32 MiB dense against
    the 32 MiB budget).

    The test is static (shapes, dtypes, budget), so ``impl='auto'``
    dispatch is decidable at trace time and batch size does not matter:
    the lane grid is sequential, one tile resident at a time.
    """
    sdt = _storage(cfg, storage_dtype)
    sub = _sublane(sdt.itemsize)
    Mp = M + (-M) % sub
    Np = N + (-N) % _LANE
    budget = _VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    acc = 4  # fp32 accumulator itemsize
    # dense: in + out storage tiles + fp32 working copy + rescale temp;
    # implicit: the input tile is computed, not loaded — out tile only
    per_elt = (sdt.itemsize + 2 * acc if implicit
               else 2 * sdt.itemsize + 2 * acc)
    tile_bytes = Mp * Np * per_elt
    vec_bytes = 4 * (Mp + Np) * acc  # a/frow/rowsum rows + b/colsum/fcol cols
    return tile_bytes + vec_bytes <= budget


# ``impl='auto'`` routing decisions, observable so the dispatch boundary is
# assertable in tests and visible in benchmarks. Only 'auto' counts — an
# explicit impl is the caller's decision, not the dispatcher's.
#
# Counters are *per-context*: the historical module-global dict is only the
# base of a contextvar-held stack, and ``dispatch_counters()`` pushes a fresh
# dict for the dynamic extent of a ``with`` block. Every decision increments
# every dict on the stack (outer scopes aggregate inner activity), and
# ``dispatch_stats()`` / ``reset_dispatch_stats()`` address the *innermost*
# scope — so two schedulers (or two tests) observing their own dispatch
# decisions no longer clobber each other's counts, and contextvars give each
# thread / asyncio task its own stack on top of the shared global base.
_DISPATCH_GLOBAL = {"resident": 0, "streamed": 0}
_DISPATCH_CTX: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "uot_dispatch_counters", default=(_DISPATCH_GLOBAL,))


@contextlib.contextmanager
def dispatch_counters():
    """Isolated ``impl='auto'`` decision counters for a ``with`` block.

    Yields a ``{'resident': 0, 'streamed': 0}`` dict that counts only the
    decisions made inside the block (in this thread/task); enclosing scopes
    — including the process-global base that ``dispatch_stats()`` reports
    outside any block — keep counting too.
    """
    counters = {"resident": 0, "streamed": 0}
    token = _DISPATCH_CTX.set(_DISPATCH_CTX.get() + (counters,))
    try:
        yield counters
    finally:
        _DISPATCH_CTX.reset(token)


def _count_dispatch(kind: str) -> None:
    for counters in _DISPATCH_CTX.get():
        counters[kind] += 1


# Dispatch *observers* ride the same contextvar-stack idiom as the
# counters, but receive the full decision context — enough to charge the
# docstring's per-solve traffic formulas without re-deriving the routing
# (repro.obs.TrafficAccountant is the intended subscriber).
_DISPATCH_OBS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "uot_dispatch_observers", default=())


@contextlib.contextmanager
def dispatch_observer(cb):
    """Subscribe ``cb(kind, M=, N=, itemsize=, num_iters=, implicit=)`` to
    every ``impl='auto'``/``'resident'`` routing decision made in the
    dynamic extent of the ``with`` block (this thread/task). ``kind`` is
    ``'resident'`` or ``'streamed'``; ``itemsize`` is the resolved storage
    dtype's; ``num_iters`` is the config's iteration budget (the modeled
    ``T`` — per-lane tol early exit is a device-side fact the host does
    not see). Observers stack: enclosing scopes keep receiving inner
    decisions, like ``dispatch_counters``.
    """
    token = _DISPATCH_OBS.set(_DISPATCH_OBS.get() + (cb,))
    try:
        yield cb
    finally:
        _DISPATCH_OBS.reset(token)


# Kernel-launch profiling rides the same contextvar-stack idiom one layer
# deeper than the dispatch observers: where ``dispatch_observer`` sees the
# routing *decision*, ``launch_profiler`` times the routed *launch* itself
# (``repro.obs.profile.KernelProfiler`` is the intended subscriber — its
# cells are keyed by the same parameters the traffic formulas take, so
# measured seconds divide modeled bytes directly). Timing a launch forces
# a ``block_until_ready`` sync, so nothing is timed unless a profiler is
# actually installed — and ``launch_profiler`` refuses disabled/null
# profilers outright, keeping the ``obs=False`` path sync-free.
_LAUNCH_PROF: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "uot_launch_profilers", default=())


@contextlib.contextmanager
def launch_profiler(profiler):
    """Install ``profiler.observe_launch(kernel=, M=, N=, itemsize=, impl=,
    source=, lanes=, iters=, seconds=)`` for every solve/chunk launch in
    the dynamic extent (this thread/task). ``impl`` is the resolved tier
    ('resident'/'streamed'); ``seconds`` is host wall time to completion
    (the launch is synced — do not install on a path whose async overlap
    you are measuring). A None or ``enabled=False`` profiler installs
    nothing. Profilers stack like the dispatch observers.
    """
    if profiler is None or not getattr(profiler, "enabled", False):
        yield profiler
        return
    token = _LAUNCH_PROF.set(_LAUNCH_PROF.get() + (profiler,))
    try:
        yield profiler
    finally:
        _LAUNCH_PROF.reset(token)


def _profiled(kernel, fn, *, M, N, itemsize, impl, source="dense",
              lanes=1, iters=1):
    """Run ``fn()``; when profilers are installed, time it to completion
    and feed every installed profiler the measurement cell."""
    profs = _LAUNCH_PROF.get()
    if not profs:
        return fn()
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    for p in profs:
        p.observe_launch(kernel=kernel, M=M, N=N, itemsize=itemsize,
                         impl=impl, source=source, lanes=lanes, iters=iters,
                         seconds=dt)
    return out


# Measurement-driven dispatch: ``impl='auto'`` consults installed advisors
# (``repro.obs.measure.MeasuredDispatch`` over a persisted measurement
# store) BEFORE falling back to the static ``resident_fits`` budget.
# Advice is only taken where the static semantics already allow resident
# (the VMEM budget and the sub-fp32 stepped exclusion are correctness
# constraints, not tunables) — so an advisor can flip a resident-eligible
# shape to streamed when measurements say streaming is faster, never the
# reverse past the budget.
_DISPATCH_ADVISORS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "uot_dispatch_advisors", default=())


@contextlib.contextmanager
def dispatch_advisor(advisor):
    """Install ``advisor.advise(M=, N=, itemsize=, implicit=, stepped=)
    -> 'resident' | 'streamed' | None`` for ``impl='auto'`` resolutions in
    the dynamic extent (this thread/task). The innermost advisor with an
    opinion (non-None) wins; None defers to the static budget."""
    token = _DISPATCH_ADVISORS.set(_DISPATCH_ADVISORS.get() + (advisor,))
    try:
        yield advisor
    finally:
        _DISPATCH_ADVISORS.reset(token)


def dispatch_stats() -> dict:
    """{'resident': ..., 'streamed': ...} decisions made by ``impl='auto'``
    in the innermost active ``dispatch_counters()`` scope (the process-wide
    totals when no scope is active)."""
    return dict(_DISPATCH_CTX.get()[-1])


def reset_dispatch_stats() -> None:
    """Zero the innermost active scope's counters (the process-wide totals
    when no ``dispatch_counters()`` scope is active)."""
    _DISPATCH_CTX.get()[-1].update(resident=0, streamed=0)


def pad_to(x: jax.Array, m_mult: int, n_mult: int) -> jax.Array:
    """Zero-pad the last two dims to multiples (works for 2-D and 3-D)."""
    M, N = x.shape[-2:]
    pm = (-M) % m_mult
    pn = (-N) % n_mult
    if pm or pn:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
        x = jnp.pad(x, pad)
    return x


def pad_vec(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the last dim to a multiple (works for (M,) and (B, M))."""
    p = (-x.shape[-1]) % mult
    if not p:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, p)]
    return jnp.pad(x, pad)


def solve_fused(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                *, block_m: int | None = None, interpret: bool | None = None,
                storage_dtype=None, impl: str | None = None, geometry=None):
    """MAP-UOT solve built entirely from the fused Pallas kernel.

    Matches core.sinkhorn_uot_fused iterates (asserted in tests). Inputs of
    arbitrary shape; zero-padded internally to (block_m, 128) multiples.
    ``storage_dtype`` (default ``cfg.dtype``) sets the in-HBM dtype of the
    coupling matrix; accumulation/factors stay fp32.

    ``impl``: None/'kernel' runs the streamed per-iteration kernel loop
    (this function's historical behavior, fixed ``cfg.num_iters``);
    'resident' runs the whole solve VMEM-resident (one HBM read + write of
    the coupling for the entire solve, and — unlike the streamed path here
    — honoring ``cfg.tol`` early exit); 'auto' picks by ``resident_fits``.

    ``geometry=`` (exclusive with ``A0``) sources the initial coupling
    from a ``repro.geometry.Geometry``: ``A0 = K = exp(-C / reg)``. The
    solve is routed through the batched core at B=1, so — like 'auto' —
    it has ``cfg.tol`` per-lane early-exit semantics, and every ``impl``
    (including the default and 'jnp') is accepted. Implicit geometries
    never materialize an M*N cost array in HBM on the kernel path.
    """
    if geometry is not None:
        if A0 is not None:
            raise ValueError("pass either A0 or geometry=, not both")
        g = (_pc_batched(geometry)
             if isinstance(geometry, PointCloudGeometry) else geometry)
        P, colsum = solve_fused_batched(
            None, a[None], b[None], cfg, block_m=block_m,
            interpret=interpret, storage_dtype=storage_dtype, impl=impl,
            geometry=g)
        return P[0], colsum[0]
    if impl not in (None, "kernel", "auto", "resident"):
        raise ValueError(
            f"solve_fused impl must be None, 'kernel', 'auto' or 'resident',"
            f" got {impl!r} (for the vectorized XLA path use the core jnp"
            f" solvers or solve_fused_batched)")
    if impl in ("auto", "resident"):
        M, N = A0.shape
        if _resolve_auto(impl, M, N, cfg, storage_dtype):
            P, colsum, _, _ = solve_fused_resident(
                A0, a, b, cfg, interpret=interpret,
                storage_dtype=storage_dtype)
            return P, colsum
        # Over budget: stream via the batched path at B=1 rather than the
        # legacy fixed-iteration loop below, so 'auto' keeps tol semantics
        # (per-lane early exit) consistent across the dispatch boundary —
        # results must differ by tier in *traffic*, never in math.
        P, colsum = solve_fused_batched(
            A0[None], a[None], b[None], cfg, block_m=block_m,
            interpret=interpret, storage_dtype=storage_dtype)
        return P[0], colsum[0]
    return _solve_fused_streamed(A0, a, b, cfg, block_m=block_m,
                                 interpret=interpret,
                                 storage_dtype=storage_dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "storage_dtype"))
def _solve_fused_streamed(A0: jax.Array, a: jax.Array, b: jax.Array,
                          cfg: UOTConfig, *, block_m: int | None = None,
                          interpret: bool | None = None, storage_dtype=None):
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Ap = pad_to(A0.astype(sdt), bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    colsum = uot_fused.colsum(Ap, block_m=bm, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, colsum = uot_fused.fused_iteration(
            A, fcol, ap, fi=fi, block_m=bm, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


def _impl_default(impl, interpret):
    """'kernel' (Pallas) on TPU; vectorized 'jnp' elsewhere.

    Interpret-mode pallas emulation scans the grid carrying the WHOLE stack
    through a while_loop with full-buffer dynamic updates per grid step —
    O(grid * B*M*N) traffic — so it is for validation, not speed. Tests pin
    ``impl='kernel', interpret=True`` to exercise the real kernel schedule.

    'auto' and 'resident' pass through — the public wrappers resolve them
    to a tier (see ``resident_fits``) before reaching the jitted streamed
    cores, which only ever see 'kernel' or 'jnp'.
    """
    if impl is None:
        return "kernel" if (on_tpu() and not interpret) else "jnp"
    if impl not in ("kernel", "jnp", "auto", "resident"):
        raise ValueError(f"impl must be 'kernel', 'jnp', 'auto' or "
                         f"'resident', got {impl!r}")
    return impl


def _resolve_auto(impl, M, N, cfg, storage_dtype, *, stepped_sdt=None,
                  implicit=False):
    """Resolve 'auto'/'resident' to a tier for a (M, N) problem.

    Returns True to route resident. For the stepped path pass the pool's
    storage dtype as ``stepped_sdt``: sub-fp32 pools never auto-route
    resident, because the resident chunk rounds the tile once per chunk
    instead of once per iteration, which would make a bf16 lane's iterates
    depend on chunk boundaries (the streamed stepped path guarantees
    chunk-boundary invariance; see ``uot_resident.resident_stepped``).
    ``implicit`` selects the implicit-geometry VMEM budget (no input tile
    — see ``resident_fits``), widening the resident shape range.

    With a ``dispatch_advisor`` installed, a resident-eligible 'auto'
    resolution asks it first — measured tier costs override the static
    budget's guess where a measurement cell has data (None defers).
    """
    fits = resident_fits(M, N, cfg, storage_dtype=storage_dtype,
                         implicit=implicit)
    if impl == "resident":
        if not fits:
            raise ValueError(
                f"({M}, {N}) exceeds the resident VMEM budget; use "
                f"impl='auto' to fall back to the streamed tier")
        return True
    s = _storage(cfg, stepped_sdt if stepped_sdt is not None
                 else storage_dtype).itemsize
    resident = fits and not (stepped_sdt is not None
                             and jnp.dtype(stepped_sdt).itemsize < 4)
    if resident:
        for adv in reversed(_DISPATCH_ADVISORS.get()):
            choice = adv.advise(M=M, N=N, itemsize=s, implicit=implicit,
                                stepped=stepped_sdt is not None)
            if choice in ("resident", "streamed"):
                resident = choice == "resident"
                break
    kind = "resident" if resident else "streamed"
    _count_dispatch(kind)
    for cb in _DISPATCH_OBS.get():
        cb(kind, M=M, N=N, itemsize=s, num_iters=cfg.num_iters,
           implicit=implicit)
    return resident


def _stepped_iter(A, colsum, upd, *, ap, bp, fi, sdt, impl, bm, interpret):
    """One (optionally masked) batched Algorithm-1 iteration on padded state.

    ``upd`` is a (B,) bool lane mask or None. With ``upd=None`` every lane
    is updated and the row factors are not materialized on the kernel path
    (the lean fixed-iteration path). With a mask, lanes where ``upd`` is
    False keep their (A, colsum) bit-for-bit — per-lane math is
    independent, so a frozen lane's iterate is exactly the one it had when
    its flag fired. Freezing is free of extra M*N traffic: the jnp path
    masks the two rescale *factors* to exactly 1.0 (a multiplicative
    no-op, so no full-size select materializes), and the kernel path
    selects input-vs-result per tile while it is already in VMEM. Only the
    O(B*N) colsum keeps an explicit select, pinning the carried-colsum
    value under bf16 storage (recomputing it from a stored bf16 tile would
    drift by a rounding, making results chunk-boundary-dependent).

    Returns (A', colsum', frow) where frow (B, M) are this iteration's
    *computed* row factors even for frozen lanes (None on the unmasked
    kernel path); the caller turns successive frows into the per-lane
    stationarity drift via ``lane_factor_drift`` and masks what it carries.
    """
    fcol = rescale_factors(bp, colsum, fi)
    if impl == "jnp":
        fcol_m = (fcol if upd is None
                  else jnp.where(upd[:, None], fcol, 1.0))
        blk = A.astype(jnp.float32) * fcol_m[:, None, :]
        rowsum = blk.sum(axis=2)
        frow = rescale_factors(ap, rowsum, fi)
        frow_m = (frow if upd is None
                  else jnp.where(upd[:, None], frow, 1.0))
        blk = blk * frow_m[:, :, None]
        newA, newcs = blk.astype(sdt), blk.sum(axis=1)
    elif upd is None:
        newA, newcs = uot_batched.batched_fused_iteration(
            A, fcol, ap, fi=fi, block_m=bm, interpret=interpret)
        frow = None
    else:
        newA, newcs, frow = uot_batched.batched_fused_iteration_frow(
            A, fcol, ap, upd, fi=fi, block_m=bm, interpret=interpret)
    if upd is None:
        return newA, newcs, frow
    colsum = jnp.where(upd[:, None], newcs, colsum)
    return newA, colsum, frow


# ---- implicit-geometry plumbing -------------------------------------------

def _pc_batched(g: PointCloudGeometry) -> PointCloudGeometry:
    """Lift a single-problem point-cloud geometry to a batch of one."""
    if g.batch_shape:
        return g
    return dataclasses.replace(
        g, x=g.x[None], y=g.y[None], xn=g.xn[None], yn=g.yn[None],
        m_valid=None if g.m_valid is None else jnp.reshape(g.m_valid, (1,)),
        n_valid=None if g.n_valid is None else jnp.reshape(g.n_valid, (1,)))


def _pc_padded_operands(g: PointCloudGeometry, Mp: int, Np: int):
    """Zero-pad the coordinate operands to kernel-aligned (Mp, Np); returns
    (x, xn, y, yn, m_valid, n_valid) ready for the pc kernels.

    Padded coordinate rows are zeros; it is the kernels' validity mask
    (not the coordinate values) that makes the padded region of every
    computed tile exactly 0.0, mirroring a zero-padded dense stack.
    """
    B, M, _ = g.x.shape
    N = g.y.shape[1]
    x = jnp.pad(g.x, ((0, 0), (0, Mp - M), (0, 0)))
    xn = jnp.pad(g.xn, ((0, 0), (0, Mp - M)))
    y = jnp.pad(g.y, ((0, 0), (0, Np - N), (0, 0)))
    yn = jnp.pad(g.yn, ((0, 0), (0, Np - N)))
    mv = (jnp.full((B,), M, jnp.int32) if g.m_valid is None
          else g.m_valid.astype(jnp.int32))
    nv = (jnp.full((B,), N, jnp.int32) if g.n_valid is None
          else g.n_valid.astype(jnp.int32))
    return x, xn, y, yn, mv, nv


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "storage_dtype"))
def _solve_fused_batched_geometry_streamed(geom: PointCloudGeometry,
                                           a: jax.Array, b: jax.Array,
                                           cfg: UOTConfig, *,
                                           block_m: int | None = None,
                                           interpret: bool | None = None,
                                           storage_dtype=None):
    """Streamed batched solve with the Gibbs kernel computed on-chip.

    The implicit twin of ``_solve_fused_batched_streamed``'s 'kernel'
    path: Algorithm 1's preprocessing colsum and first iteration evaluate
    cost tiles in VMEM from the geometry's coordinates
    (``uot_geometry.batched_pc_*``) — the initial coupling never exists in
    HBM; the solve's first M*N write is the already-rescaled ``A1``. From
    iteration 2 the coupling is ordinary solver state and the standard
    streamed kernels take over, with identical blocking and identical
    tol bookkeeping (first-iteration drift vs unit factors), so the
    iterates match the dense-load path bit-for-bit.
    """
    interpret = _interpret_default(interpret)
    M, N = geom.shape
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Mp = M + (-M) % bm
    Np = N + (-N) % _LANE
    x, xn, y, yn, mv, nv = _pc_padded_operands(geom, Mp, Np)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi
    reg, scale = float(cfg.reg), geom.scale

    colsum0 = uot_geometry.batched_pc_colsum(
        x, xn, y, yn, mv, nv, reg=reg, scale=scale, block_m=bm,
        interpret=interpret, storage_dtype=sdt)
    if cfg.num_iters == 0:
        A = uot_geometry.batched_pc_materialize(
            x, xn, y, yn, mv, nv, reg=reg, scale=scale, block_m=bm,
            interpret=interpret, out_dtype=sdt)
        return A[:, :M, :N], colsum0[:, :N]

    fcol = rescale_factors(bp, colsum0, fi)
    Ap, colsum, frow1 = uot_geometry.batched_pc_first_iteration(
        fcol, ap, x, xn, y, yn, mv, nv, fi=fi, reg=reg, scale=scale,
        block_m=bm, interpret=interpret, out_dtype=sdt)

    it = functools.partial(_stepped_iter, ap=ap, bp=bp, fi=fi, sdt=sdt,
                           impl="kernel", bm=bm, interpret=interpret)
    if cfg.tol is None:
        def body(_, carry):
            A, colsum = carry
            A, colsum, _ = it(A, colsum, None)
            return A, colsum
        Ap, colsum = jax.lax.fori_loop(1, cfg.num_iters, body, (Ap, colsum))
    else:
        # same bookkeeping as the dense while_loop's first pass: drift of
        # the first row factors against the all-ones prior
        drift1 = lane_factor_drift(frow1, jnp.ones_like(ap))
        conv1 = drift1 <= cfg.tol

        def cond(carry):
            _, _, _, conv, i = carry
            return jnp.logical_and(i < cfg.num_iters, ~jnp.all(conv))

        def wbody(carry):
            A, colsum, prev_frow, conv, i = carry
            upd = ~conv
            A, colsum, frow = it(A, colsum, upd)
            drift = lane_factor_drift(frow, prev_frow)
            prev_frow = jnp.where(upd[:, None], frow, prev_frow)
            return A, colsum, prev_frow, conv | (drift <= cfg.tol), i + 1

        Ap, colsum, _, _, _ = jax.lax.while_loop(
            cond, wbody, (Ap, colsum, frow1, conv1, jnp.int32(1)))
    return Ap[:, :M, :N], colsum[:, :N]


def _solve_fused_batched_geometry(geom, a, b, cfg, *, block_m=None,
                                  interpret=None, storage_dtype=None,
                                  impl=None):
    """Dispatch a batched geometry solve to a tier + flavor.

    Implicit point-cloud geometries route between the tile-compute
    streamed kernels, the implicit resident kernel (with the widened
    ``resident_fits(implicit=True)`` budget) and the jnp mirror (which
    materializes the masked Gibbs stack on-device — the host still never
    ships an M*N operand). Explicit/materializable geometries (dense,
    grid) materialize their Gibbs mirror once and take the ordinary dense
    path unchanged.
    """
    if not isinstance(geom, Geometry):
        raise TypeError(f"geometry= expects a repro.geometry.Geometry, "
                        f"got {type(geom).__name__}")
    B = a.shape[0]
    if not isinstance(geom, PointCloudGeometry):
        A0 = geom.kernel(cfg.reg)
        if A0.ndim == 2:
            A0 = jnp.broadcast_to(A0, (B,) + A0.shape)
        return solve_fused_batched(A0, a, b, cfg, block_m=block_m,
                                   interpret=interpret,
                                   storage_dtype=storage_dtype, impl=impl)
    geom = _pc_batched(geom)
    if geom.x.shape[0] != B:
        raise ValueError(f"geometry batch {geom.x.shape[0]} != marginal "
                         f"batch {B}")
    interp = _interpret_default(interpret)
    impl = _impl_default(impl, interp)
    M, N = geom.shape
    s = _storage(cfg, storage_dtype).itemsize
    if impl in ("auto", "resident"):
        if _resolve_auto(impl, M, N, cfg, storage_dtype, implicit=True):
            P, colsum, _, _ = _profiled(
                "solve", lambda: solve_fused_resident(
                    None, a, b, cfg, interpret=interpret,
                    storage_dtype=storage_dtype, geometry=geom),
                M=M, N=N, itemsize=s, impl="resident", source="implicit",
                lanes=B, iters=cfg.num_iters)
            return P, colsum
        impl = _impl_default(None, interp)  # over budget: streamed default
    if impl == "jnp":
        A0 = geom.kernel(cfg.reg)
        return _profiled(
            "solve", lambda: _solve_fused_batched_streamed(
                A0, a, b, cfg, block_m=block_m, interpret=interpret,
                storage_dtype=storage_dtype, impl="jnp"),
            M=M, N=N, itemsize=s, impl="streamed", source="implicit",
            lanes=B, iters=cfg.num_iters)
    return _profiled(
        "solve", lambda: _solve_fused_batched_geometry_streamed(
            geom, a, b, cfg, block_m=block_m, interpret=interpret,
            storage_dtype=storage_dtype),
        M=M, N=N, itemsize=s, impl="streamed", source="implicit",
        lanes=B, iters=cfg.num_iters)


def solve_fused_batched(A0: jax.Array, a: jax.Array, b: jax.Array,
                        cfg: UOTConfig, *, block_m: int | None = None,
                        interpret: bool | None = None, storage_dtype=None,
                        impl: str | None = None, geometry=None):
    """MAP-UOT solve for a stack of same-shape problems in one launch.

    A0: (B, M, N); a: (B, M); b: (B, N). On TPU (``impl='kernel'``) one
    ``(batch, row_blocks)``-grid pallas_call per iteration covers the whole
    stack — one dispatch instead of B, with each problem keeping the
    read+write-once schedule and its own (1, N) column-sum accumulator.
    ``impl='jnp'`` (the non-TPU default) runs the identical padded
    iteration math vectorized over the batch in XLA. ``impl='resident'``
    runs the whole solve on the VMEM-resident tier (one read + one write of
    each coupling for the entire solve; bf16 storage is rounded once at
    the end instead of every iteration); ``impl='auto'`` picks the tier by
    ``resident_fits``. Returns (P, colsum) of shapes (B, M, N) and (B, N).

    With ``cfg.tol`` set the solve early-exits per lane: a lane whose
    row-factor stationarity ``max|frow_t - frow_{t-1}|`` (the same
    criterion as the single-problem solvers — see ``sinkhorn_baseline`` on
    why not ``|f - 1|``) falls to ``tol`` is frozen (masked out of further
    updates on the streamed tier; stops computing on the resident tier) at
    exactly that iterate, and the loop ends once every lane has converged
    or ``num_iters`` is hit — fixed-shape batches stop dragging
    already-converged problems to the iteration cap.

    ``geometry=`` (exclusive with ``A0``) sources the initial coupling
    from a ``repro.geometry.Geometry`` instead of a dense stack: the
    Gibbs kernel ``K = exp(-C / reg)`` becomes ``A0``. For implicit
    geometries (``PointCloudGeometry``, batched coordinates + optional
    per-problem valid counts) the 'kernel' path computes cost tiles
    on-chip and never materializes an M*N cost array in HBM, and
    ``impl='auto'`` uses the widened implicit resident budget (see
    ``resident_fits``); couplings match the dense-load path bit-for-bit
    in fp32.
    """
    if geometry is not None:
        if A0 is not None:
            raise ValueError("pass either A0 or geometry=, not both")
        return _solve_fused_batched_geometry(
            geometry, a, b, cfg, block_m=block_m, interpret=interpret,
            storage_dtype=storage_dtype, impl=impl)
    impl = _impl_default(impl, _interpret_default(interpret))
    B, M, N = A0.shape
    s = _storage(cfg, storage_dtype).itemsize
    if impl in ("auto", "resident"):
        if _resolve_auto(impl, M, N, cfg, storage_dtype):
            P, colsum, _, _ = _profiled(
                "solve", lambda: solve_fused_resident(
                    A0, a, b, cfg, interpret=interpret,
                    storage_dtype=storage_dtype),
                M=M, N=N, itemsize=s, impl="resident", lanes=B,
                iters=cfg.num_iters)
            return P, colsum
        impl = None  # over budget: fall through to the streamed default
    return _profiled(
        "solve", lambda: _solve_fused_batched_streamed(
            A0, a, b, cfg, block_m=block_m, interpret=interpret,
            storage_dtype=storage_dtype, impl=impl),
        M=M, N=N, itemsize=s, impl="streamed", lanes=B,
        iters=cfg.num_iters)


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "storage_dtype", "impl"))
def _solve_fused_batched_streamed(A0: jax.Array, a: jax.Array, b: jax.Array,
                                  cfg: UOTConfig, *,
                                  block_m: int | None = None,
                                  interpret: bool | None = None,
                                  storage_dtype=None,
                                  impl: str | None = None):
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    B, M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Ap = pad_to(A0.astype(sdt), bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    if impl == "jnp":
        colsum = Ap.astype(jnp.float32).sum(axis=1)
    else:
        colsum = uot_batched.batched_colsum(
            Ap, block_m=bm, interpret=interpret)

    it = functools.partial(_stepped_iter, ap=ap, bp=bp, fi=fi, sdt=sdt,
                           impl=impl, bm=bm, interpret=interpret)
    if cfg.tol is None:
        def body(_, carry):
            A, colsum = carry
            A, colsum, _ = it(A, colsum, None)
            return A, colsum
        Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    else:
        def cond(carry):
            _, _, _, conv, i = carry
            return jnp.logical_and(i < cfg.num_iters, ~jnp.all(conv))

        def wbody(carry):
            A, colsum, prev_frow, conv, i = carry
            upd = ~conv
            A, colsum, frow = it(A, colsum, upd)
            drift = lane_factor_drift(frow, prev_frow)
            prev_frow = jnp.where(upd[:, None], frow, prev_frow)
            return A, colsum, prev_frow, conv | (drift <= cfg.tol), i + 1

        Ap, colsum, _, _, _ = jax.lax.while_loop(
            cond, wbody, (Ap, colsum, jnp.ones_like(ap),
                          jnp.zeros((B,), bool), jnp.int32(0)))
    return Ap[:, :M, :N], colsum[:, :N]


def solve_fused_resident(A0: jax.Array, a: jax.Array, b: jax.Array,
                         cfg: UOTConfig, *, interpret: bool | None = None,
                         storage_dtype=None, impl: str | None = None,
                         geometry=None):
    """Whole-solve VMEM-resident MAP-UOT: load once, iterate, store once.

    A0 may be (M, N) or (B, M, N) (a/b matching). ``impl`` selects the
    flavor *within* the resident tier with the usual convention: 'kernel'
    is the Pallas lane-grid kernel (``uot_resident.resident_solve``; TPU
    default, interpretable on CPU for validation), 'jnp' (non-TPU default)
    is the same iteration fusion in one XLA executable. Both honor
    ``cfg.tol`` per lane with the streamed solvers' row-factor-stationarity
    criterion — same iterate, same iteration count.

    ``geometry=`` (exclusive with ``A0``) sources the tile from a
    ``Geometry``. Implicit point-cloud geometries run
    ``uot_resident.resident_solve_pc`` on the 'kernel' flavor — each
    lane's tile is COMPUTED in VMEM from its coordinates (per-solve
    coupling HBM traffic: write MN, no read) — and are budgeted with
    ``resident_fits(implicit=True)``, which admits shapes the dense tier
    must stream. The 'jnp' flavor materializes the Gibbs mirror on-device
    first (the host still never ships an M*N operand).

    Returns (P, colsum, iters, err); leading batch dims only if A0/the
    marginals had one. The extra per-lane outputs (iteration counts, final
    drift) come for free from the in-kernel convergence loop and are what
    the parity tests pin against the streamed tier.
    """
    interpret = _interpret_default(interpret)
    if impl not in (None, "kernel", "jnp"):
        raise ValueError(f"resident flavor must be None, 'kernel' or 'jnp', "
                         f"got {impl!r}")
    flavor = _impl_default(impl, interpret)
    if geometry is not None:
        if A0 is not None:
            raise ValueError("pass either A0 or geometry=, not both")
        return _solve_fused_resident_geometry(
            geometry, a, b, cfg, interpret=interpret,
            storage_dtype=storage_dtype, flavor=flavor)
    single = A0.ndim == 2
    if single:
        A0, a, b = A0[None], a[None], b[None]
    B, M, N = A0.shape
    if not resident_fits(M, N, cfg, storage_dtype=storage_dtype):
        # guard here too (not just in the impl='resident' dispatch routes)
        # so an over-budget shape gets this error instead of an opaque
        # Mosaic VMEM-exhaustion failure from the whole-tile BlockSpec
        raise ValueError(
            f"({M}, {N}) exceeds the resident VMEM budget; use "
            f"impl='auto' to fall back to the streamed tier")
    sdt = _storage(cfg, storage_dtype)
    sub = _sublane(sdt.itemsize)
    Ap = pad_to(A0.astype(sdt), sub, _LANE)
    ap = pad_vec(a.astype(jnp.float32), sub)
    bp = pad_vec(b.astype(jnp.float32), _LANE)
    if flavor == "kernel":
        P, colsum, iters, err = uot_resident.resident_solve(
            Ap, ap, bp, fi=cfg.fi, num_iters=cfg.num_iters, tol=cfg.tol,
            interpret=interpret)
    else:
        P, colsum, iters, err = uot_resident.resident_solve_jnp(
            Ap, ap, bp, fi=cfg.fi, num_iters=cfg.num_iters, tol=cfg.tol,
            out_dtype=sdt)
    P, colsum = P[:, :M, :N], colsum[:, :N]
    if single:
        return P[0], colsum[0], iters[0], err[0]
    return P, colsum, iters, err


def _solve_fused_resident_geometry(geom, a, b, cfg, *, interpret, flavor,
                                   storage_dtype=None):
    """Resident-tier solve with the tile sourced from a ``Geometry``."""
    if not isinstance(geom, Geometry):
        raise TypeError(f"geometry= expects a repro.geometry.Geometry, "
                        f"got {type(geom).__name__}")
    if not isinstance(geom, PointCloudGeometry):
        A0 = geom.kernel(cfg.reg)
        if A0.ndim == 2 and a.ndim == 2:
            A0 = jnp.broadcast_to(A0, (a.shape[0],) + A0.shape)
        return solve_fused_resident(A0, a, b, cfg, interpret=interpret,
                                    storage_dtype=storage_dtype,
                                    impl=flavor)
    single = a.ndim == 1
    if single:
        a, b = a[None], b[None]
    geom = _pc_batched(geom)
    B = a.shape[0]
    if geom.x.shape[0] != B:
        raise ValueError(f"geometry batch {geom.x.shape[0]} != marginal "
                         f"batch {B}")
    M, N = geom.shape
    if not resident_fits(M, N, cfg, storage_dtype=storage_dtype,
                         implicit=True):
        raise ValueError(
            f"({M}, {N}) exceeds the implicit resident VMEM budget; use "
            f"impl='auto' to fall back to the streamed tier")
    sdt = _storage(cfg, storage_dtype)
    sub = _sublane(sdt.itemsize)
    Mp = M + (-M) % sub
    Np = N + (-N) % _LANE
    ap = pad_vec(a.astype(jnp.float32), sub)
    bp = pad_vec(b.astype(jnp.float32), _LANE)
    if flavor == "kernel":
        x, xn, y, yn, mv, nv = _pc_padded_operands(geom, Mp, Np)
        P, colsum, iters, err = uot_resident.resident_solve_pc(
            x, xn, y, yn, ap, bp, mv, nv, fi=cfg.fi, reg=float(cfg.reg),
            scale=geom.scale, num_iters=cfg.num_iters, tol=cfg.tol,
            interpret=interpret, out_dtype=sdt)
    else:
        Ap = pad_to(geom.kernel(cfg.reg).astype(sdt), sub, _LANE)
        P, colsum, iters, err = uot_resident.resident_solve_jnp(
            Ap, ap, bp, fi=cfg.fi, num_iters=cfg.num_iters, tol=cfg.tol,
            out_dtype=sdt)
    P, colsum = P[:, :M, :N], colsum[:, :N]
    if single:
        return P[0], colsum[0], iters[0], err[0]
    return P, colsum, iters, err


# ---- steppable solving: explicit carried state for continuous batching ----

@dataclasses.dataclass
class LaneState:
    """Carried state of a fixed pool of batched solver lanes.

    A *lane* is one slot of a padded (L, Mp, Np) problem stack — the UOT
    analogue of an LLM serving slot. The pool is advanced a chunk of
    Algorithm-1 iterations at a time by ``solve_fused_stepped``; between
    chunks a host-side scheduler may ``lane_evict`` finished lanes and
    ``lane_admit`` queued problems into the freed slots, which is what makes
    continuous batching possible (admission never waits for the whole stack
    to finish). Free lanes hold all-zero problems — exact no-ops for the
    rescaling math — so a partially occupied pool computes the same answers
    as a dense one. Per-lane math is independent of pool occupancy, so a
    problem's trajectory is identical whatever lane it lands in and whatever
    shares the pool.

    Fields (all jax arrays; the dataclass is a registered pytree so it can
    be carried through jit/fori_loop):
      P:         (L, Mp, Np) coupling iterate, storage dtype (fp32 or bf16).
      colsum:    (L, Np) fp32 carried column sums (Algorithm 1's interweaved
                 accumulator, valid for the *next* column rescale).
      a, b:      (L, Mp) / (L, Np) fp32 marginals, zero-padded.
      frow:      (L, Mp) fp32 row rescale factors of the lane's previous
                 iteration (ones at admission) — successive frows give the
                 per-lane stationarity drift, the convergence criterion.
      iters:     (L,) int32 iterations each lane has run since admission.
      converged: (L,) bool — the lane's factor drift fell to ``cfg.tol``
                 (never set when ``cfg.tol`` is None).
      active:    (L,) bool — lane holds a live problem.
      healthy:   (L,) bool — the lane's iterates are numerically sound.
                 Cleared (latched False) by the stepped advance when the
                 lane's freshly computed row factors or carried column
                 sums go non-finite; an unhealthy lane is frozen exactly
                 like a converged one (its poison never multiplies back
                 into the pool) and reads as finished via ``lane_done``,
                 so a scheduler evicts it at the next chunk boundary.
                 Detection is traffic-free: the detector folds over the
                 O(L*(M+N)) frow/colsum values the convergence check
                 already holds — the M*N tile is never rescanned.
      m_valid:   (L,) int32 valid row count of each lane's problem (0 for a
                 free lane). Everything beyond it is exact zero padding.
      n_valid:   (L,) int32 valid column count, likewise.

    ``m_valid`` / ``n_valid`` are what let one *physical* pool host lanes of
    several padded shapes (cross-bucket lane sharing): zero-padding is an
    exact no-op for the rescaling math — padded rows/cols carry zero mass,
    get unit factors, and appended zeros are exact identities of every float
    reduction — so a lane admitted into a pool wider than its own bucket
    produces the bit-identical iterate on its valid region, and the counts
    record where that region ends without consulting host-side request
    metadata. ``lane_admit`` *enforces* the mask (zeroes everything beyond
    the counts) so a sloppy caller cannot leak payload into the padding.
    """

    P: jax.Array
    colsum: jax.Array
    a: jax.Array
    b: jax.Array
    frow: jax.Array
    iters: jax.Array
    converged: jax.Array
    active: jax.Array
    m_valid: jax.Array
    n_valid: jax.Array
    healthy: jax.Array

    @property
    def num_lanes(self) -> int:
        return self.P.shape[0]


jax.tree_util.register_dataclass(
    LaneState,
    data_fields=["P", "colsum", "a", "b", "frow", "iters", "converged",
                 "active", "m_valid", "n_valid", "healthy"],
    meta_fields=[])


def make_lane_state(num_lanes: int, M: int, N: int, cfg: UOTConfig, *,
                    block_m: int | None = None,
                    storage_dtype=None) -> LaneState:
    """Empty lane pool for problems of (padded) shape up to (M, N).

    The pool's internal shape is (M, N) rounded up to kernel alignment
    (row-block multiple, lane-width columns); admitted problems may be any
    shape that fits. One pool per shape bucket is the intended layout.
    """
    sdt = _storage(cfg, storage_dtype)
    bm = block_m or pick_block_m(M, N, sdt.itemsize)
    Mp = M + (-M) % bm
    Np = N + (-N) % _LANE
    L = num_lanes
    return LaneState(
        P=jnp.zeros((L, Mp, Np), sdt),
        colsum=jnp.zeros((L, Np), jnp.float32),
        a=jnp.zeros((L, Mp), jnp.float32),
        b=jnp.zeros((L, Np), jnp.float32),
        frow=jnp.ones((L, Mp), jnp.float32),
        iters=jnp.zeros((L,), jnp.int32),
        converged=jnp.zeros((L,), bool),
        active=jnp.zeros((L,), bool),
        m_valid=jnp.zeros((L,), jnp.int32),
        n_valid=jnp.zeros((L,), jnp.int32),
        healthy=jnp.ones((L,), bool))


def _pad_admit_payload(Mp: int, Np: int, K: jax.Array, a: jax.Array,
                       b: jax.Array, m_valid, n_valid, storage_dtype):
    """Zero-pad (and validity-mask) an admission payload to a pool shape.

    K (..., M, N), a (..., M), b (..., N); ``m_valid`` / ``n_valid`` are
    optional per-problem valid counts (int scalars or (...,) vectors,
    default: the payload's own M, N — i.e. the whole payload is live).
    Returns (Kp, ap, bp, mv, nv) padded to (Mp, Np) with everything beyond
    the valid counts forced to exactly 0.0 — the invariant cross-bucket
    lane sharing rests on. Shared by ``lane_admit`` and the cluster-tier
    admission (``repro.cluster``).
    """
    M, N = K.shape[-2:]
    lead = K.shape[:-2]
    mv = (jnp.full(lead, M, jnp.int32) if m_valid is None
          else jnp.broadcast_to(jnp.asarray(m_valid, jnp.int32), lead))
    nv = (jnp.full(lead, N, jnp.int32) if n_valid is None
          else jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), lead))
    Kp = jnp.zeros(lead + (Mp, Np), storage_dtype).at[..., :M, :N].set(
        K.astype(storage_dtype))
    ap = jnp.zeros(lead + (Mp,), jnp.float32).at[..., :M].set(
        a.astype(jnp.float32))
    bp = jnp.zeros(lead + (Np,), jnp.float32).at[..., :N].set(
        b.astype(jnp.float32))
    # enforce the mask: rows/cols beyond the per-problem valid counts are
    # exact zeros even if the caller's payload carried junk there (a no-op
    # — where(True, x, 0) is x — for the default whole-payload counts)
    rmask = jnp.arange(Mp) < mv[..., None]
    cmask = jnp.arange(Np) < nv[..., None]
    Kp = jnp.where(rmask[..., :, None] & cmask[..., None, :], Kp, 0)
    ap = jnp.where(rmask, ap, 0)
    bp = jnp.where(cmask, bp, 0)
    return Kp, ap, bp, mv, nv


@jax.jit
def lane_admit(state: LaneState, lane, K: jax.Array, a: jax.Array,
               b: jax.Array, m_valid=None, n_valid=None) -> LaneState:
    """Load one problem — or a batch — into lane(s) ``lane`` of the pool.

    ``lane`` is a traced int (K (M, N), a (M,), b (N,)) or a (k,) int
    vector (K (k, M, N), a (k, M), b (k, N)) — a whole scheduling round's
    admissions land in ONE pool update instead of k full-pytree copies.
    K/a/b are zero-padded to the pool shape. The carried column sums are
    initialized from the *stored* (possibly bf16-downcast) matrix, so a
    lane's trajectory is bit-identical to ``solve_fused_batched`` on the
    same problem.

    ``m_valid`` / ``n_valid`` (optional, int or (k,) vectors) record — and
    enforce, by masking the payload to exact zeros beyond them — each
    problem's live extent, which may be strictly smaller than the payload
    shape: the cross-bucket lane-sharing groundwork. A problem admitted
    with valid counts (M', N') into any pool wide enough for them computes
    the bit-identical iterate on its valid region as in a pool of its own
    bucket shape (appended zeros are exact identities of every reduction;
    property-tested in tests/test_cluster.py).
    """
    Mp, Np = state.P.shape[1:]
    Kp, ap, bp, mv, nv = _pad_admit_payload(Mp, Np, K, a, b, m_valid,
                                            n_valid, state.P.dtype)
    return LaneState(
        P=state.P.at[lane].set(Kp),
        colsum=state.colsum.at[lane].set(Kp.astype(jnp.float32).sum(-2)),
        a=state.a.at[lane].set(ap),
        b=state.b.at[lane].set(bp),
        frow=state.frow.at[lane].set(1.0),
        iters=state.iters.at[lane].set(0),
        converged=state.converged.at[lane].set(False),
        active=state.active.at[lane].set(True),
        m_valid=state.m_valid.at[lane].set(mv),
        n_valid=state.n_valid.at[lane].set(nv),
        healthy=state.healthy.at[lane].set(True))


@jax.jit
def lane_evict(state: LaneState, lane) -> LaneState:
    """Free lane(s) ``lane`` (int or (k,) int vector): zero the problem(s)
    and drop the active flag — one pool update however many lanes retire.

    Zero rows/cols are exact no-ops for the rescaling math, so an idle lane
    costs only the (already-paid) bandwidth of its share of the stack.
    """
    return LaneState(
        P=state.P.at[lane].set(jnp.zeros(state.P.shape[1:], state.P.dtype)),
        colsum=state.colsum.at[lane].set(0.0),
        a=state.a.at[lane].set(0.0),
        b=state.b.at[lane].set(0.0),
        frow=state.frow.at[lane].set(1.0),
        iters=state.iters.at[lane].set(0),
        converged=state.converged.at[lane].set(False),
        active=state.active.at[lane].set(False),
        m_valid=state.m_valid.at[lane].set(0),
        n_valid=state.n_valid.at[lane].set(0),
        healthy=state.healthy.at[lane].set(True))


@functools.partial(jax.jit, static_argnames=("max_iters",))
def lane_done(state: LaneState, max_iters: int) -> jax.Array:
    """(L,) bool: lane holds a finished problem — converged, at the cap,
    or frozen unhealthy (a poisoned lane stops advancing the moment its
    flag clears, so "unhealthy" is a terminal disposition too)."""
    return state.active & (state.converged | (state.iters >= max_iters)
                           | ~state.healthy)


def solve_fused_stepped(state: LaneState, n_iters: int, cfg: UOTConfig, *,
                        block_m: int | None = None,
                        interpret: bool | None = None,
                        impl: str | None = None) -> LaneState:
    """Advance every unfinished lane by up to ``n_iters`` iterations.

    The steppable form of ``solve_fused_batched``: one call runs a *chunk*
    of Algorithm-1 iterations on the whole lane pool from explicit carried
    state and returns the new state — solver control flow (convergence
    eviction, admission, deadline scheduling) lives on the host between
    chunks. Per iteration a lane is updated iff it is active, not yet
    converged, and below ``cfg.num_iters``; with ``cfg.tol`` set, a lane
    whose row-factor stationarity drift ``max|frow_t - frow_{t-1}|``
    reaches tol has ``converged`` latched and is frozen at exactly that
    iterate, so a lane's final answer is independent of chunk boundaries
    and of whatever else shares the pool — and equal to the single-problem
    tol solve. ``impl='kernel'`` (Pallas, via the frow-emitting batched
    kernel) and ``impl='jnp'`` stream the pool through HBM every
    iteration; ``impl='resident'`` runs the whole chunk with each lane's
    tile VMEM-resident (``solve_fused_stepped_resident``), and
    ``impl='auto'`` routes by ``resident_fits`` — fp32 pools only, since
    the resident chunk rounds sub-fp32 storage per chunk rather than per
    iteration, which would break chunk-boundary invariance.
    """
    impl = _impl_default(impl, _interpret_default(interpret))
    L, Mp, Np = state.P.shape
    s = jnp.dtype(state.P.dtype).itemsize
    if impl in ("auto", "resident"):
        if _resolve_auto(impl, Mp, Np, cfg, state.P.dtype,
                         stepped_sdt=state.P.dtype):
            return _profiled(
                "chunk", lambda: solve_fused_stepped_resident(
                    state, n_iters, cfg, interpret=interpret),
                M=Mp, N=Np, itemsize=s, impl="resident", lanes=L,
                iters=n_iters)
        impl = None  # over budget (or sub-fp32 pool): streamed default
    return _profiled(
        "chunk", lambda: _solve_fused_stepped_streamed(
            state, n_iters, cfg, block_m=block_m, interpret=interpret,
            impl=impl),
        M=Mp, N=Np, itemsize=s, impl="streamed", lanes=L, iters=n_iters)


def solve_fused_stepped_resident(state: LaneState, n_iters: int,
                                 cfg: UOTConfig, *,
                                 interpret: bool | None = None,
                                 impl: str | None = None) -> LaneState:
    """``solve_fused_stepped`` with the whole chunk VMEM-resident per lane.

    One launch advances every live lane up to ``n_iters`` iterations with
    its tile loaded on-chip once (read + write MN per CHUNK instead of per
    iteration); per-lane gating and the tol freeze run inside the kernel's
    while_loop, so iterates, iteration counts, and chunk-boundary behavior
    match the streamed stepped path exactly for fp32 pools. ``impl``
    selects the flavor within the tier: 'kernel' is
    ``uot_resident.resident_stepped`` (TPU default; interpretable), 'jnp'
    (non-TPU default) reuses the streamed XLA chunk — already one
    executable per chunk — with the pool upcast once at chunk entry and
    downcast once at exit (a no-op for fp32 pools, the per-chunk-rounding
    semantics of the resident kernel for sub-fp32 ones).
    """
    interpret = _interpret_default(interpret)
    if impl not in (None, "kernel", "jnp"):
        raise ValueError(f"resident flavor must be None, 'kernel' or 'jnp', "
                         f"got {impl!r}")
    Mp, Np = state.P.shape[1:]
    if not resident_fits(Mp, Np, cfg, storage_dtype=state.P.dtype):
        raise ValueError(
            f"({Mp}, {Np}) lane pool exceeds the resident VMEM budget; use "
            f"impl='auto' to fall back to the streamed tier")
    flavor = _impl_default(impl, interpret)
    if flavor == "jnp":
        sdt = state.P.dtype
        st = dataclasses.replace(state, P=state.P.astype(jnp.float32))
        st = _solve_fused_stepped_streamed(st, n_iters, cfg,
                                           interpret=interpret, impl="jnp")
        return dataclasses.replace(st, P=st.P.astype(sdt))
    # The resident kernel predates the health flag and is kept unchanged:
    # unhealthy lanes are gated out by feeding them in as converged (the
    # kernel's freeze semantics are exactly the containment we want), and
    # fresh poison is detected at CHUNK granularity from the returned
    # frow/colsum — still O(L*(M+N)), still no M*N rescan. A lane that
    # goes non-finite mid-chunk burns the rest of its own chunk budget
    # before freezing (per-lane while_loops are independent, so no other
    # lane pays anything); the streamed path detects per iteration.
    P, colsum, frow, iters, conv = uot_resident.resident_stepped(
        state.P, state.colsum, state.frow, state.iters,
        state.converged | ~state.healthy,
        state.active, state.a, state.b, fi=cfg.fi, n_iters=n_iters,
        num_iters=cfg.num_iters, tol=cfg.tol, interpret=interpret)
    ran = (state.active & state.healthy & ~state.converged
           & (state.iters < cfg.num_iters))
    finite = (jnp.isfinite(frow).all(axis=-1)
              & jnp.isfinite(colsum).all(axis=-1))
    healthy = state.healthy & (finite | ~ran)
    converged = jnp.where(state.healthy, conv > 0, state.converged)
    return LaneState(P=P, colsum=colsum, a=state.a, b=state.b, frow=frow,
                     iters=iters, converged=converged & healthy,
                     active=state.active,
                     m_valid=state.m_valid, n_valid=state.n_valid,
                     healthy=healthy)


@functools.partial(jax.jit, static_argnames=("n_iters", "cfg", "block_m",
                                             "interpret", "impl"))
def _solve_fused_stepped_streamed(state: LaneState, n_iters: int,
                                  cfg: UOTConfig, *,
                                  block_m: int | None = None,
                                  interpret: bool | None = None,
                                  impl: str | None = None) -> LaneState:
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    Mp, Np = state.P.shape[1:]
    sdt = state.P.dtype
    bm = block_m or pick_block_m(Mp, Np, sdt.itemsize)
    while Mp % bm:
        bm //= 2
    fi = cfg.fi

    def body(_, st):
        upd = (st.active & ~st.converged & st.healthy
               & (st.iters < cfg.num_iters))
        P, colsum, frow = _stepped_iter(
            st.P, st.colsum, upd, ap=st.a, bp=st.b, fi=fi, sdt=sdt,
            impl=impl, bm=bm, interpret=interpret)
        # Traffic-free lane-health detector: any NaN/Inf a lane produces
        # must pass through its row factors or carried column sums (the
        # safe divisions map a poisoned tile to poisoned factors before
        # they can silently renormalize it), and both are O(L*(M+N))
        # values this check already holds — the M*N tile is never
        # rescanned. The flag latches False and drops the lane out of
        # ``upd``, freezing it exactly like a converged lane: per-lane
        # math is independent, so every other lane's iterate stays
        # bit-identical to a fault-free pool (asserted in
        # tests/test_faults.py). NB a frozen lane's raw frow may itself
        # be non-finite garbage — gating on ``upd`` keeps stale poison
        # from re-clearing anything.
        finite = (jnp.isfinite(frow).all(axis=-1)
                  & jnp.isfinite(colsum).all(axis=-1))
        healthy = st.healthy & (finite | ~upd)
        conv = st.converged
        if cfg.tol is not None:
            drift = lane_factor_drift(frow, st.frow)
            conv = conv | (upd & healthy & (drift <= cfg.tol))
        frow = jnp.where((upd & healthy)[:, None], frow, st.frow)
        return LaneState(P=P, colsum=colsum, a=st.a, b=st.b, frow=frow,
                         iters=st.iters + upd.astype(jnp.int32),
                         converged=conv, active=st.active,
                         m_valid=st.m_valid, n_valid=st.n_valid,
                         healthy=healthy)

    return jax.lax.fori_loop(0, n_iters, body, state)


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "block_n",
                                             "interpret", "storage_dtype"))
def solve_halfpass(A0: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig,
                   *, block_m: int = 256, block_n: int = 512,
                   interpret: bool | None = None, storage_dtype=None):
    """Wide-N fallback: iteration = two half-fused passes (paper GPU design).

    Supports the same bf16-storage / fp32-accumulation mode as solve_fused.
    """
    interpret = _interpret_default(interpret)
    M, N = A0.shape
    sdt = _storage(cfg, storage_dtype)
    Ap = pad_to(A0.astype(sdt), block_m, block_n)
    ap = pad_vec(a, block_m)
    bp = pad_vec(b, block_n)
    fi = cfg.fi

    # initial column sums via a rows-scale pass with unit factors
    _, colsum = uot_halfpass.scale_rows_accum_cols(
        Ap, jnp.ones((Ap.shape[0],), jnp.float32),
        block_m=block_m, block_n=block_n, interpret=interpret)

    def body(_, carry):
        A, colsum = carry
        fcol = rescale_factors(bp, colsum, fi)
        A, rowsum = uot_halfpass.scale_cols_accum_rows(
            A, fcol, block_m=block_m, block_n=block_n, interpret=interpret)
        frow = rescale_factors(ap, rowsum, fi)
        A, colsum = uot_halfpass.scale_rows_accum_cols(
            A, frow, block_m=block_m, block_n=block_n, interpret=interpret)
        return A, colsum

    Ap, colsum = jax.lax.fori_loop(0, cfg.num_iters, body, (Ap, colsum))
    return Ap[:M, :N], colsum[:N]


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "materialize"))
def solve_uv(K: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig, *,
             block_m: int | None = None, interpret: bool | None = None,
             materialize: bool = True):
    """Beyond-paper read-only-pass solver (POT u/v semantics).

    K may be bf16 (accumulation fp32). Returns (P or None, (u, v)).
    """
    interpret = _interpret_default(interpret)
    M, N = K.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(K.dtype).itemsize)
    Kp = pad_to(K, bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    v0 = jnp.ones((Kp.shape[1],), jnp.float32)

    def body(_, v):
        u, ktu = uot_uv_fused.uv_iteration(
            Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)
        return rescale_factors(bp, ktu, fi)

    v = jax.lax.fori_loop(0, cfg.num_iters, body, v0)
    # one extra half-iteration to get the final u consistent with v
    u, _ = uot_uv_fused.uv_iteration(
        Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)

    if materialize:
        P = uot_uv_fused.materialize_coupling(
            Kp, u, v, block_m=bm, interpret=interpret)[:M, :N]
    else:
        P = None
    return P, (u[:M], v[:N])


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "interpret",
                                             "materialize", "impl"))
def solve_uv_batched(K: jax.Array, a: jax.Array, b: jax.Array,
                     cfg: UOTConfig, *, block_m: int | None = None,
                     interpret: bool | None = None, materialize: bool = True,
                     impl: str | None = None):
    """Batched read-only-pass u/v solver: K (B, M, N), a (B, M), b (B, N).

    K may be bf16 (accumulation fp32). ``impl`` is 'kernel' or 'jnp' as in
    solve_fused_batched (no resident tier: the u/v pass is read-only, so
    its streamed form already moves only M*N read bytes per iteration).
    Returns (P or None, (u, v)) with P (B, M, N) fp32, u (B, M), v (B, N).
    """
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    if impl not in ("kernel", "jnp"):
        raise ValueError(f"solve_uv_batched has no resident tier; impl must "
                         f"be 'kernel' or 'jnp', got {impl!r}")
    B, M, N = K.shape
    bm = block_m or pick_block_m(M, N, jnp.dtype(K.dtype).itemsize)
    Kp = pad_to(K, bm, _LANE)
    ap = pad_vec(a, bm)
    bp = pad_vec(b, _LANE)
    fi = cfg.fi

    v0 = jnp.ones((B, Kp.shape[2]), jnp.float32)

    if impl == "jnp":
        def uv_iter(v):
            Kv = jnp.einsum("bmn,bn->bm", Kp.astype(jnp.float32), v)
            u = rescale_factors(ap, Kv, fi)
            ktu = jnp.einsum("bmn,bm->bn", Kp.astype(jnp.float32), u)
            return u, ktu
    else:
        def uv_iter(v):
            return uot_batched.batched_uv_iteration(
                Kp, v, ap, fi=fi, block_m=bm, interpret=interpret)

    def body(_, v):
        _, ktu = uv_iter(v)
        return rescale_factors(bp, ktu, fi)

    v = jax.lax.fori_loop(0, cfg.num_iters, body, v0)
    u, _ = uv_iter(v)

    if not materialize:
        return None, (u[:, :M], v[:, :N])
    if impl == "jnp":
        P = (u[:, :, None] * Kp.astype(jnp.float32)
             * v[:, None, :])[:, :M, :N]
    else:
        P = uot_batched.batched_materialize_coupling(
            Kp, u, v, block_m=bm, interpret=interpret)[:, :M, :N]
    return P, (u[:, :M], v[:, :N])


# ---- shape-bucketed ragged batching ---------------------------------------

def bucket_shape(M: int, N: int, m_bucket: int = 64,
                 n_bucket: int = _LANE) -> tuple[int, int]:
    """The padded (M, N) bucket a problem of shape (M, N) lands in."""
    return (M + (-M) % m_bucket, N + (-N) % n_bucket)


def bucket_problems(shapes, m_bucket: int = 64, n_bucket: int = _LANE):
    """Group problem indices by padded-shape bucket.

    ``shapes`` is a sequence of (M, N). Returns ``{(Mb, Nb): [indices]}``
    with insertion order preserved within each bucket.
    """
    buckets: dict[tuple[int, int], list[int]] = {}
    for idx, (M, N) in enumerate(shapes):
        buckets.setdefault(bucket_shape(M, N, m_bucket, n_bucket),
                           []).append(idx)
    return buckets


# The bucketed path canonicalizes each chunk's batch to a power of two so
# repeated flushes with jittered queue depths land on the same jit cache
# entry instead of recompiling per flush. The counters exist so the cache
# behavior is *assertable* (tests) and observable (engine telemetry);
# jax.jit itself holds the compiled executables.
_BUCKETED_STATS = {"hits": 0, "misses": 0}
_BUCKETED_KEYS: set = set()


def bucketed_cache_stats() -> dict:
    """{'hits': ..., 'misses': ...} of bucketed-solve specializations.

    A *miss* is a (padded shape, canonical batch, dtypes, impl, interpret,
    cfg) combination seen for the first time in this process (it triggers a
    jit trace/compile); a *hit* reuses an existing compiled bucket solve.
    """
    return dict(_BUCKETED_STATS)


def reset_bucketed_cache_stats() -> None:
    """Zero the hit/miss counters and forget seen keys (for tests)."""
    _BUCKETED_STATS.update(hits=0, misses=0)
    _BUCKETED_KEYS.clear()


def canonical_batch(n: int, max_batch: int) -> int:
    """Round a chunk's batch up to the next power of two, capped at
    ``max_batch``. Pad slots are all-zero problems — exact no-ops — and the
    rounding collapses the jit-key space from one entry per queue depth to
    O(log max_batch) entries per bucket shape."""
    B = 1
    while B < n:
        B *= 2
    return min(B, max_batch)


def solve_fused_bucketed(problems, cfg: UOTConfig, *,
                         interpret: bool | None = None, storage_dtype=None,
                         impl: str | None = None, max_batch: int = 64,
                         m_bucket: int = 64, n_bucket: int = _LANE):
    """Solve a ragged list of problems via shape-bucketed batched launches.

    ``problems`` is a sequence of (A0, a, b) triples with per-problem shapes.
    Problems are grouped into padded-shape buckets; each bucket is zero-padded
    to its (Mb, Nb), stacked, and solved by ``solve_fused_batched`` in chunks
    of at most ``max_batch``. Zero padding is exact (padded rows/cols carry
    zero mass and unit factors), so each answer equals its standalone solve.

    ``impl='auto'`` is resolved per bucket chunk by ``solve_fused_batched``
    (the tier choice depends only on the bucket's padded shape and dtypes,
    so it is deterministic per cache key). Each chunk's batch dimension is
    rounded up to ``canonical_batch`` with
    zero problems, so flushes whose bucket shapes repeat reuse the compiled
    solve (see ``bucketed_cache_stats``). The padded stack is assembled
    host-side in numpy: device-side pad/stack would trace per batch
    *composition* (arity x per-problem shapes), an unbounded jit-key space
    that recompiles on nearly every flush under ragged traffic.

    Returns a list of (P, colsum) aligned with the input order.
    """
    interpret = _interpret_default(interpret)
    impl = _impl_default(impl, interpret)
    sdt = _storage(cfg, storage_dtype)
    shapes = [tuple(p[0].shape) for p in problems]
    results: list = [None] * len(problems)
    for (Mb, Nb), idxs in bucket_problems(shapes, m_bucket, n_bucket).items():
        for lo in range(0, len(idxs), max_batch):
            chunk = idxs[lo:lo + max_batch]
            Bpad = canonical_batch(len(chunk), max_batch)
            A0 = np.asarray(problems[chunk[0]][0])
            A = np.zeros((Bpad, Mb, Nb), A0.dtype)
            a = np.zeros((Bpad, Mb), np.asarray(problems[chunk[0]][1]).dtype)
            b = np.zeros((Bpad, Nb), np.asarray(problems[chunk[0]][2]).dtype)
            for k, i in enumerate(chunk):
                M, N = shapes[i]
                A[k, :M, :N] = np.asarray(problems[i][0])
                a[k, :M] = np.asarray(problems[i][1])
                b[k, :N] = np.asarray(problems[i][2])
            A, a, b = jnp.asarray(A), jnp.asarray(a), jnp.asarray(b)
            # mirror the real jit cache key: avals (shapes + all three
            # dtypes) and the static args as passed (raw storage_dtype,
            # not just the resolved sdt)
            key = (A.shape, str(A.dtype), str(a.dtype), str(b.dtype),
                   str(sdt), str(storage_dtype), impl, interpret, cfg)
            if key in _BUCKETED_KEYS:
                _BUCKETED_STATS["hits"] += 1
            else:
                _BUCKETED_KEYS.add(key)
                _BUCKETED_STATS["misses"] += 1
            P, colsum = solve_fused_batched(
                A, a, b, cfg, interpret=interpret,
                storage_dtype=storage_dtype, impl=impl)
            # one host transfer per chunk, then numpy copies per problem —
            # device-side P[k, :M, :N] would compile a slice per (position,
            # problem shape) signature, unbounded under ragged traffic, and
            # returning views would pin the whole padded chunk for as long
            # as any one result is retained
            P, colsum = np.asarray(P), np.asarray(colsum)
            for k, i in enumerate(chunk):
                M, N = shapes[i]
                results[i] = (P[k, :M, :N].copy(), colsum[k, :N].copy())
    return results
