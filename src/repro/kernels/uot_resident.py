"""VMEM-resident multi-iteration MAP-UOT Pallas kernels.

The streamed kernels (``uot_fused``, ``uot_batched``) hit the paper's
per-iteration HBM floor: read MN + write MN bytes per iteration, because the
grid walks row blocks and every iteration is its own ``pallas_call``. For the
bucketed serving shapes this repo targets (e.g. 256x384 fp32 = 384 KB) the
whole coupling matrix fits in VMEM — so the true floor is not ``2*MN`` per
*iteration* but ``MN in + MN out`` per *solve*: load the tile once, iterate
to convergence on-chip, store once.

These kernels realize that tier. The grid iterates over **lanes** (the batch
dimension) instead of row blocks; each grid step

  1. DMAs one problem's whole ``(Mp, Np)`` tile into VMEM and upcasts it to
     ``acc_dtype`` ONCE (for bf16 storage the per-iteration rounding of the
     streamed path disappears — the resident trajectory is the fp32
     trajectory, downcast once at the end),
  2. runs a ``lax.while_loop``/``fori_loop`` of full Algorithm-1 iterations
     (column rescale, row sums, row rescale, column-sum accumulation)
     entirely in VMEM, with the row-factor-stationarity convergence check
     (``max|frow_t - frow_{t-1}| <= tol``, exactly the streamed solvers'
     criterion) folded INTO the loop condition — a converged lane stops
     computing instead of being masked,
  3. writes the converged tile back once, downcasting to the storage dtype.

Per-solve HBM traffic collapses from ``iters * MN * (in+out)`` bytes to
``MN * (in+out)`` + O(M+N) — for a 25-iteration solve, 25x less. Grid steps
are sequential on the TensorCore, so per-lane while_loops of different trip
counts simply take different time; no cross-lane synchronization exists to
drag a fast lane to the slowest one's iteration count.

Four entry points (wrapped with padding/dispatch by ``ops``):

- ``resident_solve``: one-shot batched solve returning per-lane iteration
  counts and final drift alongside (P, colsum).
- ``resident_solve_pc``: the implicit-geometry twin — each lane's tile is
  COMPUTED in VMEM from point-cloud coordinates (``repro.geometry``
  tile arithmetic, bit-identical to the dense mirror) instead of DMA'd,
  so per-solve coupling traffic is ``write MN`` only and the VMEM budget
  shrinks to the coupling (``ops.resident_fits(implicit=True)``).
- ``resident_solve_jnp``: the pure-XLA mirror of the same iteration fusion
  (single jit, fp32 throughout, one downcast) so non-TPU backends get the
  fused-iteration win without interpret-mode overhead and CPU CI can
  measure it.
- ``resident_stepped``: the ``ops.LaneState``-compatible chunk advance —
  per-lane gating (active, not converged, below the iteration cap) is the
  while_loop condition, so ``UOTScheduler`` chunks become ONE launch with
  zero inter-iteration HBM round trips.

Whether a problem belongs here is a static VMEM-budget question answered by
``ops.resident_fits``; ``ops``' ``impl='auto'`` routes between this tier and
the streamed kernels per problem shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.geometry.pointcloud import gibbs_tile
from repro.kernels.uot_fused import _safe_pow


def _one_iteration(A, colsum, a, b, fi):
    """One full Algorithm-1 iteration on an in-VMEM (1, Mp, Np) tile.

    Returns (A', colsum', frow) — identical math to the streamed kernels'
    single pass (column rescale -> row sums -> row rescale -> column-sum
    accumulation), just with the tile already resident.
    """
    A = A * _safe_pow(b, colsum, fi)              # I:   column rescale
    rowsum = jnp.sum(A, axis=2, keepdims=True)    # II:  row sums
    frow = _safe_pow(a, rowsum, fi)
    A = A * frow                                  # III: row rescale
    colsum = jnp.sum(A, axis=1, keepdims=True)    # IV:  next column sums
    return A, colsum, frow


def _solve_to_convergence(A, a_ref, b_ref, *, fi: float, num_iters: int,
                          tol, acc_dtype):
    """The shared in-VMEM solve loop: Algorithm-1 iterations on an already
    loaded (or computed) ``acc_dtype`` tile, with the row-factor
    stationarity check folded into the loop condition.

    Returns (A, colsum, it, err). Both the dense-load kernel and the
    implicit-geometry kernel (``_resident_pc_kernel``) run exactly this
    loop — the tile source is the only difference between the tiers.
    """
    a = a_ref[...].astype(acc_dtype)              # (1, Mp, 1)
    b = b_ref[...].astype(acc_dtype)              # (1, 1, Np)
    colsum = jnp.sum(A, axis=1, keepdims=True)    # Algorithm-1 preprocessing
    prev = jnp.ones_like(a)
    err0 = jnp.asarray(jnp.inf, acc_dtype)

    if tol is None:
        def body(_, carry):
            A, colsum, prev, _ = carry
            A, colsum, frow = _one_iteration(A, colsum, a, b, fi)
            return A, colsum, frow, jnp.max(jnp.abs(frow - prev))
        A, colsum, prev, err = jax.lax.fori_loop(
            0, num_iters, body, (A, colsum, prev, err0))
        it = jnp.int32(num_iters)
    else:
        def cond(carry):
            _, _, _, it, err = carry
            return jnp.logical_and(it < num_iters, err > tol)

        def body(carry):
            A, colsum, prev, it, _ = carry
            A, colsum, frow = _one_iteration(A, colsum, a, b, fi)
            return A, colsum, frow, it + 1, jnp.max(jnp.abs(frow - prev))
        A, colsum, prev, it, err = jax.lax.while_loop(
            cond, body, (A, colsum, prev, jnp.int32(0), err0))
    return A, colsum, it, err


def _store_solution(A, colsum, it, err, out_ref, colsum_ref, iters_ref,
                    err_ref):
    out_ref[...] = A.astype(out_ref.dtype)        # downcast ONCE
    colsum_ref[...] = colsum.astype(colsum_ref.dtype)
    iters_ref[...] = jnp.full(iters_ref.shape, it, iters_ref.dtype)
    err_ref[...] = jnp.full(err_ref.shape, err, err_ref.dtype)


def _resident_solve_kernel(a_ref, b_ref, A_ref, out_ref, colsum_ref,
                           iters_ref, err_ref, *, fi: float, num_iters: int,
                           tol, acc_dtype):
    A = A_ref[...].astype(acc_dtype)              # upcast ONCE
    A, colsum, it, err = _solve_to_convergence(
        A, a_ref, b_ref, fi=fi, num_iters=num_iters, tol=tol,
        acc_dtype=acc_dtype)
    _store_solution(A, colsum, it, err, out_ref, colsum_ref, iters_ref,
                    err_ref)


@functools.partial(jax.jit, static_argnames=("fi", "num_iters", "tol",
                                             "interpret", "acc_dtype"))
def resident_solve(A: jax.Array, a: jax.Array, b: jax.Array, *, fi: float,
                   num_iters: int, tol: float | None = None,
                   interpret: bool = False, acc_dtype=jnp.float32):
    """Whole-solve resident kernel: a stack of problems, one launch, one
    HBM read + one write of each coupling for the ENTIRE solve.

    A: (B, Mp, Np) pre-padded (Mp % sublane == 0, Np % 128 == 0; zero
    rows/cols are exact no-ops); a: (B, Mp); b: (B, Np). The grid iterates
    over lanes; each lane runs up to ``num_iters`` Algorithm-1 iterations in
    VMEM, early-exiting when its row-factor stationarity reaches ``tol``
    (same criterion, same iterate, same count as the streamed solvers).

    Returns (A_out, colsum, iters, err): the converged couplings in the
    storage dtype of ``A``, their fp32 carried column sums, and per-lane
    iteration counts / final drifts.
    """
    B, M, N = A.shape
    kernel = functools.partial(_resident_solve_kernel, fi=fi,
                               num_iters=num_iters, tol=tol,
                               acc_dtype=acc_dtype)
    out, colsum, iters, err = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),   # a (RPD)
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # b (CPD)
            pl.BlockSpec((1, M, N), lambda i: (i, 0, 0)),   # whole tile
        ],
        out_specs=[
            pl.BlockSpec((1, M, N), lambda i: (i, 0, 0)),   # converged tile
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # colsum
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # iters
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # err
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), A.dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), acc_dtype),
        ],
        interpret=interpret,
    )(a.reshape(B, M, 1), b.reshape(B, 1, N), A)
    return out, colsum.reshape(B, N), iters.reshape(B), err.reshape(B)


def _resident_pc_kernel(a_ref, b_ref, x_ref, xn_ref, y_ref, yn_ref,
                        mv_ref, nv_ref, out_ref, colsum_ref, iters_ref,
                        err_ref, *, fi: float, reg: float, scale: float,
                        num_iters: int, tol, acc_dtype):
    # the Gibbs tile never exists in HBM: computed here, in VMEM, from the
    # O((M + N) * d) coordinate operands, then iterated on like the loaded
    # tile of _resident_solve_kernel (same loop, bit-for-bit)
    A = gibbs_tile(x_ref[...], xn_ref[...], y_ref[...], yn_ref[...],
                   reg=reg, scale=scale)
    rows = jax.lax.broadcasted_iota(jnp.int32, A.shape, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, A.shape, 2)
    A = jnp.where((rows < mv_ref[0, 0]) & (cols < nv_ref[0, 0]), A, 0.0)
    if jnp.dtype(out_ref.dtype) != jnp.dtype(acc_dtype):
        # round through the storage dtype so the iterate matches what the
        # dense path reads back from an HBM tile stored in that dtype
        A = A.astype(out_ref.dtype)
    A = A.astype(acc_dtype)
    A, colsum, it, err = _solve_to_convergence(
        A, a_ref, b_ref, fi=fi, num_iters=num_iters, tol=tol,
        acc_dtype=acc_dtype)
    _store_solution(A, colsum, it, err, out_ref, colsum_ref, iters_ref,
                    err_ref)


@functools.partial(jax.jit, static_argnames=("fi", "reg", "scale",
                                             "num_iters", "tol", "interpret",
                                             "acc_dtype", "out_dtype"))
def resident_solve_pc(x, xn, y, yn, a, b, m_valid, n_valid, *, fi: float,
                      reg: float, scale: float = 1.0, num_iters: int,
                      tol: float | None = None, interpret: bool = False,
                      acc_dtype=jnp.float32, out_dtype=jnp.float32):
    """Whole-solve resident kernel for an implicit point-cloud geometry.

    Like ``resident_solve``, but each lane's tile is COMPUTED in VMEM from
    its coordinates (x: (B, Mp, d), xn: (B, Mp), y/yn likewise; m_valid /
    n_valid: (B,) valid counts masking the zero-padded region to exact
    zeros) instead of DMA'd from HBM. Per-solve coupling HBM traffic is
    therefore ``write MN`` — the dense resident tier's ``read MN`` input
    leg becomes an O((M + N) * d) coordinate read — and, because the input
    tile no longer occupies a VMEM slot, the budget test that gates this
    tier shrinks to the coupling alone (``ops.resident_fits`` with
    ``implicit=True``), admitting shapes the dense tier must stream.

    Returns (P, colsum, iters, err) exactly like ``resident_solve`` — same
    in-VMEM loop, same convergence criterion, same per-lane counts.
    """
    B, M, d = x.shape
    N = y.shape[1]
    kernel = functools.partial(_resident_pc_kernel, fi=fi, reg=reg,
                               scale=scale, num_iters=num_iters, tol=tol,
                               acc_dtype=acc_dtype)
    out, colsum, iters, err = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),   # a (RPD)
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # b (CPD)
            pl.BlockSpec((1, M, d), lambda i: (i, 0, 0)),   # x coords
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),   # x sq norms
            pl.BlockSpec((1, N, d), lambda i: (i, 0, 0)),   # y coords
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # y sq norms
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # m_valid
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # n_valid
        ],
        out_specs=[
            pl.BlockSpec((1, M, N), lambda i: (i, 0, 0)),   # converged tile
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # colsum
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # iters
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # err
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), out_dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), acc_dtype),
        ],
        interpret=interpret,
    )(a.reshape(B, M, 1), b.reshape(B, 1, N), x, xn.reshape(B, M, 1),
      y, yn.reshape(B, 1, N), m_valid.astype(jnp.int32).reshape(B, 1),
      n_valid.astype(jnp.int32).reshape(B, 1))
    return out, colsum.reshape(B, N), iters.reshape(B), err.reshape(B)


@functools.partial(jax.jit, static_argnames=("fi", "num_iters", "tol",
                                             "out_dtype"))
def resident_solve_jnp(A: jax.Array, a: jax.Array, b: jax.Array, *,
                       fi: float, num_iters: int, tol: float | None = None,
                       out_dtype=None):
    """Pure-XLA mirror of ``resident_solve``: the same iteration fusion
    (ONE jit, fp32 state throughout, no per-iteration storage round trip)
    vectorized over the batch.

    Where the streamed ``'jnp'`` path downcasts the coupling to the storage
    dtype every iteration (mirroring what the streamed kernel's HBM writes
    do), this path upcasts once and downcasts once — for bf16 storage the
    iterates are the fp32 trajectory rounded at the end, exactly like the
    resident kernel. Frozen lanes are masked out of updates via unit
    factors (a multiplicative no-op, bit-exact), since XLA has no per-lane
    early exit; iteration counts still match the kernel per lane.

    Returns (A_out, colsum, iters, err) like ``resident_solve``.
    """
    B = A.shape[0]
    out_dtype = A.dtype if out_dtype is None else out_dtype
    A = A.astype(jnp.float32)                     # upcast ONCE
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    colsum = A.sum(axis=1)
    prev = jnp.ones_like(a)
    err0 = jnp.full((B,), jnp.inf, jnp.float32)

    def one_iter(A, colsum, upd):
        fcol = _safe_pow(b, colsum, fi)
        if upd is not None:
            fcol = jnp.where(upd[:, None], fcol, 1.0)
        A = A * fcol[:, None, :]
        frow = _safe_pow(a, A.sum(axis=2), fi)
        frow_m = frow if upd is None else jnp.where(upd[:, None], frow, 1.0)
        A = A * frow_m[:, :, None]
        newcs = A.sum(axis=1)
        if upd is not None:
            newcs = jnp.where(upd[:, None], newcs, colsum)
        return A, newcs, frow

    if tol is None:
        def body(_, carry):
            A, colsum, prev, _ = carry
            A, colsum, frow = one_iter(A, colsum, None)
            return A, colsum, frow, jnp.max(jnp.abs(frow - prev), axis=-1)
        A, colsum, prev, err = jax.lax.fori_loop(
            0, num_iters, body, (A, colsum, prev, err0))
        iters = jnp.full((B,), num_iters, jnp.int32)
    else:
        def cond(carry):
            _, _, _, _, _, conv, i = carry
            return jnp.logical_and(i < num_iters, ~jnp.all(conv))

        def body(carry):
            A, colsum, prev, err, iters, conv, i = carry
            upd = ~conv
            A, colsum, frow = one_iter(A, colsum, upd)
            drift = jnp.max(jnp.abs(frow - prev), axis=-1)
            err = jnp.where(upd, drift, err)
            prev = jnp.where(upd[:, None], frow, prev)
            return (A, colsum, prev, err, iters + upd.astype(jnp.int32),
                    conv | (upd & (drift <= tol)), i + 1)

        A, colsum, prev, err, iters, conv, i = jax.lax.while_loop(
            cond, body, (A, colsum, prev, err0, jnp.zeros((B,), jnp.int32),
                         jnp.zeros((B,), bool), jnp.int32(0)))
    return A.astype(out_dtype), colsum, iters, err   # downcast ONCE


def _resident_stepped_kernel(active_ref, conv_ref, iters_ref, a_ref, b_ref,
                             cs_ref, frow_ref, A_ref, out_ref, cs_out_ref,
                             frow_out_ref, iters_out_ref, conv_out_ref, *,
                             fi: float, n_iters: int, num_iters: int, tol,
                             acc_dtype):
    A = A_ref[...].astype(acc_dtype)              # upcast ONCE per chunk
    a = a_ref[...].astype(acc_dtype)
    b = b_ref[...].astype(acc_dtype)
    colsum = cs_ref[...].astype(acc_dtype)        # carried, (1, 1, Np)
    prev = frow_ref[...].astype(acc_dtype)        # carried, (1, Mp, 1)
    live = jnp.logical_and(active_ref[0, 0] > 0, conv_ref[0, 0] == 0)
    conv0 = conv_ref[0, 0] > 0
    it0 = iters_ref[0, 0]

    # The streamed stepped path updates a lane iff it is active, not yet
    # converged, and below the iteration cap — here that gate IS the loop
    # condition, so a finished (or free) lane's tile round-trips bit-exact
    # with zero iterations of compute.
    def cond(carry):
        _, _, _, it, conv, k = carry
        run = jnp.logical_and(live, jnp.logical_not(conv))
        return jnp.logical_and(jnp.logical_and(k < n_iters, run),
                               it < num_iters)

    def body(carry):
        A, colsum, prev, it, conv, k = carry
        A, colsum, frow = _one_iteration(A, colsum, a, b, fi)
        if tol is not None:
            conv = jnp.logical_or(conv, jnp.max(jnp.abs(frow - prev)) <= tol)
        return A, colsum, frow, it + 1, conv, k + 1

    A, colsum, prev, it, conv, _ = jax.lax.while_loop(
        cond, body, (A, colsum, prev, it0, conv0, jnp.int32(0)))

    out_ref[...] = A.astype(out_ref.dtype)        # downcast ONCE per chunk
    cs_out_ref[...] = colsum.astype(cs_out_ref.dtype)
    frow_out_ref[...] = prev.astype(frow_out_ref.dtype)
    iters_out_ref[...] = jnp.full(iters_out_ref.shape, it,
                                  iters_out_ref.dtype)
    conv_out_ref[...] = jnp.full(conv_out_ref.shape,
                                 conv.astype(conv_out_ref.dtype))


@functools.partial(jax.jit, static_argnames=("fi", "n_iters", "num_iters",
                                             "tol", "interpret", "acc_dtype"))
def resident_stepped(A: jax.Array, colsum: jax.Array, frow: jax.Array,
                     iters: jax.Array, converged: jax.Array,
                     active: jax.Array, a: jax.Array, b: jax.Array, *,
                     fi: float, n_iters: int, num_iters: int,
                     tol: float | None = None, interpret: bool = False,
                     acc_dtype=jnp.float32):
    """Chunk advance for a lane pool with the whole chunk resident in VMEM.

    The kernel form of ``ops.solve_fused_stepped``'s loop body: one launch
    advances every live lane by up to ``n_iters`` Algorithm-1 iterations
    with the lane's tile loaded into VMEM once — the streamed stepped path
    pays read+write MN per iteration per lane, this pays it per CHUNK. The
    per-lane gating (active, not converged, ``iters < num_iters``) and the
    tol freeze are the while_loop condition, so a lane that converges
    mid-chunk stops at exactly the same iterate and count as the streamed
    path (asserted in tests/test_resident.py).

    For sub-fp32 storage the tile is rounded once per chunk, not once per
    iteration — a bf16 lane's trajectory therefore depends on chunk
    boundaries, which is why ``impl='auto'`` only routes fp32 pools here
    (see ``ops.solve_fused_stepped``).

    Arrays are the corresponding ``LaneState`` fields; ``converged`` and
    ``active`` may be bool (cast to the kernel's f32/i32 carriers here).
    Returns (P, colsum, frow, iters, converged-as-int32).
    """
    B, M, N = A.shape
    kernel = functools.partial(_resident_stepped_kernel, fi=fi,
                               n_iters=n_iters, num_iters=num_iters, tol=tol,
                               acc_dtype=acc_dtype)
    out, cs, fr, it, conv = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # active
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # converged
            pl.BlockSpec((1, 1), lambda i: (i, 0)),         # iters
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),   # a
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # b
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),   # carried colsum
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),   # carried frow
            pl.BlockSpec((1, M, N), lambda i: (i, 0, 0)),   # P tile
        ],
        out_specs=[
            pl.BlockSpec((1, M, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, M, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), A.dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
            jax.ShapeDtypeStruct((B, M, 1), acc_dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(active.astype(jnp.float32).reshape(B, 1),
      converged.astype(jnp.float32).reshape(B, 1),
      iters.astype(jnp.int32).reshape(B, 1),
      a.reshape(B, M, 1), b.reshape(B, 1, N),
      colsum.reshape(B, 1, N), frow.reshape(B, M, 1), A)
    return (out, cs.reshape(B, N), fr.reshape(B, M), it.reshape(B),
            conv.reshape(B))
