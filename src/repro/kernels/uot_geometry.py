"""Implicit-geometry Pallas kernels: Gibbs tiles computed on-chip.

The streamed kernels (``uot_fused`` / ``uot_batched``) and the resident
tier (``uot_resident``) historically start from a dense initial coupling
``A0 = K`` in HBM — an ``M*N`` operand that had to be materialized
somewhere (host or device) before the solve. For implicit geometries
(``repro.geometry.PointCloudGeometry``) the Gibbs kernel is a function of
``O((M + N) * d)`` coordinates, so these kernels compute each ``(bm, N)``
tile of ``K = exp(-||x_i - y_j||^2 / (scale * reg))`` *in VMEM* from the
coordinate blocks instead of loading it:

- ``batched_pc_materialize`` — tile-compute -> store (the geometry path's
  answer to "give me A0 in HBM" when a downstream consumer needs it, e.g.
  admission into a scheduler lane pool).
- ``batched_pc_colsum`` — Algorithm 1's preprocessing pass with zero HBM
  coupling traffic: tiles are computed, column sums accumulated, nothing
  ``M*N``-sized is read **or written**.
- ``batched_pc_first_iteration`` — iteration 1 of Algorithm 1 with the
  input tile computed on-chip: the solve's first coupling write is the
  *rescaled* ``A1``, so the initial ``K`` never exists in HBM. Also emits
  the row factors (cheap O(M) write) so the tol machinery can track
  stationarity from iteration 1, exactly like the dense stepped kernel.

From iteration 2 on the coupling is the evolving solver state and the
standard streamed kernels take over — the geometry's job (sourcing the
cost) is done. Per-solve HBM coupling traffic therefore drops from
``materialize MN + read MN (colsum) + (read+write) MN * T`` to
``write MN + (read+write) MN * (T - 1)``, and nothing cost-shaped is ever
resident in HBM. The resident-tier twin (whole solve on-chip, store once)
is ``uot_resident.resident_solve_pc``.

Bitwise parity with the dense-load path (asserted in tests): the tile
arithmetic is ``repro.geometry.pointcloud.gibbs_tile`` — the same
unrolled, blocking-invariant chain the materializing mirror uses — and
each computed tile is routed through a storage-dtype roundtrip
(``astype(storage).astype(acc)``) so the iterate matches what the dense
path reads back from an HBM tile stored in that dtype. Zero-padding of a
dense stack becomes an in-kernel validity mask here (rows/cols at or past
a problem's ``(m_valid, n_valid)`` evaluate to exactly 0.0 — coordinates
always produce *nonzero* Gibbs entries, so unmasked padding would leak
mass into valid rows' sums).

Alignment contract matches ``uot_batched``: Mp % block_m == 0,
Np % 128 == 0 (ops pre-pads; padded coordinate rows are masked). The
coordinate blocks' minor dim is ``d`` (2-8), which interpret mode and the
VPU handle as-is; a hardware-TPU tuning pass may want coordinates laid
out lane-padded — a ROADMAP follow-on, not a semantics question.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.geometry.pointcloud import gibbs_tile
from repro.kernels.uot_fused import _safe_pow


def _tile(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref, i, *, block_m: int,
          reg: float, scale: float, storage_dtype, acc_dtype):
    """The shared tile prologue: compute, mask, storage-roundtrip.

    Returns the (1, bm, N) Gibbs tile in ``acc_dtype``, bit-identical to
    what the dense path would have loaded from an HBM copy of the
    zero-padded ``geometry.kernel(reg).astype(storage_dtype)``.
    """
    K = gibbs_tile(x_ref[...], xn_ref[...], y_ref[...], yn_ref[...],
                   reg=reg, scale=scale)
    shape = K.shape                                   # (1, bm, N)
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 1) + i * block_m
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    K = jnp.where((rows < mv_ref[0, 0]) & (cols < nv_ref[0, 0]), K, 0.0)
    if jnp.dtype(storage_dtype) != jnp.dtype(acc_dtype):
        K = K.astype(storage_dtype)
    return K.astype(acc_dtype)


def _pc_specs(B, M, N, d, block_m):
    """in_specs for the (x, xn, y, yn, m_valid, n_valid) operand prefix."""
    return [
        pl.BlockSpec((1, block_m, d), lambda b, i: (b, i, 0)),  # x rows
        pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # x sq norms
        pl.BlockSpec((1, N, d), lambda b, i: (b, 0, 0)),        # y (whole)
        pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # y sq norms
        pl.BlockSpec((1, 1), lambda b, i: (b, 0)),              # m_valid
        pl.BlockSpec((1, 1), lambda b, i: (b, 0)),              # n_valid
    ]


def _pc_args(x, xn, y, yn, m_valid, n_valid):
    B, M, d = x.shape
    N = y.shape[1]
    return (x, xn.reshape(B, M, 1), y, yn.reshape(B, 1, N),
            m_valid.astype(jnp.int32).reshape(B, 1),
            n_valid.astype(jnp.int32).reshape(B, 1))


def _materialize_kernel(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref,
                        out_ref, *, block_m, reg, scale, acc_dtype):
    i = pl.program_id(1)
    K = _tile(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref, i,
              block_m=block_m, reg=reg, scale=scale,
              storage_dtype=out_ref.dtype, acc_dtype=acc_dtype)
    out_ref[...] = K.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("reg", "scale", "block_m",
                                             "interpret", "acc_dtype",
                                             "out_dtype"))
def batched_pc_materialize(x, xn, y, yn, m_valid, n_valid, *, reg: float,
                           scale: float = 1.0, block_m: int = 256,
                           interpret: bool = False, acc_dtype=jnp.float32,
                           out_dtype=jnp.float32):
    """Materialize the zero-padded Gibbs stack from coordinates on-device.

    x: (B, Mp, d); xn: (B, Mp); y: (B, Np, d); yn: (B, Np); m_valid /
    n_valid: (B,) per-problem valid counts. Returns (B, Mp, Np) in
    ``out_dtype``. One tile-compute -> store pass: the cost matrix never
    exists, and the host never ships anything ``M*N``-sized.
    """
    B, M, d = x.shape
    N = y.shape[1]
    assert M % block_m == 0, (M, block_m)
    kernel = functools.partial(_materialize_kernel, block_m=block_m,
                               reg=reg, scale=scale, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=(B, M // block_m),
        in_specs=_pc_specs(B, M, N, d, block_m),
        out_specs=pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), out_dtype),
        interpret=interpret,
    )(*_pc_args(x, xn, y, yn, m_valid, n_valid))


def _colsum_kernel(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref, cs_ref, *,
                   block_m, reg, scale, storage_dtype, acc_dtype):
    i = pl.program_id(1)
    K = _tile(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref, i,
              block_m=block_m, reg=reg, scale=scale,
              storage_dtype=storage_dtype, acc_dtype=acc_dtype)

    @pl.when(i == 0)
    def _init():
        cs_ref[...] = jnp.zeros_like(cs_ref)

    cs_ref[...] += jnp.sum(K, axis=1, keepdims=True).astype(cs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("reg", "scale", "block_m",
                                             "interpret", "storage_dtype",
                                             "acc_dtype"))
def batched_pc_colsum(x, xn, y, yn, m_valid, n_valid, *, reg: float,
                      scale: float = 1.0, block_m: int = 256,
                      interpret: bool = False, storage_dtype=jnp.float32,
                      acc_dtype=jnp.float32):
    """Initial column sums straight from coordinates: (B, Np) in acc_dtype.

    The Algorithm-1 preprocessing pass with ZERO M*N HBM traffic — the
    tiles live only in VMEM. ``storage_dtype`` is the dtype the dense path
    would have stored ``A0`` in; the computed tile takes the same rounding
    roundtrip so the sums match that path bit-for-bit.
    """
    B, M, d = x.shape
    N = y.shape[1]
    assert M % block_m == 0, (M, block_m)
    kernel = functools.partial(_colsum_kernel, block_m=block_m, reg=reg,
                               scale=scale, storage_dtype=storage_dtype,
                               acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(B, M // block_m),
        in_specs=_pc_specs(B, M, N, d, block_m),
        out_specs=pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
        interpret=interpret,
    )(*_pc_args(x, xn, y, yn, m_valid, n_valid))
    return out.reshape(B, N)


def _first_iter_kernel(fcol_ref, a_ref, x_ref, xn_ref, y_ref, yn_ref,
                       mv_ref, nv_ref, out_ref, colsum_ref, frow_ref, *,
                       fi, block_m, reg, scale, acc_dtype):
    i = pl.program_id(1)
    blk = _tile(x_ref, xn_ref, y_ref, yn_ref, mv_ref, nv_ref, i,
                block_m=block_m, reg=reg, scale=scale,
                storage_dtype=out_ref.dtype, acc_dtype=acc_dtype)

    # identical post-tile chain to uot_batched's fused iteration kernels —
    # the tile source is the only difference between the two paths
    blk = blk * fcol_ref[...].astype(acc_dtype)      # I: column rescale
    rowsum = jnp.sum(blk, axis=2, keepdims=True)     # II
    frow = _safe_pow(a_ref[...].astype(acc_dtype), rowsum, fi)
    blk = blk * frow                                 # III: row rescale

    out_ref[...] = blk.astype(out_ref.dtype)
    frow_ref[...] = frow.astype(frow_ref.dtype)

    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(blk, axis=1,
                               keepdims=True).astype(colsum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fi", "reg", "scale", "block_m",
                                             "interpret", "acc_dtype",
                                             "out_dtype"))
def batched_pc_first_iteration(factor_col, a, x, xn, y, yn, m_valid,
                               n_valid, *, fi: float, reg: float,
                               scale: float = 1.0, block_m: int = 256,
                               interpret: bool = False,
                               acc_dtype=jnp.float32,
                               out_dtype=jnp.float32):
    """Iteration 1 of Algorithm 1 with the input tile computed on-chip.

    factor_col: (B, Np) column factors from ``batched_pc_colsum``'s sums;
    a: (B, Mp) row marginals; coordinate operands as in
    ``batched_pc_colsum``. Returns (A1, next_colsum, frow) of shapes
    (B, Mp, Np) [``out_dtype`` — the solve's storage dtype], (B, Np) and
    (B, Mp) [both acc]. The solve's first M*N HBM *write* is the already
    rescaled ``A1``; the Gibbs kernel itself never touches HBM. From here
    the standard streamed kernels iterate on ``A1``.
    """
    B, M, d = x.shape
    N = y.shape[1]
    assert M % block_m == 0, (M, block_m)
    kernel = functools.partial(_first_iter_kernel, fi=fi, block_m=block_m,
                               reg=reg, scale=scale, acc_dtype=acc_dtype)
    out, colsum, frow = pl.pallas_call(
        kernel,
        grid=(B, M // block_m),
        in_specs=[
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # fcol
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # a (RPD)
        ] + _pc_specs(B, M, N, d, block_m),
        out_specs=[
            pl.BlockSpec((1, block_m, N), lambda b, i: (b, i, 0)),  # A1 tile
            pl.BlockSpec((1, 1, N), lambda b, i: (b, 0, 0)),        # colsum
            pl.BlockSpec((1, block_m, 1), lambda b, i: (b, i, 0)),  # frow
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M, N), out_dtype),
            jax.ShapeDtypeStruct((B, 1, N), acc_dtype),
            jax.ShapeDtypeStruct((B, M, 1), acc_dtype),
        ],
        interpret=interpret,
    )(factor_col.reshape(B, 1, N), a.reshape(B, M, 1),
      *_pc_args(x, xn, y, yn, m_valid, n_valid))
    return out, colsum.reshape(B, N), frow.reshape(B, M)
