"""MAP-UOT fused-iteration Pallas TPU kernel.

The paper's single-pass interweaving (Algorithm 1) mapped to the TPU memory
hierarchy. One pallas_call performs a FULL UOT iteration (column rescale +
row rescale + next-iteration column-sum accumulation) streaming the coupling
matrix HBM -> VMEM -> HBM exactly once:

    grid step i (sequential on the TensorCore):
        blk  = A[i*bm:(i+1)*bm, :]          # (bm, N) tile, DMA'd to VMEM
        blk *= factor_col[None, :]          # computation I   (col rescale)
        rowsum = blk.sum(1)                 # computation II  (VPU reduce)
        blk *= ((a_i / rowsum) ** fi)[:,N]  # computation III (row rescale)
        colsum_acc += blk.sum(0)            # computation IV  (VMEM acc)
        A[i*bm:(i+1)*bm, :] = blk           # written back once

TPU adaptation notes (DESIGN.md section 2): the paper's per-thread
``NextSum_col[T][N]`` partials + pthread join become a single VMEM
accumulator revisited across *sequential* grid steps (no atomics needed);
AVX2 vectorization becomes (8, 128)-aligned VPU tiles; the GPU warp-shuffle
reduction degenerates to a VPU cross-lane ``jnp.sum``.

HBM traffic per iteration: read MN + write MN elements (+O(M+N)) — the
information-theoretic minimum — vs 4 reads + 2 writes for the POT baseline.

Mixed precision: ``A`` may be stored bf16 (the tile is upcast to
``acc_dtype`` fp32 on load, all sums/factors computed fp32, and the tile
downcast once on store), halving the bytes moved by this bandwidth-bound
kernel. bf16 tiles want block_m a multiple of 16 (see ops.sublane_for);
``ops.pick_block_m`` budgets VMEM with the two itemsizes separately.

Cost source: this kernel *loads* its tile — the initial coupling must
exist in HBM. For implicit geometries (point clouds), the solve's colsum
and first-iteration passes have tile-COMPUTE twins in ``uot_geometry``
that evaluate the Gibbs tile in VMEM from coordinates, after which the
coupling is ordinary solver state and these kernels take over (the
``geometry=`` path of ``ops.solve_fused``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _safe_pow(target, sums, fi: float):
    """(target / sums) ** fi with 0-sum guard (matches core.rescale_factors)."""
    safe = jnp.where(sums > 0, sums, 1.0)
    ratio = jnp.where(sums > 0, target / safe, 1.0)
    if fi == 1.0:
        return ratio
    return jnp.power(ratio, fi)


def _fused_iter_kernel(fcol_ref, a_ref, A_ref, out_ref, colsum_ref, *,
                       fi: float, acc_dtype):
    i = pl.program_id(0)

    blk = A_ref[...].astype(acc_dtype)          # (bm, N)
    fcol = fcol_ref[...].astype(acc_dtype)      # (1, N)

    blk = blk * fcol                             # I: column rescale
    rowsum = jnp.sum(blk, axis=1, keepdims=True)  # II: row sums (bm, 1)
    frow = _safe_pow(a_ref[...].astype(acc_dtype), rowsum, fi)
    blk = blk * frow                             # III: row rescale

    out_ref[...] = blk.astype(out_ref.dtype)

    # IV: accumulate next iteration's column sums. Grid steps run
    # sequentially on TPU, so the revisited (1, N) accumulator block needs
    # no synchronization (the pthread-join / atomicAdd of the paper).
    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(blk, axis=0, keepdims=True).astype(colsum_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("fi", "block_m", "interpret", "acc_dtype"))
def fused_iteration(A: jax.Array, factor_col: jax.Array, a: jax.Array, *,
                    fi: float, block_m: int = 256, interpret: bool = False,
                    acc_dtype=jnp.float32):
    """One MAP-UOT iteration. A: (M, N); factor_col: (N,); a: (M,).

    Shapes must be pre-padded: M % block_m == 0 and N % 128 == 0 (the ops.py
    wrapper pads with zeros, which the rescaling math is invariant to).

    Returns (A_next, next_colsum) with next_colsum fp32 of shape (N,).
    """
    M, N = A.shape
    assert M % block_m == 0, (M, block_m)
    grid = (M // block_m,)

    kernel = functools.partial(_fused_iter_kernel, fi=fi, acc_dtype=acc_dtype)
    out, colsum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N), lambda i: (0, 0)),        # factor_col
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),  # a (RPD)
            pl.BlockSpec((block_m, N), lambda i: (i, 0)),  # A tile
        ],
        out_specs=[
            pl.BlockSpec((block_m, N), lambda i: (i, 0)),  # A' tile
            pl.BlockSpec((1, N), lambda i: (0, 0)),        # colsum acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), A.dtype),
            jax.ShapeDtypeStruct((1, N), acc_dtype),
        ],
        interpret=interpret,
    )(factor_col.reshape(1, N), a.reshape(M, 1), A)
    return out, colsum.reshape(N)


def _colsum_only_kernel(A_ref, colsum_ref, *, acc_dtype):
    """Initial column sums (the Algorithm 1 'preprocessing' pass)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(
        A_ref[...].astype(acc_dtype), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret", "acc_dtype"))
def colsum(A: jax.Array, *, block_m: int = 256, interpret: bool = False,
           acc_dtype=jnp.float32):
    M, N = A.shape
    assert M % block_m == 0
    out = pl.pallas_call(
        functools.partial(_colsum_only_kernel, acc_dtype=acc_dtype),
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, N), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, N), acc_dtype),
        interpret=interpret,
    )(A)
    return out.reshape(N)
