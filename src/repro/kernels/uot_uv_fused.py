"""Beyond-paper kernel: one READ-ONLY pass per u/v Sinkhorn iteration.

In the POT u/v-potential form the Gibbs kernel K never changes; an iteration
needs (K v) and (K^T u_new). The same interweaving insight that MAP-UOT
applies to the matrix-scaling form applies here with an even better traffic
bound: while streaming row block i to compute (K v)_i, the fresh
u_i = (a_i / (K v)_i)^fi is immediately available, so u_i * K[i, :] can be
accumulated into the K^T u partials during the SAME pass.

HBM traffic per iteration: M*N element READS, ZERO matrix writes
(vs MAP-UOT's MN read + MN write). K can additionally be stored bf16
(accumulators fp32), halving bytes again: total up to 12x less traffic than
the fp32 POT baseline.

    grid step i:
        blk = K[i*bm:(i+1)*bm, :]                 # read-only tile
        Kv_i = (blk * v[None, :]).sum(1)          # matvec piece
        u_i = (a_i / Kv_i) ** fi
        ktu_acc += (blk * u_i[:, None]).sum(0)    # transposed matvec piece
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.uot_fused import _safe_pow


def _uv_iter_kernel(v_ref, a_ref, K_ref, u_ref, ktu_ref, *, fi: float,
                    acc_dtype):
    i = pl.program_id(0)

    blk = K_ref[...].astype(acc_dtype)           # (bm, N) read-only
    v = v_ref[...].astype(acc_dtype)             # (1, N)

    Kv = jnp.sum(blk * v, axis=1, keepdims=True)  # (bm, 1)
    u = _safe_pow(a_ref[...].astype(acc_dtype), Kv, fi)
    u_ref[...] = u.astype(u_ref.dtype)

    @pl.when(i == 0)
    def _init():
        ktu_ref[...] = jnp.zeros_like(ktu_ref)

    ktu_ref[...] += jnp.sum(blk * u, axis=0, keepdims=True).astype(ktu_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fi", "block_m", "interpret",
                                             "acc_dtype"))
def uv_iteration(K: jax.Array, v: jax.Array, a: jax.Array, *, fi: float,
                 block_m: int = 256, interpret: bool = False,
                 acc_dtype=jnp.float32):
    """One u/v iteration's matrix work in a single read pass.

    Returns (u, KTu) — the caller finishes with v' = (b / KTu) ** fi (O(N)).
    """
    M, N = K.shape
    assert M % block_m == 0
    u, ktu = pl.pallas_call(
        functools.partial(_uv_iter_kernel, fi=fi, acc_dtype=acc_dtype),
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((1, N), lambda i: (0, 0)),        # v
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),  # a
            pl.BlockSpec((block_m, N), lambda i: (i, 0)),  # K tile
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),  # u
            pl.BlockSpec((1, N), lambda i: (0, 0)),        # K^T u acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, 1), acc_dtype),
            jax.ShapeDtypeStruct((1, N), acc_dtype),
        ],
        interpret=interpret,
    )(v.reshape(1, N), a.reshape(M, 1), K)
    return u.reshape(M), ktu.reshape(N)


def _materialize_kernel(u_ref, v_ref, K_ref, P_ref, *, acc_dtype):
    blk = K_ref[...].astype(acc_dtype)
    P_ref[...] = (blk * u_ref[...].astype(acc_dtype)
                  * v_ref[...].astype(acc_dtype)).astype(P_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret",
                                             "acc_dtype", "out_dtype"))
def materialize_coupling(K: jax.Array, u: jax.Array, v: jax.Array, *,
                         block_m: int = 256, interpret: bool = False,
                         acc_dtype=jnp.float32, out_dtype=jnp.float32):
    """P = diag(u) K diag(v) — one final pass after the solve."""
    M, N = K.shape
    assert M % block_m == 0
    P = pl.pallas_call(
        functools.partial(_materialize_kernel, acc_dtype=acc_dtype),
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
            pl.BlockSpec((block_m, N), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
    )(u.reshape(M, 1), v.reshape(1, N), K)
    return P
