"""Half-fused MAP-UOT passes with 2-D (row x col) tiling for wide matrices.

When a full (block_m, N) stripe no longer fits VMEM (N beyond ~1M fp32
columns) the paper's GPU design applies: split the iteration into two
half-fused kernels, each one read+write pass (paper Algorithms 2 and 4):

  * ``scale_rows_accum_cols``  — A *= frow[:, None]; colsum += A.sum(0)
    (paper part 2). Grid is (col_blocks, row_blocks) with the ROW dimension
    innermost so each (1, bn) column-sum accumulator block sees all its
    contributing grid steps consecutively (TPU revisit rule) — this replaces
    the GPU's atomicAdd into global Sum_col.
  * ``scale_cols_accum_rows``  — A *= fcol[None, :]; rowsum += A.sum(1)
    (paper part 4). Grid is (row_blocks, col_blocks), column dim innermost.

Full iteration = both kernels = 2 reads + 2 writes (Q = 4MN elements), vs
6MN for the baseline, matching the paper's GPU traffic model. These kernels
are also the local building blocks of the 2-D sharded distributed solver.

Mixed precision: like ``uot_fused``, ``A`` may be stored bf16 — tiles are
upcast to ``acc_dtype`` (fp32) for the multiply and both reductions, and
downcast once on store, halving Q in bytes (``ops.solve_halfpass`` threads
``storage_dtype`` through both passes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_rows_accum_cols_kernel(frow_ref, A_ref, out_ref, colsum_ref, *,
                                  acc_dtype):
    i = pl.program_id(1)  # row block (innermost)

    blk = A_ref[...].astype(acc_dtype) * frow_ref[...].astype(acc_dtype)
    out_ref[...] = blk.astype(out_ref.dtype)

    @pl.when(i == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += jnp.sum(blk, axis=0, keepdims=True).astype(colsum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret",
                                             "acc_dtype"))
def scale_rows_accum_cols(A: jax.Array, frow: jax.Array, *, block_m: int = 256,
                          block_n: int = 512, interpret: bool = False,
                          acc_dtype=jnp.float32):
    """A * frow[:, None], plus column sums of the result. (paper part 2)."""
    M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0, (A.shape, block_m, block_n)
    grid = (N // block_n, M // block_m)  # row dim innermost
    out, colsum = pl.pallas_call(
        functools.partial(_scale_rows_accum_cols_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, 1), lambda j, i: (i, 0)),       # frow
            pl.BlockSpec((block_m, block_n), lambda j, i: (i, j)),  # A
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, i: (i, j)),
            pl.BlockSpec((1, block_n), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), A.dtype),
            jax.ShapeDtypeStruct((1, N), acc_dtype),
        ],
        interpret=interpret,
    )(frow.reshape(M, 1), A)
    return out, colsum.reshape(N)


def _scale_cols_accum_rows_kernel(fcol_ref, A_ref, out_ref, rowsum_ref, *,
                                  acc_dtype):
    j = pl.program_id(1)  # col block (innermost)

    blk = A_ref[...].astype(acc_dtype) * fcol_ref[...].astype(acc_dtype)
    out_ref[...] = blk.astype(out_ref.dtype)

    @pl.when(j == 0)
    def _init():
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)

    rowsum_ref[...] += jnp.sum(blk, axis=1, keepdims=True).astype(rowsum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret",
                                             "acc_dtype"))
def scale_cols_accum_rows(A: jax.Array, fcol: jax.Array, *, block_m: int = 256,
                          block_n: int = 512, interpret: bool = False,
                          acc_dtype=jnp.float32):
    """A * fcol[None, :], plus row sums of the result. (paper part 4)."""
    M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0, (A.shape, block_m, block_n)
    grid = (M // block_m, N // block_n)  # col dim innermost
    out, rowsum = pl.pallas_call(
        functools.partial(_scale_cols_accum_rows_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),        # fcol
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),  # A
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), A.dtype),
            jax.ShapeDtypeStruct((M, 1), acc_dtype),
        ],
        interpret=interpret,
    )(fcol.reshape(1, N), A)
    return out, rowsum.reshape(M)
