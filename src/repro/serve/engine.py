"""Batched serving engines: LLM continuous batching + UOT request batching.

This module holds serving tiers 1-2 of the ladder described in the
``repro.serve`` package docstring; tier 3 (the continuous-batching
``UOTScheduler``) lives in ``repro.serve.scheduler``.

``ServeEngine`` — slot-based continuous batching over decode_step. A fixed
pool of B slots shares one compiled decode_step (one token for all slots per
call). Requests are admitted into free slots (prefill fills the slot's cache
region), generate until EOS/max_tokens, then free the slot for the next
queued request — the standard continuous-batching serving shape, minus
speculative decoding.

The per-slot KV-cache writes work because decode_step's cache update is
per-sequence (dynamic_update_slice at each slot's own index). For the
recurrent families the state is constant-size per slot. For simplicity the
engine tracks ONE shared cache_index per step group when slots are aligned
(prefill-once, generate-many benchmark mode) and per-slot indices otherwise.

``UOTBatchEngine`` — flush-barrier request batching for the UOT solver
(tier 2). Clients submit independent (K, a, b) problems of arbitrary
shapes; ``flush()`` groups the queue into padded-shape buckets and solves
each bucket with ONE batched fused-kernel launch
(``ops.solve_fused_batched``) instead of a kernel launch per request.
Zero-padding inside a bucket is exact, so every response equals its
standalone solve. Chunk batch sizes are canonicalized to powers of two so
flushes with repeating bucket shapes reuse the compiled solves
(``cache_stats()`` exposes the hit/miss counters). The flush is a barrier:
for latency-sensitive traffic use ``UOTScheduler`` instead.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obslib
from repro.core.problem import UOTConfig
from repro.kernels import ops as uot_ops


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Aligned-batch serving (all slots step together).

    greedy sampling; cache_len bounds prompt+generation length.
    """

    def __init__(self, model, params, batch_size: int, cache_len: int):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))

    def generate(self, prompts: list[np.ndarray], max_new_tokens: int = 16):
        """Serve a list of equal-length prompts (<= B at a time)."""
        assert len(prompts) <= self.B
        S = len(prompts[0])
        assert all(len(p) == S for p in prompts), "aligned-batch engine"
        B = self.B
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i] = p

        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        out_tokens = [[] for _ in range(B)]
        index = S
        cur = jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1)
        for i in range(B):
            out_tokens[i].append(int(cur[i]))

        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache,
                                         cur[:, None].astype(jnp.int32),
                                         jnp.int32(index))
            cur = jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1)
            index += 1
            for i in range(B):
                out_tokens[i].append(int(cur[i]))
        return [np.asarray(t, np.int32) for t in out_tokens[:len(prompts)]]

    def throughput_probe(self, steps: int = 8, prompt_len: int = 8):
        """Tokens/sec of the decode loop (batch B), for benchmarks."""
        import time
        prompts = [np.random.randint(0, self.cfg.vocab_size,
                                     size=prompt_len).astype(np.int32)
                   for _ in range(self.B)]
        # warmup + compile
        self.generate(prompts, max_new_tokens=2)
        t0 = time.perf_counter()
        self.generate(prompts, max_new_tokens=steps)
        dt = time.perf_counter() - t0
        return self.B * steps / dt


@dataclasses.dataclass
class UOTRequest:
    rid: int
    K: np.ndarray | None        # (M, N) initial coupling / Gibbs kernel
    a: np.ndarray               # (M,) row marginal
    b: np.ndarray               # (N,) column marginal
    # coordinate payload (set iff K is None — see submit_points): the
    # request ships (M + N) * (d + 1) floats instead of M * N
    x: np.ndarray | None = None
    y: np.ndarray | None = None
    xn: np.ndarray | None = None
    yn: np.ndarray | None = None
    scale: float = 1.0

    @property
    def shape(self) -> tuple[int, int]:
        if self.K is not None:
            return tuple(self.K.shape)
        return (self.x.shape[0], self.y.shape[0])


class UOTBatchEngine:
    """Shape-bucketed batch solving of queued UOT requests.

    submit() enqueues a problem and returns a request id; flush() drains the
    queue with one batched kernel launch per (padded-shape bucket, max_batch
    chunk) and returns {rid: coupling}. ``storage_dtype=jnp.bfloat16``
    selects the mixed-precision path (bf16 matrix in HBM, fp32 accumulation)
    for ~2x less HBM traffic per iteration at ~1e-2 relative error.
    """

    def __init__(self, cfg: UOTConfig, *, max_batch: int = 64,
                 m_bucket: int = 64, n_bucket: int = 128,
                 storage_dtype=None, interpret: bool | None = None,
                 impl: str | None = None,
                 obs: "obslib.Observability | bool | None" = None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.m_bucket = m_bucket
        self.n_bucket = n_bucket
        self.storage_dtype = storage_dtype
        self.interpret = interpret
        self.impl = impl
        # Observability (see repro.obs): "engine.*" metrics; flush()
        # charges each request's modeled solve bytes on the 'flush' route,
        # with the tier taken from the actual dispatch decisions
        # (ops.dispatch_observer) when impl routes via 'auto'/'resident'.
        if obs is None:
            obs = obslib.Observability()
        elif obs is False:
            obs = obslib.Observability(enabled=False, chain=False)
        self.obs = obs
        reg = obs.registry
        self._c_submitted = reg.counter("engine.submitted")
        self._c_flushes = reg.counter("engine.flushes")
        self._c_flushed = reg.counter("engine.flushed")
        self._queue: list[UOTRequest] = []
        self._next_rid = 0

    def submit(self, K, a, b) -> int:
        # payloads stay host-side numpy until flush() assembles the padded
        # batch (also in numpy) — one device transfer per bucket chunk
        # instead of three boundary crossings per request
        rid = self._next_rid
        self._next_rid += 1
        self._c_submitted.inc()
        self._queue.append(UOTRequest(rid, np.asarray(K), np.asarray(a),
                                      np.asarray(b)))
        return rid

    def submit_points(self, x, y, a, b, *, scale: float = 1.0) -> int:
        """Enqueue a point-cloud problem (squared-Euclidean cost
        ``C = ||x - y||^2 / scale`` of the (M, d) / (N, d) clouds).

        The request payload — and the per-request host->device transfer at
        flush — is ``(M + N) * (d + 1)`` floats (coordinates + squared
        norms) instead of the ``M * N`` kernel matrix; the flush solves
        these requests through ``ops.solve_fused_batched(geometry=...)``,
        whose kernel path computes the Gibbs tiles on-chip (no M*N cost
        array in HBM). Results are bit-identical to submitting
        ``geometry.kernel(cfg.reg)`` densely.
        """
        from repro.geometry import PointCloudGeometry
        g = PointCloudGeometry.from_points(x, y, scale=scale)
        rid = self._next_rid
        self._next_rid += 1
        self._c_submitted.inc()
        self._queue.append(UOTRequest(
            rid, None, np.asarray(a), np.asarray(b),
            x=np.asarray(g.x), y=np.asarray(g.y), xn=np.asarray(g.xn),
            yn=np.asarray(g.yn), scale=float(scale)))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> dict[int, jax.Array]:
        """Solve every queued request; returns {rid: coupling (M, N)}."""
        reqs, self._queue = self._queue, []
        if not reqs:
            return {}
        self._c_flushes.inc()
        self._c_flushed.inc(len(reqs))
        dense = [r for r in reqs if r.K is not None]
        points = [r for r in reqs if r.K is None]
        out: dict[int, jax.Array] = {}
        # record the flush's actual tier routing per (bucket, implicit)
        # so the traffic charges below use what dispatch DID, not a
        # re-derivation of what it should do
        decisions: dict[tuple[int, int, bool], tuple[str, int, int]] = {}

        def _observe(kind, *, M, N, itemsize, num_iters, implicit):
            decisions[(M, N, implicit)] = (kind, itemsize, num_iters)

        with uot_ops.dispatch_observer(_observe):
            if dense:
                results = uot_ops.solve_fused_bucketed(
                    [(r.K, r.a, r.b) for r in dense], self.cfg,
                    interpret=self.interpret,
                    storage_dtype=self.storage_dtype,
                    impl=self.impl, max_batch=self.max_batch,
                    m_bucket=self.m_bucket, n_bucket=self.n_bucket)
                out.update({r.rid: P for r, (P, _) in zip(dense, results)})
            if points:
                out.update(self._flush_points(points))
        self._charge_flush(reqs, decisions)
        return out

    def _charge_flush(self, reqs, decisions) -> None:
        """Charge each flushed request's modeled solve bytes (route
        'flush') at its padded bucket shape. An explicit (non-auto) impl
        makes no routing decision and streams — the fallback tier when a
        request's bucket has no recorded decision."""
        if not self.obs.traffic.enabled:
            return
        s_default = (np.dtype(self.storage_dtype).itemsize
                     if self.storage_dtype is not None else 4)
        for r in reqs:
            M, N = r.shape
            Mb, Nb = uot_ops.bucket_shape(M, N, self.m_bucket,
                                          self.n_bucket)
            implicit = r.K is None
            kind, s, T = decisions.get(
                (Mb, Nb, implicit),
                ("streamed", s_default, self.cfg.num_iters))
            self.obs.traffic.charge_solve(
                route="flush", tier=kind, M=Mb, N=Nb, s=s, T=T,
                source="implicit" if implicit else "dense",
                d=int(r.x.shape[1]) if implicit else None)

    def _flush_points(self, reqs) -> dict[int, np.ndarray]:
        """Bucketed batched solving of coordinate-payload requests.

        Mirrors ``ops.solve_fused_bucketed``'s chunking (padded-shape
        buckets, ``canonical_batch`` pow2 batch canonicalization, numpy
        host assembly) but the assembled stack is the ``O((M + N) * d)``
        coordinate operands + per-problem valid counts, handed to
        ``solve_fused_batched`` as a batched ``PointCloudGeometry``.
        Zero-padding exactness comes from the kernels' validity masks
        instead of zero matrix entries. Requests are additionally grouped
        by (d, scale), which brand the geometry's jit signature.
        """
        from repro.geometry import PointCloudGeometry
        results: dict[int, np.ndarray] = {}
        groups: dict[tuple, list] = {}
        for r in reqs:
            M, N = r.shape
            bucket = uot_ops.bucket_shape(M, N, self.m_bucket,
                                          self.n_bucket)
            groups.setdefault((bucket, r.x.shape[1], r.scale),
                              []).append(r)
        for (bucket, d, scale), members in groups.items():
            Mb, Nb = bucket
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                Bpad = uot_ops.canonical_batch(len(chunk), self.max_batch)
                xs = np.zeros((Bpad, Mb, d), np.float32)
                xns = np.zeros((Bpad, Mb), np.float32)
                ys = np.zeros((Bpad, Nb, d), np.float32)
                yns = np.zeros((Bpad, Nb), np.float32)
                mv = np.zeros(Bpad, np.int32)
                nv = np.zeros(Bpad, np.int32)
                a = np.zeros((Bpad, Mb), np.float32)
                b = np.zeros((Bpad, Nb), np.float32)
                for k, r in enumerate(chunk):
                    M, N = r.shape
                    xs[k, :M], xns[k, :M] = r.x, r.xn
                    ys[k, :N], yns[k, :N] = r.y, r.yn
                    mv[k], nv[k] = M, N
                    a[k, :M] = r.a
                    b[k, :N] = r.b
                geom = PointCloudGeometry(
                    x=jnp.asarray(xs), y=jnp.asarray(ys),
                    xn=jnp.asarray(xns), yn=jnp.asarray(yns),
                    m_valid=jnp.asarray(mv), n_valid=jnp.asarray(nv),
                    scale=scale)
                P, _ = uot_ops.solve_fused_batched(
                    None, jnp.asarray(a), jnp.asarray(b), self.cfg,
                    interpret=self.interpret,
                    storage_dtype=self.storage_dtype, impl=self.impl,
                    geometry=geom)
                P = np.asarray(P)
                for k, r in enumerate(chunk):
                    M, N = r.shape
                    results[r.rid] = P[k, :M, :N].copy()
        return results

    @staticmethod
    def cache_stats() -> dict:
        """Process-wide bucketed-solve jit reuse counters (hits/misses)."""
        return uot_ops.bucketed_cache_stats()
