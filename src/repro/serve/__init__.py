"""UOT + LLM serving engines — four tiers of request batching.

Tier 1 — per-request (``kernels.ops.solve_fused``): one launch per problem.
  Use for one-off solves, offline analysis, or problems too large to share
  a lane pool. No queueing, no cross-request amortization.

Tier 2 — bucketed flush (``UOTBatchEngine``): queue requests, then
  ``flush()`` solves each padded-shape bucket in one batched launch
  (compiled solves memoized across flushes). Use for offline/batch jobs
  where all requests are known up front and tail latency doesn't matter —
  every request in a flush waits for the slowest problem of its bucket.

Tier 3 — continuous scheduler (``UOTScheduler``): fixed lane pools advance
  chunk-by-chunk; converged lanes are evicted and their results returned
  immediately, freed lanes are refilled from the queue
  earliest-deadline-first, and ``submit`` applies backpressure. Use for
  online serving under live traffic on ONE device — it trades a small
  per-chunk host round trip for tail latency and deadline awareness
  (deadline misses are counted per request and aggregated in ``stats()``,
  and ``shed_policy='drop'/'degrade'`` refuses or down-budgets requests
  whose deadline already passed at admission).

Tier 4 — cluster scheduler (``repro.cluster.ClusterScheduler``): tier 3
  scaled across a device mesh. Per-device lane pools are stacked into a
  ``ClusterLaneState`` and ALL advance in one ``shard_map``-ped chunk
  launch; a router places each request on a device shard (least-loaded or
  bucket-affinity, optionally sharing one physical pool across buckets via
  valid-extent masking), an async double-buffered step loop overlaps host
  admission with the in-flight device chunk, and problems too large for
  any lane pool escape to the row-sharded gang solvers
  (``core.distributed.gang_solve`` — the paper's Tianhe-1 design) behind
  the same submit API. Results are bit-identical to tier 3 per request.

Traffic / placement decision table — pick the lowest row your traffic
needs; every row serves the rows above it too:

  =====================  ==================  ==========================
  traffic shape          tier                why
  =====================  ==================  ==========================
  one-off / huge         1 (``solve_fused``  no queue to amortize; gang
                         or the distributed  (``gang_solve``) when one
                         solvers)            device can't hold M*N
  batch job, all known   2 (``UOTBatch-      one launch per bucket beats
  up front               Engine``)           per-request dispatch; the
                                             flush barrier is acceptable
  live traffic, one      3 (``UOT-           per-lane eviction + EDF
  device's worth         Scheduler``)        admission: tail latency,
                                             deadlines, backpressure
  live traffic beyond    4 (``Cluster-       D devices' pools in one
  one device; mixed      Scheduler``)        launch; router places small
  sizes incl. over-                          problems, gang absorbs the
  budget                                     over-sized tail — nothing
                                             is rejected by shape
  =====================  ==================  ==========================

All request tiers accept **coordinate payloads** (``submit_points``) for
point-cloud costs: a request ships ``(M + N) * (d + 1)`` floats instead of
the ``M * N`` kernel matrix (``PointCloudGeometry.payload_nbytes``), the
Gibbs kernel is evaluated on-device (on-chip tiles on the TPU kernel path
— see ``repro.geometry``), and results are bit-identical to dense
submission of the same geometry's ``kernel(cfg.reg)``. The O(M + N)
payload is also what makes tier 4's routing cheap: placing a coordinate
request on any device shard costs a vector transfer, never a matrix.

Every tier accepts ``impl='auto'``: problems whose padded tile fits the
VMEM budget run on the resident kernel tier (whole solve — or whole
scheduler chunk — on-chip, one HBM read + write of the coupling instead of
one per iteration; see ``repro.kernels.ops``'s dispatch table), larger
ones stream.

Failure model — what each tier guarantees when requests or hardware
misbehave (tiers 3 and 4; tiers 1/2 are library calls — exceptions
propagate to the caller, nothing is shared, nothing to contain):

* **Admission** (tiers 3-4): ``submit``/``submit_points`` validate
  marginals and config (``core.health.validate_problem`` — non-finite /
  negative / empty marginals, shape/dtype mismatches, and the ``uv_safe``
  scaling-space overflow bound) and raise a typed ``InvalidProblemError``
  with the assigned rid; the refusal is recorded (telemetry
  ``status='rejected'`` + a pollable ``RequestFailure``) so refused rids
  still resolve. Backpressure raises ``QueueFullError``;
  ``submit_with_retry`` is the canonical capped-exponential-backoff
  client loop.
* **In flight** (tiers 3-4): per-lane health flags
  (``ops.LaneState.healthy``) fold a traffic-free non-finite detector
  over values the chunk advance already holds; a poisoned lane is frozen
  and quarantined at the next chunk boundary while every other lane stays
  bit-identical to a fault-free run (per-lane independence — tested).
* **Recovery**: tier 3 retries a quarantined request once on the
  log-domain tier (``status='retried_ok'`` — a *different tier's* answer,
  see the damping note in ``core.health``); tier 4 first bounces it to a
  healthy device (bit-identical answer, ``retries=1``) and only escalates
  on a second corruption. Unrecoverable requests end as typed
  ``RequestFailure`` (``status='failed'``) — never an exception out of
  ``step()``, never a poisoned neighbor.
* **Device faults** (tier 4): a device whose every active lane goes
  unhealthy at once is quarantined — drained, excluded from placement,
  reported in ``stats()['device_health']``; with no healthy device left,
  the lane queue drains through the gang path. ``gang_timeout=`` bounds
  the gang tier's wall clock (breaches deliver + mark ``timed_out`` and
  latch a degraded budget).
* **Resolution invariant** (tiers 3-4): every submitted rid resolves via
  ``poll`` to exactly one of — a coupling (``ok`` / ``retried_ok`` /
  ``timed_out``), or a ``RequestFailure`` (``failed`` / ``rejected`` /
  ``lost``) — property-tested under seeded fault schedules
  (``repro.serve.faults``, tests/test_faults*.py, and the
  ``benchmarks/bench_chaos.py`` discrete-event chaos harness).

Overload model (tiers 3-4, ``predictive=True``) — what happens when
offered load exceeds capacity. The principle: refuse or coarsen work
*early and labeled*, never lose it silently.

* **Service-time model** — ``core.predict``: predicted iterations
  (analytic TI contraction rate, refined online by a per-(bucket,
  imbalance-bin) EWMA fed from eviction telemetry) times a
  seconds-per-iteration rate (pinned via ``seconds_per_iter=`` or
  learned online from completions). The model stays *inert until
  calibrated* — it never refuses work on a guess.
* **Feasibility admission** — a deadline that cannot be met even
  starting immediately (``now + feasibility_margin * predicted_service
  > deadline``) is refused at ``submit`` with a typed
  ``InfeasibleDeadline`` (``shed_policy='drop'``) or walked straight
  down the degrade ladder (``'degrade'``) — *before* burning queue
  slots or lane time. With ``shed_policy='none'`` prediction only
  powers ordering and retry hints.
* **Predicted-finish-time EDF** — once calibrated, admission orders by
  least slack (deadline minus predicted service) instead of bare
  deadline: a long job with a near deadline outranks a short one.
* **Degrade ladder** — level 0: full solve. Level 1: truncated
  Sinkhorn at ``degrade_iters``, labeled with the analytic truncation
  error (``core.predict.estimate_truncation_error``). Level 2
  (point-cloud requests with finite ``reg_m``): exact sliced 1-D UOT
  (``geometry.sliced`` over ``core.solve_1d`` — O(n_proj (M+N)
  log(M+N)), no M*N anything), labeled with the certified per-slice
  gap + Monte-Carlo std err; solved host-side the same scheduling
  round, occupying no lane. Every degraded result carries
  ``degrade_level`` + ``est_error`` on its telemetry — coarse answers
  are always labeled, never passed off as full solves.
* **Brownout control** — ``overload.BrownoutController`` steps the
  ladder level applied to NEW admissions up/down on queue pressure
  (backlog over lane capacity) with two watermarks + patience
  hysteresis, so sustained overload sheds accuracy to drain the
  backlog and transient spikes don't flap the ladder.
* **Backpressure hints** — ``QueueFullError`` carries ``queue_depth``
  and a ``retry_after`` hint (predicted backlog drain time);
  ``submit_with_retry`` uses the hint as its backoff base, falling
  back to blind exponential backoff when prediction is off.
* Metrics: ``serve.admission.infeasible``, ``serve.degrade.l{1,2}``,
  ``serve.degrade.brownout_level``, ``serve.predict.rel_err`` (the
  predictor's audit histogram), mirrored under ``cluster.*`` for
  tier 4 (whose gate exempts gang-routed requests — the lane model
  does not describe row-sharded gang solves).

Observability (``repro.obs``) — every serving tier carries one bundle
(``obs=`` on the tier 2/3/4 constructors: ``None`` builds a fresh enabled
bundle chained to the process-global one, ``False`` keeps the registry but
swaps the tracer/accountant for null twins, or pass an
``obs.Observability`` directly):

* **Metrics registry** — the running totals behind ``stats()`` ARE
  registry counters (never a parallel tally): ``serve.submitted``,
  ``serve.completed``, ``serve.rejected``, ``serve.failed``,
  ``serve.retried_ok``, ``serve.timed_out``, ``serve.shed_dropped``,
  ``serve.shed_degraded``, ``serve.deadline_misses``,
  ``serve.deadlined_completed``, ``serve.unhealthy_evictions``,
  ``serve.lost_results``, ``serve.window_dropped_{requests,occupancy,
  dispositions}`` (what the ``max_log`` trims discarded — surfaced in
  ``stats()['window_dropped']``), dispatch routing under
  ``serve.dispatch.{resident,streamed}``, wait/latency/iteration
  histograms under ``serve.{wait_s,latency_s,iters}``, and
  occupancy/queue-depth gauges. Tier 4 mirrors the same names under
  ``cluster.*`` (plus ``requeued``, ``gang_timeouts``,
  ``gang_completed``, ``devices_quarantined``) and adds router counters
  ``cluster.router.{least_loaded,affinity_hits,affinity_spills,
  shared_pool,placement_stalls,gang_routed}``; tier 2 counts
  ``engine.{submitted,flushes,flushed}``.
* **Span tracer** — per-request lifecycle events (``submit``, ``queue``,
  ``place``, ``chunk``, ``evict``, ``requeue``, ``escalate``, ``shed``,
  ``gang``, ``lost``, ``poll``, and exactly one terminal ``complete`` per
  rid — the zero-span-loss invariant ``SpanTracer.check_complete``
  audits and ``benchmarks/bench_chaos.py`` hard-asserts). Export with
  ``write_jsonl`` / render with ``render_timeline``. Chunk events ride
  host flag arrays the eviction scan already fetches — no extra device
  syncs.
* **HBM-traffic accountant** — every dispatch decision (admission's
  cost-source payment, chunk advances, full solves, gang collectives) is
  charged its modeled bytes from the ``kernels.ops`` dispatch-table
  formulas at padded shapes, keyed by route (``flush``/``lane``/
  ``gang``) and tier (``streamed``/``resident``), with a roofline
  summary via ``launch.roofline``. Totals are mechanically re-derivable
  from the per-record formula keys (asserted in tests and the chaos
  bench).

``benchmarks/run.py`` dumps the global bundle to ``OBS_<suite>.json`` per
suite; ``benchmarks/bench_obs.py`` is the obs-on-vs-off overhead gate
(<= 5% on throughput and p99).

``ServeEngine`` is the LLM-token sibling of tier 3: slot-based continuous
batching over ``decode_step`` (the architecture ``UOTScheduler`` mirrors,
with solver lanes in place of KV-cache slots).
"""
from repro.serve.engine import (Request, ServeEngine, UOTBatchEngine,
                                UOTRequest)
from repro.serve.overload import (BrownoutController, InfeasibleDeadline,
                                  queue_pressure)
from repro.serve.scheduler import (QueueFullError, RequestFailure,
                                   RequestTelemetry, ScheduledRequest,
                                   UOTScheduler, submit_with_retry)
from repro.serve import faults, overload

__all__ = ["ServeEngine", "Request", "UOTBatchEngine", "UOTRequest",
           "UOTScheduler", "ScheduledRequest", "RequestTelemetry",
           "QueueFullError", "RequestFailure", "submit_with_retry",
           "InfeasibleDeadline", "BrownoutController", "queue_pressure",
           "faults", "overload"]
