"""UOT + LLM serving engines — four tiers of request batching.

Tier 1 — per-request (``kernels.ops.solve_fused``): one launch per problem.
  Use for one-off solves, offline analysis, or problems too large to share
  a lane pool. No queueing, no cross-request amortization.

Tier 2 — bucketed flush (``UOTBatchEngine``): queue requests, then
  ``flush()`` solves each padded-shape bucket in one batched launch
  (compiled solves memoized across flushes). Use for offline/batch jobs
  where all requests are known up front and tail latency doesn't matter —
  every request in a flush waits for the slowest problem of its bucket.

Tier 3 — continuous scheduler (``UOTScheduler``): fixed lane pools advance
  chunk-by-chunk; converged lanes are evicted and their results returned
  immediately, freed lanes are refilled from the queue
  earliest-deadline-first, and ``submit`` applies backpressure. Use for
  online serving under live traffic on ONE device — it trades a small
  per-chunk host round trip for tail latency and deadline awareness
  (deadline misses are counted per request and aggregated in ``stats()``,
  and ``shed_policy='drop'/'degrade'`` refuses or down-budgets requests
  whose deadline already passed at admission).

Tier 4 — cluster scheduler (``repro.cluster.ClusterScheduler``): tier 3
  scaled across a device mesh. Per-device lane pools are stacked into a
  ``ClusterLaneState`` and ALL advance in one ``shard_map``-ped chunk
  launch; a router places each request on a device shard (least-loaded or
  bucket-affinity, optionally sharing one physical pool across buckets via
  valid-extent masking), an async double-buffered step loop overlaps host
  admission with the in-flight device chunk, and problems too large for
  any lane pool escape to the row-sharded gang solvers
  (``core.distributed.gang_solve`` — the paper's Tianhe-1 design) behind
  the same submit API. Results are bit-identical to tier 3 per request.

Traffic / placement decision table — pick the lowest row your traffic
needs; every row serves the rows above it too:

  =====================  ==================  ==========================
  traffic shape          tier                why
  =====================  ==================  ==========================
  one-off / huge         1 (``solve_fused``  no queue to amortize; gang
                         or the distributed  (``gang_solve``) when one
                         solvers)            device can't hold M*N
  batch job, all known   2 (``UOTBatch-      one launch per bucket beats
  up front               Engine``)           per-request dispatch; the
                                             flush barrier is acceptable
  live traffic, one      3 (``UOT-           per-lane eviction + EDF
  device's worth         Scheduler``)        admission: tail latency,
                                             deadlines, backpressure
  live traffic beyond    4 (``Cluster-       D devices' pools in one
  one device; mixed      Scheduler``)        launch; router places small
  sizes incl. over-                          problems, gang absorbs the
  budget                                     over-sized tail — nothing
                                             is rejected by shape
  =====================  ==================  ==========================

All request tiers accept **coordinate payloads** (``submit_points``) for
point-cloud costs: a request ships ``(M + N) * (d + 1)`` floats instead of
the ``M * N`` kernel matrix (``PointCloudGeometry.payload_nbytes``), the
Gibbs kernel is evaluated on-device (on-chip tiles on the TPU kernel path
— see ``repro.geometry``), and results are bit-identical to dense
submission of the same geometry's ``kernel(cfg.reg)``. The O(M + N)
payload is also what makes tier 4's routing cheap: placing a coordinate
request on any device shard costs a vector transfer, never a matrix.

Every tier accepts ``impl='auto'``: problems whose padded tile fits the
VMEM budget run on the resident kernel tier (whole solve — or whole
scheduler chunk — on-chip, one HBM read + write of the coupling instead of
one per iteration; see ``repro.kernels.ops``'s dispatch table), larger
ones stream.

``ServeEngine`` is the LLM-token sibling of tier 3: slot-based continuous
batching over ``decode_step`` (the architecture ``UOTScheduler`` mirrors,
with solver lanes in place of KV-cache slots).
"""
from repro.serve.engine import (Request, ServeEngine, UOTBatchEngine,
                                UOTRequest)
from repro.serve.scheduler import (QueueFullError, RequestTelemetry,
                                   ScheduledRequest, UOTScheduler)

__all__ = ["ServeEngine", "Request", "UOTBatchEngine", "UOTRequest",
           "UOTScheduler", "ScheduledRequest", "RequestTelemetry",
           "QueueFullError"]
