"""UOT + LLM serving engines — three tiers of request batching.

Tier 1 — per-request (``kernels.ops.solve_fused``): one launch per problem.
  Use for one-off solves, offline analysis, or problems too large to share
  a lane pool. No queueing, no cross-request amortization.

Tier 2 — bucketed flush (``UOTBatchEngine``): queue requests, then
  ``flush()`` solves each padded-shape bucket in one batched launch
  (compiled solves memoized across flushes). Use for offline/batch jobs
  where all requests are known up front and tail latency doesn't matter —
  every request in a flush waits for the slowest problem of its bucket.

Tier 3 — continuous scheduler (``UOTScheduler``): fixed lane pools advance
  chunk-by-chunk; converged lanes are evicted and their results returned
  immediately, freed lanes are refilled from the queue
  earliest-deadline-first, and ``submit`` applies backpressure. Use for
  online serving under live traffic — it trades a small per-chunk host
  round trip for tail latency and deadline awareness (deadline misses are
  counted per request and aggregated in ``stats()``, and
  ``shed_policy='drop'/'degrade'`` refuses or down-budgets requests whose
  deadline already passed at admission).

Both request tiers accept **coordinate payloads** (``submit_points``) for
point-cloud costs: a request ships ``(M + N) * (d + 1)`` floats instead of
the ``M * N`` kernel matrix, the Gibbs kernel is evaluated on-device
(on-chip tiles on the TPU kernel path — see ``repro.geometry``), and
results are bit-identical to dense submission of the same geometry's
``kernel(cfg.reg)``.

Every tier accepts ``impl='auto'``: problems whose padded tile fits the
VMEM budget run on the resident kernel tier (whole solve — or whole
scheduler chunk — on-chip, one HBM read + write of the coupling instead of
one per iteration; see ``repro.kernels.ops``'s dispatch table), larger
ones stream.

``ServeEngine`` is the LLM-token sibling of tier 3: slot-based continuous
batching over ``decode_step`` (the architecture ``UOTScheduler`` mirrors,
with solver lanes in place of KV-cache slots).
"""
from repro.serve.engine import (Request, ServeEngine, UOTBatchEngine,
                                UOTRequest)
from repro.serve.scheduler import (QueueFullError, RequestTelemetry,
                                   ScheduledRequest, UOTScheduler)

__all__ = ["ServeEngine", "Request", "UOTBatchEngine", "UOTRequest",
           "UOTScheduler", "ScheduledRequest", "RequestTelemetry",
           "QueueFullError"]
