from repro.serve.engine import (Request, ServeEngine, UOTBatchEngine,
                                UOTRequest)

__all__ = ["ServeEngine", "Request", "UOTBatchEngine", "UOTRequest"]
