"""Seeded, composable fault injectors — the chaos side of fault containment.

The schedulers' containment ladder (admission validation, lane-health
detection, quarantine-and-retry, device quarantine — see
``repro.serve.scheduler``'s and ``repro.cluster.scheduler``'s docstrings)
is only trustworthy if it is *exercised*: every claim of the form "a NaN
payload cannot poison its lane-mates" needs a test that actually submits
NaN payloads next to healthy traffic and bit-compares the healthy answers
against a fault-free run. This module is that traffic generator.

Protocol (duck-typed; schedulers accept any object with these methods via
their ``fault_injector=`` constructor hook):

* ``on_submit(rid, K, a, b) -> (K, a, b, tag)`` — called once per
  submission with the request's payload (``K`` is None for coordinate
  requests); returns the possibly-mutated payload plus a fault tag
  (``None`` = untouched). The scheduler stores the tag on the request
  (chaos bookkeeping only — the runtime never reads it).
* ``on_step(scheduler) -> None`` — called at the top of every scheduling
  round with the live scheduler; may corrupt in-flight state through the
  drill hooks (``inject_lane_fault`` / ``inject_device_fault``).

Determinism contract: every per-request decision draws from a
``numpy`` Philox stream keyed on ``(seed, rid)`` — NOT on arrival order,
submission time, or a shared stream — so the same seed produces the same
fault set for the same rids regardless of how the requests interleave.
That is what lets the property test assert "any arrival order, same fault
schedule, every rid resolves" (tests/test_faults_property.py).

Injectors (compose freely with ``Compose``; first injector to tag a
request wins, so rates are per-injector, applied in order):

* ``NaNPayload`` — a NaN in the kernel matrix: passes O(M+N) admission
  validation by design, poisons the lane at its first chunk, exercises
  detector -> quarantine -> escalation-fails -> ``status='failed'``.
* ``PayloadCorruption`` — a finite bit-flip-style corruption (one entry
  scaled): solves fine, answers differ. Exercises the *bookkeeping*
  boundary: tagged rids are excluded from bit-identity comparison; that
  untagged rids must still match is exactly the blast-radius claim.
* ``OverflowConfig`` — marginal mass scaled into the scaling-space
  overflow regime: rejected at admission by the ``uv_safe`` bound
  (finite ``reg_m``), or served by the containment ladder when the bound
  does not apply.
* ``StuckLane`` — the kernel sharpened (entrywise power): a genuinely
  slow-converging problem that rides its lane to the iteration cap
  (``status='timed_out'`` under ``tol``) instead of converging —
  the slow-poke fault, not a numeric one.
* ``DeviceBlackout`` — one device shard's pool state NaN'd wholesale at
  a chosen step (cluster only; a no-op on schedulers without the hook):
  exercises quarantine, drain-and-requeue, and placement exclusion.
* ``LaneFault`` — seeded in-flight lane corruption of individual
  requests (intact host payload): exercises the single-device
  escalation path / the cluster requeue bounce.
"""
from __future__ import annotations

import numpy as np


class FaultInjector:
    """Base injector: touches nothing. Subclass and override; schedulers
    only need the two methods, not this class."""

    def on_submit(self, rid: int, K, a, b):
        return K, a, b, None

    def on_step(self, scheduler) -> None:
        pass


class _SeededInjector(FaultInjector):
    """Per-request Philox streams keyed (seed, rid); ``injected`` maps
    rid -> tag for every request this injector actually touched."""

    tag = "fault"

    def __init__(self, rate: float, seed: int = 0):
        self.rate = float(rate)
        self.seed = int(seed)
        self.injected: dict[int, str] = {}

    def _rng(self, rid: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, rid])

    def _mark(self, rid: int) -> str:
        self.injected[rid] = self.tag
        return self.tag


class NaNPayload(_SeededInjector):
    """With probability ``rate``, one kernel entry becomes NaN (dense
    requests only — coordinate payloads have no K to poison)."""

    tag = "nan_payload"

    def on_submit(self, rid, K, a, b):
        rng = self._rng(rid)
        if K is not None and rng.random() < self.rate:
            K = np.array(K, dtype=np.float32, copy=True)
            K[rng.integers(K.shape[0]), rng.integers(K.shape[1])] = np.nan
            return K, a, b, self._mark(rid)
        return K, a, b, None


class PayloadCorruption(_SeededInjector):
    """With probability ``rate``, one kernel entry is scaled by
    ``factor`` — finite, silent corruption: the request solves, the
    answer is wrong. Tagged so harnesses exclude it from bit-identity
    checks (and assert untagged neighbors still match)."""

    tag = "corrupt_payload"

    def __init__(self, rate: float, seed: int = 0, factor: float = 32.0):
        super().__init__(rate, seed)
        self.factor = float(factor)

    def on_submit(self, rid, K, a, b):
        rng = self._rng(rid)
        if K is not None and rng.random() < self.rate:
            K = np.array(K, dtype=np.float32, copy=True)
            K[rng.integers(K.shape[0]),
              rng.integers(K.shape[1])] *= self.factor
            return K, a, b, self._mark(rid)
        return K, a, b, None


class OverflowConfig(_SeededInjector):
    """With probability ``rate``, the row marginal's total mass is scaled
    by ``mass_factor`` — pushing the request into the scaling-space
    overflow regime for finite-``reg_m`` configs, where the admission
    bound (``core.health.uv_safe``) refuses it with a typed
    ``InvalidProblemError('uv_overflow')``."""

    tag = "overflow_cfg"

    def __init__(self, rate: float, seed: int = 0,
                 mass_factor: float = 1e30):
        super().__init__(rate, seed)
        self.mass_factor = float(mass_factor)

    def on_submit(self, rid, K, a, b):
        rng = self._rng(rid)
        if rng.random() < self.rate:
            a = np.asarray(a, dtype=np.float32) * np.float32(
                self.mass_factor)
            return K, a, b, self._mark(rid)
        return K, a, b, None


class StuckLane(_SeededInjector):
    """With probability ``rate``, the kernel is sharpened entrywise
    (``K ** power``, clamped away from 0): a much peakier problem whose
    factor trajectory converges far more slowly — the lane rides to the
    iteration cap instead of converging (``status='timed_out'`` when the
    scheduler runs with ``tol``). A *slowness* fault: all values stay
    finite, containment must budget it, not quarantine it."""

    tag = "stuck_lane"

    def __init__(self, rate: float, seed: int = 0, power: float = 8.0):
        super().__init__(rate, seed)
        self.power = float(power)

    def on_submit(self, rid, K, a, b):
        rng = self._rng(rid)
        if K is not None and rng.random() < self.rate:
            K = np.asarray(K, dtype=np.float32)
            tiny = np.float32(np.finfo(np.float32).tiny)
            K = np.maximum(K, tiny) ** np.float32(self.power)
            K = np.maximum(K, tiny)
            return K, a, b, self._mark(rid)
        return K, a, b, None


class DeviceBlackout(FaultInjector):
    """Black out device ``device`` once, at the first round where the
    scheduler has taken >= ``at_step`` steps AND the device is running
    >= ``min_active`` lanes. The busy-ness gate matters: the cluster's
    blackout signature (quarantine) is *every* active lane on a device
    going unhealthy at once — striking a near-idle device is
    indistinguishable from a single lane fault and is (correctly) handled
    per-request instead. Cluster-only: silently a no-op on schedulers
    without an ``inject_device_fault`` hook."""

    tag = "device_blackout"

    def __init__(self, device: int, at_step: int = 2, min_active: int = 2):
        self.device = int(device)
        self.at_step = int(at_step)
        self.min_active = int(min_active)
        self.fired = False

    def on_step(self, scheduler) -> None:
        if (self.fired or scheduler._steps < self.at_step
                or not hasattr(scheduler, "inject_device_fault")):
            return
        if scheduler._device_active(self.device) < self.min_active:
            return
        # black-box note BEFORE the strike: a flight capture of the
        # resulting quarantine shows the injection that caused it
        scheduler.obs.flight.note(
            "fault", device=self.device, tag=self.tag,
            step=scheduler._steps)
        scheduler.inject_device_fault(self.device)
        self.fired = True


class LaneFault(_SeededInjector):
    """Each round, each in-flight request's (seed, rid, step)-keyed coin
    decides whether its lane state is corrupted in place (host payload
    intact — the transient-device-fault model). Exercises the
    single-device log-domain escalation and the cluster requeue bounce."""

    tag = "lane_fault"

    def on_step(self, scheduler) -> None:
        for pool in scheduler._pools.values():
            for req in list(pool.requests.values()):
                rng = np.random.default_rng(
                    [self.seed, req.rid, scheduler._steps])
                # only strike once per request: a second strike would
                # exhaust its retry budget by design, which is a
                # scenario tests set up explicitly, not at random
                if req.rid not in self.injected and (
                        rng.random() < self.rate):
                    if scheduler.inject_lane_fault(req.rid):
                        self._mark(req.rid)


class Compose(FaultInjector):
    """Chain injectors; the first to tag a submission wins (rates are
    per-injector, applied in order). ``on_step`` fans out to all.
    ``injected`` merges the children's rid -> tag maps."""

    def __init__(self, injectors):
        self.injectors = list(injectors)

    def on_submit(self, rid, K, a, b):
        for inj in self.injectors:
            K, a, b, tag = inj.on_submit(rid, K, a, b)
            if tag is not None:
                return K, a, b, tag
        return K, a, b, None

    def on_step(self, scheduler) -> None:
        for inj in self.injectors:
            inj.on_step(scheduler)

    @property
    def injected(self) -> dict[int, str]:
        merged: dict[int, str] = {}
        for inj in self.injectors:
            merged.update(getattr(inj, "injected", {}))
        return merged
