"""Continuous-batching UOT scheduler: solver lanes as serving slots.

The third serving tier (see ``repro.serve``'s module docstring for the
ladder). ``UOTBatchEngine.flush()`` is a barrier: every request in a flush
waits for the slowest problem of its bucket, and requests that arrive while
a flush is running wait for the whole thing. This module replaces the
barrier with the LLM continuous-batching shape, applied to solver state
instead of KV caches:

* one fixed **lane pool** per (m_bucket, n_bucket) padded-shape bucket — a
  ``kernels.ops.LaneState`` stack advanced a *chunk* of Algorithm-1
  iterations at a time by ``ops.solve_fused_stepped`` (one batched launch
  per chunk, Pallas ``'kernel'`` or vectorized ``'jnp'``);
* between chunks, lanes whose per-lane row-factor stationarity drift passed
  ``cfg.tol`` (or that hit ``cfg.num_iters``) are **evicted** and their
  couplings returned immediately — a fast-converging problem never waits
  for a slow lane-mate;
* queued requests are **admitted** into free or freshly-evicted lanes
  earliest-deadline-first (ties: higher priority, then FIFO), so a late
  urgent request starts solving one chunk-boundary after it arrives instead
  of one full flush later;
* ``submit`` applies **backpressure**: beyond ``max_queue`` waiting
  requests it raises ``QueueFullError`` instead of growing an unbounded
  queue.

Because per-lane math is independent of pool occupancy (free lanes are
zero problems — exact no-ops), every request's answer equals its standalone
solve regardless of arrival order, admission interleaving, or evictions;
tests/test_scheduler.py asserts this property for both impls.

Telemetry: every completed request carries a ``RequestTelemetry`` (wait
time, solve iterations, lane, converged-vs-cap, deadline + whether it was
missed, shed disposition, terminal ``status`` + retry count),
``occupancy_log`` snapshots lane utilization and the running
deadline-miss total per step, and ``stats()`` reports
``deadline_misses`` / ``miss_rate`` / ``shed_dropped`` / ``shed_degraded``
— the inputs for the latency/occupancy/miss numbers in
``benchmarks/bench_serve.py``.

Fault containment (the robustness contract; see ``repro.serve``'s
"Failure model" section for the tier-by-tier story):

* **admission** — ``submit``/``submit_points`` run
  ``core.health.validate_problem`` (``validate=True``): non-finite /
  negative / empty marginals, shape/dtype mismatches, and
  overflow-regime ``(cfg, a, b)`` combinations (the ``uv_safe``
  amplification bound) raise a typed ``InvalidProblemError`` carrying
  the assigned rid — the request is refused with telemetry
  (``status='rejected'``) instead of poisoning a shared lane.
* **in flight** — the stepped advance's lane-health detector
  (``ops.LaneState.healthy``) freezes a lane whose factors/colsums go
  non-finite; eviction sees the flag (and double-checks the evicted
  coupling slice host-side, which also catches poison landing after the
  convergence latch) and quarantines the request. Every OTHER lane is
  bit-identical to a fault-free pool — per-lane math is independent.
* **escalation** — a quarantined request is retried ONCE on
  ``sinkhorn_uot_log`` via ``core.health.escalate_log_solve`` (the
  numerically robust tier, escalated iteration budget). A finite
  escalated coupling completes the request with ``status='retried_ok'``;
  anything else is a typed ``RequestFailure`` (``status='failed'``).
* **resolution** — ``poll`` resolves EVERY submitted rid exactly once:
  the coupling, or a ``RequestFailure``
  (failed / rejected / lost-to-the-result-bound), or None only while
  genuinely pending. A convergence-wanting request that hit
  ``cfg.num_iters`` still returns its capped coupling but is recorded
  ``status='timed_out'``.
* **chaos hook** — ``fault_injector=`` (see ``repro.serve.faults``)
  mutates payloads at submit and may corrupt lane state between steps;
  it exists so the containment above is *tested* under seeded fault
  schedules, not assumed.

Deadline-aware shedding (``shed_policy``): a request whose deadline has
already passed when it reaches admission cannot meet it no matter what —
``'drop'`` refuses it a lane entirely (telemetry-only completion,
``lane=-1``), ``'degrade'`` admits it with a reduced iteration budget
(``degrade_iters``, default one chunk) so it returns a coarse answer
after a single scheduling quantum. ``'none'`` (default) keeps the
serve-everything behavior.

Point-cloud requests (``submit_points``) carry coordinates + precomputed
squared norms — ``(M + N) * (d + 1)`` floats instead of ``M * N`` — and
materialize their Gibbs kernel on-device at admission via the geometry
mirror, so a coordinate request's lane trajectory is bit-identical to
dense submission of ``geometry.kernel(cfg.reg)`` (tests assert it).

With ``impl='auto'`` each pool's chunk advance is routed per bucket shape
by ``ops.resident_fits``: fp32 pools that fit the VMEM budget run their
whole chunk with each lane's tile resident
(``ops.solve_fused_stepped_resident`` — one launch, no per-iteration HBM
round trips), larger or sub-fp32 pools keep the streamed masked kernel.

This scheduler is single-device; ``repro.cluster.ClusterScheduler`` (the
fourth tier) stacks one such lane-pool set per mesh device, advances them
all in one ``shard_map`` launch, and routes over-sized problems to the
distributed gang — with results bit-identical to this class per request.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro import obs as obslib
from repro.core.problem import UOTConfig
from repro.core.health import (InvalidProblemError, escalate_log_solve,
                               validate_problem)
from repro.core.predict import (IterPredictor, estimate_truncation_error,
                                measured_seconds_per_iter)
from repro.geometry import PointCloudGeometry
from repro.geometry.sliced import lift_coupling_np, sliced_uot
from repro.kernels import ops
from repro.serve.overload import (BrownoutController, InfeasibleDeadline,
                                  queue_pressure)

# registry counter names shared by both schedulers ("serve.<name>" /
# "cluster.<name>"): the running totals stats() reports — refactored
# from ad-hoc int fields onto repro.obs.MetricsRegistry (PR 7); the
# stats() dict shapes are unchanged
_COUNTER_NAMES = (
    "submitted", "completed", "rejected", "failed", "retried_ok",
    "timed_out", "unhealthy_evictions", "lost_results", "deadline_misses",
    "deadlined_completed", "shed_dropped", "shed_degraded",
    "window_dropped_requests", "window_dropped_occupancy",
    "window_dropped_dispositions")


class QueueFullError(RuntimeError):
    """Raised by submit() when the waiting queue is at max_queue.

    Carries the observed ``queue_depth`` and, when the scheduler's
    service-time model has calibrated (``predictive=True`` and at least
    one completion observed), a ``retry_after`` hint in seconds — the
    predicted time for the backlog to drain one full lane round. Both
    are None-safe: prediction off means ``retry_after is None`` and
    clients fall back to their own backoff base (``submit_with_retry``
    does exactly that).
    """

    def __init__(self, message: str, *, queue_depth: int | None = None,
                 retry_after: float | None = None):
        super().__init__(message)
        self.queue_depth = queue_depth
        self.retry_after = retry_after


def submit_with_retry(scheduler, *args, attempts: int = 6,
                      base_delay: float = 0.05, max_delay: float = 2.0,
                      seed: int = 0, sleep: Callable[[float], None] = None,
                      submit: Callable | None = None, **kwargs) -> int:
    """Client-side backpressure helper: ``scheduler.submit(*args,
    **kwargs)`` with capped exponential backoff on ``QueueFullError``.

    The docstring advice "the caller sheds load or retries later" made
    concrete: up to ``attempts`` tries, sleeping
    ``min(max_delay, base_delay * 2**i) * (0.5 + 0.5 * jitter)`` between
    them — deterministic jitter from ``seed`` (``numpy`` Philox, no global
    RNG state), so a fleet of callers configured with distinct seeds
    decorrelates its retry storms *reproducibly*. After the last failed
    attempt the final ``QueueFullError`` propagates (give-up semantics:
    the caller learns the queue never drained; nothing is silently
    dropped). ``submit=`` overrides the bound method (e.g.
    ``scheduler.submit_points``); ``sleep=`` is injectable for tests and
    simulated clocks — when omitted it resolves to the *scheduler's* own
    injected ``sleep`` (both schedulers accept ``sleep=`` next to
    ``clock=``), so a fake-clock scheduler never races wall time through
    this helper. Validation errors (``InvalidProblemError``) are NOT
    retried — a refused problem stays refused.

    When the raised ``QueueFullError`` carries a ``retry_after`` hint
    (the scheduler's predicted backlog drain time — see
    ``predictive=``), that hint replaces ``base_delay`` as the backoff
    base: the client waits roughly as long as the queue actually needs,
    instead of a blind constant. With prediction off the behavior is
    exactly the historical capped-exponential one.
    """
    if sleep is None:
        sleep = getattr(scheduler, "sleep", None) or time.sleep
    fn = submit if submit is not None else scheduler.submit
    rng = np.random.default_rng(seed)
    for attempt in range(attempts):
        try:
            return fn(*args, **kwargs)
        except QueueFullError as err:
            if attempt == attempts - 1:
                raise
            base = (err.retry_after
                    if getattr(err, "retry_after", None) else base_delay)
            delay = min(max_delay, base * (2.0 ** attempt))
            sleep(delay * (0.5 + 0.5 * float(rng.random())))
    raise AssertionError("unreachable")  # pragma: no cover


@dataclasses.dataclass
class RequestFailure:
    """The typed terminal disposition ``poll`` returns when a request did
    not end in a usable coupling: ``status`` is ``'failed'`` (poisoned in
    flight, escalation also failed), ``'rejected'`` (refused at admission
    or shed-dropped), or ``'lost'`` (completed fine, but the bounded
    result store evicted the coupling before it was polled — the answer
    is gone, the *disposition* is not). ``reason`` is human-readable;
    ``retries`` counts escalation attempts spent."""

    rid: int
    status: str
    reason: str
    retries: int = 0


@dataclasses.dataclass
class ScheduledRequest:
    """A queued UOT problem plus its scheduling attributes.

    Payload stays host-side numpy while queued; the single host->device
    transfer happens at admission (already padded to the bucket shape).
    Point-cloud requests (``submit_points``) carry coordinates + squared
    norms instead of ``K`` — ``(M + N) * (d + 1)`` floats instead of
    ``M * N`` — and materialize their Gibbs kernel on-device at admission.
    """

    rid: int
    K: np.ndarray | None        # (M, N) initial coupling / Gibbs kernel
    a: np.ndarray               # (M,) row marginal
    b: np.ndarray               # (N,) column marginal
    shape: tuple[int, int]
    bucket: tuple[int, int]
    arrival: float
    deadline: float | None = None   # absolute time; None = no deadline
    priority: int = 0               # higher = more urgent (EDF tie-break)
    # coordinate payload (set iff K is None): the geometry-sourced request
    x: np.ndarray | None = None     # (M, d)
    y: np.ndarray | None = None     # (N, d)
    xn: np.ndarray | None = None    # (M,) precomputed squared norms
    yn: np.ndarray | None = None    # (N,)
    scale: float = 1.0
    # deadline-aware shedding state (set at admission time)
    max_iters: int | None = None    # reduced budget for degraded requests
    shed: str | None = None         # None | 'degraded' ('dropped' never
    #                                 occupies a lane, only telemetry)
    # overload-model state (predictive=True; see repro.serve's overload
    # model): ladder level 0/1/2, the admission-time iteration
    # prediction, and the error label attached to degraded answers
    degrade_level: int = 0
    predicted_iters: float | None = None
    est_error: float | None = None
    # fault-containment state
    retries: int = 0                # escalation/requeue attempts spent
    fault: str | None = None        # injector tag (chaos bookkeeping only;
    #                                 the runtime never reads it)

    def edf_key(self):
        """Earliest-deadline-first with priority then FIFO tie-breaks."""
        d = self.deadline if self.deadline is not None else float("inf")
        return (d, -self.priority, self.rid)

    def slack_key(self, service: float | None):
        """Least-slack ordering: EDF on the *latest feasible start time*
        (deadline minus predicted service). Falls back to plain EDF when
        no service prediction is available."""
        if self.deadline is None:
            return (float("inf"), -self.priority, self.rid)
        d = self.deadline - (service or 0.0)
        return (d, -self.priority, self.rid)


@dataclasses.dataclass
class RequestTelemetry:
    """Per-request serving record, filled at eviction."""

    rid: int
    bucket: tuple[int, int]
    lane: int                   # -1 for requests dropped at admission
    arrival: float
    admitted: float
    completed: float
    iters: int
    converged: bool             # False = hit the num_iters cap
    deadline: float | None = None   # the request's absolute deadline
    shed: str | None = None     # 'dropped' / 'degraded' / None
    # terminal disposition: 'ok' | 'retried_ok' (completed on the
    # log-domain escalation tier) | 'timed_out' (capped, coupling still
    # delivered) | 'failed' (typed failure) | 'rejected' (refused at
    # admission / shed-dropped)
    status: str = "ok"
    retries: int = 0            # escalation attempts spent
    # overload-model labels: ladder level (0 = full solve), the error
    # estimate attached to degraded answers (truncation model at level
    # 1, certified sliced gap + MC std err at level 2), and what the
    # admission-time predictor said (None with prediction off)
    degrade_level: int = 0
    est_error: float | None = None
    predicted_iters: float | None = None

    @property
    def wait(self) -> float:
        return self.admitted - self.arrival

    @property
    def latency(self) -> float:
        return self.completed - self.arrival

    @property
    def missed(self) -> bool:
        """Completed after its deadline (False when no deadline was set)."""
        return self.deadline is not None and self.completed > self.deadline


class _LanePool:
    """One shape bucket's lane pool + host-side lane bookkeeping."""

    def __init__(self, bucket: tuple[int, int], num_lanes: int,
                 cfg: UOTConfig, *, storage_dtype=None):
        self.bucket = bucket
        self.cfg = cfg
        self.state = ops.make_lane_state(
            num_lanes, bucket[0], bucket[1], cfg,
            storage_dtype=storage_dtype)
        self.requests: dict[int, ScheduledRequest] = {}   # lane -> request
        self.admitted_at: dict[int, float] = {}           # lane -> time
        self.idle_steps = 0      # consecutive scheduler rounds with 0 lanes

    @property
    def num_lanes(self) -> int:
        return self.state.num_lanes

    def free_lanes(self) -> list[int]:
        return [i for i in range(self.num_lanes) if i not in self.requests]

    @property
    def occupancy(self) -> float:
        return len(self.requests) / self.num_lanes


class UOTScheduler:
    """Deadline-aware continuous batching over steppable UOT lane pools.

    Usage::

        sched = UOTScheduler(UOTConfig(num_iters=100, tol=1e-4))
        rid = sched.submit(K, a, b, deadline=now + 0.5, priority=1)
        results = sched.run()          # {rid: coupling}, or step() manually

    ``chunk_iters`` is the scheduling quantum: smaller chunks admit and
    evict sooner (better tail latency) at the cost of more host round
    trips per solve. ``cfg.tol`` enables convergence eviction; with
    ``tol=None`` every lane runs exactly ``cfg.num_iters`` and the answer
    equals the fixed-iteration ``solve_fused`` exactly.

    Memory is bounded for long-running serving: results not collected from
    a ``step()``/``run()`` return value are held for ``poll`` — which hands
    a result out exactly once (take semantics) — but only the most recent
    ``max_results`` of them (couplings are large; the step/run return is
    the primary delivery); telemetry keeps the most recent ``max_log``
    request records / occupancy snapshots; and a lane pool whose bucket
    has been empty for ``pool_idle_ttl`` consecutive steps is released
    (recreated on demand), so one-off request shapes don't pin device
    memory forever.
    """

    def __init__(self, cfg: UOTConfig, *, lanes_per_pool: int = 8,
                 chunk_iters: int = 4, max_queue: int = 1024,
                 m_bucket: int = 64, n_bucket: int = 128,
                 storage_dtype=None, interpret: bool | None = None,
                 impl: str | None = None, max_log: int = 10_000,
                 max_results: int = 256, pool_idle_ttl: int | None = 100,
                 shed_policy: str = "none",
                 degrade_iters: int | None = None,
                 validate: bool = True, retry_escalate: bool = True,
                 escalate_factor: int = 2, fault_injector=None,
                 predictive: bool = False,
                 seconds_per_iter: float | None = None,
                 measurements=None,
                 feasibility_margin: float = 1.0,
                 brownout: "BrownoutController | None" = None,
                 predictor: "IterPredictor | None" = None,
                 sliced_n_proj: int = 32, sliced_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 obs: "obslib.Observability | bool | None" = None,
                 slos=None, op_interval: int = 4):
        if lanes_per_pool < 1:
            raise ValueError("lanes_per_pool must be >= 1")
        if chunk_iters < 1:
            raise ValueError("chunk_iters must be >= 1")
        if shed_policy not in ("none", "drop", "degrade"):
            raise ValueError(f"shed_policy must be 'none', 'drop' or "
                             f"'degrade', got {shed_policy!r}")
        self.cfg = cfg
        self.lanes_per_pool = lanes_per_pool
        self.chunk_iters = chunk_iters
        self.max_queue = max_queue
        self.m_bucket = m_bucket
        self.n_bucket = n_bucket
        self.storage_dtype = storage_dtype
        self.interpret = interpret
        self.impl = impl
        self.max_log = max_log
        self.max_results = max_results
        self.pool_idle_ttl = pool_idle_ttl
        # Deadline-aware shedding: a request whose deadline has ALREADY
        # passed when it reaches the head of the admission queue cannot
        # meet it no matter what — 'drop' refuses it the lane entirely
        # (telemetry-only completion), 'degrade' admits it with a reduced
        # iteration budget (``degrade_iters``, default one chunk) so it
        # returns a coarse answer after a single scheduling quantum
        # instead of occupying a lane for a full solve. 'none' keeps the
        # historical serve-everything behavior. The budget is enforced at
        # chunk granularity (lanes advance ``chunk_iters`` at a time).
        self.shed_policy = shed_policy
        self.degrade_iters = (chunk_iters if degrade_iters is None
                              else degrade_iters)
        # Fault containment: ``validate`` gates the typed admission checks
        # (``core.health.validate_problem``); ``retry_escalate`` gates the
        # one-shot log-domain retry of quarantined (unhealthy-evicted)
        # requests, with ``escalate_factor`` scaling the escalated
        # iteration budget; ``fault_injector`` is the chaos hook
        # (``repro.serve.faults``) — None in production.
        self.validate = validate
        self.retry_escalate = retry_escalate
        self.escalate_factor = escalate_factor
        self.fault_injector = fault_injector
        # Overload model (predictive=True; see repro.serve's overload
        # model section). The service-time model is
        # ``predicted_iters * seconds_per_iter``: iterations from
        # ``core.predict`` (analytic contraction rate + per-bucket EWMA
        # fed by eviction telemetry), seconds-per-iteration either
        # pinned (``seconds_per_iter=``, e.g. a measured value under a
        # simulated clock) or learned online from completions (EWMA of
        # latency/iters; the gate stays inert until the first
        # completion calibrates it — never a guess in fake units).
        # ``feasibility_margin`` scales predicted service before the
        # deadline comparison (>1 = conservative admission). The gate
        # only refuses/degrades when a shed_policy is active ('drop'
        # refuses with InfeasibleDeadline, 'degrade' walks the ladder);
        # with shed_policy='none' prediction still powers least-slack
        # EDF + retry_after hints but never refuses work.
        self.predictive = predictive
        self.feasibility_margin = feasibility_margin
        self.predictor = predictor if predictor is not None else IterPredictor()
        self.brownout = brownout
        if predictive and brownout is None and shed_policy == "degrade":
            self.brownout = BrownoutController()
        self.sliced_n_proj = sliced_n_proj
        self.sliced_seed = sliced_seed
        self._spi_pinned = seconds_per_iter
        self._spi_ewma: float | None = None
        self._iters_ewma: float | None = None
        # Measured performance (repro.obs.measure): a MeasurementStore
        # recorded on THIS machine. Two consumers: the service-time model
        # converts predicted iterations to seconds via measured chunk
        # cost (after the pinned value, before the completion EWMA — a
        # pinned value is the caller asserting units, e.g. a simulated
        # clock, and must win), and impl='auto' chunk dispatch consults
        # the store's per-tier cells via ops.dispatch_advisor. NB the
        # store holds wall-clock us: do not combine with a simulated
        # clock unless the trace was measured in the same units.
        self.measurements = measurements
        self._advisor = (obslib.MeasuredDispatch(measurements)
                         if measurements is not None else None)
        self._pending_completed: dict[int, np.ndarray] = {}
        self.clock = clock
        self.sleep = sleep
        # Observability: None -> a fresh enabled bundle on this scheduler's
        # clock, chained to the process-global one; False -> metrics only
        # (stats() needs the registry) with tracing/traffic disabled and
        # no global chaining; or pass a bundle. See repro.obs.
        if obs is None:
            obs = obslib.Observability(clock=clock)
        elif obs is False:
            obs = obslib.Observability(enabled=False, clock=clock,
                                       chain=False)
        self.obs = obs
        # Operational plane (repro.obs "Operational telemetry"): rolling
        # windows over this registry, burn-rate SLO alerting (``slos=``,
        # a list of obslib.SLO — empty by default so nothing pages
        # unless objectives were declared), and the black-box flight
        # recorder, all on THIS scheduler's clock. A firing alert
        # freezes the flight ring (_on_alert). Null twins under
        # obs=False — the per-round hook costs three no-op calls. A
        # bundle that already carries a plane (caller attached their
        # own) is kept unless this scheduler declares objectives.
        if not obs.windows.enabled or slos:
            obs.attach_operational(slos=slos or (), clock=clock,
                                   on_alert=(self._on_alert,))
        self.flight = obs.flight
        self.exporter = obs.exporter
        # window tick + SLO evaluation run every ``op_interval`` rounds
        # (and whenever the scheduler drains): the full-registry
        # snapshot is the plane's only per-round O(metrics) cost, and
        # decimating it keeps the whole plane inside bench_obs's <= 5%
        # bar without losing alerting resolution (burn-rate windows are
        # many rounds wide by construction)
        self.op_interval = max(1, int(op_interval))
        reg = obs.registry
        self._c = {k: reg.counter("serve." + k) for k in _COUNTER_NAMES}
        self._h_wait = reg.histogram("serve.wait_s")
        self._h_latency = reg.histogram("serve.latency_s")
        self._h_iters = reg.histogram("serve.iters",
                                      buckets=obslib.DEFAULT_COUNT_BUCKETS)
        self._g_queued = reg.gauge("serve.queued")
        self._g_in_flight = reg.gauge("serve.in_flight")
        self._g_occupancy = reg.gauge("serve.occupancy")
        self._c_dispatch = {k: reg.counter("serve.dispatch." + k)
                            for k in ("resident", "streamed")}
        # overload-model observability: degrade-ladder activity per
        # level, feasibility refusals, and the iteration predictor's
        # relative absolute error (|predicted - actual| / actual) so the
        # control loop is auditable from the registry alone
        self._c_infeasible = reg.counter("serve.admission.infeasible")
        self._c_degrade = {lvl: reg.counter(f"serve.degrade.l{lvl}")
                           for lvl in (1, 2)}
        self._g_brownout = reg.gauge("serve.degrade.brownout_level")
        self._h_pred_err = reg.histogram("serve.predict.rel_err")

        self._queue: list[ScheduledRequest] = []
        self._pools: dict[tuple[int, int], _LanePool] = {}
        self._next_rid = 0
        self._results: dict[int, np.ndarray] = {}
        # rid -> RequestFailure: the terminal dispositions of requests
        # that did NOT end in a polled coupling. Kept separate from (and
        # much smaller than) the coupling store so the ``max_results``
        # bound can never erase the *fact* of a failure — only couplings
        # are size-bounded, and a coupling evicted un-polled leaves a
        # 'lost' tombstone here. Trimmed FIFO at ``max_log``.
        self._dispositions: dict[int, RequestFailure] = {}
        self._steps = 0
        self.request_log: list[RequestTelemetry] = []
        self.occupancy_log: list[dict] = []
        # The running totals (deadline accounting, shed decisions,
        # fault-containment outcomes) live in ``self._c`` registry
        # counters — exact, survive request_log trimming, and visible in
        # the process-global registry dump. stats() reads them back.

    # ---- submission -------------------------------------------------------

    def _reject(self, rid: int, bucket, deadline, err: InvalidProblemError,
                now: float) -> None:
        """Record a refused admission: telemetry + a typed disposition so
        ``poll(rid)`` resolves the rid instead of returning pending-forever,
        then re-raise with the rid attached."""
        self._c["rejected"].inc()
        self._log_request(RequestTelemetry(
            rid=rid, bucket=bucket, lane=-1, arrival=now, admitted=now,
            completed=now, iters=0, converged=False, deadline=deadline,
            status="rejected"))
        self.obs.tracer.emit(rid, "complete", status="rejected",
                             reason=err.reason)
        self._store_disposition(RequestFailure(
            rid=rid, status="rejected", reason=f"{err.reason}: {err}"))
        raise err

    def _store_disposition(self, failure: RequestFailure) -> None:
        self._dispositions[failure.rid] = failure
        while len(self._dispositions) > self.max_log:
            self._dispositions.pop(next(iter(self._dispositions)))
            self._c["window_dropped_dispositions"].inc()
        fl = self.obs.flight
        if fl.enabled:
            fl.note("failure", rid=failure.rid, status=failure.status)
            if failure.status == "failed":
                # dump_on RequestFailure: an unrecovered fault is an
                # incident — freeze the rounds that led up to it
                fl.dump("request_failure",
                        reason=f"rid {failure.rid}: {failure.reason}")

    def _log_request(self, rec: RequestTelemetry) -> None:
        """THE append path for request telemetry: append, then trim to
        ``max_log`` immediately, counting what fell off. Trimming only at
        the per-step occupancy snapshot (the historical behavior) missed
        every record appended between snapshots — shed-drops and
        submit-time rejections landed untrimmed and, worse, uncounted
        when a later snapshot trimmed them away. One helper, one window,
        one counter."""
        self.request_log.append(rec)
        excess = len(self.request_log) - self.max_log
        if excess > 0:
            self._c["window_dropped_requests"].inc(excess)
            del self.request_log[:excess]

    # ---- service-time model (predictive=True) -----------------------------

    def _seconds_per_iter(self, bucket=None) -> float | None:
        """Pinned value, else the measured chunk rate (per-bucket when
        ``bucket`` is given, else aggregated), else the online EWMA, else
        None (uncalibrated)."""
        if self._spi_pinned is not None:
            return self._spi_pinned
        if self.measurements is not None:
            M, N = bucket if bucket is not None else (None, None)
            spi = measured_seconds_per_iter(self.measurements, M=M, N=N)
            if spi is None and bucket is not None:
                spi = measured_seconds_per_iter(self.measurements)
            if spi is not None:
                return spi
        return self._spi_ewma

    def _predict_request_iters(self, req: ScheduledRequest) -> float:
        return self.predictor.predict(
            self.cfg, bucket=req.bucket,
            mass_a=float(req.a.sum()), mass_b=float(req.b.sum()))

    def _predicted_service(self, req: ScheduledRequest) -> float | None:
        """Predicted lane seconds for ``req``, None while uncalibrated."""
        spi = self._seconds_per_iter(req.bucket)
        if not self.predictive or spi is None:
            return None
        if req.predicted_iters is None:
            req.predicted_iters = self._predict_request_iters(req)
        return req.predicted_iters * spi

    def _retry_after_hint(self) -> float | None:
        """Predicted backlog drain time for QueueFullError: queued work
        (mean observed iterations each) over total lane throughput."""
        spi = self._seconds_per_iter()
        if (not self.predictive or spi is None
                or self._iters_ewma is None):
            return None
        total_lanes = max(
            1, sum(p.num_lanes for p in self._pools.values())
            or self.lanes_per_pool)
        return (len(self._queue) * self._iters_ewma * spi) / total_lanes

    def _feasibility_gate(self, req: ScheduledRequest, now: float,
                          rid: int) -> None:
        """Refuse or degrade a request whose SLO is already unmeetable —
        BEFORE it burns queue slots or lane time. Raises
        ``InfeasibleDeadline`` (shed_policy='drop') or walks the degrade
        ladder (shed_policy='degrade'). No-op when prediction is off,
        uncalibrated, the request has no deadline, or shed_policy='none'
        (prediction then only powers ordering + retry hints)."""
        if (not self.predictive or req.deadline is None
                or self.shed_policy == "none"):
            return
        service = self._predicted_service(req)
        if service is None:
            return
        finish = now + self.feasibility_margin * service
        if finish <= req.deadline:
            return
        if self.shed_policy == "drop":
            self._c_infeasible.inc()
            self.obs.tracer.emit(rid, "shed", policy="infeasible",
                                 predicted_finish=finish,
                                 deadline=req.deadline)
            err = InfeasibleDeadline(
                f"request {rid} cannot meet its deadline: predicted "
                f"finish {finish:.4f} > deadline {req.deadline:.4f} "
                f"(predicted {req.predicted_iters:.0f} iters)",
                rid=rid, deadline=req.deadline, predicted_finish=finish,
                predicted_iters=req.predicted_iters)
            self._reject(rid, req.bucket, req.deadline, err, now)
        # 'degrade': give it the deepest budget that CAN fit, labeled
        self._c_infeasible.inc()
        self._degrade(req, self.max_degrade_level(req))

    def max_degrade_level(self, req: ScheduledRequest) -> int:
        """Level 2 (sliced) needs coordinates to project and a finite
        marginal relaxation (the 1-D FW dual is a KL dual); dense or
        balanced requests top out at the deepest truncation (level 1)."""
        return (2 if req.K is None and np.isfinite(self.cfg.reg_m)
                else 1)

    def _complete_sliced(self, req: ScheduledRequest, now: float) -> None:
        """Finish a level-2 request on the host sliced tier: ``n_proj``
        exact 1-D solves in one vmapped launch (O(n_proj (M+N) log(M+N))
        — no lane, no M*N compute), the per-slice monotone plans averaged
        into the delivered coupling, and the certified error label
        (mean per-slice FW gap + Monte-Carlo std err) on the telemetry.
        Completes THIS scheduling round via the pending buffer."""
        M, N = req.shape
        res = sliced_uot(req.x, req.y, req.a, req.b,
                         rho=float(self.cfg.reg_m), scale=req.scale,
                         n_proj=self.sliced_n_proj, seed=self.sliced_seed)
        P = lift_coupling_np(res, M, N).astype(np.float32)
        req.est_error = res.est_error
        self._pending_completed[req.rid] = self._results[req.rid] = P
        self._trim_results()
        rec = RequestTelemetry(
            rid=req.rid, bucket=req.bucket, lane=-1,
            arrival=req.arrival, admitted=now, completed=now,
            iters=0, converged=True, deadline=req.deadline,
            shed="degraded", status="ok", retries=req.retries,
            degrade_level=2, est_error=res.est_error,
            predicted_iters=req.predicted_iters)
        if rec.deadline is not None:
            self._c["deadlined_completed"].inc()
            self._c["deadline_misses"].inc(int(rec.missed))
        self._c["completed"].inc()
        self._h_wait.observe(rec.wait)
        self._h_latency.observe(rec.latency)
        self._h_iters.observe(0)
        self.obs.tracer.emit(req.rid, "complete", status="ok", iters=0,
                             degrade_level=2, est_error=res.est_error)
        self._log_request(rec)

    def _degrade(self, req: ScheduledRequest, level: int) -> None:
        """Apply degrade-ladder ``level`` to a queued request (idempotent
        upward: a request never degrades *less* than already promised)."""
        level = min(level, self.max_degrade_level(req))
        if level <= req.degrade_level:
            return
        req.degrade_level = level
        if req.shed != "degraded":
            req.shed = "degraded"
            self._c["shed_degraded"].inc()
        self._c_degrade[level].inc()
        self.obs.tracer.emit(req.rid, "degrade", level=level)
        self.obs.flight.note("degrade", rid=req.rid, level=level)
        if level == 1:
            req.max_iters = min(self.cfg.num_iters, self.degrade_iters)
            req.est_error = estimate_truncation_error(
                self.cfg, req.max_iters,
                mass_a=float(req.a.sum()), mass_b=float(req.b.sum()))
        # level 2 (sliced) bypasses the lanes entirely at admission —
        # est_error comes from the solve itself (certified per-slice
        # gap + Monte-Carlo std err), not a model

    def submit(self, K, a, b, *, deadline: float | None = None,
               priority: int = 0) -> int:
        """Enqueue a problem; returns its request id.

        Raises ``QueueFullError`` when ``max_queue`` requests are already
        waiting (in-flight lanes don't count) — the caller sheds load or
        retries later instead of the queue growing without bound (see
        ``submit_with_retry`` for the canonical retry loop). Raises
        ``InvalidProblemError`` (rid attached, telemetry recorded,
        ``poll(rid)`` resolves to the typed failure) for problems the
        admission validator refuses — see the module docstring's fault
        containment notes.
        """
        if len(self._queue) >= self.max_queue:
            raise QueueFullError(
                f"queue at max_queue={self.max_queue}; retry later",
                queue_depth=len(self._queue),
                retry_after=self._retry_after_hint())
        K = np.asarray(K)
        a = np.asarray(a)
        b = np.asarray(b)
        rid = self._next_rid
        self._next_rid += 1
        fault = None
        if self.fault_injector is not None:
            K, a, b, fault = self.fault_injector.on_submit(rid, K, a, b)
            if fault is not None:
                self.obs.flight.note("fault", rid=rid, tag=fault)
        M, N = K.shape
        bucket = ops.bucket_shape(M, N, self.m_bucket, self.n_bucket)
        now = self.clock()
        self._c["submitted"].inc()
        self.obs.tracer.emit(rid, "submit", M=M, N=N, bucket=list(bucket),
                             kind="dense", deadline=deadline,
                             priority=priority)
        if self.validate:
            try:
                validate_problem(self.cfg, a, b, shape=(M, N), rid=rid)
            except InvalidProblemError as err:
                self._reject(rid, bucket, deadline, err, now)
        req = ScheduledRequest(
            rid=rid, K=K, a=a, b=b, shape=(M, N), bucket=bucket,
            arrival=now, deadline=deadline, priority=priority, fault=fault)
        self._feasibility_gate(req, now, rid)   # may raise / degrade
        self._queue.append(req)
        self.obs.tracer.emit(rid, "queue", depth=len(self._queue),
                             route="lane")
        return rid

    def submit_points(self, x, y, a, b, *, scale: float = 1.0,
                      deadline: float | None = None,
                      priority: int = 0) -> int:
        """Enqueue a point-cloud problem: squared-Euclidean cost of the
        (M, d) / (N, d) coordinate clouds, ``C = ||x - y||^2 / scale``.

        The request payload is ``(M + N) * (d + 1)`` floats (coordinates +
        precomputed squared norms) instead of the dense ``M * N`` kernel —
        the Gibbs kernel is materialized on-DEVICE at admission, straight
        into the lane pool. A lane's trajectory is bit-identical to
        ``submit(K=geometry.kernel(cfg.reg), ...)`` for the same
        coordinates (asserted in tests): same mirror arithmetic, same
        pool, same math.
        """
        if len(self._queue) >= self.max_queue:
            raise QueueFullError(
                f"queue at max_queue={self.max_queue}; retry later",
                queue_depth=len(self._queue),
                retry_after=self._retry_after_hint())
        # from_points computes the squared norms ONCE with the shared
        # jitted helper — reusing them at admission is what keeps the
        # batched device materialization bit-identical to a per-request
        # geometry's kernel() (see repro.geometry.pointcloud rule 1)
        g = PointCloudGeometry.from_points(x, y, scale=scale)
        M, N = g.shape
        a = np.asarray(a)
        b = np.asarray(b)
        rid = self._next_rid
        self._next_rid += 1
        fault = None
        if self.fault_injector is not None:
            _, a, b, fault = self.fault_injector.on_submit(rid, None, a, b)
            if fault is not None:
                self.obs.flight.note("fault", rid=rid, tag=fault)
        bucket = ops.bucket_shape(M, N, self.m_bucket, self.n_bucket)
        now = self.clock()
        self._c["submitted"].inc()
        self.obs.tracer.emit(rid, "submit", M=M, N=N, bucket=list(bucket),
                             kind="points", deadline=deadline,
                             priority=priority)
        if self.validate:
            try:
                validate_problem(self.cfg, a, b, shape=(M, N), rid=rid)
            except InvalidProblemError as err:
                self._reject(rid, bucket, deadline, err, now)
        req = ScheduledRequest(
            rid=rid, K=None, a=a, b=b, shape=(M, N), bucket=bucket,
            arrival=now, deadline=deadline, priority=priority,
            x=np.asarray(g.x), y=np.asarray(g.y), xn=np.asarray(g.xn),
            yn=np.asarray(g.yn), scale=float(scale), fault=fault)
        self._feasibility_gate(req, now, rid)   # may raise / degrade
        self._queue.append(req)
        self.obs.tracer.emit(rid, "queue", depth=len(self._queue),
                             route="lane")
        return rid

    @property
    def pending(self) -> int:
        """Requests waiting for a lane."""
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Requests currently occupying lanes."""
        return sum(len(p.requests) for p in self._pools.values())

    def poll(self, rid: int):
        """The terminal disposition of ``rid``: the finished coupling, a
        ``RequestFailure`` (failed / rejected / lost), or None only while
        the request is genuinely pending. Nothing vanishes: every
        submitted rid eventually resolves to exactly one non-None value
        (property-tested under fault injection).

        Take semantics: a result is handed out exactly once and then
        dropped, so an uncollected backlog cannot grow without bound.
        """
        with self.obs.phases.phase("serve.poll"):
            out = self._results.pop(rid, None)
            if out is not None:
                self.obs.tracer.emit(rid, "poll", resolved="coupling")
                return out
            out = self._dispositions.pop(rid, None)
            self.obs.tracer.emit(
                rid, "poll",
                resolved="failure" if out is not None else "pending")
            return out

    # ---- the scheduling loop ---------------------------------------------

    def step(self) -> dict[int, np.ndarray]:
        """One scheduling round: evict -> admit -> advance one chunk.

        Returns the requests completed by this round, ``{rid: P (M, N)}``
        as host numpy arrays (also retained for ``poll``, padding-free
        copies). Eviction happens *before* admission
        so freshly-freed lanes are immediately reusable — the continuous
        part of continuous batching.
        """
        if self.fault_injector is not None:
            self.fault_injector.on_step(self)
        if self.brownout is not None:
            total = (sum(p.num_lanes for p in self._pools.values())
                     or self.lanes_per_pool)
            self._g_brownout.set(self.brownout.observe(
                queue_pressure(len(self._queue), total)))
        ph = self.obs.phases
        with ph.phase("serve.evict"):
            completed = self._evict_finished()
        with ph.phase("serve.admit"):
            self._admit_queued()
        if self._pending_completed:
            # level-2 (sliced) completions produced during admission —
            # delivered with this round's evictions
            completed.update(self._pending_completed)
            self._pending_completed.clear()
        with ph.phase("serve.chunk"):
            for bucket, pool in list(self._pools.items()):
                if pool.requests:
                    pool.idle_steps = 0
                    # launch_profiler times the chunk to completion (a
                    # no-op install under obs=False); the advisor makes
                    # impl='auto' routing measurement-driven when a
                    # MeasurementStore was passed
                    with ops.dispatch_counters() as counters, \
                            ops.launch_profiler(self.obs.profile), \
                            (ops.dispatch_advisor(self._advisor)
                             if self._advisor is not None
                             else contextlib.nullcontext()):
                        pool.state = ops.solve_fused_stepped(
                            pool.state, self.chunk_iters, self.cfg,
                            interpret=self.interpret, impl=self.impl)
                    self._charge_chunk(pool, counters)
                else:
                    # a pool pins lanes x Mp x Np of device memory;
                    # traffic whose shape never recurs must not pin it
                    # forever
                    pool.idle_steps += 1
                    if (self.pool_idle_ttl is not None
                            and pool.idle_steps > self.pool_idle_ttl):
                        del self._pools[bucket]
        self._steps += 1
        self._snapshot_occupancy()
        self._operational_round()
        return completed

    def _on_alert(self, alert) -> None:
        """SLO alert routing beyond the monitor's own (registry +
        tracer): note the transition in the black box and freeze it the
        moment an alert fires — the capture holds the rounds that led
        up to the breach."""
        fl = self.obs.flight
        fl.note("alert", slo=alert.name, state=alert.state,
                burn=alert.burn_fast)
        if alert.state == "firing":
            fl.dump(f"alert:{alert.name}", reason=alert.describe())

    def _operational_round(self) -> None:
        """Per-round operational-plane upkeep: close the flight
        recorder's round, tick the rolling windows, evaluate SLO burn
        rates. All three are null twins under obs=False."""
        obs = self.obs
        if obs.flight.enabled:
            obs.flight.record_round(
                self._steps, queued=len(self._queue),
                in_flight=self.in_flight,
                occupancy=self._g_occupancy.value,
                deadline_misses=self._c["deadline_misses"].value)
        if (self._steps % self.op_interval == 0
                or (not self.in_flight and not self.pending)):
            obs.windows.tick()
            obs.slo.evaluate()

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Step until queue and lanes drain (or ``max_steps`` *additional*
        steps ran); returns all completions."""
        start = self._steps
        out: dict[int, np.ndarray] = {}
        while self.pending or self.in_flight:
            out.update(self.step())
            if max_steps is not None and self._steps - start >= max_steps:
                break
        out.update(self._evict_finished())  # final chunk's completions
        return out

    # ---- internals --------------------------------------------------------

    def _charge_chunk(self, pool, counters: dict) -> None:
        """Charge one chunk advance's modeled HBM bytes to the traffic
        accountant and fold the pool's ``impl='auto'`` routing into the
        registry dispatch counters. With an explicit (non-auto) impl the
        stepped path makes no routing decision — the streamed formula
        applies (the resident chunk only runs via auto/resident routing).
        """
        for k, v in counters.items():
            if v:
                self._c_dispatch[k].inc(v)
        if not self.obs.traffic.enabled:
            return
        tier = ("resident" if counters["resident"] > 0 else "streamed")
        Mb, Nb = pool.bucket
        self.obs.traffic.charge_chunk(
            route="lane", tier=tier, L=pool.num_lanes, M=Mb, N=Nb,
            s=jnp.dtype(pool.state.P.dtype).itemsize,
            chunk_iters=self.chunk_iters)

    def _request_kernel(self, req: ScheduledRequest) -> np.ndarray:
        """The request's (M, N) coupling matrix for an off-lane re-solve:
        the stored payload for dense requests, the geometry's Gibbs mirror
        for coordinate requests."""
        if req.K is not None:
            return req.K
        g = PointCloudGeometry(
            x=jnp.asarray(req.x), y=jnp.asarray(req.y),
            xn=jnp.asarray(req.xn), yn=jnp.asarray(req.yn),
            scale=req.scale)
        return np.asarray(g.kernel(self.cfg.reg))

    def _escalate(self, req: ScheduledRequest):
        """One log-domain retry of a quarantined request. Returns
        ``(P or None, iters)`` — P non-None iff the escalated solve
        produced an all-finite coupling. The retry runs synchronously at
        eviction (the robust tier is the slow path; a poisoned request is
        rare by construction, so blocking the round is the simple-and-
        correct choice — noted in ROADMAP as a possible async follow-up).
        """
        if not self.retry_escalate or req.retries >= 1:
            return None, 0
        req.retries += 1
        P, stats, ok = escalate_log_solve(
            self._request_kernel(req), req.a, req.b, self.cfg,
            factor=self.escalate_factor)
        return (P if ok else None), stats["iters"]

    def _trim_results(self) -> None:
        # the poll pickup store is bounded (oldest dropped) —
        # step()/run() return values are the primary delivery. An
        # un-polled coupling that falls off the bound leaves a 'lost'
        # tombstone so the client can still distinguish "pending" from
        # "gone" (the disposition store is O(1) per request, not O(M*N),
        # so IT is not what the bound protects).
        while len(self._results) > self.max_results:
            old = next(iter(self._results))
            self._results.pop(old)
            self._c["lost_results"].inc()
            self.obs.tracer.emit(old, "lost")
            self._store_disposition(RequestFailure(
                rid=old, status="lost",
                reason="coupling evicted from the bounded result store "
                       "(max_results) before it was polled"))

    def _evict_finished(self) -> dict[int, np.ndarray]:
        completed: dict[int, np.ndarray] = {}
        now = self.clock()
        tr = self.obs.tracer
        for pool in self._pools.values():
            if not pool.requests:
                continue
            iters = np.asarray(pool.state.iters)
            conv = np.asarray(pool.state.converged)
            healthy = np.asarray(pool.state.healthy)
            if tr.enabled:
                # per-request chunk progress, from the host copies this
                # eviction pass already fetched — no extra device sync
                for l, req in pool.requests.items():
                    tr.emit(req.rid, "chunk", lane=l, device=-1,
                            iters=int(iters[l]), converged=bool(conv[l]),
                            healthy=bool(healthy[l]))
            # a degraded request finishes at its reduced budget, not the
            # global cap (the budget is enforced at chunk granularity —
            # the device gate still runs lanes toward cfg.num_iters); an
            # unhealthy lane is finished the moment its flag clears
            finished = [
                l for l, req in list(pool.requests.items())
                if not healthy[l] or conv[l] or iters[l] >= (
                    req.max_iters if req.max_iters is not None
                    else self.cfg.num_iters)]
            if not finished:
                continue
            for lane in finished:
                req = pool.requests.pop(lane)
                admitted = pool.admitted_at.pop(lane)
                M, N = req.shape
                P = None
                if healthy[lane]:
                    # slice per lane on device (one jit signature per lane
                    # index) so only the finished lane crosses to the
                    # host, then trim to the request shape in numpy — not
                    # the whole pool, no per-(lane, shape) compile jitter,
                    # and a copy so the retained result doesn't pin the
                    # padded lane buffer
                    P = np.asarray(pool.state.P[lane])[:M, :N].copy()
                    # second line of defense, O(M*N) on the one evicted
                    # slice only: poison that lands AFTER the convergence
                    # latch froze the lane (e.g. injected state
                    # corruption) never passes through the detector's
                    # frow/colsum window — catch it on the way out
                    if not np.all(np.isfinite(P)):
                        P = None
                n_iters = int(iters[lane])
                tr.emit(req.rid, "evict", lane=lane, device=-1,
                        iters=n_iters, converged=bool(conv[lane]),
                        healthy=bool(healthy[lane] and P is not None))
                if P is not None:
                    timed_out = (self.cfg.tol is not None
                                 and not conv[lane]
                                 and req.max_iters is None)
                    status = "timed_out" if timed_out else "ok"
                    self._c["timed_out"].inc(int(timed_out))
                else:
                    self._c["unhealthy_evictions"].inc()
                    self.obs.flight.note("unhealthy", rid=req.rid,
                                         lane=lane)
                    tr.emit(req.rid, "escalate", retries=req.retries + 1)
                    P, n_iters = self._escalate(req)
                    status = "retried_ok" if P is not None else "failed"
                if P is not None:
                    if status == "retried_ok":
                        self._c["retried_ok"].inc()
                    completed[req.rid] = self._results[req.rid] = P
                    self._trim_results()
                else:
                    self._c["failed"].inc()
                    self._store_disposition(RequestFailure(
                        rid=req.rid, status="failed",
                        reason="lane state went non-finite and the "
                               "log-domain escalation did not recover",
                        retries=req.retries))
                rec = RequestTelemetry(
                    rid=req.rid, bucket=pool.bucket, lane=lane,
                    arrival=req.arrival, admitted=admitted,
                    completed=now, iters=n_iters,
                    converged=bool(conv[lane] & healthy[lane]),
                    deadline=req.deadline, shed=req.shed,
                    status=status, retries=req.retries,
                    degrade_level=req.degrade_level,
                    est_error=req.est_error,
                    predicted_iters=req.predicted_iters)
                if rec.deadline is not None:
                    self._c["deadlined_completed"].inc()
                    self._c["deadline_misses"].inc(int(rec.missed))
                self._c["completed"].inc()
                self._h_wait.observe(rec.wait)
                self._h_latency.observe(rec.latency)
                self._h_iters.observe(n_iters)
                if (self.predictive and n_iters > 0
                        and status in ("ok", "timed_out")
                        and req.max_iters is None):
                    # close the control loop: feed the predictor the
                    # actual count (full solves only — truncated budgets
                    # would bias the model), refine the online
                    # seconds-per-iteration rate, and record the
                    # prediction's relative error for auditing
                    self.predictor.observe(
                        self.cfg, n_iters, bucket=pool.bucket,
                        mass_a=float(req.a.sum()),
                        mass_b=float(req.b.sum()))
                    a_ = 0.25
                    self._iters_ewma = (
                        n_iters if self._iters_ewma is None
                        else self._iters_ewma + a_ * (n_iters
                                                      - self._iters_ewma))
                    dt = (now - admitted) / n_iters
                    if dt > 0.0:
                        self._spi_ewma = (
                            dt if self._spi_ewma is None
                            else self._spi_ewma + a_ * (dt - self._spi_ewma))
                    if req.predicted_iters:
                        self._h_pred_err.observe(
                            abs(req.predicted_iters - n_iters) / n_iters)
                tr.emit(req.rid, "complete", status=status, iters=n_iters,
                        retries=req.retries)
                self._log_request(rec)
            # one pool update for the whole round's evictions; the index
            # vector is padded to the pool size with duplicates (same
            # zeroing either way) so there is ONE jit signature per pool,
            # not one per eviction count — and eviction's zeroing is also
            # what scrubs a poisoned lane's NaNs out of the pool
            lanes = finished + [finished[-1]] * (pool.num_lanes
                                                 - len(finished))
            pool.state = ops.lane_evict(pool.state,
                                        jnp.asarray(lanes, jnp.int32))
        return completed

    def inject_lane_fault(self, rid: int) -> bool:
        """Chaos/drill hook: corrupt the in-flight lane currently holding
        ``rid`` with NaN state (tile + factors), simulating device-memory
        poisoning mid-solve — the host-side payload stays intact, so the
        quarantine-and-retry path can recover the request on the
        log-domain tier (``status='retried_ok'``). Returns False when the
        rid is not in a lane (queued / already finished). Test
        infrastructure — never called by the serving loop itself."""
        for pool in self._pools.values():
            for lane, req in pool.requests.items():
                if req.rid == rid:
                    st = pool.state
                    pool.state = dataclasses.replace(
                        st,
                        P=st.P.at[lane].set(
                            jnp.asarray(jnp.nan, st.P.dtype)),
                        colsum=st.colsum.at[lane].set(jnp.nan),
                        frow=st.frow.at[lane].set(jnp.nan))
                    return True
        return False

    def _shed_at_admission(self, req: ScheduledRequest, now: float) -> bool:
        """Apply the shed policy to a request whose deadline already
        passed; returns True when the request was dropped entirely."""
        if (self.shed_policy == "none" or req.deadline is None
                or now <= req.deadline):
            return False
        if self.shed_policy == "drop":
            self._c["shed_dropped"].inc()
            self._c["rejected"].inc()
            self._log_request(RequestTelemetry(
                rid=req.rid, bucket=req.bucket, lane=-1,
                arrival=req.arrival, admitted=now, completed=now,
                iters=0, converged=False, deadline=req.deadline,
                shed="dropped", status="rejected"))
            self.obs.tracer.emit(req.rid, "shed", policy="drop")
            self.obs.flight.note("shed", rid=req.rid, policy="drop")
            self.obs.tracer.emit(req.rid, "complete", status="rejected",
                                 reason="deadline passed at admission "
                                        "(shed_policy='drop')")
            # a dropped request must still resolve at poll() — 'rejected'
            # disposition, never silently absent
            self._store_disposition(RequestFailure(
                rid=req.rid, status="rejected",
                reason="deadline already passed at admission "
                       "(shed_policy='drop')"))
            return True
        # 'degrade': an expired deadline walks the ladder — level 1
        # normally, deeper when the brownout controller says the whole
        # system is already shedding accuracy
        self.obs.tracer.emit(req.rid, "shed", policy="degrade")
        level = max(1, self.brownout.level if self.brownout else 0)
        self._degrade(req, level)
        return False

    def _degrade_if_infeasible(self, req: ScheduledRequest,
                               now: float) -> None:
        """Re-judge feasibility against the REMAINING deadline budget at
        admission time — the submit-time gate cannot see queue wait. A
        full solve that no longer fits degrades to the shallowest level
        that does (level 1's service is the ``degrade_iters`` budget,
        else the deepest level the request supports), so every request
        still served at ``degrade_level == 0`` was feasibility-clean at
        BOTH judgment points: the no-SLO-miss-among-full-quality
        property the overload bench hard-asserts. Active only under
        shed_policy='degrade' with a calibrated model; expired deadlines
        are ``_shed_at_admission``'s job."""
        if (self.shed_policy != "degrade" or not self.predictive
                or req.deadline is None or req.degrade_level > 0):
            return
        spi = self._seconds_per_iter()
        service = self._predicted_service(req)
        if spi is None or service is None:
            return
        if now + self.feasibility_margin * service <= req.deadline:
            return
        lvl1 = min(self.cfg.num_iters, self.degrade_iters) * spi
        level = (1 if now + self.feasibility_margin * lvl1 <= req.deadline
                 else self.max_degrade_level(req))
        self._c_infeasible.inc()
        self.obs.tracer.emit(req.rid, "shed", policy="infeasible_wait",
                             level=level)
        self._degrade(req, level)

    def _admit_queued(self) -> None:
        if not self._queue:
            return
        now = self.clock()
        remaining: list[ScheduledRequest] = []
        placements: dict[tuple[int, int], list[tuple[int, ScheduledRequest]]]
        placements = {}
        # predicted-finish-time EDF: with a calibrated service-time model
        # the queue orders by least slack (deadline minus predicted
        # service) — a long job with a near deadline outranks a short job
        # with the same deadline; uncalibrated, this is exactly edf_key
        if self.predictive and self._seconds_per_iter() is not None:
            def admit_key(r):
                return r.slack_key(self._predicted_service(r))
        else:
            admit_key = ScheduledRequest.edf_key
        brownout_level = (self.brownout.level
                          if (self.brownout is not None
                              and self.shed_policy == "degrade") else 0)
        for req in sorted(self._queue, key=admit_key):
            if req.shed is None and self._shed_at_admission(req, now):
                continue                  # dropped: telemetry only, no lane
            self._degrade_if_infeasible(req, now)
            if brownout_level:
                # sustained overload: new admissions shed accuracy so the
                # backlog drains faster than it grows
                self._degrade(req, brownout_level)
            if req.degrade_level >= 2 and req.K is None:
                # level 2: solve NOW on the host sliced tier — never
                # occupies a lane, returns this same scheduling round
                self._complete_sliced(req, now)
                continue
            pool = self._pools.get(req.bucket)
            if pool is None:
                pool = self._pools[req.bucket] = _LanePool(
                    req.bucket, self.lanes_per_pool, self.cfg,
                    storage_dtype=self.storage_dtype)
            free = pool.free_lanes()
            if not free:
                remaining.append(req)
                continue
            lane = free[0]
            placements.setdefault(req.bucket, []).append((lane, req))
            pool.requests[lane] = req
            pool.admitted_at[lane] = now
            self.obs.flight.note("place", rid=req.rid, lane=lane)
            self.obs.tracer.emit(req.rid, "place", lane=lane, device=-1,
                                 bucket=list(req.bucket), route="lane")
        for bucket, placed in placements.items():
            # Normalize to the bucket shape host-side (numpy) so lane_admit
            # never traces per request shape, and land a round's admissions
            # in as few pool updates as possible. Each group's batch is
            # padded to the pool size by repeating the last admission
            # (duplicate scatter indices with identical payloads are
            # harmless), so each pool compiles ONE admit signature per
            # payload kind — not one per admission count. Dense requests
            # ship their K; point requests ship coordinates + norms
            # ((M + N) * (d + 1) floats) and materialize K on-device,
            # grouped by (d, scale) since those shape/brand the
            # materializer.
            dense = [(l, r) for l, r in placed if r.K is not None]
            points: dict[tuple[int, float], list] = {}
            for l, r in placed:
                if r.K is None:
                    points.setdefault((r.x.shape[1], r.scale),
                                      []).append((l, r))
            if dense:
                self._admit_dense(bucket, dense)
            for (d, scale), group in points.items():
                self._admit_points(bucket, group, d, scale)
        # EDF order (which already ends in the rid FIFO tie-break) is
        # recomputed from scratch next round, so storage order is free.
        self._queue = remaining

    def _admit_dense(self, bucket, placed) -> None:
        pool = self._pools[bucket]
        Mb, Nb = bucket
        L = pool.num_lanes
        Kp = np.zeros((L, Mb, Nb), np.float32)
        ap = np.zeros((L, Mb), np.float32)
        bp = np.zeros((L, Nb), np.float32)
        lanes = np.empty(L, np.int32)
        for j in range(L):
            lane, req = placed[min(j, len(placed) - 1)]
            M, N = req.shape
            Kp[j, :M, :N] = req.K
            ap[j, :M] = req.a
            bp[j, :N] = req.b
            lanes[j] = lane
        self.obs.traffic.charge_admission(
            route="lane", M=Mb, N=Nb, s=4, source="dense",
            count=len(placed))
        pool.state = ops.lane_admit(
            pool.state, jnp.asarray(lanes), jnp.asarray(Kp),
            jnp.asarray(ap), jnp.asarray(bp))

    def _admit_points(self, bucket, placed, d: int, scale: float) -> None:
        """Admit a round's point-cloud requests: transfer coordinates,
        materialize the masked Gibbs stack on-device (the geometry
        mirror's arithmetic, so lanes are bit-identical to dense
        submission of ``geometry.kernel(cfg.reg)``), one pool update."""
        pool = self._pools[bucket]
        Mb, Nb = bucket
        L = pool.num_lanes
        xs = np.zeros((L, Mb, d), np.float32)
        xns = np.zeros((L, Mb), np.float32)
        ys = np.zeros((L, Nb, d), np.float32)
        yns = np.zeros((L, Nb), np.float32)
        mv = np.zeros(L, np.int32)
        nv = np.zeros(L, np.int32)
        ap = np.zeros((L, Mb), np.float32)
        bp = np.zeros((L, Nb), np.float32)
        lanes = np.empty(L, np.int32)
        for j in range(L):
            lane, req = placed[min(j, len(placed) - 1)]
            M, N = req.shape
            xs[j, :M], xns[j, :M] = req.x, req.xn
            ys[j, :N], yns[j, :N] = req.y, req.yn
            mv[j], nv[j] = M, N
            ap[j, :M] = req.a
            bp[j, :N] = req.b
            lanes[j] = lane
        g = PointCloudGeometry(
            x=jnp.asarray(xs), y=jnp.asarray(ys), xn=jnp.asarray(xns),
            yn=jnp.asarray(yns), m_valid=jnp.asarray(mv),
            n_valid=jnp.asarray(nv), scale=scale)
        self.obs.traffic.charge_admission(
            route="lane", M=Mb, N=Nb, s=4, source="implicit", d=d,
            count=len(placed))
        pool.state = ops.lane_admit(
            pool.state, jnp.asarray(lanes), g.kernel(self.cfg.reg),
            jnp.asarray(ap), jnp.asarray(bp))

    def _snapshot_occupancy(self) -> None:
        occ = {str(b): p.occupancy for b, p in self._pools.items()}
        self.occupancy_log.append({
            "step": self._steps,
            "queued": len(self._queue),
            "deadline_misses": self._c["deadline_misses"].value,  # running
            "pools": occ,
        })
        self._g_queued.set(len(self._queue))
        self._g_in_flight.set(self.in_flight)
        self._g_occupancy.set(sum(occ.values()) / len(occ) if occ else 0.0)
        # the bounded telemetry window silently narrows what stats()'s
        # latency/p99 aggregates describe — count what falls off so the
        # truncation is visible (stats()['window_dropped'] + registry).
        # Request records trim at append time (_log_request — every
        # producer path, including shed-drops and submit-time rejects);
        # the occupancy window has exactly one producer, here.
        self._c["window_dropped_occupancy"].inc(
            max(0, len(self.occupancy_log) - self.max_log))
        del self.occupancy_log[:-self.max_log]

    # ---- telemetry --------------------------------------------------------

    def stats(self) -> dict:
        """Aggregate serving telemetry over the retained log window
        (the last ``max_log`` completions / occupancy snapshots).
        ``deadline_misses`` / ``miss_rate`` are *running* totals over every
        completion (misses / completions-that-had-deadlines), so they stay
        correct after the window trims; ``window_dropped`` counts what the
        trims discarded, so the narrowing itself is visible. The running
        totals are registry counters (``serve.*`` in ``self.obs.registry``
        — see ``repro.serve``'s Observability section for the mapping)."""
        c = self._c
        misses = {
            "deadline_misses": c["deadline_misses"].value,
            "miss_rate": (c["deadline_misses"].value
                          / c["deadlined_completed"].value
                          if c["deadlined_completed"].value else 0.0),
            # running shed totals (drop: refused a lane at admission;
            # degrade: admitted with the reduced iteration budget)
            "shed_dropped": c["shed_dropped"].value,
            "shed_degraded": c["shed_degraded"].value,
            # running fault-containment totals (exact; survive trimming)
            "rejected": c["rejected"].value,
            "failed": c["failed"].value,
            "retried_ok": c["retried_ok"].value,
            "timed_out": c["timed_out"].value,
            "unhealthy_evictions": c["unhealthy_evictions"].value,
            "lost_results": c["lost_results"].value,
            "window_dropped": {
                "requests": c["window_dropped_requests"].value,
                "occupancy": c["window_dropped_occupancy"].value,
                "dispositions": c["window_dropped_dispositions"].value,
            },
            # overload-model totals (predictive admission + degrade
            # ladder; zeros when the features are off)
            "admission_infeasible": self._c_infeasible.value,
            "degrade_levels": {lvl: ctr.value
                               for lvl, ctr in self._c_degrade.items()},
            "brownout_level": (self.brownout.level
                               if self.brownout is not None else 0),
            "seconds_per_iter": self._seconds_per_iter(),
        }
        status_counts: dict[str, int] = {}
        for t in self.request_log:
            status_counts[t.status] = status_counts.get(t.status, 0) + 1
        misses["status_counts"] = status_counts
        # dropped and admission-rejected requests never solved anything:
        # they appear in the log (lane=-1) but are excluded from the
        # latency / iteration aggregates, which describe served work
        served = [t for t in self.request_log
                  if t.shed != "dropped" and t.status != "rejected"]
        if not served:
            return {"completed": 0, "steps": self._steps, "wait_mean": 0.0,
                    "wait_p99": 0.0, "latency_p50": 0.0, "latency_p99": 0.0,
                    "iters_mean": 0.0, "iters_max": 0,
                    "converged_frac": 0.0, "occupancy_mean": 0.0, **misses}
        waits = np.array([t.wait for t in served])
        lats = np.array([t.latency for t in served])
        iters = np.array([t.iters for t in served])
        occ = [o for snap in self.occupancy_log
               for o in snap["pools"].values()]
        return {
            "completed": len(served),
            "steps": self._steps,
            "wait_mean": float(waits.mean()),
            "wait_p99": float(np.percentile(waits, 99)),
            "latency_p50": float(np.percentile(lats, 50)),
            "latency_p99": float(np.percentile(lats, 99)),
            "iters_mean": float(iters.mean()),
            "iters_max": int(iters.max()),
            "converged_frac": float(np.mean([t.converged for t in served])),
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            **misses,
        }
