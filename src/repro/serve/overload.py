"""Overload-robustness primitives shared by both schedulers.

Three small pieces the serving tiers compose into the overload model
documented in ``repro.serve``'s module docstring:

* ``InfeasibleDeadline`` — the typed refusal for a request whose
  predicted service time cannot meet its SLO. A subclass of
  ``core.health.InvalidProblemError`` (``reason='infeasible_deadline'``)
  so every existing admission-error handler already catches it; carries
  the prediction that justified the refusal.
* ``BrownoutController`` — hysteresis ladder controller: steps the
  degrade level UP after ``patience`` consecutive observations of queue
  pressure above ``high``, DOWN after ``patience`` consecutive
  observations below ``low``. Pressure is queue depth over total lane
  capacity — a dimensionless "how many scheduling rounds deep is the
  backlog" signal both schedulers already have on hand.
* ``queue_pressure`` — that signal, as a plain function.

Degrade levels (the ladder both schedulers implement):

  0  full solve — no degradation.
  1  truncated Sinkhorn at ``degrade_iters`` — coarse coupling, error
     labeled via ``core.predict.estimate_truncation_error``.
  2  sliced 1-D estimate (``geometry.sliced``, point-cloud requests) —
     O(n_proj * (M+N) log(M+N)) with a certified-per-slice error label;
     dense requests, which have no coordinates to project, stay at the
     deepest truncation budget instead.
"""
from __future__ import annotations

import dataclasses

from repro.core.health import InvalidProblemError

__all__ = ["InfeasibleDeadline", "BrownoutController", "queue_pressure"]


class InfeasibleDeadline(InvalidProblemError):
    """Refused at admission: the deadline cannot be met even if the
    request started solving immediately (predicted service time alone
    overshoots it). Raised *before* the request burns lane time; the rid
    still resolves via ``poll`` to a ``'rejected'`` disposition."""

    def __init__(self, message: str, *, rid: int | None = None,
                 deadline: float | None = None,
                 predicted_finish: float | None = None,
                 predicted_iters: float | None = None):
        super().__init__("infeasible_deadline", message, rid=rid)
        self.deadline = deadline
        self.predicted_finish = predicted_finish
        self.predicted_iters = predicted_iters


def queue_pressure(queue_depth: int, total_lanes: int) -> float:
    """Backlog depth in units of one full lane-capacity round."""
    return queue_depth / max(1, total_lanes)


@dataclasses.dataclass
class BrownoutController:
    """Hysteresis degrade-ladder controller.

    ``observe(pressure)`` once per scheduling round; ``level`` is the
    current ladder level to apply to NEW admissions. The two-watermark +
    patience shape means transient spikes (one deep round) don't flap
    the ladder, and recovery requires the backlog to actually drain
    (below ``low``), not merely stop growing.
    """

    high: float = 2.0        # step up after `patience` rounds above this
    low: float = 0.5         # step down after `patience` rounds below
    patience: int = 3
    max_level: int = 2
    level: int = 0
    _above: int = 0
    _below: int = 0

    def observe(self, pressure: float) -> int:
        if pressure >= self.high:
            self._above += 1
            self._below = 0
            if self._above >= self.patience and self.level < self.max_level:
                self.level += 1
                self._above = 0
        elif pressure <= self.low:
            self._below += 1
            self._above = 0
            if self._below >= self.patience and self.level > 0:
                self.level -= 1
                self._below = 0
        else:
            self._above = 0
            self._below = 0
        return self.level
