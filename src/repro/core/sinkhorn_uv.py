"""POT ``sinkhorn_knopp_unbalanced`` u/v-potential form + fused variant.

Semantics (POT-faithful):   u = (a / (K v)) ** fi ;  v = (b / (K^T u)) ** fi
with the Gibbs kernel K held constant and the coupling materialized only at
the end as P = diag(u) K diag(v).

Beyond-paper memory optimization (``sinkhorn_uot_uv_fused``): both matvecs
of an iteration are computed in ONE read-only pass over K. Row block i gives
(K v)_i by a row-dot; u_i is then immediately available, so u_i * K[i, :] can
be accumulated into the K^T u partials during the same pass. Traffic per
iteration: M*N element *reads*, ZERO writes — half of MAP-UOT's 2*M*N
(which must write A back every iteration), and K can additionally be stored
in bf16 (u, v, accumulators stay fp32). The corresponding explicit-schedule
kernel is ``repro.kernels.uot_uv_fused``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors
from repro.geometry import Geometry


def _Kv(K, v, cfg: UOTConfig):
    """``K @ v`` for a dense kernel matrix or, lazily, a ``Geometry``
    (grid: per-axis contractions, never M*N; point cloud: row-chunked
    on-the-fly tiles)."""
    if isinstance(K, Geometry):
        return K.apply_kernel(v, cfg.reg)
    return K @ v


def _KTu(K, u, cfg: UOTConfig):
    if isinstance(K, Geometry):
        return K.apply_kernel_T(u, cfg.reg)
    return u @ K              # row-major-friendly transposed matvec


def _coupling(K, u, v, cfg: UOTConfig):
    Kd = K.kernel(cfg.reg) if isinstance(K, Geometry) else K
    return (u[:, None] * Kd * v[None, :]).astype(cfg.dtype)


def translation_noise_floor(amplification: float, dtype) -> float:
    """Magnitude below which a computed dual translation is rounding
    jitter, not signal: the translation formulas multiply a log-difference
    (accurate to a few ulps) by ``amplification`` (``rho/(2*eps)`` in
    scaling space, ``rho/2`` on potentials), so near the fixed point the
    amplified noise would sit above a tight ``tol`` forever and stall the
    stationarity stopping criterion. Translations under this floor are
    zeroed — by then TI's work (killing the mass-imbalance mode) is done
    and the plain contraction finishes the tail."""
    return amplification * 16 * float(jnp.finfo(dtype).eps)


def translate_uv(u, v, a, b, eps: float, rho: float):
    """Optimal dual translation in scaling space (Séjourné et al.,
    arXiv:2201.00730, equal marginal strengths rho1 = rho2 = rho).

    In potential space f = eps*log u, g = eps*log v, translating to
    (f + t, g - t) with

        t = (rho/2) * log(<a, e^{-f/rho}> / <b, e^{-g/rho}>)

    maximizes the dual objective along the translation direction: it
    balances the masses of ``a e^{-f/rho}`` and ``b e^{-g/rho}`` in closed
    form instead of letting the alternating updates shuttle the imbalance
    back and forth (the slow mode of UOT Sinkhorn for large rho/eps).
    Scaling space: ``u *= e^{t/eps}``, ``v /= e^{t/eps}`` — applied in log
    space because ``e^{t/eps} = (Sa/Sb)**(rho/(2*eps))`` overflows fp32
    for large ``rho/eps`` even when the translated potentials are benign.
    Zero entries of u/v (from zero marginal mass) are left at zero.

    Sub-noise translations are zeroed via ``translation_noise_floor``.
    (For very large ``rho/eps`` the scaling-space iterates themselves
    overflow fp32; that regime belongs to ``sinkhorn_uot_log``, whose TI
    path works on the potentials directly.)
    """
    p = eps / rho
    logu = jnp.log(jnp.where(u > 0, u, 1.0))
    logv = jnp.log(jnp.where(v > 0, v, 1.0))
    sa = jnp.sum(jnp.where(u > 0, a * jnp.exp(-p * logu), 0.0))
    sb = jnp.sum(jnp.where(v > 0, b * jnp.exp(-p * logv), 0.0))
    logk = rho / (2 * eps) * (jnp.log(sa) - jnp.log(sb))
    noise = translation_noise_floor(rho / (2 * eps), logk.dtype)
    logk = jnp.where(jnp.abs(logk) > noise, logk, 0.0)
    u = jnp.where(u > 0, jnp.exp(logu + logk), 0.0)
    v = jnp.where(v > 0, jnp.exp(logv - logk), 0.0)
    return u, v


def _ti_enabled(cfg: UOTConfig) -> bool:
    # Balanced problems (fi == 1) are the gauge-freedom case: translation
    # never changes P, so the extra reductions would buy nothing.
    return cfg.translation_invariant and cfg.reg_m != float("inf")


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_uv(K, a: jax.Array, b: jax.Array, cfg: UOTConfig):
    """POT-style u/v iteration. Returns (P, (u, v), stats).

    ``K`` is the dense Gibbs kernel matrix — or a
    ``repro.geometry.Geometry``, evaluated lazily: every matvec goes
    through ``apply_kernel`` / ``apply_kernel_T``, so a ``GridGeometry``
    iterates entirely on per-axis factors (never forming M*N) and a
    ``PointCloudGeometry`` computes row-chunked tiles on the fly. Only the
    final coupling materialization is dense.

    With ``cfg.translation_invariant`` the optimal dual translation is
    applied after every iteration (see ``translate_uv``) — same fixed
    point, far fewer iterations on mass-imbalanced problems.
    """
    fi = cfg.fi
    ti = _ti_enabled(cfg)
    M, N = K.shape
    u0 = jnp.ones((M,), jnp.float32)
    v0 = jnp.ones((N,), jnp.float32)

    def body(carry):
        u, v, it, _ = carry
        Kv = _Kv(K, v, cfg)
        u_new = rescale_factors(a, Kv, fi)
        KTu = _KTu(K, u_new, cfg)
        v_new = rescale_factors(b, KTu, fi)
        if ti:
            u_new, v_new = translate_uv(u_new, v_new, a, b, cfg.reg,
                                        cfg.reg_m)
        err = jnp.max(jnp.abs(u_new - u) / jnp.maximum(jnp.abs(u_new), 1e-30))
        return u_new, v_new, it + 1, err

    if cfg.tol is None:
        u, v, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, lambda _, c: body(c),
            (u0, v0, jnp.int32(0), jnp.float32(jnp.inf)))
    else:
        def cond(carry):
            _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        u, v, iters, err = jax.lax.while_loop(
            cond, body, (u0, v0, jnp.int32(0), jnp.float32(jnp.inf)))

    P = _coupling(K, u, v, cfg)
    return P, (u, v), {"iters": iters, "err": err}


def uv_fused_iteration(K, v, a, b, fi):
    """One u/v iteration expressed as the single-read-pass computation.

    jnp semantic reference for the Pallas kernel: (Kv, u) then (K^T u, v)
    where the kernel computes K@v and K.T@u_new in the same streaming pass.
    """
    Kv = K @ v
    u = rescale_factors(a, Kv, fi)
    KTu = u @ K              # row-major-friendly transposed matvec
    v_new = rescale_factors(b, KTu, fi)
    return u, v_new


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_uv_fused(K, a: jax.Array, b: jax.Array,
                          cfg: UOTConfig):
    """Fused-schedule u/v solver (same iterates as ``sinkhorn_uot_uv``).

    ``K`` may be a ``Geometry`` (lazy kernel applications) like
    ``sinkhorn_uot_uv``; the explicit single-read-pass schedule is the
    dense-matrix story, the geometry story is that each "pass" never
    touches an M*N operand at all.
    """
    fi = cfg.fi
    ti = _ti_enabled(cfg)
    M, N = K.shape
    v0 = jnp.ones((N,), jnp.float32)
    u0 = jnp.ones((M,), jnp.float32)

    def body(_, carry):
        u, v = carry
        u = rescale_factors(a, _Kv(K, v, cfg), fi)
        v = rescale_factors(b, _KTu(K, u, cfg), fi)
        if ti:
            u, v = translate_uv(u, v, a, b, cfg.reg, cfg.reg_m)
        return u, v

    u, v = jax.lax.fori_loop(0, cfg.num_iters, body, (u0, v0))
    P = _coupling(K, u, v, cfg)
    return P, (u, v), {"iters": jnp.int32(cfg.num_iters)}
