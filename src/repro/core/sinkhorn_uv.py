"""POT ``sinkhorn_knopp_unbalanced`` u/v-potential form + fused variant.

Semantics (POT-faithful):   u = (a / (K v)) ** fi ;  v = (b / (K^T u)) ** fi
with the Gibbs kernel K held constant and the coupling materialized only at
the end as P = diag(u) K diag(v).

Beyond-paper memory optimization (``sinkhorn_uot_uv_fused``): both matvecs
of an iteration are computed in ONE read-only pass over K. Row block i gives
(K v)_i by a row-dot; u_i is then immediately available, so u_i * K[i, :] can
be accumulated into the K^T u partials during the same pass. Traffic per
iteration: M*N element *reads*, ZERO writes — half of MAP-UOT's 2*M*N
(which must write A back every iteration), and K can additionally be stored
in bf16 (u, v, accumulators stay fp32). The corresponding explicit-schedule
kernel is ``repro.kernels.uot_uv_fused``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_uv(K: jax.Array, a: jax.Array, b: jax.Array, cfg: UOTConfig):
    """POT-style u/v iteration. Returns (P, (u, v), stats)."""
    fi = cfg.fi
    M, N = K.shape
    u0 = jnp.ones((M,), jnp.float32)
    v0 = jnp.ones((N,), jnp.float32)

    def body(carry):
        u, v, it, _ = carry
        Kv = K @ v
        u_new = rescale_factors(a, Kv, fi)
        KTu = u_new @ K          # row-major-friendly transposed matvec
        v_new = rescale_factors(b, KTu, fi)
        err = jnp.max(jnp.abs(u_new - u) / jnp.maximum(jnp.abs(u_new), 1e-30))
        return u_new, v_new, it + 1, err

    if cfg.tol is None:
        u, v, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, lambda _, c: body(c),
            (u0, v0, jnp.int32(0), jnp.float32(jnp.inf)))
    else:
        def cond(carry):
            _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        u, v, iters, err = jax.lax.while_loop(
            cond, body, (u0, v0, jnp.int32(0), jnp.float32(jnp.inf)))

    P = (u[:, None] * K * v[None, :]).astype(cfg.dtype)
    return P, (u, v), {"iters": iters, "err": err}


def uv_fused_iteration(K, v, a, b, fi):
    """One u/v iteration expressed as the single-read-pass computation.

    jnp semantic reference for the Pallas kernel: (Kv, u) then (K^T u, v)
    where the kernel computes K@v and K.T@u_new in the same streaming pass.
    """
    Kv = K @ v
    u = rescale_factors(a, Kv, fi)
    KTu = u @ K              # row-major-friendly transposed matvec
    v_new = rescale_factors(b, KTu, fi)
    return u, v_new


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_uv_fused(K: jax.Array, a: jax.Array, b: jax.Array,
                          cfg: UOTConfig):
    """Fused-schedule u/v solver (same iterates as ``sinkhorn_uot_uv``)."""
    fi = cfg.fi
    M, N = K.shape
    v0 = jnp.ones((N,), jnp.float32)
    u0 = jnp.ones((M,), jnp.float32)

    def body(_, carry):
        u, v = carry
        u, v = uv_fused_iteration(K, v, a, b, fi)
        return u, v

    u, v = jax.lax.fori_loop(0, cfg.num_iters, body, (u0, v0))
    P = (u[:, None] * K * v[None, :]).astype(cfg.dtype)
    return P, (u, v), {"iters": jnp.int32(cfg.num_iters)}
