"""Convergence / diagnostic metrics for UOT solves."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def marginal_error(P: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """L1 marginal violation (balanced-sense diagnostic)."""
    return jnp.sum(jnp.abs(P.sum(1) - a)) + jnp.sum(jnp.abs(P.sum(0) - b))


def mass(P: jax.Array) -> jax.Array:
    return jnp.sum(P)


def factor_drift(target: jax.Array, sums: jax.Array, fi: float) -> jax.Array:
    """max |(target/sums)^fi - 1| — the rescale-factor drift used as the
    stopping criterion by the scaling-form solvers (a factor of exactly 1
    means that rescale is a no-op, i.e. converged)."""
    safe = jnp.where(sums > 0, sums, 1.0)
    ratio = jnp.where(sums > 0, target / safe, 1.0)
    return jnp.max(jnp.abs(jnp.power(ratio, fi) - 1.0))


def lane_factor_drift(factors: jax.Array, prev_factors: jax.Array
                      ) -> jax.Array:
    """Per-lane stationarity drift of successive rescale factors.

    ``factors`` / ``prev_factors`` are (B, K) stacks of per-lane row
    factors from iterations t and t-1. Returns (B,) ``max_k |f_t - f_{t-1}|``
    — the batched form of the single-problem solvers' stopping criterion.
    Under unequal masses the UOT scaling factors converge to constant
    non-unit values (reciprocal between the row and column steps), so
    ``|f - 1|`` never vanishes; iterate convergence shows up as successive
    factors going *stationary*. Zero-padded rows carry factor exactly 1 in
    every iteration and contribute 0 to the max.
    """
    return jnp.max(jnp.abs(factors - prev_factors), axis=-1)
