"""Exact 1-D (unbalanced) optimal transport in O((M+N) log(M+N)).

Two solvers, both far outside the Sinkhorn family — no epsilon, no
M*N anything (Gouvine, arXiv:2311.17704 names the regime; the
construction here is the classical quantile merge plus the 1-D
Frank-Wolfe of Séjourné et al., arXiv:2201.00730 §5):

* **Balanced** (``solve_1d_balanced_np`` / jnp inside ``solve_1d``):
  for sorted supports and any cost ``|x - y|**p`` convex in (x - y),
  the monotone (north-west / quantile-merge) coupling is exact. It is
  built from two cumsums, one merge-sort of the quantile levels, and
  two ``searchsorted`` calls — O((M+N) log(M+N)), and the plan has a
  *fixed* support size of at most M+N segments, which is what makes
  the jnp path vmappable (sliced-UOT runs hundreds of these in one
  launch — see ``repro.geometry.sliced``).

* **Unbalanced (KL marginals)** (``solve_1d_np`` / ``solve_1d``):
  Frank-Wolfe on the UOT dual
  ``sup {rho<a, 1-e^(-f/rho)> + rho<b, 1-e^(-g/rho)> : f + g <= c}``.
  Each step re-weights the marginals by the current potentials
  (``a~ = a e^(-f/rho)``), applies the closed-form optimal translation
  (the same ``(rho/2) log(Sa/Sb)`` as ``sinkhorn_uv.translate_uv`` —
  it equalizes the reweighted masses, which is exactly what makes the
  linear minimization oracle bounded), and calls the *exact* balanced
  solver as the LMO: the chain-rule potentials of the monotone plan
  are the balanced dual optimum. Primal extraction is the monotone
  plan between the final reweighted marginals — its marginals are
  ``a~``/``b~`` *exactly*, so the KL terms are closed-form.

  Every iterate is dual-feasible, so ``dual`` is a certified lower
  bound and ``primal - dual`` (``gap``) is a certified optimality gap
  — that gap is the error estimate the serving degrade ladder attaches
  to sliced results (``repro.serve``'s overload model).

Cost model: ``c(x, y) = cost_scale * |x - y|**p`` with ``p`` in {1, 2}.
``p=2`` with ``cost_scale = d / scale`` is the sliced match for
``PointCloudGeometry``'s ``C = ||x - y||^2 / scale`` (the factor ``d``
makes ``E_theta[d * (theta . (x - y))^2] = ||x - y||^2`` for uniform
unit ``theta``).

Shapes are static everywhere on the jnp path (segments padded to
M+N), so ``jax.vmap(functools.partial(solve_1d, ...))`` over a stack
of projections compiles once.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

_EXP_CLIP = 50.0  # |f| / rho beyond this is saturated (exp under/overflow)


# ---------------------------------------------------------------------------
# numpy host path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Plan1D:
    """A sparse 1-D transport plan: ``w[k]`` mass from ``x[i[k]]`` to
    ``y[j[k]]`` (original, unsorted indices), at most M+N segments."""

    i: np.ndarray
    j: np.ndarray
    w: np.ndarray
    cost: float        # transport term only: sum(w * c(x_i, y_j))


@dataclasses.dataclass(frozen=True)
class Solve1DResult:
    """Certified unbalanced 1-D solve: ``primal >= uot >= dual``."""

    primal: float      # objective of ``plan`` (transport + KL terms)
    dual: float        # dual objective of (f, g) — certified lower bound
    gap: float         # primal - dual (>= 0): certified optimality gap
    plan: Plan1D
    f: np.ndarray      # dual potentials, original index order
    g: np.ndarray
    ta: np.ndarray     # reweighted marginals a * e^(-f/rho) = plan rows
    tb: np.ndarray


def _cost_np(dx: np.ndarray, p: int, cost_scale: float) -> np.ndarray:
    d = np.abs(dx)
    return cost_scale * (d if p == 1 else d * d)


def _merge_segments_np(ca: np.ndarray, cb: np.ndarray):
    """Quantile-merge segments of two cumulative weight vectors sharing
    the same total mass: (i, j, w) with i/j sorted-order indices."""
    m = min(ca[-1], cb[-1])
    q = np.sort(np.concatenate([np.minimum(ca, m), np.minimum(cb, m)]))
    q = np.concatenate([[0.0], q])
    w = np.maximum(np.diff(q), 0.0)
    mid = q[:-1] + 0.5 * w
    i = np.minimum(np.searchsorted(ca, mid, side="left"), len(ca) - 1)
    j = np.minimum(np.searchsorted(cb, mid, side="left"), len(cb) - 1)
    return i, j, w


def solve_1d_balanced_np(x, a, y, b, *, p: int = 2,
                         cost_scale: float = 1.0) -> Plan1D:
    """Exact balanced 1-D OT: the monotone plan of the quantile merge.

    Requires ``sum(a) == sum(b)`` (up to float tolerance; the merge
    clips to the smaller total). Exact for any cost convex in (x - y)
    — here ``cost_scale * |x - y|**p``.
    """
    x = np.asarray(x, np.float64).ravel()
    y = np.asarray(y, np.float64).ravel()
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    ox = np.argsort(x, kind="stable")
    oy = np.argsort(y, kind="stable")
    i, j, w = _merge_segments_np(np.cumsum(a[ox]), np.cumsum(b[oy]))
    cost = float(np.sum(w * _cost_np(x[ox][i] - y[oy][j], p, cost_scale)))
    return Plan1D(i=ox[i], j=oy[j], w=w, cost=cost)


def _chain_potentials_np(xs, ys, i, j, p, cost_scale):
    """Dual potentials of the monotone plan via the complementary-
    slackness chain: f[i] + g[j] = c(i, j) along the (sorted-order)
    segment path. Returns (f, g) in sorted order."""
    f = np.zeros(len(xs))
    g = np.zeros(len(ys))

    def c(ii, jj):
        d = abs(xs[ii] - ys[jj])
        return cost_scale * (d if p == 1 else d * d)

    fcur = c(i[0], j[0])
    gcur = 0.0
    f[i[0]] = fcur
    g[j[0]] = gcur
    ip, jp = i[0], j[0]
    for k in range(1, len(i)):
        ik, jk = i[k], j[k]
        if ik != ip:
            fcur = c(ik, jp) - gcur
            f[ik] = fcur
        gcur = c(ik, jk) - fcur
        g[jk] = gcur
        ip, jp = ik, jk
    # Rows/cols the merge never visited (possible when a reweighted mass
    # underflows to a float cumsum tie) would keep potential 0, which can
    # be INfeasible. Give them the always-feasible floor -max(other side):
    # touched pairs keep chain feasibility, mixed pairs sum to <= 0 <= c,
    # and skipped-skipped pairs need max(f)+max(g) >= 0, guaranteed by
    # f[i0] = c >= 0, g[j0] = 0. Loose only where the mass is ~0, so the
    # LMO/dual values are unaffected.
    fmask = np.zeros(len(xs), bool)
    gmask = np.zeros(len(ys), bool)
    fmask[i] = True
    gmask[j] = True
    if not fmask.all():
        f[~fmask] = -g[gmask].max()
    if not gmask.all():
        g[~gmask] = -f[fmask].max()
    return f, g


def _kl_np(s: np.ndarray, q: np.ndarray) -> float:
    """KL(q*s | q) = sum q * (s log s - s + 1), with 0 log 0 = 0."""
    s = np.maximum(s, 1e-300)
    return float(np.sum(q * (s * np.log(s) - s + 1.0)))


def solve_1d_np(x, a, y, b, *, rho: float, p: int = 2,
                cost_scale: float = 1.0, n_fw: int = 32,
                tol: float | None = None) -> Solve1DResult:
    """Exact-LMO Frank-Wolfe for 1-D KL-unbalanced OT (host path).

    ``rho`` is the marginal KL weight (``cfg.reg_m``); ``rho=inf``
    reduces to the balanced solver (requires matching masses). ``tol``
    stops early once the Frank-Wolfe linearized gap drops below it.
    """
    x = np.asarray(x, np.float64).ravel()
    y = np.asarray(y, np.float64).ravel()
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    if not math.isfinite(rho):
        plan = solve_1d_balanced_np(x, a, y, b, p=p, cost_scale=cost_scale)
        f, g = _potentials_original_np(x, a, y, b, p, cost_scale)
        dual = float(a @ f + b @ g)
        return Solve1DResult(primal=plan.cost, dual=dual,
                             gap=max(0.0, plan.cost - dual), plan=plan,
                             f=f, g=g, ta=a, tb=b)
    f = np.zeros(len(x))
    g = np.zeros(len(y))
    # Every iterate yields BOTH a certified lower bound (the dual value —
    # each iterate is feasible) and a certified upper bound (the monotone
    # plan between the reweighted marginals is primal-feasible with
    # closed-form KL terms). FW oscillates, so we keep the best of each
    # across the whole trajectory — the reported gap is the envelope's,
    # typically ~10x tighter than the final iterate's.
    best_dual = -math.inf
    best_primal = math.inf
    best_fg = (f, g)
    for k in range(n_fw + 1):
        # closed-form translation (sinkhorn_uv.translate_uv's formula):
        # equalizes the reweighted masses, which bounds the LMO
        sa = float(a @ np.exp(np.clip(-f / rho, -_EXP_CLIP, _EXP_CLIP)))
        sb = float(b @ np.exp(np.clip(-g / rho, -_EXP_CLIP, _EXP_CLIP)))
        t = 0.5 * rho * math.log(sa / sb)
        f = f + t
        g = g - t
        ef = np.exp(np.clip(-f / rho, -_EXP_CLIP, _EXP_CLIP))
        eg = np.exp(np.clip(-g / rho, -_EXP_CLIP, _EXP_CLIP))
        ta = a * ef
        tb = b * eg
        dual_k = float(rho * (a @ (1.0 - ef) + b @ (1.0 - eg)))
        best_dual = max(best_dual, dual_k)
        plan_k = solve_1d_balanced_np(x, ta, y, tb, p=p,
                                      cost_scale=cost_scale)
        primal_k = plan_k.cost + rho * (_kl_np(ef, a) + _kl_np(eg, b))
        if primal_k < best_primal:
            best_primal = primal_k
            best_fg = (f, g)
        if k == n_fw or (tol is not None
                         and best_primal - best_dual <= tol):
            break
        fp, gp = _potentials_original_np(x, ta, y, tb, p, cost_scale)
        # max(line search, 2/(k+2)): exact line search alone zigzags in
        # the near-balanced regime (large rho — the dual is nearly linear
        # and FW bounces between polytope vertices); the open-loop floor
        # breaks the cycle. Empirically ~1e2x tighter gaps at n_fw=32
        # than either rule alone for rho within ~10x of the cost scale.
        gamma = max(_line_search_np(a, b, f, g, fp, gp, rho),
                    2.0 / (k + 2.0))
        f = (1.0 - gamma) * f + gamma * fp
        g = (1.0 - gamma) * g + gamma * gp
    # deliver the best-primal iterate's plan with the envelope gap
    f, g = best_fg
    ef = np.exp(np.clip(-f / rho, -_EXP_CLIP, _EXP_CLIP))
    eg = np.exp(np.clip(-g / rho, -_EXP_CLIP, _EXP_CLIP))
    ta = a * ef
    tb = b * eg
    plan = solve_1d_balanced_np(x, ta, y, tb, p=p, cost_scale=cost_scale)
    return Solve1DResult(primal=best_primal, dual=best_dual,
                         gap=max(0.0, best_primal - best_dual), plan=plan,
                         f=f, g=g, ta=ta, tb=tb)


def _line_search_np(a, b, f, g, fp, gp, rho, iters: int = 40) -> float:
    """Exact Frank-Wolfe step: the dual objective is concave along the
    segment (f, g) -> (fp, gp), so bisect on its directional derivative
    ``<a e^(-phi/rho), fp - f> + <b e^(-psi/rho), gp - g>``. Exact line
    search is what makes the FW practical — the 2/(k+2) schedule needs
    hundreds of steps for the same gap (Séjourné et al. use the same
    device in the 1-D FW)."""
    df = fp - f
    dg = gp - g

    def deriv(gamma):
        phi = f + gamma * df
        psi = g + gamma * dg
        return (a @ (np.exp(np.clip(-phi / rho, -_EXP_CLIP, _EXP_CLIP)) * df)
                + b @ (np.exp(np.clip(-psi / rho, -_EXP_CLIP, _EXP_CLIP))
                       * dg))

    if deriv(1.0) >= 0.0:
        return 1.0
    lo, hi = 0.0, 1.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if deriv(mid) > 0.0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _potentials_original_np(x, wa, y, wb, p, cost_scale):
    """Chain potentials of the monotone plan between (wa, wb), mapped
    back to original index order."""
    ox = np.argsort(x, kind="stable")
    oy = np.argsort(y, kind="stable")
    i, j, _ = _merge_segments_np(np.cumsum(wa[ox]), np.cumsum(wb[oy]))
    fs, gs = _chain_potentials_np(x[ox], y[oy], i, j, p, cost_scale)
    f = np.empty_like(fs)
    g = np.empty_like(gs)
    f[ox] = fs
    g[oy] = gs
    return f, g


def uot_objective_np(P, C, a, b, rho: float) -> float:
    """Unregularized KL-UOT objective of an arbitrary dense plan — the
    yardstick the exact solver is validated against (the entropic
    reference plan's objective must upper-bound ``primal`` up to its
    regularization bias)."""
    P = np.asarray(P, np.float64)
    r = P.sum(axis=1)
    c = P.sum(axis=0)

    def kl(pv, qv):
        pv = np.asarray(pv, np.float64)
        qv = np.asarray(qv, np.float64)
        mask = pv > 0
        return float(np.sum(pv[mask] * np.log(pv[mask] / qv[mask]))
                     - pv.sum() + qv.sum())

    return float(np.sum(P * C) + rho * (kl(r, a) + kl(c, b)))


# ---------------------------------------------------------------------------
# jnp path (fixed shapes; vmappable)
# ---------------------------------------------------------------------------

def _cost_jnp(dx, p, cost_scale):
    d = jnp.abs(dx)
    return cost_scale * (d if p == 1 else d * d)


def _merge_segments_jnp(ca, cb):
    """Fixed-size quantile merge: (i, j, w) with M+N segments (trailing
    zero-width segments carry zero mass)."""
    M = ca.shape[0]
    N = cb.shape[0]
    m = jnp.minimum(ca[-1], cb[-1])
    q = jnp.sort(jnp.concatenate([jnp.minimum(ca, m), jnp.minimum(cb, m)]))
    q = jnp.concatenate([jnp.zeros((1,), q.dtype), q])
    w = jnp.maximum(jnp.diff(q), 0.0)
    mid = q[:-1] + 0.5 * w
    i = jnp.clip(jnp.searchsorted(ca, mid, side="left"), 0, M - 1)
    j = jnp.clip(jnp.searchsorted(cb, mid, side="left"), 0, N - 1)
    return i, j, w


def _chain_potentials_jnp(xs, ys, i, j, p, cost_scale):
    """lax.scan version of the complementary-slackness chain."""

    def c(ii, jj):
        return _cost_jnp(xs[ii] - ys[jj], p, cost_scale)

    def step(carry, k):
        fcur, gcur, ip, jp = carry
        ik, jk = i[k], j[k]
        f_new = jnp.where(ik == ip, fcur, c(ik, jp) - gcur)
        g_new = c(ik, jk) - f_new
        return (f_new, g_new, ik, jk), (f_new, g_new)

    f0 = c(i[0], j[0])
    g0 = jnp.zeros((), xs.dtype)
    (_, _, _, _), (fseq, gseq) = jax.lax.scan(
        step, (f0, g0, i[0], j[0]), jnp.arange(1, i.shape[0]))
    fseq = jnp.concatenate([f0[None], fseq])
    gseq = jnp.concatenate([g0[None], gseq])
    f = jnp.zeros(xs.shape[0], xs.dtype).at[i].set(fseq)
    g = jnp.zeros(ys.shape[0], ys.dtype).at[j].set(gseq)
    # skipped-index feasibility floor — see _chain_potentials_np
    fmask = jnp.zeros(xs.shape[0], bool).at[i].set(True)
    gmask = jnp.zeros(ys.shape[0], bool).at[j].set(True)
    fmax = jnp.max(jnp.where(fmask, f, -jnp.inf))
    gmax = jnp.max(jnp.where(gmask, g, -jnp.inf))
    f = jnp.where(fmask, f, -gmax)
    g = jnp.where(gmask, g, -fmax)
    return f, g


def _kl_jnp(s, q):
    s = jnp.maximum(s, 1e-30)
    return jnp.sum(q * (s * jnp.log(s) - s + 1.0))


@functools.partial(jax.jit, static_argnames=("p", "n_fw"))
def solve_1d(x, a, y, b, rho, *, p: int = 2, cost_scale=1.0,
             n_fw: int = 16) -> dict:
    """jnp twin of ``solve_1d_np``: fixed ``n_fw`` Frank-Wolfe steps,
    fixed-size outputs — safe under ``jax.vmap`` (sliced-UOT stacks
    projections on the leading axis).

    Returns ``{'primal', 'dual', 'gap', 'seg_i', 'seg_j', 'seg_w'}``
    with the plan segments in *original* index order ((M+N,) arrays;
    zero-width segments carry zero mass).
    """
    x = jnp.asarray(x, jnp.float32).ravel()
    y = jnp.asarray(y, jnp.float32).ravel()
    a = jnp.asarray(a, jnp.float32).ravel()
    b = jnp.asarray(b, jnp.float32).ravel()
    rho = jnp.asarray(rho, jnp.float32)
    cost_scale = jnp.asarray(cost_scale, jnp.float32)
    ox = jnp.argsort(x)
    oy = jnp.argsort(y)
    xs, a_s = x[ox], a[ox]
    ys, b_s = y[oy], b[oy]

    def line_search(f, g, fp, gp):
        # bisection on the concave dual's directional derivative — see
        # _line_search_np (fixed 25 halvings: exact to ~3e-8)
        df, dg = fp - f, gp - g

        def deriv(gamma):
            ephi = jnp.exp(jnp.clip(-(f + gamma * df) / rho,
                                    -_EXP_CLIP, _EXP_CLIP))
            epsi = jnp.exp(jnp.clip(-(g + gamma * dg) / rho,
                                    -_EXP_CLIP, _EXP_CLIP))
            return jnp.dot(a_s * ephi, df) + jnp.dot(b_s * epsi, dg)

        def bisect(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            up = deriv(mid) > 0.0
            return jnp.where(up, mid, lo), jnp.where(up, hi, mid)

        lo, hi = jax.lax.fori_loop(
            0, 25, bisect, (jnp.zeros((), jnp.float32),
                            jnp.ones((), jnp.float32)))
        gamma = 0.5 * (lo + hi)
        return jnp.where(deriv(jnp.ones((), jnp.float32)) >= 0.0,
                         jnp.ones((), jnp.float32), gamma)

    def translate_eval(f, g):
        # translate, then evaluate both certified bounds at this iterate
        sa = jnp.dot(a_s, jnp.exp(jnp.clip(-f / rho, -_EXP_CLIP, _EXP_CLIP)))
        sb = jnp.dot(b_s, jnp.exp(jnp.clip(-g / rho, -_EXP_CLIP, _EXP_CLIP)))
        t = 0.5 * rho * jnp.log(sa / sb)
        f, g = f + t, g - t
        ef = jnp.exp(jnp.clip(-f / rho, -_EXP_CLIP, _EXP_CLIP))
        eg = jnp.exp(jnp.clip(-g / rho, -_EXP_CLIP, _EXP_CLIP))
        ta = a_s * ef
        tb = b_s * eg
        i, j, w = _merge_segments_jnp(jnp.cumsum(ta), jnp.cumsum(tb))
        cost = jnp.sum(w * _cost_jnp(xs[i] - ys[j], p, cost_scale))
        primal = cost + rho * (_kl_jnp(ef, a_s) + _kl_jnp(eg, b_s))
        dual = rho * (jnp.dot(a_s, 1.0 - ef) + jnp.dot(b_s, 1.0 - eg))
        return f, g, ta, tb, i, j, primal, dual

    # best-iterate envelope — see the numpy path's rationale
    def fw_step(k, carry):
        f, g, best_p, best_d, fb, gb = carry
        f, g, ta, tb, i, j, primal_k, dual_k = translate_eval(f, g)
        better = primal_k < best_p
        best_p = jnp.where(better, primal_k, best_p)
        fb = jnp.where(better, f, fb)
        gb = jnp.where(better, g, gb)
        best_d = jnp.maximum(best_d, dual_k)
        fp, gp = _chain_potentials_jnp(xs, ys, i, j, p, cost_scale)
        # hybrid step — see the numpy path's rationale
        gamma = jnp.maximum(line_search(f, g, fp, gp),
                            2.0 / (k.astype(jnp.float32) + 2.0))
        return ((1.0 - gamma) * f + gamma * fp,
                (1.0 - gamma) * g + gamma * gp,
                best_p, best_d, fb, gb)

    z_f = jnp.zeros(x.shape[0], jnp.float32)
    z_g = jnp.zeros(y.shape[0], jnp.float32)
    f, g, best_p, best_d, fb, gb = jax.lax.fori_loop(
        0, n_fw, fw_step,
        (z_f, z_g, jnp.asarray(jnp.inf, jnp.float32),
         jnp.asarray(-jnp.inf, jnp.float32), z_f, z_g))
    # evaluate the final iterate too, then extract the best one's plan
    f, g, _, _, _, _, primal_k, dual_k = translate_eval(f, g)
    better = primal_k < best_p
    best_p = jnp.where(better, primal_k, best_p)
    fb = jnp.where(better, f, fb)
    gb = jnp.where(better, g, gb)
    best_d = jnp.maximum(best_d, dual_k)
    ef = jnp.exp(jnp.clip(-fb / rho, -_EXP_CLIP, _EXP_CLIP))
    eg = jnp.exp(jnp.clip(-gb / rho, -_EXP_CLIP, _EXP_CLIP))
    i, j, w = _merge_segments_jnp(jnp.cumsum(a_s * ef), jnp.cumsum(b_s * eg))
    return {
        "primal": best_p,
        "dual": best_d,
        "gap": jnp.maximum(best_p - best_d, 0.0),
        "seg_i": ox[i],
        "seg_j": oy[j],
        "seg_w": w,
    }
