"""Problem-health checks + the log-domain escalation adapter.

The serving tiers (``repro.serve``, ``repro.cluster``) share one lane pool
across many requests, so a single ill-posed payload has a blast radius far
beyond its own answer: a NaN marginal poisons the batched rescale factors of
its lane, and a uv/matrix-scaling solve in the fp32 overflow regime
documented in ``core.sinkhorn_uv`` returns garbage *silently* (overflowed
iterates collapse through the safe divisions to a zero coupling with a
stationary factor trajectory — no NaN ever surfaces). This module is the
admission side of fault containment:

- ``validate_problem`` raises a typed ``InvalidProblemError`` for the
  request classes that are cheap to detect BEFORE they touch device state:
  non-finite / negative marginals, shape or dtype mismatches, empty
  marginals, and overflow-regime ``(cfg, a, b)`` combinations. Marginal
  checks are O(M + N); the M*N kernel payload is deliberately NOT scanned
  here (that would double admission traffic) — non-finite kernel entries
  are caught in flight by the lane-health detector in
  ``ops.solve_fused_stepped`` instead.
- ``uv_safe`` is the overflow-regime predicate, derived from the
  ``sinkhorn_uv.translate_uv`` amplification bound: the scaling-space
  iterates carry the mass-imbalance mode as a factor
  ``(sum(a)/sum(b)) ** (rho/(2*eps))`` (Séjourné et al., arXiv:2201.00730),
  so its log magnitude ``rho/(2*eps) * |log sum(a) - log sum(b)|`` against
  the fp32 exponent range is a cheap, conservative classifier for "the
  scaling-space tiers will overflow / underflow on this problem".
- ``escalate_log_solve`` is where refused-or-poisoned requests go: one
  solve on ``sinkhorn_uot_log`` — the numerically robust tier, whose
  iterates live in potential space where the same mode is an *additive*
  translation — with an escalated iteration budget. The matrix-scaling
  lanes iterate on the stored coupling ``A0`` directly, so the adapter
  reconstructs the cost as ``C = -reg * log(A0)`` and solves the same
  ``(C, a, b, cfg)`` problem in potential space. NB the escalated answer
  carries the *potential-form* (POT ``sinkhorn_knopp_unbalanced``)
  semantics — for ``fi < 1`` that differs from the scaling-form lane
  answer by the two forms' damping difference (see ``core.problem``'s
  module docstring); schedulers mark such results ``retried_ok`` rather
  than ``ok`` precisely because they are a different tier's answer.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.problem import UOTConfig
from repro.core.log_domain import sinkhorn_uot_log


class InvalidProblemError(ValueError):
    """A request refused at admission, with a machine-readable reason.

    ``reason`` is one of: ``'shape'``, ``'dtype'``, ``'non_finite'``,
    ``'negative'``, ``'empty'``, ``'uv_overflow'``. ``rid`` is the request
    id the scheduler assigned before refusing (so the refusal is
    addressable in telemetry), or None outside a scheduler.
    """

    def __init__(self, reason: str, message: str, *, rid: int | None = None):
        super().__init__(message)
        self.reason = reason
        self.rid = rid


def uv_amplification_log(cfg: UOTConfig, a, b) -> float:
    """log-magnitude of the scaling-space mass-imbalance factor.

    ``translate_uv`` shows the mode the uv/matrix-scaling iterates must
    represent: ``e^{t/eps} = (Sa/Sb) ** (rho/(2*eps))``, i.e. a log
    magnitude of ``rho/(2*eps) * |log Sa - log Sb|``. Balanced problems
    (``reg_m=inf``) have no such mode (gauge freedom) and return 0.
    Returns +inf for empty marginals (callers reject those separately).
    """
    sa = float(np.sum(a))
    sb = float(np.sum(b))
    if not (sa > 0.0 and sb > 0.0) or not math.isfinite(sa + sb):
        return math.inf
    rho, eps = cfg.reg_m, cfg.reg
    if rho == math.inf:
        return 0.0
    return rho / (2.0 * eps) * abs(math.log(sa) - math.log(sb))


def uv_safe(cfg: UOTConfig, a, b, *, dtype=jnp.float32,
            margin: float = 0.5) -> bool:
    """True when the scaling-space tiers can represent this problem's
    mass-imbalance mode in ``dtype`` without overflow/underflow.

    The bound is ``uv_amplification_log`` against ``margin *
    log(finfo(dtype).max)`` — margin < 1 leaves exponent headroom for the
    transient iterates, which overshoot the fixed-point factor before TI or
    the alternating updates rein them in. Problems failing this predicate
    belong to ``sinkhorn_uot_log`` (see ``escalate_log_solve``), whose
    potential-space iterates carry the same mode additively.
    """
    ceiling = margin * math.log(float(jnp.finfo(dtype).max))
    return uv_amplification_log(cfg, a, b) <= ceiling


def validate_problem(cfg: UOTConfig, a, b, *,
                     shape: tuple[int, int] | None = None,
                     rid: int | None = None,
                     check_overflow: bool = True) -> None:
    """Raise ``InvalidProblemError`` for requests that would poison a lane.

    O(M + N): marginals only. ``shape`` (M, N), when given, is the payload
    shape the marginals must match (K's shape for dense requests, the
    cloud sizes for coordinate requests).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    for name, v in (("a", a), ("b", b)):
        if v.ndim != 1:
            raise InvalidProblemError(
                "shape", f"marginal {name} must be 1-D, got shape "
                f"{v.shape}", rid=rid)
        if not np.issubdtype(v.dtype, np.floating):
            raise InvalidProblemError(
                "dtype", f"marginal {name} must be floating, got "
                f"{v.dtype}", rid=rid)
        if not np.all(np.isfinite(v)):
            raise InvalidProblemError(
                "non_finite", f"marginal {name} has non-finite entries",
                rid=rid)
        if np.any(v < 0):
            raise InvalidProblemError(
                "negative", f"marginal {name} has negative entries",
                rid=rid)
        if not np.sum(v) > 0:
            raise InvalidProblemError(
                "empty", f"marginal {name} has zero total mass", rid=rid)
    if shape is not None:
        M, N = shape
        if a.shape != (M,) or b.shape != (N,):
            raise InvalidProblemError(
                "shape", f"marginals ({a.shape[0]},)/({b.shape[0]},) do "
                f"not match problem shape ({M}, {N})", rid=rid)
    if check_overflow and not uv_safe(cfg, a, b):
        raise InvalidProblemError(
            "uv_overflow",
            f"(cfg, a, b) is in the scaling-space overflow regime "
            f"(amplification log {uv_amplification_log(cfg, a, b):.1f} "
            f"exceeds the fp32 budget) — this problem belongs to the "
            f"log-domain tier", rid=rid)


def escalation_config(cfg: UOTConfig, *, factor: int = 2,
                      num_iters: int | None = None) -> UOTConfig:
    """The escalated config a quarantined request retries under: same
    (reg, reg_m) problem, a larger iteration budget (the robust tier is
    the last stop — give it room), fp32 math."""
    iters = num_iters if num_iters is not None else factor * cfg.num_iters
    return dataclasses.replace(cfg, num_iters=iters, dtype=jnp.float32)


def escalate_log_solve(K, a, b, cfg: UOTConfig, *,
                       factor: int = 2, num_iters: int | None = None):
    """Re-solve a quarantined request on ``sinkhorn_uot_log``.

    ``K`` is the request's stored coupling / Gibbs matrix (the matrix the
    lane iterated on); the log solve runs from ``C = -reg * log(K)``, the
    same ``(C, a, b, cfg)`` problem in potential space (with the
    potential-form damping semantics — see the module docstring). Entries
    with ``K <= 0`` map to an effectively infinite cost (zero coupling
    there — exactly what the scaling iteration preserves for a zero
    entry).

    Returns ``(P, stats, ok)`` where ``ok`` is True iff the escalated solve
    produced an all-finite coupling — the caller records ``retried_ok`` on
    True and a typed failure on False. The solve itself never raises on bad
    numerics; a NaN payload simply comes back ``ok=False``.
    """
    ecfg = escalation_config(cfg, factor=factor, num_iters=num_iters)
    K = jnp.asarray(K, jnp.float32)
    tiny = float(jnp.finfo(jnp.float32).tiny)
    C = -ecfg.reg * jnp.log(jnp.maximum(K, tiny))
    # a non-finite payload entry must stay poisonous (NaN in -> not-ok out),
    # not be laundered into a large finite cost by the maximum() clamp
    C = jnp.where(jnp.isfinite(K), C, jnp.nan)
    P, _, stats = sinkhorn_uot_log(C, jnp.asarray(a, jnp.float32),
                                   jnp.asarray(b, jnp.float32), ecfg)
    P = np.asarray(P)
    ok = bool(np.all(np.isfinite(P)))
    return P, {"iters": int(stats["iters"]), "err": float(stats["err"]),
               "num_iters": ecfg.num_iters}, ok
