"""Distributed UOT solvers — the paper's Tianhe-1 design in shard_map.

The paper scales MAP-UOT to the Tianhe-1 supercomputer by row-sharding the
coupling matrix across MPI ranks; the only communication per iteration is an
``MPI_Allreduce`` of the length-N partial column sums (Algorithm 1 lines
16-20 replaced by the allreduce). We map this 1:1 onto JAX:

  rank                -> mesh device along a named axis
  row-shard of A      -> shard_map block of A sharded on that axis
  MPI_Allreduce       -> jax.lax.psum of the local column-sum partials

Beyond the paper we add:
  * a 2-D sharded solver (rows on one axis, columns on another) for matrices
    too large for 1-D sharding — row sums psum over the column axis and
    column sums psum over the row axis;
  * an overlapped variant that hides the column-sum reduction behind the
    next row-block's compute using a ppermute ring (compute/comm overlap);
  * optional bf16 storage with fp32 reduction (``storage_dtype=`` on every
    solver builder): each row block lives in the storage dtype between
    iterations, is upcast once per iteration for the rescale math, and
    every sum / psum / ppermute reduction accumulates fp32 — halving the
    resident bytes per device while the collectives stay fp32-exact;
  * ``gang_solve`` — the serving-tier entry adapter: pad rows to the mesh
    size, shard, run the row-sharded gang, hand back trimmed host numpy.
    ``repro.cluster.ClusterScheduler`` routes problems too large for any
    lane pool here instead of rejecting them.

All variants produce iterates identical to ``sinkhorn_uot_fused`` (up to
float reduction order; bf16 storage to the documented bf16 bars) —
asserted in tests on 8 forced host devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.problem import UOTConfig, rescale_factors


def _storage(cfg: UOTConfig, storage_dtype) -> jnp.dtype:
    return jnp.dtype(storage_dtype if storage_dtype is not None
                     else cfg.dtype)


# ---------------------------------------------------------------------------
# 1-D row-sharded MAP-UOT (the paper's cluster design)
# ---------------------------------------------------------------------------

def rowsharded_fused_solver(mesh: Mesh, axis: str, cfg: UOTConfig, *,
                            storage_dtype=None):
    """Build a jit-able solver fn over a row-sharded coupling matrix.

    Returns solve(A, a, b) -> (A, colsum) where A is sharded P(axis, None)
    and a is sharded P(axis); b is replicated. One psum (== MPI_Allreduce)
    per iteration.

    ``storage_dtype`` (default ``cfg.dtype``) is the dtype each device
    carries its row block in between iterations; the rescale math and
    every reduction (local sums AND the psum) run fp32, so a bf16 gang
    halves per-device residency without touching collective precision.
    The returned coupling is in the storage dtype, the colsum fp32.
    """
    fi = cfg.fi
    sdt = _storage(cfg, storage_dtype)

    def local_iter(A_blk, colsum, a_blk, b):
        # Column rescale with globally-reduced column sums (already psum'ed)
        blk = A_blk.astype(jnp.float32) * rescale_factors(b, colsum, fi)[None, :]
        rowsum = blk.sum(axis=1)
        blk = blk * rescale_factors(a_blk, rowsum, fi)[:, None]
        # Partial column sums of the local row block -> allreduce (fp32)
        partial = blk.sum(axis=0)
        return blk.astype(sdt), jax.lax.psum(partial, axis)

    def solve_shard(A_blk, a_blk, b):
        A_blk = A_blk.astype(sdt)
        colsum = jax.lax.psum(A_blk.astype(jnp.float32).sum(axis=0), axis)

        def body(_, carry):
            A_blk, colsum = carry
            return local_iter(A_blk, colsum, a_blk, b)

        A_blk, colsum = jax.lax.fori_loop(
            0, cfg.num_iters, body, (A_blk, colsum))
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(axis, None), P()),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# 2-D sharded MAP-UOT (beyond paper: rows x cols over two mesh axes)
# ---------------------------------------------------------------------------

def sharded2d_fused_solver(mesh: Mesh, row_axis: str, col_axis: str,
                           cfg: UOTConfig, *, storage_dtype=None):
    """2-D sharded solver: A sharded P(row_axis, col_axis).

    Row sums need a psum over ``col_axis``; column sums a psum over
    ``row_axis``. Marginals a sharded on row_axis, b on col_axis. Two small
    vector collectives per iteration — still O(M/Pr + N/Pc) bytes, never the
    matrix itself. ``storage_dtype`` as in ``rowsharded_fused_solver``:
    blocks stored in it, all math and both psums fp32.
    """
    fi = cfg.fi
    sdt = _storage(cfg, storage_dtype)

    def solve_shard(A_blk, a_blk, b_blk):
        A_blk = A_blk.astype(sdt)
        colsum = jax.lax.psum(A_blk.astype(jnp.float32).sum(axis=0),
                              row_axis)

        def body(_, carry):
            A_blk, colsum = carry
            blk = A_blk.astype(jnp.float32)
            blk = blk * rescale_factors(b_blk, colsum, fi)[None, :]
            rowsum = jax.lax.psum(blk.sum(axis=1), col_axis)
            blk = blk * rescale_factors(a_blk, rowsum, fi)[:, None]
            colsum = jax.lax.psum(blk.sum(axis=0), row_axis)
            return blk.astype(sdt), colsum

        A_blk, colsum = jax.lax.fori_loop(
            0, cfg.num_iters, body, (A_blk, colsum))
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis), P(col_axis)),
        out_specs=(P(row_axis, col_axis), P(col_axis)),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Overlapped variant: ring-reduce column partials behind next block compute
# ---------------------------------------------------------------------------

def rowsharded_overlapped_solver(mesh: Mesh, axis: str, cfg: UOTConfig,
                                 num_chunks: int = 4, *,
                                 storage_dtype=None):
    """Row-sharded solver that overlaps the column-sum reduction with compute.

    The local row block is split into ``num_chunks`` chunks. After chunk k's
    partial column sums are ready, a ring reduce-scatter step (ppermute) for
    chunk k-1's partials runs concurrently with chunk k+1's compute — XLA's
    async collective scheduling on TPU overlaps the ppermute DMA with the VPU
    work. The final factors equal the blocking psum version exactly.

    This mirrors (and improves on) the paper's blocking MPI_Allreduce: on
    Tianhe-1 the allreduce serializes after the pass; here it rides along.
    ``storage_dtype`` as in ``rowsharded_fused_solver``: chunks are upcast
    to fp32 for the rescale math and the ring partials stay fp32.
    """
    fi = cfg.fi
    n_dev = mesh.shape[axis]
    sdt = _storage(cfg, storage_dtype)

    def solve_shard(A_blk, a_blk, b):
        A_blk = A_blk.astype(sdt)
        Mloc = A_blk.shape[0]
        chunk = Mloc // num_chunks

        def one_iter(carry, _):
            A_blk, colsum = carry
            fcol = rescale_factors(b, colsum, fi)

            def chunk_body(k, state):
                A_blk, acc = state
                blk = jax.lax.dynamic_slice_in_dim(A_blk, k * chunk, chunk, 0)
                blk = blk.astype(jnp.float32) * fcol[None, :]
                rowsum = blk.sum(axis=1)
                a_chunk = jax.lax.dynamic_slice_in_dim(a_blk, k * chunk, chunk, 0)
                blk = blk * rescale_factors(a_chunk, rowsum, fi)[:, None]
                acc = acc + blk.sum(axis=0)
                A_blk = jax.lax.dynamic_update_slice_in_dim(
                    A_blk, blk.astype(sdt), k * chunk, 0)
                return A_blk, acc

            A_blk, partial = jax.lax.fori_loop(
                0, num_chunks, chunk_body,
                (A_blk, jnp.zeros_like(colsum)))
            # Ring all-reduce of partials via ppermute (log-free, n-1 steps);
            # on TPU each step is an async DMA that overlaps with the next
            # iteration's first chunks once XLA's LHS kicks in.
            acc = partial
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
            recv = partial
            for _ in range(n_dev - 1):
                recv = jax.lax.ppermute(recv, axis, perm)
                acc = acc + recv
            return (A_blk, acc), None

        colsum0 = jax.lax.psum(A_blk.astype(jnp.float32).sum(axis=0), axis)
        (A_blk, colsum), _ = jax.lax.scan(
            one_iter, (A_blk, colsum0), None, length=cfg.num_iters)
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(axis, None), P()),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def shard_inputs(mesh: Mesh, axis: str, A, a, b):
    """Place (A, a, b) with the 1-D row sharding used by the solvers."""
    sA = jax.device_put(A, NamedSharding(mesh, P(axis, None)))
    sa = jax.device_put(a, NamedSharding(mesh, P(axis)))
    sb = jax.device_put(b, NamedSharding(mesh, P()))
    return sA, sa, sb


# ---------------------------------------------------------------------------
# Serving-tier gang entry: one adapter from a raw request to the row gang
# ---------------------------------------------------------------------------

# Built solver fns per (mesh, axis, cfg, storage dtype, num_chunks-or-None):
# building re-traces shard_map + jit, so serving traffic must reuse them.
_GANG_SOLVERS: dict = {}


def gang_solve(mesh: Mesh, axis: str, K, a, b, cfg: UOTConfig, *,
               storage_dtype=None, overlapped: bool = False,
               num_chunks: int = 4):
    """Solve one over-sized request on the row-sharded device gang.

    The serving-tier entry adapter that unifies the lane-pool and
    distributed tiers behind one submit API: ``repro.cluster``'s router
    sends problems whose shape fails the lane-pool budget here instead of
    rejecting them. Handles the impedance mismatch a raw request carries:

      * rows are zero-padded so M divides the gang size (zero rows have
        zero marginal mass -> unit factors -> stay zero: exact no-ops,
        the same invariant the lane pools rest on);
      * inputs are placed with ``shard_inputs`` (one host->device scatter
        of O(M*N/D) bytes per device), the compiled gang solver is built
        once per (mesh, axis, cfg, storage dtype) and cached;
      * the result is trimmed back to (M, N) host numpy.

    Runs the fixed ``cfg.num_iters`` budget (the gang's fori_loop has no
    tol early-exit — one over-sized solve saturates the mesh, so there is
    no lane-mate to stop dragging). Returns ``(P, colsum)`` numpy arrays.
    ``overlapped=True`` uses the ring-reduce compute/comm-overlap variant.
    """
    K = np.asarray(K)
    M, N = K.shape
    n_dev = mesh.shape[axis]
    # the overlapped solver's chunk loop covers Mloc // num_chunks * num_chunks
    # local rows, so rows must also divide into whole chunks per device —
    # otherwise tail rows are never rescaled and silently corrupt the
    # ring-reduced column sums
    row_mult = n_dev * num_chunks if overlapped else n_dev
    pm = (-M) % row_mult
    if pm:
        K = np.pad(K, ((0, pm), (0, 0)))
        a = np.pad(np.asarray(a), (0, pm))
    sdt = _storage(cfg, storage_dtype)
    key = (mesh, axis, cfg, sdt.name, num_chunks if overlapped else None)
    solver = _GANG_SOLVERS.get(key)
    if solver is None:
        solver = _GANG_SOLVERS[key] = (
            rowsharded_overlapped_solver(mesh, axis, cfg,
                                         num_chunks=num_chunks,
                                         storage_dtype=storage_dtype)
            if overlapped
            else rowsharded_fused_solver(mesh, axis, cfg,
                                         storage_dtype=storage_dtype))
    sA, sa, sb = shard_inputs(mesh, axis, jnp.asarray(K, sdt),
                              jnp.asarray(a, jnp.float32),
                              jnp.asarray(b, jnp.float32))
    A, colsum = solver(sA, sa, sb)
    return np.asarray(A)[:M], np.asarray(colsum)
