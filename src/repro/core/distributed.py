"""Distributed UOT solvers — the paper's Tianhe-1 design in shard_map.

The paper scales MAP-UOT to the Tianhe-1 supercomputer by row-sharding the
coupling matrix across MPI ranks; the only communication per iteration is an
``MPI_Allreduce`` of the length-N partial column sums (Algorithm 1 lines
16-20 replaced by the allreduce). We map this 1:1 onto JAX:

  rank                -> mesh device along a named axis
  row-shard of A      -> shard_map block of A sharded on that axis
  MPI_Allreduce       -> jax.lax.psum of the local column-sum partials

Beyond the paper we add:
  * a 2-D sharded solver (rows on one axis, columns on another) for matrices
    too large for 1-D sharding — row sums psum over the column axis and
    column sums psum over the row axis;
  * an overlapped variant that hides the column-sum reduction behind the
    next row-block's compute using a ppermute ring (compute/comm overlap);
  * optional bf16 storage with fp32 reduction.

All variants produce iterates identical to ``sinkhorn_uot_fused`` (up to
float reduction order) — asserted in tests on 8 forced host devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.problem import UOTConfig, rescale_factors


# ---------------------------------------------------------------------------
# 1-D row-sharded MAP-UOT (the paper's cluster design)
# ---------------------------------------------------------------------------

def rowsharded_fused_solver(mesh: Mesh, axis: str, cfg: UOTConfig):
    """Build a jit-able solver fn over a row-sharded coupling matrix.

    Returns solve(A, a, b) -> (A, colsum) where A is sharded P(axis, None)
    and a is sharded P(axis); b is replicated. One psum (== MPI_Allreduce)
    per iteration.
    """
    fi = cfg.fi

    def local_iter(A_blk, colsum, a_blk, b):
        # Column rescale with globally-reduced column sums (already psum'ed)
        A_blk = A_blk * rescale_factors(b, colsum, fi)[None, :]
        rowsum = A_blk.sum(axis=1)
        A_blk = A_blk * rescale_factors(a_blk, rowsum, fi)[:, None]
        # Partial column sums of the local row block -> allreduce
        partial = A_blk.sum(axis=0)
        return A_blk, jax.lax.psum(partial, axis)

    def solve_shard(A_blk, a_blk, b):
        colsum = jax.lax.psum(A_blk.sum(axis=0), axis)

        def body(_, carry):
            A_blk, colsum = carry
            return local_iter(A_blk, colsum, a_blk, b)

        A_blk, colsum = jax.lax.fori_loop(
            0, cfg.num_iters, body, (A_blk, colsum))
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(axis, None), P()),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# 2-D sharded MAP-UOT (beyond paper: rows x cols over two mesh axes)
# ---------------------------------------------------------------------------

def sharded2d_fused_solver(mesh: Mesh, row_axis: str, col_axis: str,
                           cfg: UOTConfig):
    """2-D sharded solver: A sharded P(row_axis, col_axis).

    Row sums need a psum over ``col_axis``; column sums a psum over
    ``row_axis``. Marginals a sharded on row_axis, b on col_axis. Two small
    vector collectives per iteration — still O(M/Pr + N/Pc) bytes, never the
    matrix itself.
    """
    fi = cfg.fi

    def solve_shard(A_blk, a_blk, b_blk):
        colsum = jax.lax.psum(A_blk.sum(axis=0), row_axis)

        def body(_, carry):
            A_blk, colsum = carry
            A_blk = A_blk * rescale_factors(b_blk, colsum, fi)[None, :]
            rowsum = jax.lax.psum(A_blk.sum(axis=1), col_axis)
            A_blk = A_blk * rescale_factors(a_blk, rowsum, fi)[:, None]
            colsum = jax.lax.psum(A_blk.sum(axis=0), row_axis)
            return A_blk, colsum

        A_blk, colsum = jax.lax.fori_loop(
            0, cfg.num_iters, body, (A_blk, colsum))
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis), P(col_axis)),
        out_specs=(P(row_axis, col_axis), P(col_axis)),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Overlapped variant: ring-reduce column partials behind next block compute
# ---------------------------------------------------------------------------

def rowsharded_overlapped_solver(mesh: Mesh, axis: str, cfg: UOTConfig,
                                 num_chunks: int = 4):
    """Row-sharded solver that overlaps the column-sum reduction with compute.

    The local row block is split into ``num_chunks`` chunks. After chunk k's
    partial column sums are ready, a ring reduce-scatter step (ppermute) for
    chunk k-1's partials runs concurrently with chunk k+1's compute — XLA's
    async collective scheduling on TPU overlaps the ppermute DMA with the VPU
    work. The final factors equal the blocking psum version exactly.

    This mirrors (and improves on) the paper's blocking MPI_Allreduce: on
    Tianhe-1 the allreduce serializes after the pass; here it rides along.
    """
    fi = cfg.fi
    n_dev = mesh.shape[axis]

    def solve_shard(A_blk, a_blk, b):
        Mloc = A_blk.shape[0]
        chunk = Mloc // num_chunks

        def one_iter(carry, _):
            A_blk, colsum = carry
            fcol = rescale_factors(b, colsum, fi)

            def chunk_body(k, state):
                A_blk, acc = state
                blk = jax.lax.dynamic_slice_in_dim(A_blk, k * chunk, chunk, 0)
                blk = blk * fcol[None, :]
                rowsum = blk.sum(axis=1)
                a_chunk = jax.lax.dynamic_slice_in_dim(a_blk, k * chunk, chunk, 0)
                blk = blk * rescale_factors(a_chunk, rowsum, fi)[:, None]
                acc = acc + blk.sum(axis=0)
                A_blk = jax.lax.dynamic_update_slice_in_dim(A_blk, blk, k * chunk, 0)
                return A_blk, acc

            A_blk, partial = jax.lax.fori_loop(
                0, num_chunks, chunk_body,
                (A_blk, jnp.zeros_like(colsum)))
            # Ring all-reduce of partials via ppermute (log-free, n-1 steps);
            # on TPU each step is an async DMA that overlaps with the next
            # iteration's first chunks once XLA's LHS kicks in.
            acc = partial
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
            recv = partial
            for _ in range(n_dev - 1):
                recv = jax.lax.ppermute(recv, axis, perm)
                acc = acc + recv
            return (A_blk, acc), None

        colsum0 = jax.lax.psum(A_blk.sum(axis=0), axis)
        (A_blk, colsum), _ = jax.lax.scan(
            one_iter, (A_blk, colsum0), None, length=cfg.num_iters)
        return A_blk, colsum

    sharded = shard_map(
        solve_shard, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P()),
        out_specs=(P(axis, None), P()),
        check_rep=False)
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def shard_inputs(mesh: Mesh, axis: str, A, a, b):
    """Place (A, a, b) with the 1-D row sharding used by the solvers."""
    sA = jax.device_put(A, NamedSharding(mesh, P(axis, None)))
    sa = jax.device_put(a, NamedSharding(mesh, P(axis)))
    sb = jax.device_put(b, NamedSharding(mesh, P()))
    return sA, sa, sb
