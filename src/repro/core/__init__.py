"""Core UOT solvers — the paper's contribution (MAP-UOT) plus baselines.

Solver family
-------------
- ``sinkhorn_baseline``: POT-style 4-pass matrix-scaling iteration (the
  paper's Figure 1 baseline).
- ``sinkhorn_fused``: MAP-UOT — single-pass interweaved row+column rescaling
  (paper Algorithm 1). Identical fixed point & iterates, 3x less HBM traffic.
- ``sinkhorn_uv``: POT ``sinkhorn_knopp_unbalanced`` u/v-potential form
  (kernel matrix K stays constant) + a fused one-read-pass variant
  (beyond-paper: Q = M*N reads, zero writes, per iteration).
- ``log_domain``: numerically stabilized potentials-space solver.
- ``distributed``: shard_map row-sharded & 2-D sharded solvers (the paper's
  MPI_Allreduce design mapped to jax.lax.psum).
- ``health``: typed admission validation (``InvalidProblemError``, the
  ``uv_safe`` overflow-regime predicate) + the log-domain escalation
  adapter the serving tiers quarantine-and-retry through.
- ``solve_1d``: exact 1-D (un)balanced OT in O((M+N) log(M+N)) — the
  quantile-merge balanced solver + exact-LMO Frank-Wolfe with a
  certified optimality gap; the basis of ``geometry.sliced`` and the
  serving degrade ladder's deepest tier.
- ``predict``: analytic + online-corrected iteration prediction (the
  TI contraction rate inverted) — the scheduler's service-time model
  for feasibility admission and predicted-finish-time EDF.
"""
from repro.core.problem import (UOTConfig, UOTProblem, gibbs_kernel,
                                uot_cost)
from repro.core.sinkhorn_baseline import sinkhorn_uot_baseline
from repro.core.sinkhorn_fused import (sinkhorn_uot_fused,
                                       sinkhorn_uot_fused_batched)
from repro.core.sinkhorn_uv import sinkhorn_uot_uv, sinkhorn_uot_uv_fused
from repro.core.log_domain import sinkhorn_uot_log
from repro.core.convergence import (factor_drift, lane_factor_drift,
                                    marginal_error, mass)
from repro.core.health import (InvalidProblemError, escalate_log_solve,
                               escalation_config, uv_safe, validate_problem)
from repro.core.predict import (IterPredictor, analytic_iters,
                                estimate_truncation_error, predict_iters)
from repro.core.solve_1d import (Plan1D, Solve1DResult, solve_1d,
                                 solve_1d_balanced_np, solve_1d_np)

__all__ = [
    "UOTConfig",
    "UOTProblem",
    "gibbs_kernel",
    "uot_cost",
    "sinkhorn_uot_baseline",
    "sinkhorn_uot_fused",
    "sinkhorn_uot_fused_batched",
    "sinkhorn_uot_uv",
    "sinkhorn_uot_uv_fused",
    "sinkhorn_uot_log",
    "marginal_error",
    "mass",
    "factor_drift",
    "lane_factor_drift",
    "InvalidProblemError",
    "uv_safe",
    "validate_problem",
    "escalation_config",
    "escalate_log_solve",
    "Plan1D",
    "Solve1DResult",
    "solve_1d",
    "solve_1d_balanced_np",
    "solve_1d_np",
    "IterPredictor",
    "analytic_iters",
    "estimate_truncation_error",
    "predict_iters",
]
