"""Per-request iteration prediction — the scheduler's service-time model.

Sinkhorn-family UOT solvers contract geometrically: the marginal error
after ``k`` iterations behaves like ``e0 * fi**k`` with
``fi = reg_m / (reg_m + reg)`` (Séjourné et al., arXiv:2201.00730 give
the translation-invariant contraction rate; Pham et al.,
arXiv:2002.03293 bound iterations in the same quantities). Inverting
gives the **analytic** iteration estimate

    iters ~= log(e0 / tol) / (-log fi),
    e0 = 1 + |log(mass(a) / mass(b))|

which captures the *trend* across (reg, reg_m, imbalance) well but
carries a roughly constant multiplicative bias (~0.4-0.6x measured on
the log-domain solver — the rate bound is loose by a constant). The
**online** layer absorbs that bias: ``IterPredictor`` keeps a per-
(bucket, imbalance-bin) EWMA of ``log(actual / analytic)`` fed by the
iteration telemetry both schedulers already record, so the first few
completions of a bucket calibrate every later prediction.

Serving uses this in three places (``repro.serve``'s overload model):

* **feasibility admission** — predicted service time vs the request's
  deadline, *before* burning lane time;
* **predicted-finish-time EDF** — queue ordering by least slack;
* **degrade labeling** — ``estimate_truncation_error`` turns a
  truncated iteration budget into the marginal-error label attached to
  level-1 degraded results.

Iterations become *seconds* via a per-iteration rate. Historically that
rate was an assumed constant (``seconds_per_iter=``) or a completion-fed
EWMA; ``measured_seconds_per_iter`` replaces the constant with measured
per-chunk service time from a ``repro.obs.measure.MeasurementStore`` —
the profiler's chunk cells record wall-clock us per L-lane
chunk_iters-iteration advance, and dividing by ``L * chunk_iters`` gives
the per-lane-iteration rate the service model wants. Both schedulers
consult it (``measurements=``) between the pinned value and the online
EWMA: pinned beats measured beats learned beats uncalibrated.

Everything here is host-side float arithmetic — nothing jitted, nothing
per-element; one ``predict`` costs a dict lookup and two ``log`` calls.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["analytic_iters", "predict_iters", "estimate_truncation_error",
           "IterPredictor", "measured_seconds_per_iter"]


def measured_seconds_per_iter(store, *, M: int | None = None,
                              N: int | None = None,
                              itemsize: int | None = None) -> float | None:
    """Seconds per lane-iteration from measured chunk cells.

    ``store`` is a ``repro.obs.measure.MeasurementStore`` (or None).
    ``M``/``N`` select one pool bucket's padded shape; None aggregates
    over every chunk cell (the bucketless rate ``_retry_after_hint``-
    style consumers want). Returns None when the store holds no matching
    steady-state chunk measurement — the caller falls back to its EWMA,
    never to a guess.
    """
    if store is None:
        return None
    us = store.us_per_lane_iter(kernel="chunk", M=M, N=N,
                                itemsize=itemsize)
    return us * 1e-6 if us is not None else None

# measured multiplicative bias of the analytic rate bound on the
# log-domain solver (see module docstring); the EWMA refines per bucket
_ANALYTIC_BIAS = 0.5
# default convergence target when the config runs without a tolerance
_DEFAULT_TOL = 1e-4


def _fi(reg: float, reg_m: float) -> float:
    if math.isinf(reg_m):
        return 1.0
    return reg_m / (reg_m + reg)


def analytic_iters(cfg, mass_a: float = 1.0, mass_b: float = 1.0) -> float:
    """Closed-form iteration estimate from the contraction rate.

    ``cfg`` is a ``core.problem.UOTConfig``; ``mass_a`` / ``mass_b`` are
    the marginal totals (their log-ratio is the imbalance mode the TI
    translation removes — kept in ``e0`` as a mild, always-safe bump).
    Returns a float, clipped to ``[1, cfg.num_iters]``; with no ``tol``
    the solver runs exactly ``cfg.num_iters``, so that is the answer.
    """
    if cfg.tol is None:
        return float(cfg.num_iters)
    fi = _fi(cfg.reg, cfg.reg_m)
    if fi >= 1.0:
        return float(cfg.num_iters)
    tol = max(cfg.tol, 1e-12)
    imb = abs(math.log(max(mass_a, 1e-12) / max(mass_b, 1e-12)))
    e0 = 1.0 + imb
    iters = _ANALYTIC_BIAS * math.log(max(e0 / tol, 1.0 + 1e-9)) / -math.log(fi)
    return float(min(max(iters, 1.0), cfg.num_iters))


def predict_iters(problem, cfg) -> float:
    """Analytic iteration estimate for a problem-like object.

    ``problem`` is anything with ``a`` / ``b`` marginal arrays (a
    ``ScheduledRequest``, a ``UOTProblem``, or a bare namespace); falls
    back to unit masses when they are absent. This is the stateless
    entry point — serving uses an ``IterPredictor`` instance so the
    estimate improves online.
    """
    a = getattr(problem, "a", None)
    b = getattr(problem, "b", None)
    mass_a = float(a.sum()) if a is not None else 1.0
    mass_b = float(b.sum()) if b is not None else 1.0
    return analytic_iters(cfg, mass_a, mass_b)


def estimate_truncation_error(cfg, iters: float,
                              mass_a: float = 1.0,
                              mass_b: float = 1.0) -> float:
    """Marginal-error estimate after truncating at ``iters`` iterations.

    The inverse of ``analytic_iters``: ``e0 * fi**(iters / bias)``. This
    is the error label serving attaches to level-1 (truncated-Sinkhorn)
    degraded results — same model, same units as ``cfg.tol``.
    """
    fi = _fi(cfg.reg, cfg.reg_m)
    imb = abs(math.log(max(mass_a, 1e-12) / max(mass_b, 1e-12)))
    e0 = 1.0 + imb
    if fi >= 1.0:
        return e0
    return float(e0 * fi ** (max(iters, 0.0) / _ANALYTIC_BIAS))


@dataclasses.dataclass
class _Cell:
    log_ratio: float = 0.0
    count: int = 0


class IterPredictor:
    """Analytic rate + per-(bucket, imbalance-bin) EWMA bias correction.

    ``observe`` feeds completed requests' actual iteration counts (the
    telemetry the schedulers already record at eviction); ``predict``
    multiplies the analytic estimate by ``exp(EWMA[log(actual /
    analytic)])`` for the request's cell, falling back — fine (bucket,
    imbalance-bin, reg, reg_m) -> per-(reg, reg_m) regime -> global ->
    raw analytic — while cells are cold. The state is a tiny host dict
    — safe to share across pools and configs, cheap to discard.
    """

    def __init__(self, alpha: float = 0.25, n_imb_bins: int = 4):
        self.alpha = alpha
        self.n_imb_bins = n_imb_bins
        self._cells: dict[tuple, _Cell] = {}
        self._global = _Cell()

    # -- keying ----------------------------------------------------------
    def _imb_bin(self, mass_a: float, mass_b: float) -> int:
        imb = abs(math.log(max(mass_a, 1e-12) / max(mass_b, 1e-12)))
        return min(int(imb / 0.5), self.n_imb_bins - 1)

    def _key(self, cfg, bucket, mass_a, mass_b):
        # (reg, reg_m) is in the key so one predictor instance shared
        # across configs (calibration sweeps, multi-tenant pools) never
        # blends contraction regimes; inside one scheduler cfg is fixed
        # and the key degenerates to (bucket, imbalance-bin)
        return (bucket, self._imb_bin(mass_a, mass_b),
                float(cfg.reg), float(cfg.reg_m))

    def _cfg_key(self, cfg):
        # the mid-level fallback: the analytic bias is chiefly a
        # function of the contraction regime (reg, reg_m), much less of
        # bucket/imbalance — a cold fine cell borrows its regime's bias
        # before falling back to the regime-mixed global
        return (float(cfg.reg), float(cfg.reg_m))

    # -- online update ---------------------------------------------------
    def observe(self, cfg, actual_iters: float, *, bucket=None,
                mass_a: float = 1.0, mass_b: float = 1.0) -> None:
        base = analytic_iters(cfg, mass_a, mass_b)
        if base <= 0 or actual_iters <= 0:
            return
        r = math.log(actual_iters / base)
        for cell in (self._cells.setdefault(
                self._key(cfg, bucket, mass_a, mass_b), _Cell()),
                self._cells.setdefault(self._cfg_key(cfg), _Cell()),
                self._global):
            if cell.count == 0:
                cell.log_ratio = r
            else:
                cell.log_ratio += self.alpha * (r - cell.log_ratio)
            cell.count += 1

    # -- prediction ------------------------------------------------------
    def predict(self, cfg, *, bucket=None, mass_a: float = 1.0,
                mass_b: float = 1.0) -> float:
        base = analytic_iters(cfg, mass_a, mass_b)
        cell = self._cells.get(self._key(cfg, bucket, mass_a, mass_b))
        if cell is None or cell.count == 0:
            cell = self._cells.get(self._cfg_key(cfg))
        if cell is None or cell.count == 0:
            cell = self._global
        if cell.count == 0:
            return base
        return float(min(max(base * math.exp(cell.log_ratio), 1.0),
                         cfg.num_iters))

    def snapshot(self) -> dict:
        """Cell table for ``stats()`` / debugging."""
        out = {"global": (self._global.log_ratio, self._global.count)}
        for k, c in self._cells.items():
            out[str(k)] = (c.log_ratio, c.count)
        return out
