"""POT-style baseline UOT: four separate passes over the coupling per iter.

This is the paper's Figure 1 / Section 2.1 baseline, written deliberately as
the same four full-matrix passes Numpy performs:

    pass 1: colsum = A.sum(0)                  (read MN)
    pass 2: A *= (CPD/colsum)**fi  [broadcast] (read MN + write MN)
    pass 3: rowsum = A.sum(1)                  (read MN)
    pass 4: A *= (RPD/rowsum)**fi  [broadcast] (read MN + write MN)

Memory traffic Q = 6*M*N elements per iteration — the quantity MAP-UOT
reduces to 2*M*N. On TPU the XLA fusion engine may merge some of these
passes; the Pallas kernels in ``repro.kernels`` make the schedule explicit.
Iterates are bit-comparable with ``sinkhorn_fused``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors


def _one_iteration(A, a, b, fi):
    # Column rescale first, then row rescale — the order used by MAP-UOT
    # Algorithm 1; the paper notes the order does not matter in practice.
    colsum = A.sum(axis=0)
    A = A * rescale_factors(b, colsum, fi)[None, :]
    rowsum = A.sum(axis=1)
    A = A * rescale_factors(a, rowsum, fi)[:, None]
    return A


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_baseline(A0: jax.Array, a: jax.Array, b: jax.Array,
                          cfg: UOTConfig):
    """Run the 4-pass baseline for ``cfg.num_iters`` (or until ``cfg.tol``).

    Args:
      A0: initial coupling (the Gibbs kernel), shape (M, N).
      a: row marginal RPD, shape (M,).
      b: column marginal CPD, shape (N,).
      cfg: solver configuration.

    Returns:
      (A, stats) where stats = {"iters": int32, "err": f32} — err is the
      final max |rowfactor - 1| drift.
    """
    fi = cfg.fi
    A0 = A0.astype(cfg.dtype)
    prev0 = jnp.ones_like(a)

    def body(carry):
        A, prev_rf, it, _ = carry
        colsum = A.sum(axis=0)
        A = A * rescale_factors(b, colsum, fi)[None, :]
        rowsum = A.sum(axis=1)
        rf = rescale_factors(a, rowsum, fi)
        A = A * rf[:, None]
        # Stationarity of the row factor: under unequal masses the matrix
        # form converges to a coupling where factors are constant (reciprocal
        # between row/col step) but != 1, so |rf - 1| never vanishes; the
        # iterate-convergence signal is |rf_t - rf_{t-1}| -> 0.
        err = jnp.max(jnp.abs(rf - prev_rf))
        return A, rf, it + 1, err

    if cfg.tol is None:
        def fori_body(_, carry):
            return body(carry)
        A, _, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, fori_body,
            (A0, prev0, jnp.int32(0), jnp.float32(jnp.inf)))
    else:
        def cond(carry):
            _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        A, _, iters, err = jax.lax.while_loop(
            cond, body, (A0, prev0, jnp.int32(0), jnp.float32(jnp.inf)))

    return A, {"iters": iters, "err": err}
