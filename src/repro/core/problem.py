"""Entropic unbalanced optimal transport problem definition.

The entropic UOT problem between histograms ``a`` (len M) and ``b`` (len N)
with ground cost ``C`` is

    min_P  <C, P> + reg * KL(P | a b^T) + reg_m * (KL(P 1 | a) + KL(P^T 1 | b))

solved by Sinkhorn-style scaling of the Gibbs kernel K = exp(-C / reg) with
relaxation exponent ``fi = reg_m / (reg_m + reg)`` (fi -> 1 recovers balanced
Sinkhorn-Knopp matrix scaling).

The paper (MAP-UOT / COFFEE / POT demo in its Figure 1) iterates directly on
the coupling matrix:

    A <- A * ((CPD / colsum(A)) ** fi)[None, :]       (column rescale)
    A <- A * ((RPD / rowsum(A)) ** fi)[:, None]       (row rescale)

All solvers in this package share this contract so they can be compared
element-wise. The u/v-potential form (``sinkhorn_uv``) matches POT's
``sinkhorn_knopp_unbalanced`` semantics and is kept separate (see DESIGN.md
on the damping difference between the two forms for fi < 1).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UOTConfig:
    """Configuration for an entropic UOT solve.

    Attributes:
      reg: entropic regularization epsilon.
      reg_m: marginal KL relaxation strength rho. ``float("inf")`` gives
        balanced Sinkhorn (fi == 1).
      num_iters: fixed iteration count (one iteration = one column + one row
        rescale). Used by benchmark/fixed-budget paths.
      tol: optional early-exit tolerance on the rescaling-factor drift
        ``max(|alpha - 1|)``; enables a ``lax.while_loop`` path.
      dtype: storage dtype for the coupling matrix (accumulation is fp32).
      translation_invariant: apply the optimal dual translation after each
        iteration of the *potential-form* solvers (``sinkhorn_uv``,
        ``log_domain``) — Séjourné et al., arXiv:2201.00730. The classical
        UOT update shuttles the mass imbalance between the marginals and
        contracts slowly for large ``reg_m/reg``; translating ``(f, g)`` by
        the closed-form optimal constant each iteration removes that mode
        and cuts the iteration count dramatically on unbalanced problems.
        A no-op for balanced problems (``reg_m=inf``: translation is the
        exact gauge freedom of P) and for the matrix-form solvers, whose
        iteration depends on the coupling alone — A = diag(u) K diag(v) is
        invariant under the translation ``u *= k, v /= k``, so their
        trajectory already cannot be improved this way.
    """

    reg: float = 0.05
    reg_m: float = 1.0
    num_iters: int = 100
    tol: float | None = None
    dtype: jnp.dtype = jnp.float32
    translation_invariant: bool = False

    @property
    def fi(self) -> float:
        if self.reg_m == float("inf"):
            return 1.0
        return self.reg_m / (self.reg_m + self.reg)


def gibbs_kernel(C: jax.Array, reg: float, dtype=jnp.float32) -> jax.Array:
    """K = exp(-C / reg), the initial coupling for scaling-form solvers."""
    return jnp.exp(-C / reg).astype(dtype)


@dataclasses.dataclass(frozen=True)
class UOTProblem:
    """A UOT instance: the marginals plus where their ground cost comes
    from — either an explicit dense ``C`` or a ``repro.geometry.Geometry``
    (exactly one of the two).

    The cost source is evaluated *lazily* by the consumers: the
    potential-form solvers (``sinkhorn_uv``, ``log_domain``) accept the
    problem's geometry directly and apply the kernel / logsumexp through
    it (never forming ``M*N`` for grid geometries, row-chunked for point
    clouds), and the kernel stack (``ops.solve_fused*``) computes implicit
    geometries' Gibbs tiles on-chip. ``initial_coupling`` materializes
    ``K = exp(-C / reg)`` for the matrix-scaling solvers that iterate on a
    dense coupling by construction.

    A registered pytree, so problems pass through jit boundaries whole.
    """

    a: jax.Array
    b: jax.Array
    geometry: "object | None" = None    # repro.geometry.Geometry
    C: jax.Array | None = None

    def __post_init__(self):
        if (self.geometry is None) == (self.C is None):
            raise ValueError("UOTProblem needs exactly one of geometry / C")

    @classmethod
    def from_cost(cls, C, a, b) -> "UOTProblem":
        return cls(a=jnp.asarray(a), b=jnp.asarray(b), C=jnp.asarray(C))

    @classmethod
    def from_points(cls, x, y, a, b, *, scale: float = 1.0) -> "UOTProblem":
        from repro.geometry import PointCloudGeometry
        return cls(a=jnp.asarray(a), b=jnp.asarray(b),
                   geometry=PointCloudGeometry.from_points(x, y,
                                                           scale=scale))

    @classmethod
    def from_grid(cls, factors, a, b) -> "UOTProblem":
        from repro.geometry import GridGeometry
        return cls(a=jnp.asarray(a), b=jnp.asarray(b),
                   geometry=GridGeometry(tuple(factors)))

    @property
    def shape(self) -> tuple[int, int]:
        if self.geometry is not None:
            return self.geometry.shape
        return tuple(self.C.shape[-2:])

    def geom(self):
        """The problem's cost source as a ``Geometry`` (dense C wrapped)."""
        if self.geometry is not None:
            return self.geometry
        from repro.geometry import DenseGeometry
        return DenseGeometry(self.C)

    def cost_matrix(self) -> jax.Array:
        return self.C if self.C is not None else self.geometry.cost()

    def initial_coupling(self, reg: float, dtype=jnp.float32) -> jax.Array:
        """Materialized ``K = exp(-C / reg)`` for matrix-scaling solvers."""
        return self.geom().kernel(reg).astype(dtype)


jax.tree_util.register_dataclass(
    UOTProblem, data_fields=["a", "b", "geometry", "C"], meta_fields=[])


def uot_cost(P: jax.Array, C: jax.Array, a: jax.Array, b: jax.Array,
             reg: float, reg_m: float) -> jax.Array:
    """Primal entropic UOT objective value (for convergence diagnostics)."""
    eps = 1e-38
    transport = jnp.sum(P * C)
    ab = a[:, None] * b[None, :]
    kl_joint = jnp.sum(P * (jnp.log(P + eps) - jnp.log(ab + eps)) - P + ab)
    row, col = P.sum(1), P.sum(0)
    kl_row = jnp.sum(row * (jnp.log(row + eps) - jnp.log(a + eps)) - row + a)
    kl_col = jnp.sum(col * (jnp.log(col + eps) - jnp.log(b + eps)) - col + b)
    return transport + reg * kl_joint + reg_m * (kl_row + kl_col)


@partial(jax.jit, static_argnames=("fi",))
def rescale_factors(target: jax.Array, sums: jax.Array, fi: float) -> jax.Array:
    """(target / sums) ** fi with safe division (0/0 -> 1, i.e. no-op)."""
    safe = jnp.where(sums > 0, sums, 1.0)
    ratio = jnp.where(sums > 0, target / safe, 1.0)
    if fi == 1.0:
        return ratio
    return jnp.power(ratio, fi)
