"""Log-domain stabilized UOT solver (potentials space).

For small ``reg`` the Gibbs kernel underflows in fp32; the standard fix is
to iterate on dual potentials f, g:

    f = fi * eps * (log a - logsumexp((g - C) / eps, axis=1))
    g = fi * eps * (log b - logsumexp((f - C^T) / eps ... , axis=0))

with eps = reg and fi = reg_m / (reg_m + reg). Coupling:
P = exp((f[:,None] + g[None,:] - C) / eps).

This path exists for numerical robustness (serving, tiny-eps analysis); the
memory-optimized paths operate in linear space like the paper.

Precision: potentials and reductions are computed in
``promote_types(cfg.dtype, float32)`` — sub-fp32 storage configs keep the
repo-wide fp32 accumulation floor, while fp64 configs (with x64 enabled)
are no longer silently truncated to fp32. The log floor on the marginals is
the compute dtype's smallest *normal* (``finfo.tiny``), not a hardcoded
constant: the old ``1e-38`` was subnormal even in fp32 and underflows to
exactly 0 when a caller hands fp16 marginals, turning ``log`` into ``-inf``
and the potentials into NaN fodder. Only the final coupling is cast to
``cfg.dtype``.

With ``cfg.translation_invariant`` the optimal dual translation
(Séjourné et al., arXiv:2201.00730) is applied after each iteration:
``(f, g) <- (f + t, g - t)`` with
``t = (rho/2) * log(<a, e^{-f/rho}> / <b, e^{-g/rho}>)`` — the closed-form
mass rebalancing that removes UOT Sinkhorn's slow mode on unbalanced
problems (no-op when ``reg_m=inf``, where translation is the exact gauge
freedom of P).

``C`` may also be a ``repro.geometry.Geometry``, in which case the
logsumexp reductions are evaluated *through the geometry*
(``apply_lse`` / ``apply_lse_T``): a ``GridGeometry`` runs them as staged
per-axis logsumexps over its small factors — the solve never forms an
``M*N`` array — and a ``PointCloudGeometry`` computes row-chunked cost
tiles on the fly. Pass ``materialize=False`` to skip the final dense
coupling and get ``P=None`` (the potentials are returned either way), the
memory-honest mode for implicit geometries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.core.sinkhorn_uv import translation_noise_floor
from repro.geometry import Geometry


@partial(jax.jit, static_argnames=("cfg", "materialize"))
def sinkhorn_uot_log(C, a: jax.Array, b: jax.Array, cfg, *,
                     materialize: bool = True):
    """Log-domain UOT. Returns (P, (f, g), stats).

    ``C``: dense cost matrix or a ``Geometry`` (evaluated lazily through
    its staged/chunked logsumexps). ``materialize=False`` returns
    ``P=None`` — with a ``GridGeometry`` the whole solve then never
    touches an ``M*N`` operand.
    """
    eps = cfg.reg
    fi = cfg.fi
    rho = cfg.reg_m
    ti = cfg.translation_invariant and rho != float("inf")
    geom = isinstance(C, Geometry)
    M, N = C.shape
    ptype = jnp.promote_types(jnp.dtype(cfg.dtype), jnp.float32)
    tiny = float(jnp.finfo(ptype).tiny)
    if not geom:
        C = C.astype(ptype)
    loga = jnp.log(jnp.maximum(a.astype(ptype), tiny))
    logb = jnp.log(jnp.maximum(b.astype(ptype), tiny))
    f0 = jnp.zeros((M,), ptype)
    g0 = jnp.zeros((N,), ptype)

    def lse_rows(g):
        return (C.apply_lse(g, eps) if geom
                else logsumexp((g[None, :] - C) / eps, axis=1))

    def lse_cols(f):
        return (C.apply_lse_T(f, eps) if geom
                else logsumexp((f[:, None] - C) / eps, axis=0))

    def body(carry):
        f, g, it, _ = carry
        f_new = fi * eps * (loga - lse_rows(g))
        g_new = fi * eps * (logb - lse_cols(f_new))
        if ti:
            t = 0.5 * rho * (logsumexp(loga - f_new / rho)
                             - logsumexp(logb - g_new / rho))
            # the 0.5*rho amplification turns logsumexp rounding into
            # stationarity-stalling jitter near the fixed point
            t = jnp.where(jnp.abs(t) > translation_noise_floor(0.5 * rho,
                                                               ptype),
                          t, 0.0)
            f_new, g_new = f_new + t, g_new - t
        err = jnp.max(jnp.abs(f_new - f))
        return f_new, g_new, it + 1, err

    err0 = jnp.asarray(jnp.inf, ptype)
    if cfg.tol is None:
        f, g, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, lambda _, c: body(c),
            (f0, g0, jnp.int32(0), err0))
    else:
        def cond(carry):
            _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        f, g, iters, err = jax.lax.while_loop(
            cond, body, (f0, g0, jnp.int32(0), err0))

    if not materialize:
        return None, (f, g), {"iters": iters, "err": err}
    Cd = C.cost().astype(ptype) if geom else C
    P = jnp.exp((f[:, None] + g[None, :] - Cd) / eps).astype(cfg.dtype)
    return P, (f, g), {"iters": iters, "err": err}
