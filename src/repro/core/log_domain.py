"""Log-domain stabilized UOT solver (potentials space).

For small ``reg`` the Gibbs kernel underflows in fp32; the standard fix is
to iterate on dual potentials f, g:

    f = fi * eps * (log a - logsumexp((g - C) / eps, axis=1))
    g = fi * eps * (log b - logsumexp((f - C^T) / eps ... , axis=0))

with eps = reg and fi = reg_m / (reg_m + reg). Coupling:
P = exp((f[:,None] + g[None,:] - C) / eps).

This path exists for numerical robustness (serving, tiny-eps analysis); the
memory-optimized paths operate in linear space like the paper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_log(C: jax.Array, a: jax.Array, b: jax.Array, cfg):
    """Log-domain UOT. Returns (P, (f, g), stats)."""
    eps = cfg.reg
    fi = cfg.fi
    M, N = C.shape
    loga = jnp.log(jnp.maximum(a, 1e-38))
    logb = jnp.log(jnp.maximum(b, 1e-38))
    f0 = jnp.zeros((M,), jnp.float32)
    g0 = jnp.zeros((N,), jnp.float32)

    def body(carry):
        f, g, it, _ = carry
        f_new = fi * eps * (loga - logsumexp((g[None, :] - C) / eps, axis=1))
        g_new = fi * eps * (logb - logsumexp((f_new[:, None] - C) / eps, axis=0))
        err = jnp.max(jnp.abs(f_new - f))
        return f_new, g_new, it + 1, err

    if cfg.tol is None:
        f, g, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, lambda _, c: body(c),
            (f0, g0, jnp.int32(0), jnp.float32(jnp.inf)))
    else:
        def cond(carry):
            _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        f, g, iters, err = jax.lax.while_loop(
            cond, body, (f0, g0, jnp.int32(0), jnp.float32(jnp.inf)))

    P = jnp.exp((f[:, None] + g[None, :] - C) / eps).astype(cfg.dtype)
    return P, (f, g), {"iters": iters, "err": err}
