"""End-to-end UOT applications (paper Section 5.5: domain adaptation).

``color_transfer`` reproduces the paper's end-to-end benchmark: normalize the
color palette of a source image toward a target image by solving UOT between
the two color clouds and applying the barycentric map. Images are synthetic
here (no dataset in the container) but the compute path is the real one and
its runtime is dominated by the UOT solve, matching the paper's Figure 17.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, gibbs_kernel
from repro.core.sinkhorn_fused import sinkhorn_uot_fused
from repro.core.sinkhorn_baseline import sinkhorn_uot_baseline


def pairwise_sq_dists(X: jax.Array, Y: jax.Array) -> jax.Array:
    """||x_i - y_j||^2 cost matrix, shape (M, N)."""
    x2 = jnp.sum(X * X, axis=1)[:, None]
    y2 = jnp.sum(Y * Y, axis=1)[None, :]
    return jnp.maximum(x2 + y2 - 2.0 * (X @ Y.T), 0.0)


def color_transfer(src_colors: jax.Array, dst_colors: jax.Array,
                   cfg: UOTConfig | None = None, fused: bool = True):
    """UOT color transfer between two (n, 3) color clouds.

    Returns (mapped_src_colors, coupling). Uniform marginals; cost is
    squared Euclidean in RGB; the barycentric projection maps each source
    color to the coupling-weighted mean of target colors.
    """
    cfg = cfg or UOTConfig(reg=0.05, reg_m=10.0, num_iters=200)
    M, N = src_colors.shape[0], dst_colors.shape[0]
    a = jnp.full((M,), 1.0 / M)
    b = jnp.full((N,), 1.0 / N)
    C = pairwise_sq_dists(src_colors, dst_colors)
    C = C / jnp.max(C)
    A0 = gibbs_kernel(C, cfg.reg)
    # Scale so initial mass matches marginal mass (standard POT practice).
    A0 = A0 * (a[:, None] * b[None, :])
    solver = sinkhorn_uot_fused if fused else sinkhorn_uot_baseline
    P, _ = solver(A0, a, b, cfg)
    rowsum = jnp.maximum(P.sum(axis=1, keepdims=True), 1e-30)
    mapped = (P @ dst_colors) / rowsum
    return mapped, P


def color_transfer_geometry(src_colors, dst_colors,
                            cfg: UOTConfig | None = None, *,
                            impl: str | None = None,
                            interpret: bool | None = None):
    """Color transfer on the point-cloud geometry path.

    Same *application* as ``color_transfer`` — barycentric palette
    mapping — but the RGB clouds themselves are the cost source: a
    ``PointCloudGeometry`` hands the solver coordinates, and the kernel
    stack computes squared-Euclidean Gibbs tiles on-chip — no ``M*N``
    cost matrix is ever built, and a serving request ships
    ``(M + N) * (3 + 1)`` floats (coordinates + squared norms) instead of
    ``M * N``.

    NOT the same *entropic problem* as ``color_transfer``, on two counts:
    cost normalization uses the static unit-cube bound
    (``||x - y||^2 <= 3`` for RGB in [0, 1]^3, so ``scale=3``) instead of
    the data-dependent ``max(C)`` — a bound you can know without forming
    C — and the initial coupling is the plain Gibbs kernel ``K``, without
    ``color_transfer``'s POT-style ``* (a b^T)`` mass prescaling (the
    geometry contract is ``A0 = K``; for ``fi < 1`` the matrix-form fixed
    point depends on that init). Expect qualitatively equivalent mapped
    colors, not identical couplings.

    Returns (mapped_src_colors, coupling).
    """
    from repro.geometry import PointCloudGeometry
    from repro.kernels import ops

    cfg = cfg or UOTConfig(reg=0.05, reg_m=10.0, num_iters=200)
    M, N = src_colors.shape[0], dst_colors.shape[0]
    a = jnp.full((M,), 1.0 / M)
    b = jnp.full((N,), 1.0 / N)
    geometry = PointCloudGeometry.from_points(src_colors, dst_colors,
                                              scale=3.0)
    P, _ = ops.solve_fused(None, a, b, cfg, geometry=geometry, impl=impl,
                           interpret=interpret)
    rowsum = jnp.maximum(P.sum(axis=1, keepdims=True), 1e-30)
    mapped = (P @ jnp.asarray(dst_colors)) / rowsum
    return mapped, P


def wasserstein_distance(X: jax.Array, Y: jax.Array, a=None, b=None,
                         cfg: UOTConfig | None = None):
    """Entropic UOT 'distance' <C, P*> between point clouds (eval metric)."""
    cfg = cfg or UOTConfig(reg=0.05, reg_m=1.0, num_iters=200)
    M, N = X.shape[0], Y.shape[0]
    a = jnp.full((M,), 1.0 / M) if a is None else a
    b = jnp.full((N,), 1.0 / N) if b is None else b
    C = pairwise_sq_dists(X, Y)
    scale = jnp.max(C)
    A0 = gibbs_kernel(C / scale, cfg.reg) * (a[:, None] * b[None, :])
    P, _ = sinkhorn_uot_fused(A0, a, b, cfg)
    return jnp.sum(P * C), P
