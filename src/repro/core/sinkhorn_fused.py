"""MAP-UOT fused iteration (paper Algorithm 1) — reference jnp semantics.

The interweaving trick: the column sums needed for iteration t+1's column
rescale are accumulated *while* iteration t's row rescale streams through the
matrix, so each iteration touches A exactly once (read + write = 2*M*N
elements, the information-theoretic minimum, vs 6*M*N for the baseline).

This module is the pure-jnp *semantic* reference, structured exactly like
Algorithm 1 (column-sum carry across iterations). XLA on CPU/TPU will fuse
some of it on its own; the explicit single-pass memory schedule lives in
``repro.kernels.uot_fused`` (Pallas). Both must produce iterates equal to
``sinkhorn_uot_baseline`` up to float addition order.

Algorithm 1 structure per iteration (column rescale first, then row):
    factor_col = (CPD / carried_colsum) ** fi        # O(N)
    per row i:                                        # one pass over A
        A[i,:] *= factor_col                          #   computation I
        s = sum_j A[i,j]                              #   computation II
        factor_row = (RPD[i] / s) ** fi               # O(1)
        A[i,:] *= factor_row                          #   computation III
        carried_colsum += A[i,:]                      #   computation IV
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.problem import UOTConfig, rescale_factors


def fused_iteration(A, colsum, a, b, fi):
    """One MAP-UOT iteration given carried column sums; returns (A', colsum').

    The jnp expression of the single-pass body: both rescales and both sum
    accumulations expressed on the full matrix (row order is the Pallas
    kernel's concern; the math is row-separable so this is exact).
    """
    factor_col = rescale_factors(b, colsum, fi)
    A = A * factor_col[None, :]              # computation I
    rowsum = A.sum(axis=1)                   # computation II
    factor_row = rescale_factors(a, rowsum, fi)
    A = A * factor_row[:, None]              # computation III
    new_colsum = A.sum(axis=0)               # computation IV
    return A, new_colsum, factor_row


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_fused(A0: jax.Array, a: jax.Array, b: jax.Array,
                       cfg: UOTConfig):
    """MAP-UOT solver: Algorithm 1 for ``cfg.num_iters`` (or ``cfg.tol``).

    Returns (A, stats) — iterates match ``sinkhorn_uot_baseline`` exactly.
    """
    fi = cfg.fi
    A0 = A0.astype(cfg.dtype)
    colsum0 = A0.sum(axis=0)  # "preprocessed" init of Factor_col (Alg. 1)
    prev0 = jnp.ones_like(a)

    def body(carry):
        A, colsum, prev_rf, it, _ = carry
        A, colsum, factor_row = fused_iteration(A, colsum, a, b, fi)
        # Factor stationarity (see sinkhorn_baseline for why not |rf - 1|).
        err = jnp.max(jnp.abs(factor_row - prev_rf))
        return A, colsum, factor_row, it + 1, err

    if cfg.tol is None:
        A, colsum, _, iters, err = jax.lax.fori_loop(
            0, cfg.num_iters, lambda _, c: body(c),
            (A0, colsum0, prev0, jnp.int32(0), jnp.float32(jnp.inf)))
    else:
        def cond(carry):
            _, _, _, it, err = carry
            return jnp.logical_and(it < cfg.num_iters, err > cfg.tol)
        A, colsum, _, iters, err = jax.lax.while_loop(
            cond, body, (A0, colsum0, prev0, jnp.int32(0), jnp.float32(jnp.inf)))

    return A, {"iters": iters, "err": err, "colsum": colsum}


@partial(jax.jit, static_argnames=("cfg",))
def sinkhorn_uot_fused_batched(A0: jax.Array, a: jax.Array, b: jax.Array,
                               cfg: UOTConfig):
    """Batched Algorithm 1 — pure-jnp semantic reference for the stacked path.

    A0: (B, M, N); a: (B, M); b: (B, N). Simply ``vmap`` of the single-problem
    solver: this is the *semantic* target the batched Pallas kernel
    (``repro.kernels.uot_batched``) must match; the explicit one-launch
    (batch, row_blocks) memory schedule lives in the kernel. Note the
    ``cfg.tol`` early-exit under vmap only stops once EVERY problem in the
    stack has converged (converged problems keep iterating harmlessly —
    their factors are ~1).
    """
    return jax.vmap(lambda A_, a_, b_: sinkhorn_uot_fused(A_, a_, b_, cfg)
                    )(A0, a, b)
