from repro.parallel.sharding import (
    state_specs, param_specs, batch_specs, cache_specs, activation_ctx)

__all__ = ["state_specs", "param_specs", "batch_specs", "cache_specs",
           "activation_ctx"]
