"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Stage s (device s on the ``pipe`` axis) owns layer slice s of the stacked
params. Microbatches flow left-to-right: on tick t, stage s processes
microbatch (t - s) if it is in range, then ppermutes its activation to
stage s+1. Total ticks = n_micro + P - 1; bubble fraction (P-1)/(T).

This is the optional PP dimension (off by default — the production mesh
uses DP x TP; PP becomes attractive at >2 pods when cross-DCI FSDP gathers
dominate). Correctness is asserted against sequential layer application in
tests/test_pipeline.py on forced host devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, axis: str, stage_fn, stage_params, x_mb):
    """Run a P-stage pipeline.

    Args:
      mesh: mesh containing ``axis`` with P devices.
      axis: pipeline axis name.
      stage_fn: (params_for_one_stage, x) -> y, applied by every stage.
      stage_params: pytree whose leaves have leading dim P (one slice per
        stage) — sharded over ``axis``.
      x_mb: (n_micro, mb, ...) microbatched input (replicated).

    Returns:
      (n_micro, mb, ...) outputs (gathered from the last stage).
    """
    p_size = mesh.shape[axis]
    n_micro = x_mb.shape[0]
    ticks = n_micro + p_size - 1

    def per_stage(params, x_mb):
        # params: leaves (1, ...) — this stage's slice
        params = jax.tree.map(lambda v: v[0], params)
        s = jax.lax.axis_index(axis)
        mb_shape = x_mb.shape[1:]

        def tick(carry, t):
            left_in, ys = carry
            # stage 0 ingests microbatch t; others take the permuted input
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x0 = jax.lax.dynamic_index_in_dim(x_mb, mb_idx, 0, False)
            x_in = jnp.where(s == 0, x0, left_in)
            active = jnp.logical_and(t - s >= 0, t - s < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # emit on the last stage at position t - (P-1)
            out_idx = jnp.clip(t - (p_size - 1), 0, n_micro - 1)
            emit = jnp.logical_and(s == p_size - 1, active)
            cur = jax.lax.dynamic_index_in_dim(ys, out_idx, 0, False)
            ys = jax.lax.dynamic_update_index_in_dim(
                ys, jnp.where(emit, y, cur), out_idx, 0)
            # shift activations one stage right
            right = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(p_size - 1)])
            return (right, ys), None

        ys0 = jnp.zeros((n_micro,) + mb_shape, x_mb.dtype)
        left0 = jnp.zeros(mb_shape, x_mb.dtype)
        (_, ys), _ = jax.lax.scan(tick, (left0, ys0), jnp.arange(ticks))
        # only the last stage holds real outputs; psum broadcasts them
        # (all other stages contribute zeros)
        return jax.lax.psum(ys, axis)

    n_axes = x_mb.ndim
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params,
                               is_leaf=lambda x: hasattr(x, "shape")),
                  P(*([None] * n_axes))),
        out_specs=P(*([None] * n_axes)),
        check_rep=False)
    return fn(stage_params, x_mb)
