"""Sharding rules: DP x FSDP x TP (+ EP for MoE, SP for long context).

Axis roles (mesh axes named in launch.mesh):
  * ``data``  — batch data parallelism AND FSDP (ZeRO-3-style parameter /
    optimizer-state sharding: per-layer all-gather inside the scan, grads
    reduce-scattered back — the standard scan+FSDP pattern).
  * ``model`` — tensor parallelism (Megatron col/row pairs), expert
    parallelism for MoE (experts over ``model``), and KV-cache / sequence
    sharding for serving shapes.
  * ``pod``   — outermost data parallelism across pods (gradient all-reduce
    crosses the DCI; FSDP gathers stay INTRA-pod by construction).

Rules are name+shape based: a tensor is sharded on an axis only when the
dim divides the axis size — so the same rule table serves every arch (e.g.
smollm's 15 heads simply skip head sharding while its d_ff shards).
"""
from __future__ import annotations

import contextlib
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex on the flattened path, TP dim, FSDP dim) — dims index the
# *effective* (unstacked) shape; negative = none.
_RULES: list[tuple[str, int, int]] = [
    (r"attn/w_[qkv]$", 1, 0),
    (r"attn/w_o$", 0, 1),
    (r"mlp/w_(gate|up)$", 1, 0),
    (r"mlp/w_down$", 0, 1),
    (r"moe/w_(gate|up|down)$", 0, 1),     # dim0 = experts -> EP
    (r"moe/w_router$", -1, -1),
    (r"embed/table$", 1, 0),              # d over TP, vocab over FSDP
    (r"head/w_out$", 1, 0),               # vocab-parallel head
    (r"mlstm/w_[qkv]$", 1, 0),
    (r"mlstm/w_gate$", 1, 0),
    (r"mlstm/w_o$", 0, 1),
    (r"mlstm/w_[fi]$", -1, 0),
    (r"slstm/w_[zifo]$", 1, 0),
    (r"slstm/r_[zifo]$", -1, -1),
    (r"slstm/w_o$", 0, 1),
    (r"mamba/w_(z|xbc)$", 1, 0),
    (r"mamba/w_o$", 0, 1),
    (r"mamba/conv_k$", 1, -1),
    (r"mamba/w_dt$", -1, 0),
]

# leading stacked-layer dims by top-level param group (never sharded)
_STACK_DIMS = {"layers": 1, "mlstm": 2, "slstm": 1, "mamba": 2,
               "mamba_tail": 1, "shared_attn": 0}


def _path_str(path) -> str:
    return "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)


def _leaf_spec(path, leaf, mesh_axes: dict[str, int], cfg,
               fsdp: bool) -> P:
    """mesh_axes: {"data": 16, "model": 16, ...}."""
    pstr = _path_str(path)
    shape = leaf.shape
    top = pstr.split("/")[0]
    nstack = _STACK_DIMS.get(top, 0)
    if top == "head" and cfg is not None and cfg.family == "audio":
        nstack = 1  # (K, d, V) codebook-stacked head
    eff = shape[nstack:]
    spec: list[Any] = [None] * len(shape)

    tp_size = mesh_axes.get("model", 1)
    fsdp_size = mesh_axes.get("data", 1)

    for pat, tp_dim, fsdp_dim in _RULES:
        if re.search(pat, pstr):
            if tp_dim >= 0 and tp_dim < len(eff) and \
                    eff[tp_dim] % tp_size == 0 and tp_size > 1:
                spec[nstack + tp_dim] = "model"
            if fsdp and fsdp_dim >= 0 and fsdp_dim < len(eff) and \
                    eff[fsdp_dim] % fsdp_size == 0 and fsdp_size > 1 and \
                    int(np.prod(eff)) >= (1 << 20) and \
                    spec[nstack + fsdp_dim] is None:
                spec[nstack + fsdp_dim] = "data"
            break
    return P(*spec)


def param_specs(cfg, params_shapes, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec pytree for a param(-shaped) tree.

    params_shapes: pytree of ShapeDtypeStruct (jax.eval_shape of init).
    """
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mesh_axes, cfg, fsdp),
        params_shapes)


def state_specs(cfg, state_shapes, mesh: Mesh, fsdp: bool = True):
    """Specs for the full TrainState {params, opt{m,v,count}, step}."""
    pspecs = param_specs(cfg, state_shapes["params"], mesh, fsdp)
    return {"params": pspecs,
            "opt": {"m": pspecs, "v": jax.tree.map(lambda s: s, pspecs),
                    "count": P()},
            "step": P()}


def _dp_axes(mesh: Mesh):
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def batch_specs(cfg, batch_shapes, mesh: Mesh):
    """Shard every batch input's leading (batch) dim over the DP axes."""
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(path, leaf):
        b = leaf.shape[0]
        lead = dp if b % dp_size == 0 else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_specs(cfg, cache_shapes, mesh: Mesh):
    """Decode-cache sharding.

    KV caches (layer-stacked: (L, B, S, kvH, hd)): batch over DP when
    divisible; kv-heads over ``model`` when divisible, else the cache
    SEQUENCE dim over ``model`` (MQA long-context: flash-decoding-style
    sharded softmax, XLA partitions the logsumexp).
    SSM states ((..., B, H, ...)): heads over ``model`` when divisible.
    """
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tp = mesh.shape.get("model", 1)

    def spec(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        s: list[Any] = [None] * len(shape)
        if re.search(r"(^|/)(k|v)$", pstr) and len(shape) == 5:
            # (L, B, S, kvH, hd)
            if shape[1] % dp_size == 0:
                s[1] = dp
            if shape[3] % tp == 0 and tp > 1:
                s[3] = "model"
            elif shape[2] % tp == 0 and tp > 1:
                s[2] = "model"
            return P(*s)
        # SSM / recurrent states: (..., B, H, ...) — find the batch dim by
        # matching known layouts: mlstm (cyc,m,B,H,hd,hd)/(cyc,m,B,H,hd);
        # slstm (cyc,B,H,hd); mamba (cyc,m,B,H,ds,hd); conv (cyc,m,B,W,C).
        for i, d in enumerate(shape):
            if d % dp_size == 0 and d > 1 and dp_size > 1:
                s[i] = dp
                # try heads on the next dim
                if i + 1 < len(shape) and shape[i + 1] % tp == 0 and tp > 1:
                    s[i + 1] = "model"
                return P(*s)
        # batch may be 1 (long_500k): shard a head-like dim over model only
        for i, d in enumerate(shape[2:], start=2):
            if d % tp == 0 and tp > 1 and d >= tp:
                s[i] = "model"
                return P(*s)
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


# ---------------------------------------------------------------------------
# Activation-sharding context (used by the model via constrain())
# ---------------------------------------------------------------------------

_ACT: dict[str, P] | None = None


@contextlib.contextmanager
def activation_ctx(specs: dict[str, P] | None):
    """Install activation PartitionSpecs for model-internal constraints.

    Keys: "carry" — the (B, S, d) residual stream at block boundaries
    (the remat-saved tensor; e.g. P(("data",), "model", None) = Megatron-SP
    sequence sharding).
    """
    global _ACT
    prev = _ACT
    _ACT = specs
    try:
        yield
    finally:
        _ACT = prev


def constrain(x, name: str):
    if _ACT is not None and name in _ACT:
        return jax.lax.with_sharding_constraint(x, _ACT[name])
    return x
