"""Production mesh builders (functions — importing never touches jax device
state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    ``data`` doubles as the FSDP axis; ``pod`` is pure DP across the DCI
    (nothing in the sharding rules names the pod count — scaling to N pods
    is a shape change here only).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))
