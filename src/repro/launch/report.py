"""Generate EXPERIMENTS.md tables from results/dryrun*/ JSON records.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir results]
Prints markdown to stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib


def load(dirpath):
    recs = {}
    d = pathlib.Path(dirpath)
    if not d.exists():
        return recs
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        recs[r["cell"]] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def fmt_t(t):
    if t >= 1:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.1f}ms"
    return f"{t * 1e6:.0f}us"


def dryrun_table(scan_recs):
    lines = ["| cell | mesh | devices | status | compile (s) | "
             "state GiB/dev | collective ops |",
             "|---|---|---|---|---|---|---|"]
    for cell, r in sorted(scan_recs.items()):
        coll = r.get("roofline", {}).get("collectives", {}).get("counts", {})
        coll_s = " ".join(f"{k}:{v}" for k, v in sorted(coll.items())) or "-"
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['mesh']} | {r['devices']} "
            f"| {r['status']} | {r.get('compile_s', '-')} "
            f"| {fmt_bytes(r.get('state_bytes_per_dev'))} | {coll_s} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = ["| arch | shape | method | T_comp | T_mem | T_coll | "
             "bottleneck | useful-FLOPs ratio | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for cell, r in sorted(recs.items()):
        if r["status"] != "ok" or "roofline" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | ERROR: "
                         f"{r.get('error', '?')[:60]} | | | | | |")
            continue
        rr = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('method', 'unrolled')} "
            f"| {fmt_t(rr['t_comp_s'])} | {fmt_t(rr['t_mem_s'])} "
            f"| {fmt_t(rr['t_coll_s'])} | {rr['bottleneck']} "
            f"| {rr.get('useful_flops_ratio', 0):.3f} "
            f"| {rr.get('mfu_bound', 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results")
    args = ap.parse_args()
    base = pathlib.Path(args.dir)

    scan = load(base / "dryrun_scan")
    roof = load(base / "dryrun")

    print("## Dry-run (compile proof, scanned form)\n")
    print(dryrun_table(scan))
    print("\n## Roofline (single-pod, unrolled/extrapolated cost)\n")
    print(roofline_table(roof))


if __name__ == "__main__":
    main()
