import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init). Everything below is ordinary launch code.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. eval_shapes the train state / serving params / decode cache
     (ShapeDtypeStruct only — nothing is allocated);
  3. jit's the step with in/out shardings from repro.parallel.sharding,
     .lower(...).compile() — success proves the sharding config is coherent
     (no shape mismatches, no unsupported collectives, partitionable);
  4. records memory_analysis + cost_analysis + parsed collective bytes to
     results/dryrun/<cell>.json (incremental: done cells are skipped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi [--out results/dryrun] [--force]
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, shapes_for  # noqa: E402
from repro.data.pipeline import make_batch_specs  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.model import build_model, cast_floats  # noqa: E402
from repro.optim.adamw import OptConfig  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402
from repro.optim.adamw import adamw_init  # noqa: E402


def _named(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _analytic_bytes_per_dev(shapes, specs, mesh) -> int:
    """Sharded storage bytes per device for a (shapes, specs) pytree pair."""
    axis = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, spec):
        n = int(np.prod(s.shape)) if s.shape else 1
        denom = 1
        for p in spec:
            if p is None:
                continue
            for ax in (p if isinstance(p, tuple) else (p,)):
                denom *= axis[ax]
        return n * s.dtype.itemsize // max(denom, 1)

    return sum(jax.tree.leaves(jax.tree.map(
        one, shapes, specs, is_leaf=lambda x: isinstance(x, P))))


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             force: bool = False, act_spec: str = "default",
             scan_layers: bool = False, overrides: dict | None = None,
             serve_fsdp: bool = True, suffix: str = "") -> dict:
    cell_id = f"{arch}__{shape_name}__{mesh_kind}"
    if suffix:
        cell_id = f"{cell_id}__{suffix}"
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    # Unrolled layers by default: XLA's cost_analysis counts while-loop
    # bodies ONCE, so the scanned form undercounts FLOPs/bytes/collectives
    # by ~the layer count. Unrolling gives faithful roofline numbers (and is
    # a stricter compile test); --scan restores the compact form.
    cfg = dataclasses.replace(get_arch(arch), scan_layers=scan_layers,
                              **(overrides or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(mesh.devices.shape))
    model = build_model(cfg)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_kind, "devices": n_dev, "status": "error",
           "overrides": overrides or {}, "serve_fsdp": serve_fsdp}
    t0 = time.time()
    try:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch_shapes = make_batch_specs(cfg, shape)
        # serve paths may drop FSDP (weights replicated over the data axis,
        # TP-sharded only) — the serving-vs-training sharding hillclimb.
        pspecs = shd.param_specs(cfg, params_shapes, mesh, fsdp=serve_fsdp)
        bspecs = shd.batch_specs(cfg, batch_shapes, mesh)

        dp = ("pod", "data") if mesh_kind == "multi" else ("data",)
        act = None
        if act_spec == "sp":
            act = {"carry": P(dp, "model", None)}
        elif act_spec == "cp":
            # context-parallel attention: q-sequence over 'model'
            act = {"attn_q": P(dp, None, "model", None)}

        if shape.kind == "train":
            step = make_train_step(model, OptConfig())
            state_shapes = {
                "params": params_shapes,
                "opt": jax.eval_shape(adamw_init, params_shapes),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            sspecs = shd.state_specs(cfg, state_shapes, mesh)
            with mesh:
                with shd.activation_ctx(act):
                    lowered = jax.jit(
                        step,
                        in_shardings=(_named(mesh, sspecs),
                                      _named(mesh, bspecs)),
                        out_shardings=(_named(mesh, sspecs), None),
                    ).lower(state_shapes, batch_shapes)
                compiled = lowered.compile()
            state_bytes = _analytic_bytes_per_dev(state_shapes, sspecs, mesh)
        elif shape.kind == "prefill":
            serve_shapes = jax.eval_shape(
                lambda p: cast_floats(p, jnp.bfloat16), params_shapes)

            def prefill_fn(p, b):
                return model.prefill(p, b, cache_len=shape.seq_len)

            with mesh:
                with shd.activation_ctx(act):
                    lowered = jax.jit(
                        prefill_fn,
                        in_shardings=(_named(mesh, pspecs),
                                      _named(mesh, bspecs)),
                    ).lower(serve_shapes, batch_shapes)
                compiled = lowered.compile()
            state_bytes = _analytic_bytes_per_dev(serve_shapes, pspecs, mesh)
        else:  # decode
            serve_shapes = jax.eval_shape(
                lambda p: cast_floats(p, jnp.bfloat16), params_shapes)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspecs = shd.cache_specs(cfg, cache_shapes, mesh)

            def decode_fn(p, cache, toks, idx):
                return model.decode_step(p, cache, toks, idx)

            tok_shape = batch_shapes["tokens"]
            tok_spec = bspecs["tokens"]
            with mesh:
                lowered = jax.jit(
                    decode_fn,
                    in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                                  NamedSharding(mesh, tok_spec), None),
                    out_shardings=(None, _named(mesh, cspecs)),
                ).lower(serve_shapes, cache_shapes, tok_shape,
                        jax.ShapeDtypeStruct((), jnp.int32))
                compiled = lowered.compile()
            state_bytes = (_analytic_bytes_per_dev(serve_shapes, pspecs, mesh)
                           + _analytic_bytes_per_dev(cache_shapes, cspecs,
                                                     mesh))

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {k: int(getattr(mem, k)) for k in dir(mem)
                     if k.endswith("size_in_bytes")} if mem else {}
        except Exception:
            mem_d = {}
        hlo = compiled.as_text()
        rr = roofline.analyze(cost, hlo, cfg, shape, num_devices=n_dev)
        rec.update(status="ok",
                   compile_s=round(time.time() - t0, 1),
                   state_bytes_per_dev=int(state_bytes),
                   memory_analysis=mem_d,
                   roofline=rr,
                   act_spec=act_spec)
    except Exception as e:  # noqa: BLE001 — record, continue sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    print(f"[{status:5s}] {cell_id}  ({rec['compile_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--act-spec", default="default", choices=["default", "sp"])
    ap.add_argument("--scan", action="store_true",
                    help="scan-over-layers (fast compile, undercounted cost)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = args.mesh.split(",")

    n_ok = n_err = 0
    for arch in archs:
        cfg = get_arch(arch)
        cell_shapes = (shapes_for(cfg) if args.shape == "all"
                       else args.shape.split(","))
        for shape_name in cell_shapes:
            if shape_name not in shapes_for(cfg):
                print(f"[skip ] {arch}__{shape_name} (not in this arch's "
                      "shape set; see DESIGN.md section 6)")
                continue
            for mesh_kind in meshes:
                rec = run_cell(arch, shape_name, mesh_kind, out_dir,
                               force=args.force, act_spec=args.act_spec,
                               scan_layers=args.scan)
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] != "ok"
    print(f"done: {n_ok} ok, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
