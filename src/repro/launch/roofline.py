"""Roofline analysis from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per direction).

Terms (seconds, PER DEVICE — the post-SPMD HLO module is per-partition, so
cost_analysis numbers are already per device):
    T_comp = FLOPs / 197e12
    T_mem  = bytes_accessed / 819e9
    T_coll = collective_bytes_moved / 50e9

collective_bytes is parsed from the optimized HLO: for each all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute, bytes moved
per device are estimated from the per-partition result shape (all-reduce
counts 2x: reduce-scatter + all-gather phases of a ring).
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE[dims]{layout} opcode(` — possibly tuple-typed
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum bytes moved per device by collective ops in an HLO module."""
    per_kind: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        b = _shape_bytes(type_str)
        if kind == "all-reduce":
            b *= 2  # ring: reduce-scatter + all-gather phases
        per_kind[kind] = per_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    coll_bytes: float

    @property
    def t_comp(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_mem(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_coll(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time (perfect overlap = max of the terms)."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    def roofline_fraction(self) -> float:
        """Fraction of the binding roof actually 'used' by useful work:
        dominant-term share of the no-overlap sum (1.0 = single clean
        bottleneck, low = time smeared across terms)."""
        s = self.t_comp + self.t_mem + self.t_coll
        return self.t_bound / s if s else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_comp_s": self.t_comp,
            "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens.

    For decode shapes D = global_batch (one token per sequence)."""
    n = cfg.param_count()
    if cfg.family == "moe":
        emb = cfg.padded_vocab * cfg.d_model * 2
        expert = cfg.num_experts * 3 * cfg.d_model * cfg.d_ff
        active = n - cfg.num_layers * expert \
            + cfg.num_layers * cfg.top_k * 3 * cfg.d_model * cfg.d_ff
        n = active
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analyze(cost: dict, hlo_text: str, cfg=None, shape=None,
            num_devices: int = 256) -> dict:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    terms = RooflineTerms(flops, hbm, coll["total_bytes"])
    out = terms.as_dict()
    out["collectives"] = coll
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops_total"] = mf
        out["model_flops_per_dev"] = mf / num_devices
        out["useful_flops_ratio"] = (mf / num_devices) / flops if flops else 0.0
        # MFU bound implied by the roofline terms
        out["mfu_bound"] = (mf / num_devices / PEAK_FLOPS) / terms.t_bound \
            if terms.t_bound else 0.0
    return out
