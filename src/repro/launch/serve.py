"""Serving launcher CLI (batched greedy decode with KV cache).

Local run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve CLI demo supports LM-batch archs; see "
                         "examples/serve_demo.py for the engine API")

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]
    outs = engine.generate(prompts, max_new_tokens=args.max_new)
    for i, o in enumerate(outs):
        print(f"req{i}: {o.tolist()}")
    print(f"throughput: {engine.throughput_probe():.1f} tok/s")


if __name__ == "__main__":
    main()
