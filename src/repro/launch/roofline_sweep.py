import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Single-pod roofline sweep with faithful (unrolled) cost numbers.

Methodology (EXPERIMENTS.md section Roofline):
  * XLA's cost_analysis counts while-loop bodies ONCE, so the scanned form
    undercounts; roofline cells are lowered with layers UNROLLED.
  * Small/medium stacks compile unrolled directly.
  * For the big stacks (88/81/60/48-MoE layers) compiling the full unrolled
    backward graph takes tens of minutes on this 1-core container, so their
    train/prefill cells use TWO reduced-depth unrolled lowers (L1 < L2, same
    widths) and linear per-layer extrapolation:
        v(L) = v(L1) + (v(L2) - v(L1)) / (L2 - L1) * (L - L1)
    which is exact for homogeneous stacks (embed/head/loss terms cancel in
    the delta). The FULL config's compile-proof for these cells is the
    scanned lowering (results/dryrun_scan). Records carry method tags.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline_sweep [--arch all]
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, shapes_for  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

EXTRAP_KEYS = ("flops_per_dev", "hbm_bytes_per_dev", "coll_bytes_per_dev")


def _direct_ok(cfg, shape) -> bool:
    if shape.kind == "decode":
        return True
    if cfg.family in ("moe", "hybrid"):
        return cfg.num_layers <= 16
    return cfg.num_layers <= 48


def _reduced(cfg, n_layers):
    return dataclasses.replace(cfg, num_layers=n_layers)


def _layer_points(cfg):
    if cfg.family == "hybrid":
        c = cfg.attn_every
        return c, 2 * c          # one / two full cycles
    return 8, 16


def run_extrapolated(arch, shape_name, out_dir, force=False):
    from repro.launch import dryrun
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    cell_id = f"{arch}__{shape_name}__single"
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    L1, L2 = _layer_points(cfg)
    sub_dir = out_dir / "extrap"
    recs = {}
    for L in (L1, L2):
        # monkey-style: register a temp arch name resolving to the reduced cfg
        name = f"{arch}@L{L}"
        from repro.configs import registry
        registry.ARCHS[name] = _reduced(cfg, L)
        try:
            recs[L] = dryrun.run_cell(name, shape_name, "single", sub_dir,
                                      force=force, scan_layers=False)
        finally:
            registry.ARCHS.pop(name, None)
        if recs[L]["status"] != "ok":
            out_path.parent.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(recs[L], indent=1, default=str))
            return recs[L]

    r1, r2 = recs[L1]["roofline"], recs[L2]["roofline"]
    L = cfg.num_layers
    roof = dict(r2)
    for k in EXTRAP_KEYS:
        per_layer = (r2[k] - r1[k]) / (L2 - L1)
        roof[k] = r1[k] + per_layer * (L - L1)
    from repro.launch.roofline import RooflineTerms, model_flops
    terms = RooflineTerms(roof["flops_per_dev"], roof["hbm_bytes_per_dev"],
                          roof["coll_bytes_per_dev"])
    roof.update(terms.as_dict())
    mf = model_flops(cfg, shape)
    roof["model_flops_total"] = mf
    roof["model_flops_per_dev"] = mf / 256
    roof["useful_flops_ratio"] = roof["model_flops_per_dev"] / roof["flops_per_dev"]
    roof["mfu_bound"] = ((roof["model_flops_per_dev"] / 197e12) / terms.t_bound
                         if terms.t_bound else 0.0)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": "single", "devices": 256, "status": "ok",
           "method": f"extrapolated(L{L1},L{L2})",
           "compile_s": recs[L1]["compile_s"] + recs[L2]["compile_s"],
           "state_bytes_per_dev": None,
           "roofline": roof}
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"[ok   ] {cell_id}  (extrapolated L{L1},L{L2})", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    n_err = 0
    for arch in archs:
        cfg = get_arch(arch)
        for shape_name in shapes_for(cfg):
            shape = SHAPES[shape_name]
            if _direct_ok(cfg, shape):
                rec = run_cell(arch, shape_name, "single", out_dir,
                               scan_layers=False, force=args.force)
            else:
                rec = run_extrapolated(arch, shape_name, out_dir,
                                       force=args.force)
            n_err += rec["status"] != "ok"
    print(f"roofline sweep done, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
