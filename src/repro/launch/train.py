"""Training launcher CLI.

Local run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 50

Cluster run (per-host; jax.distributed picks up the TPU topology):
  python -m repro.launch.train --arch granite-34b --shape train_4k \
      --coordinator <host:port> --num-hosts 64 --host-id $ID
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import SHAPES, get_arch, smoke_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model import build_model
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config + tiny batch (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--router", default=None, choices=[None, "topk", "sinkhorn"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        seq_len, global_batch = 64, 4
    else:
        shape = SHAPES[args.shape]
        seq_len, global_batch = shape.seq_len, shape.global_batch
    if args.router:
        cfg = dataclasses.replace(cfg, router=args.router)

    model = build_model(cfg)
    pipe = SyntheticTokenPipeline(cfg, seq_len=seq_len,
                                  global_batch=global_batch,
                                  shard_id=args.host_id,
                                  num_shards=args.num_hosts)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                         ckpt_dir=args.ckpt_dir, warmup=max(args.steps // 10, 1),
                         microbatches=args.microbatches, log_every=10)
    trainer = Trainer(model, pipe, OptConfig(lr=args.lr), tcfg)
    state = trainer.run(jax.random.PRNGKey(0))
    for rec in trainer.metrics_log:
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"sec {rec['sec']:.2f}")
    print(f"finished at step {int(state['step'])}; "
          f"restarts={trainer.restarts} stragglers={trainer.stragglers}")


if __name__ == "__main__":
    main()
