import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Perf hillclimb: lower chosen (arch x shape) cells with variant configs and
record the roofline deltas (EXPERIMENTS.md section Perf).

The three chosen pairs (from the baseline table):
  1. smollm-360m / train_4k    — worst roofline fraction (memory-bound on
     materialized attention scores).
  2. granite-3-2b / decode_32k — most collective-bound (training shardings
     reused for serving FSDP-gathers the weights every token).
  3. olmoe-1b-7b / train_4k    — paper-representative (Sinkhorn-UOT router
     runs the MAP-UOT fused iteration inside every MoE layer).

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--only smollm|granite|olmoe]
"""
import argparse      # noqa: E402
import pathlib       # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

OUT = pathlib.Path("results/hillclimb")

VARIANTS = {
    "smollm": [
        # (suffix, overrides, serve_fsdp, act_spec)
        ("flash", {"attn_impl": "flash"}, True, "default"),
        ("flash_cp", {"attn_impl": "flash"}, True, "cp"),
        ("flash_cp_dots",
         {"attn_impl": "flash", "remat_policy": "dots"}, True, "cp"),
        ("flash_cp_dots_bf16loss",
         {"attn_impl": "flash", "remat_policy": "dots",
          "loss_matmul_dtype": "bf16"}, True, "cp"),
    ],
    "granite": [
        ("nofsdp", {}, False, "default"),
        ("nofsdp_bf16loss", {"loss_matmul_dtype": "bf16"}, False, "default"),
    ],
    "olmoe": [
        ("topk", {"router": "topk"}, True, "default"),
        ("flash_dots",
         {"attn_impl": "flash", "remat_policy": "dots"}, True, "default"),
        ("flash_dots_cap1",
         {"attn_impl": "flash", "remat_policy": "dots",
          "capacity_factor": 1.0}, True, "default"),
    ],
}

CELLS = {
    "smollm": ("smollm-360m", "train_4k"),
    "granite": ("granite-3-2b", "decode_32k"),
    "olmoe": ("olmoe-1b-7b", "train_4k"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    for key, (arch, shape) in CELLS.items():
        if args.only and key != args.only:
            continue
        # ensure the unrolled baseline exists in results/dryrun
        run_cell(arch, shape, "single", pathlib.Path("results/dryrun"),
                 scan_layers=False)
        for suffix, overrides, serve_fsdp, act_spec in VARIANTS[key]:
            rec = run_cell(arch, shape, "single", OUT, force=args.force,
                           scan_layers=False, overrides=overrides,
                           serve_fsdp=serve_fsdp, suffix=suffix,
                           act_spec=act_spec)
            rr = rec.get("roofline", {})
            if rec["status"] == "ok":
                print(f"    -> {suffix}: comp={rr['t_comp_s']:.3f} "
                      f"mem={rr['t_mem_s']:.3f} coll={rr['t_coll_s']:.3f} "
                      f"bottleneck={rr['bottleneck']} "
                      f"mfu_bound={rr.get('mfu_bound', 0):.4f}")


if __name__ == "__main__":
    main()
