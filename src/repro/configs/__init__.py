"""Architecture configs (assigned pool) + shape cells + registry."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shapes_for
from repro.configs.registry import ARCHS, get_arch, smoke_config, SMOKE_SHAPE

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shapes_for", "ARCHS",
           "get_arch", "smoke_config", "SMOKE_SHAPE"]
