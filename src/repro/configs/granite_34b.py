"""granite-34b — dense code model, MQA (kv=1).
[arXiv:2405.04324; hf]

GPT-BigCode lineage: d_ff = 4*d with an ungated GELU MLP (2 matmuls) —
that is what lands the model at its 34B nameplate (SwiGLU at this d_ff
would be 47B).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", num_layers=88, d_model=6144,
    num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152,
    mlp_gated=False)
