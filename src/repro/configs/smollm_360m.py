"""smollm-360m — small llama-arch dense model.
[hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense", num_layers=32, d_model=960,
    num_heads=15, num_kv_heads=5, d_ff=2560, vocab_size=49152)
