"""zamba2-7b — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified]

Adaptation (DESIGN.md section 6): shared attn applied every 6 mamba layers;
sliding_window bounds its KV at the 500k decode cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, attn_every=6, sliding_window=4096)
