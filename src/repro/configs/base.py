"""Model / shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    router: str = "topk"        # topk | sinkhorn  (sinkhorn = paper's UOT)
    capacity_factor: float = 1.25
    sinkhorn_iters: int = 4
    sinkhorn_fi: float = 0.7

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64      # mamba2 head dim
    slstm_every: int = 0        # xlstm: every k-th layer is sLSTM (0 = none)
    attn_every: int = 0         # zamba2: shared attn applied every k layers
    gla_chunk: int = 64

    # --- modality (vlm / audio) ---
    num_codebooks: int = 0      # musicgen output heads
    num_image_tokens: int = 0   # llava: prefix positions fed by image embeds

    # --- attention impl (hillclimb lever; see EXPERIMENTS.md section Perf) ---
    attn_impl: str = "naive"     # naive (materialized scores) | flash
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024

    # --- common ---
    mlp_gated: bool = True       # SwiGLU (3 matmuls) vs GELU MLP (2 matmuls)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0     # used by hybrid attn blocks at 500k
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    loss_matmul_dtype: str = "f32"  # f32 | bf16 (head matmul; lse stays f32)
    scan_layers: bool = True
    loss_chunks: int = 8

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def ssm_heads(self) -> int:
        # mamba2 d_inner = 2 * d_model, split into heads of ssm_head_dim
        return (2 * self.d_model) // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        H, kvH, hd = self.num_heads, self.num_kv_heads, self.hd
        emb = self.padded_vocab * d
        head = d * self.padded_vocab * max(1, self.num_codebooks or 1)
        per_layer = 0
        mlp_mats = 3 if self.mlp_gated else 2
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * H * hd + 2 * d * kvH * hd + H * hd * d
            if self.family == "moe":
                ffp = self.num_experts * 3 * d * ff + d * self.num_experts
            else:
                ffp = mlp_mats * d * ff
            per_layer = attn + ffp + 2 * d
            total = emb + head + L * per_layer
        elif self.family == "ssm":   # xlstm: q,k,v,gate,out projections
            m = 5 * d * H * hd + 2 * d * H + d
            total = emb + head + L * m
        elif self.family == "hybrid":  # zamba2
            di = 2 * d
            mamba = d * di * 2 + d * (di + 2 * self.ssm_state) + di * d
            n_attn = L // max(self.attn_every, 1)
            attn = d * H * hd + 2 * d * kvH * hd + H * hd * d + 3 * d * ff
            total = emb + head + L * mamba + attn  # attn params SHARED
        else:
            total = emb + head
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic decode; only SSM/hybrid archs run it
# (DESIGN.md section 6).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        names.append("long_500k")
    return tuple(names)
