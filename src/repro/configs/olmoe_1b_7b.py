"""olmoe-1b-7b — MoE, 64 experts top-8.
[arXiv:2409.02060; hf]

router="sinkhorn" turns on the paper-technique integration (MAP-UOT fused
iterations balance the token->expert assignment); "topk" matches the
published checkpoint behaviour.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304,
    num_experts=64, top_k=8, router="sinkhorn")
