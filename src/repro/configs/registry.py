"""Architecture registry: the 10 assigned configs + reduced smoke variants.

One module per architecture under ``repro.configs`` holds the exact assigned
dims (sources cited there); ``--arch <id>`` resolves here.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, shapes_for
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI_3_8B
from repro.configs.smollm_360m import CONFIG as SMOLLM_360M
from repro.configs.granite_3_2b import CONFIG as GRANITE_3_2B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    GRANITE_34B, PHI4_MINI_3_8B, SMOLLM_360M, GRANITE_3_2B, OLMOE_1B_7B,
    MOONSHOT_V1_16B, XLSTM_350M, ZAMBA2_7B, LLAVA_NEXT_34B, MUSICGEN_MEDIUM,
]}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    reduced = dict(
        num_layers=max(2, (cfg.attn_every or 2)),
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        head_dim=16,
        gla_chunk=8,
        loss_chunks=2,
    )
    if cfg.family == "moe":
        # ample capacity so smoke tests are drop-free (deterministic refs)
        reduced.update(num_experts=4, top_k=2, capacity_factor=4.0)
    if cfg.family == "ssm":
        reduced.update(slstm_every=2, num_layers=4)
    if cfg.family == "hybrid":
        reduced.update(attn_every=2, num_layers=5, ssm_state=8,
                       ssm_head_dim=16)
    if cfg.family == "vlm":
        reduced.update(num_image_tokens=8)
    return dataclasses.replace(cfg, **reduced)


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
