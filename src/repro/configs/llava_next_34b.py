"""llava-next-34b — VLM backbone (anyres frontend stubbed).
[hf:llava-hf/llava-v1.6-34b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64000,
    num_image_tokens=1152)
