"""xlstm-350m — mLSTM + sLSTM blocks (7:1), d_ff=0.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm", num_layers=24, d_model=1024,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304, slstm_every=8)
